"""Quantized serving tiers vs the exact tree (ISSUE 9).

The enterprise claim: per-chunk symmetric int8 (optionally magnitude-pruned)
storage of the ELL ranker weights cuts per-partition memory several-fold
while the beam search stays within a measured quality envelope. This
benchmark pins the envelope as *tolerance rows* (``metric=value<=bound`` /
``metric=value>=floor`` — see ``check_regression``):

* ``quant_memory_shrink`` — per-partition manifest ``memory_bytes``,
  exact vs quantized, must shrink **>= 3.5x** (int8; the pruned tier lands
  around 7x). Measured from :class:`~repro.index.partition.PartitionManifest`
  after :func:`repro.quant.quantize_index`, not estimated.
* ``quant_recall_floor`` — recall@k of the quantized tier against the exact
  tier on the same queries, floored per tier.
* ``quant_score_mae`` — mean |Δ| of the descending top-k scores against the
  exact tier, bounded per tier.
* ``quant_kernel_parity`` — structural flag: the fused in-register dequant
  kernel (``mscm_pallas_grouped_q``) is **bitwise-identical** to running the
  exact grouped kernel on the dequantized weights. This pins "quantization
  error comes from storage, never from the kernel".
* ``quant_tier_parity`` — structural flag: the int8 tier returns bitwise-
  identical results across partition counts and sync modes (P=2/P=4 x
  level/pipelined) — quantize-per-partition must not depend on topology.

Quality rows sweep tier x beam x qt through the partitioned planner (the
served configuration: exact f32 router head + quantized partition rankers).

Run: ``python -m benchmarks.bench_quant [--n 32] [--json PATH]``
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List

import jax
import numpy as np

from benchmarks.common import build_benchmark_tree, csv_line, ell_queries, time_fn
from repro.data.xmr_data import XMRShape
from repro.index import ScatterGatherPlanner, partition_tree
from repro.quant import (
    dequantize_tree,
    quantize_index,
    quantize_tree,
    recall_at_k,
    score_mae,
)

# Branching 64 so the per-column f32->int8 shrink is not swamped by the
# int32 row-index plane and the phantom pad chunk (at branching 16 the
# measured shrink is ~3.3x and the 3.5x floor would gate on tree geometry
# rather than on storage). d/L match the partitioned bench scale.
SHAPE = XMRShape("quant-4k", 4096, 4096, 64, 32, 64)
BRANCHING = 64

# Measured on the shape above (P=2, seed 0): int8 3.80x, pruned 7.51x.
SHRINK_FLOOR = 3.5

# Per-tier quality envelope, pinned with margin below measured values
# (int8: recall 0.994 / mae ~5e-4; pruned keep=0.5 drops real weight mass
# so its floor is lower — recall 0.93-0.96 / mae ~4e-3 measured — it
# trades recall for the extra ~2x memory, and the row records how much).
RECALL_FLOOR = {"int8": 0.95, "int8_pruned": 0.80}
MAE_BOUND = {"int8": 2e-3, "int8_pruned": 2e-2}


def _bitwise(got, ref) -> bool:
    return bool(
        np.array_equal(np.asarray(got[0]), np.asarray(ref[0]))
        and np.array_equal(np.asarray(got[1]), np.asarray(ref[1]))
    )


def run(
    *,
    n_queries: int = 32,
    tiers=("int8", "int8_pruned"),
    beams=(4, 10),
    qts=(4, 8),
    topk: int = 10,
    seed: int = 0,
) -> List[str]:
    rng = np.random.default_rng(seed)
    tree = build_benchmark_tree(SHAPE, BRANCHING, rng)
    xi, xv = ell_queries(SHAPE, n_queries, rng)
    lines = []

    # -- kernel parity: fused dequant == dequantize-then-exact, bitwise ----
    qtree = quantize_tree(tree, tier="int8")
    ref_deq = jax.block_until_ready(
        dequantize_tree(qtree).infer(
            xi, xv, beam=10, topk=topk, method="mscm_pallas_grouped"
        )
    )
    got_q = jax.block_until_ready(
        qtree.infer(xi, xv, beam=10, topk=topk, method="mscm_pallas_grouped_q")
    )
    lines.append(
        csv_line(
            f"{SHAPE.name}/quant/kernel-parity",
            1e6 * time_fn(
                lambda: qtree.infer(
                    xi, xv, beam=10, topk=topk,
                    method="mscm_pallas_grouped_q",
                ),
                warmup=1, iters=3,
            ) / n_queries,
            f"quant_kernel_parity={_bitwise(got_q, ref_deq)}",
        )
    )

    idx = partition_tree(tree, 2)
    exact_bytes = [p.memory_bytes for p in idx.manifest.partitions]

    for tier in tiers:
        qidx = quantize_index(idx, tier=tier)
        m = qidx.manifest

        # -- memory: the whole point — manifest bytes, not an estimate -----
        shrink = min(
            eb / p.memory_bytes
            for eb, p in zip(exact_bytes, m.partitions)
        )
        lines.append(
            csv_line(
                f"{SHAPE.name}/quant/{tier}-memory",
                m.max_partition_bytes() / 1e3,  # kB, reported not gated
                f"quant_memory_shrink={shrink:.2f}>={SHRINK_FLOOR} "
                f"max_part_kb={m.max_partition_bytes() / 1e3:.0f} "
                f"dtype={m.partitions[0].dtype} tier={tier}",
            )
        )

        # -- quality envelope vs the exact tier, beam x qt -----------------
        for beam in beams:
            ref = jax.block_until_ready(
                ScatterGatherPlanner(
                    idx, beam=beam, topk=topk, method="mscm_pallas_grouped"
                ).infer(xi, xv)
            )
            t_ref = None
            for qt in qts:
                planner = ScatterGatherPlanner(
                    qidx, beam=beam, topk=topk,
                    method="mscm_pallas_grouped_q", qt=qt,
                )
                got = jax.block_until_ready(planner.infer(xi, xv))
                recall = recall_at_k(ref[1], got[1])
                mae = score_mae(ref[0], got[0])
                t_q = time_fn(lambda: planner.infer(xi, xv),
                              warmup=1, iters=3)
                if t_ref is None:
                    t_ref = time_fn(
                        lambda: ScatterGatherPlanner(
                            idx, beam=beam, topk=topk,
                            method="mscm_pallas_grouped",
                        ).infer(xi, xv),
                        warmup=1, iters=3,
                    )
                lines.append(
                    csv_line(
                        f"{SHAPE.name}/quant/{tier}-b{beam}-qt{qt}",
                        1e6 * t_q / n_queries,
                        f"quant_recall_floor={recall:.4f}"
                        f">={RECALL_FLOOR[tier]} "
                        f"quant_score_mae={mae:.5f}<={MAE_BOUND[tier]} "
                        f"overhead={t_q / t_ref:.2f}x",
                    )
                )

    # -- topology invariance: int8 results must not depend on P or sync ----
    runs = []
    for p in (2, 4):
        qp = quantize_index(partition_tree(tree, p), tier="int8")
        for sync in ("level", "pipelined"):
            planner = ScatterGatherPlanner(
                qp, beam=10, topk=topk,
                method="mscm_pallas_grouped_q", sync=sync,
            )
            runs.append(jax.block_until_ready(planner.infer(xi, xv)))
    parity = all(_bitwise(r, runs[0]) for r in runs[1:])
    lines.append(
        csv_line(
            f"{SHAPE.name}/quant/tier-parity",
            0.0,
            f"quant_tier_parity={parity} topologies=P2/P4x level/pipelined",
        )
    )
    return lines


def main(argv=None) -> List[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--beams", type=int, nargs="+", default=[4, 10])
    ap.add_argument("--qts", type=int, nargs="+", default=[4, 8])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args(argv)
    lines = run(n_queries=args.n, beams=tuple(args.beams),
                qts=tuple(args.qts))
    for line in lines:
        print(line)
    if args.json:
        from benchmarks.run import _parse_rows

        with open(args.json, "w") as f:
            json.dump(
                {"rows": _parse_rows(lines), "completed": True}, f, indent=2
            )
        print(f"# wrote {args.json}", file=sys.stderr)
    return lines


if __name__ == "__main__":
    main()
