"""Compare a fresh benchmark JSON against baseline / previous-run artifacts.

CI runs the benchmark smoke (``python -m benchmarks.run --json
BENCH_ci.json``) and then::

    python -m benchmarks.check_regression BENCH_ci.json

Rows are matched by name against ``benchmarks/BENCH_baseline.json`` (skipped
gracefully when no baseline is committed). Timing rows (``us_per_call``) are
compared as ratios; shared-runner drift makes hard timing gates flaky, so by
default regressions are *reported* and only ``--strict`` turns them into a
nonzero exit. Structural rows are always strict: a ``<flag>=False`` for any
flag in ``STRUCT_FLAGS`` (bitwise identity, batch amortization, overload
P99 boundedness, nonzero shed under 4x load, pipelined/overlap/cache
claims) in any derived field fails the check regardless of mode — those
encode correctness/behavioral claims, not wall-clock. Numeric *tolerance*
rows (``metric=value<=bound`` / ``metric=value>=floor`` in a derived field
— the quantized tier's measured recall/MAE/memory contract) are equally
strict, and enjoy the same missing-row protection: a baseline row carrying
either kind of claim may not silently disappear from the current run.

The fresh JSON must also carry ``"completed": true`` (benchmarks.run stamps
it) — a crashed run's partial artifact must never pass the gate vacuously.

**Perf trajectory** (ISSUE 5): CI additionally downloads the previous
successful main run's artifact and runs::

    python -m benchmarks.check_regression BENCH_ci.json --trend prev/BENCH_ci.json

Trend mode compares run-over-run instead of against the committed baseline:
timing drift beyond ``--trend-ratio`` (default 1.5x) *warns* (consecutive
runs share much less environment than a committed baseline assumes — the
trajectory artifact, not one comparison, is the signal), while structural
flags still gate hard. ``--append-trajectory BENCH_trajectory.jsonl``
appends this run's one-line summary to the rolling JSONL artifact CI
re-uploads each run, which is where the trajectory accumulates.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
from typing import Dict, List, Tuple

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_baseline.json")

# Tile-count and share rows are deterministic counters, not timings; hold
# them to an exact-ish tolerance instead of the timing ratio.
COUNTER_MARKERS = ("_tiles", "_share_", "matmul_share")

# Boolean claims in derived fields: "<flag>=False" anywhere fails the gate.
STRUCT_FLAGS = (
    "bitwise_identical",
    "amortizes",
    "p99_bounded",
    "shed_nonzero",
    "partition_parity",            # scatter-gather == unpartitioned, bitwise
    "partition_memory_balanced",   # per-device model bytes shrink ~1/P
    "pipelined_parity",            # overlapped sync == level sync, bitwise
    "overlap_speedup",             # pipelined >= level throughput, multidevice
    "cache_parity",                # hot-beam cache hit == cold run, bitwise
    "gateway_parity",              # HTTP + fleet RPC == in-process, bitwise
    "recovery_bounded",            # supervisor respawned within the bound
    "degraded_parity",             # degraded responses survivor-exact
    "quant_kernel_parity",         # grouped_q == grouped on dequantized f32
    "quant_tier_parity",           # int8 tier bitwise across P x sync modes
    "adaptive_full_beam_parity",   # every beam tier bitwise-exact, tier 0
                                   # identical to a no-SLO engine, all
                                   # serving topologies
    "slo_p99_bounded",             # adaptive 4x-overload p99 within 5x of 1x
    "recall_floor_met",            # frontier recall >= worst-tier floor
)

# Numeric tolerance claims in derived fields: ``name=value<=bound`` /
# ``name=value>=floor`` — the quantized tier's *measured contract* (recall@k
# floor, score-MAE bound, memory-shrink floor). Like STRUCT_FLAGS they are
# always strict: a breached bound encodes a broken accuracy/memory contract,
# not wall-clock drift, so it fails the gate in every mode.
_TOLERANCE_RE = re.compile(
    r"([A-Za-z_]\w*)=(-?[\d.]+(?:[eE][-+]?\d+)?)"
    r"(<=|>=)(-?[\d.]+(?:[eE][-+]?\d+)?)"
)


def _failed_flags(derived: str) -> List[str]:
    return [f for f in STRUCT_FLAGS if f"{f}=False" in derived]


def _has_flags(derived: str) -> bool:
    return any(f"{f}=" in derived for f in STRUCT_FLAGS)


def _failed_tolerances(derived: str) -> List[str]:
    """Breached ``name=value<=bound`` / ``name=value>=floor`` claims."""
    out = []
    for name, value, op, bound in _TOLERANCE_RE.findall(derived):
        v, b = float(value), float(bound)
        ok = v <= b if op == "<=" else v >= b
        if not ok:
            out.append(f"{name}={value} violates {op}{bound}")
    return out


def _has_tolerances(derived: str) -> bool:
    return bool(_TOLERANCE_RE.search(derived))


def _rows_by_name(doc: dict) -> Dict[str, dict]:
    return {r["name"]: r for r in doc.get("rows", []) if "name" in r}


def _is_counter(name: str) -> bool:
    return any(m in name for m in COUNTER_MARKERS)


def check_completed(current: dict) -> List[str]:
    """The fresh artifact must assert it ran to completion.

    ``benchmarks.run`` / ``bench_partitioned --json`` stamp
    ``"completed": true`` only when every sub-benchmark returned; a crashed
    run writes ``false`` (and lists ``failures``). A missing key means the
    artifact predates the contract or came from a crashed writer — refuse
    those too, or a truncated JSON would pass the gate with zero rows.
    """
    if current.get("completed") is True:
        return []
    failures = current.get("failures") or []
    detail = f" (failures: {failures})" if failures else ""
    return [
        "artifact incomplete: manifest key 'completed' is "
        f"{current.get('completed')!r}{detail} — refusing to gate on a "
        "partial benchmark run"
    ]


def compare(
    current: dict,
    baseline: dict,
    max_ratio: float,
    *,
    timing_gates: bool = True,
    missing_gates: bool = True,
) -> Tuple[List[str], List[str]]:
    """Returns (report_lines, failures). Failures are structural or — for
    timing rows, when ``timing_gates`` — ratio breaches beyond
    ``max_ratio``; with ``timing_gates=False`` (trend mode) breaches are
    reported in the lines but never appended to failures. ``missing_gates``
    controls whether a structural row present in ``baseline`` but absent
    from ``current`` fails: against the *committed* baseline that is the
    whole point (dropping a structural row must not quietly pass), but in
    trend mode the reference is just the previous run — a PR that
    legitimately renames or retires a row (and regenerates the committed
    baseline) must not be unfailable until a main run without the row
    lands, so trend mode only reports it."""
    cur, base = _rows_by_name(current), _rows_by_name(baseline)
    report: List[str] = []
    failures: List[str] = []
    for name, row in sorted(cur.items()):
        derived = row.get("derived", "")
        if _failed_flags(derived):
            failures.append(f"{name}: structural flag failed ({derived})")
        for breach in _failed_tolerances(derived):
            failures.append(f"{name}: tolerance breached ({breach})")
        b = base.get(name)
        if b is None or b.get("us_per_call", 0) <= 0:
            continue
        ratio = row["us_per_call"] / b["us_per_call"]
        tag = ""
        if _is_counter(name):
            if ratio > 1.02:  # counters should not grow
                tag = "  << COUNTER REGRESSION"
                if missing_gates:
                    # Like missing rows, counter drift is a *committed-
                    # baseline* contract: a PR that legitimately changes a
                    # counter regenerates the baseline, but cannot rewrite
                    # the previous run's artifact — trend mode only warns.
                    failures.append(
                        f"{name}: counter {b['us_per_call']:.0f} -> "
                        f"{row['us_per_call']:.0f}")
        elif ratio > max_ratio:
            tag = f"  << {ratio:.2f}x SLOWER than baseline"
            if timing_gates:
                failures.append(f"{name}: {ratio:.2f}x over baseline "
                                f"({b['us_per_call']:.1f} -> "
                                f"{row['us_per_call']:.1f} us)")
        report.append(f"{name:55s} {b['us_per_call']:>12.1f} "
                      f"{row['us_per_call']:>12.1f} {ratio:>7.2f}x{tag}")
    missing = sorted(set(base) - set(cur))
    for name in missing:
        line = f"{name:55s} (row disappeared from current run)"
        b_derived = base[name].get("derived", "")
        if _is_counter(name) or _has_flags(b_derived) \
                or _has_tolerances(b_derived):
            line += "  << MISSING STRUCTURAL ROW"
            if missing_gates:
                # Dropping a structural row must not quietly pass the gate —
                # that would erase exactly the coverage this check exists for.
                failures.append(
                    f"{name}: structural/counter row missing from current run"
                )
        report.append(line)
    return report, failures


def trajectory_row(current: dict) -> dict:
    """One compact line for the rolling ``BENCH_trajectory.jsonl`` artifact."""
    return {
        "sha": os.environ.get("GITHUB_SHA", ""),
        "run_id": os.environ.get("GITHUB_RUN_ID", ""),
        "ref": os.environ.get("GITHUB_REF_NAME", ""),
        "wall_s": current.get("wall_s"),
        "completed": current.get("completed"),
        "rows": {
            r["name"]: r["us_per_call"] for r in current.get("rows", [])
        },
    }


def append_trajectory(current: dict, path: str) -> None:
    with open(path, "a") as f:
        f.write(json.dumps(trajectory_row(current)) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh BENCH json (e.g. BENCH_ci.json)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--max-ratio", type=float, default=4.0,
                    help="timing ratio above which a row is flagged")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on flagged timing rows (structural "
                         "failures always exit 1)")
    ap.add_argument("--trend", default=None, metavar="PREV_JSON",
                    help="compare against the previous run's artifact "
                         "instead of the committed baseline; timing drift "
                         "warns, structural flags still gate")
    ap.add_argument("--trend-ratio", type=float, default=1.5,
                    help="run-over-run timing ratio that triggers a "
                         "trend warning")
    ap.add_argument("--append-trajectory", default=None, metavar="JSONL",
                    help="append this run's summary row to the rolling "
                         "trajectory artifact")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)

    completeness = check_completed(current)

    if args.append_trajectory:
        append_trajectory(current, args.append_trajectory)
        print(f"# appended trajectory row to {args.append_trajectory}")

    if args.trend is not None:
        # -- run-over-run trajectory mode -------------------------------
        if not os.path.exists(args.trend):
            print(f"# no previous-run artifact at {args.trend}; "
                  "trend comparison skipped (first run on this branch?)")
            _, failures = compare(current, {"rows": []}, args.trend_ratio)
            failures += completeness
            for fail in failures:
                print(f"FAIL {fail}")
            return 1 if failures else 0
        with open(args.trend) as f:
            prev = json.load(f)
        report, failures = compare(
            current, prev, args.trend_ratio,
            timing_gates=False, missing_gates=False,
        )
        failures += completeness
        print(f"{'name':55s} {'previous_us':>12s} {'current_us':>12s} "
              f"{'ratio':>8s}")
        for line in report:
            print(line)
        warned = sum("SLOWER" in line for line in report)
        if warned:
            print(f"# {warned} row(s) drifted over {args.trend_ratio}x vs "
                  "the previous run (trend mode: warning only — watch "
                  "BENCH_trajectory.jsonl)")
        for fail in failures:
            print(f"FAIL {fail}")
        return 1 if failures else 0

    if not os.path.exists(args.baseline):
        print(f"# no baseline at {args.baseline}; skipping comparison")
        # Structural flags are still checked against the fresh run alone.
        _, failures = compare(current, {"rows": []}, args.max_ratio)
        failures += completeness
        for fail in failures:
            print(f"FAIL {fail}")
        return 1 if failures else 0
    with open(args.baseline) as f:
        baseline = json.load(f)

    report, failures = compare(current, baseline, args.max_ratio)
    failures += completeness
    print(f"{'name':55s} {'baseline_us':>12s} {'current_us':>12s} {'ratio':>8s}")
    for line in report:
        print(line)
    structural = [
        f for f in failures
        if "structural" in f or "counter" in f or "incomplete" in f
        or "tolerance" in f
    ]
    timing = [f for f in failures if f not in structural]
    for fail in failures:
        print(f"FAIL {fail}")
    if structural:
        return 1
    if timing and args.strict:
        return 1
    if timing:
        print(f"# {len(timing)} timing regression(s) over {args.max_ratio}x "
              "(non-strict mode: not gating)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
