"""Compare a fresh benchmark JSON against the committed baseline artifact.

CI runs the benchmark smoke (``python -m benchmarks.run --json
BENCH_ci.json``) and then::

    python -m benchmarks.check_regression BENCH_ci.json

Rows are matched by name against ``benchmarks/BENCH_baseline.json`` (skipped
gracefully when no baseline is committed). Timing rows (``us_per_call``) are
compared as ratios; shared-runner drift makes hard timing gates flaky, so by
default regressions are *reported* and only ``--strict`` turns them into a
nonzero exit. Structural rows are always strict: a ``<flag>=False`` for any
flag in ``STRUCT_FLAGS`` (bitwise identity, batch amortization, overload
P99 boundedness, nonzero shed under 4x load) in any derived field fails the
check regardless of mode — those encode correctness/behavioral claims, not
wall-clock.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_baseline.json")

# Tile-count and share rows are deterministic counters, not timings; hold
# them to an exact-ish tolerance instead of the timing ratio.
COUNTER_MARKERS = ("_tiles", "_share_", "matmul_share")

# Boolean claims in derived fields: "<flag>=False" anywhere fails the gate.
STRUCT_FLAGS = (
    "bitwise_identical",
    "amortizes",
    "p99_bounded",
    "shed_nonzero",
    "partition_parity",            # scatter-gather == unpartitioned, bitwise
    "partition_memory_balanced",   # per-device model bytes shrink ~1/P
)


def _failed_flags(derived: str) -> List[str]:
    return [f for f in STRUCT_FLAGS if f"{f}=False" in derived]


def _has_flags(derived: str) -> bool:
    return any(f"{f}=" in derived for f in STRUCT_FLAGS)


def _rows_by_name(doc: dict) -> Dict[str, dict]:
    return {r["name"]: r for r in doc.get("rows", []) if "name" in r}


def _is_counter(name: str) -> bool:
    return any(m in name for m in COUNTER_MARKERS)


def compare(
    current: dict, baseline: dict, max_ratio: float
) -> Tuple[List[str], List[str]]:
    """Returns (report_lines, failures). Failures are structural or — for
    timing rows — ratio breaches beyond ``max_ratio``."""
    cur, base = _rows_by_name(current), _rows_by_name(baseline)
    report: List[str] = []
    failures: List[str] = []
    for name, row in sorted(cur.items()):
        derived = row.get("derived", "")
        if _failed_flags(derived):
            failures.append(f"{name}: structural flag failed ({derived})")
        b = base.get(name)
        if b is None or b.get("us_per_call", 0) <= 0:
            continue
        ratio = row["us_per_call"] / b["us_per_call"]
        tag = ""
        if _is_counter(name):
            if ratio > 1.02:  # counters should not grow
                tag = "  << COUNTER REGRESSION"
                failures.append(f"{name}: counter {b['us_per_call']:.0f} -> "
                                f"{row['us_per_call']:.0f}")
        elif ratio > max_ratio:
            tag = f"  << {ratio:.2f}x SLOWER than baseline"
            failures.append(f"{name}: {ratio:.2f}x over baseline "
                            f"({b['us_per_call']:.1f} -> {row['us_per_call']:.1f} us)")
        report.append(f"{name:55s} {b['us_per_call']:>12.1f} "
                      f"{row['us_per_call']:>12.1f} {ratio:>7.2f}x{tag}")
    missing = sorted(set(base) - set(cur))
    for name in missing:
        line = f"{name:55s} (row disappeared from current run)"
        b_derived = base[name].get("derived", "")
        if _is_counter(name) or _has_flags(b_derived):
            # Dropping a structural row must not quietly pass the gate —
            # that would erase exactly the coverage this check exists for.
            failures.append(
                f"{name}: structural/counter row missing from current run"
            )
            line += "  << MISSING STRUCTURAL ROW"
        report.append(line)
    return report, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh BENCH json (e.g. BENCH_ci.json)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--max-ratio", type=float, default=4.0,
                    help="timing ratio above which a row is flagged")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on flagged timing rows (structural "
                         "failures always exit 1)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    if not os.path.exists(args.baseline):
        print(f"# no baseline at {args.baseline}; skipping comparison")
        # Structural flags are still checked against the fresh run alone.
        _, failures = compare(current, {"rows": []}, args.max_ratio)
        for fail in failures:
            print(f"FAIL {fail}")
        return 1 if failures else 0
    with open(args.baseline) as f:
        baseline = json.load(f)

    report, failures = compare(current, baseline, args.max_ratio)
    print(f"{'name':55s} {'baseline_us':>12s} {'current_us':>12s} {'ratio':>8s}")
    for line in report:
        print(line)
    structural = [f for f in failures if "structural" in f or "counter" in f]
    timing = [f for f in failures if f not in structural]
    for fail in failures:
        print(f"FAIL {fail}")
    if structural:
        return 1
    if timing and args.strict:
        return 1
    if timing:
        print(f"# {len(timing)} timing regression(s) over {args.max_ratio}x "
              "(non-strict mode: not gating)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
