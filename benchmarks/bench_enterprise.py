"""Paper Table 4 / §6: enterprise-scale semantic product search.

The paper's model: L = 100M products, d = 4M features, branching 32,
beam 10/20, single-thread batch mode -> 0.88 ms/query (MSCM binary search),
8x over vanilla. 100M labels do not fit this CPU container; we run the
same tree GEOMETRY at L = 32^4 = 1,048,576 (depth matches the paper's
lower levels, d is the full 4M) and report the MSCM-vs-vanilla ratio plus
per-query latency; the full-size serving step is additionally dry-run
compiled on the production mesh (launch/serve_dryrun).
"""

from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np

from benchmarks.common import build_benchmark_tree, csv_line, ell_queries, time_fn
from repro.data.xmr_data import ENTERPRISE_SHAPE, XMRShape

SCALED = XMRShape("enterprise-1m", 4_000_000, 32**4, 10_000,
                  ENTERPRISE_SHAPE.query_nnz, ENTERPRISE_SHAPE.col_nnz)


def run(*, beams=(10, 20), n_queries=64, seed=0, branching=32) -> List[str]:
    rng = np.random.default_rng(seed)
    t0 = time.time()
    tree = build_benchmark_tree(SCALED, branching, rng)
    build_s = time.time() - t0
    lines = [csv_line("enterprise/build", 1e6 * build_s,
                      f"L={SCALED.L},d={SCALED.d},mem={tree.memory_bytes()/1e9:.2f}GB")]
    xi, xv = ell_queries(SCALED, n_queries, rng, width=256)
    for beam in beams:
        per_q = {}
        for method in ("mscm_searchsorted", "mscm_dense", "vanilla"):
            times = []
            for _ in range(3):
                t = time_fn(lambda: tree.infer(xi, xv, beam=beam, topk=10,
                                               method=method), warmup=1, iters=3)
                times.append(1e6 * t / n_queries)
            arr = np.asarray(times)
            per_q[method] = float(np.mean(arr))
            lines.append(csv_line(
                f"enterprise/beam{beam}/{method}", float(np.mean(arr)),
                f"p95={np.percentile(arr, 95):.0f}us",
            ))
        sp = per_q["vanilla"] / per_q["mscm_searchsorted"]
        lines.append(csv_line(f"enterprise/beam{beam}/speedup", 0.0,
                              f"mscm_binsearch_vs_vanilla={sp:.2f}x"))
    return lines


def main(argv=None) -> List[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-queries", type=int, default=64)
    ap.add_argument("--beams", nargs="*", type=int, default=[10, 20])
    args = ap.parse_args(argv)
    lines = run(beams=tuple(args.beams), n_queries=args.n_queries)
    for l in lines:
        print(l)
    return lines


if __name__ == "__main__":
    main()
