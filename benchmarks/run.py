"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Fast defaults; per-table flags via
``python -m benchmarks.bench_<name> --help``.

  Tables 1-3 / Figs 3-4  -> bench_mscm       (datasets × branching × setting)
  Table 4 / §6           -> bench_enterprise (d=4M, 1M-label tree geometry)
  Figure 5               -> bench_napkin     (per-column ref vs MSCM)
  Figure 6 / §6.1        -> bench_parallel   (batch-amortization analogue)
  beyond-paper           -> bench_xmr_head   (MSCM vocab-tree LM head)
  §Roofline              -> roofline         (dry-run derived, no timing)
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow; default is CI-size)")
    ap.add_argument("--skip-enterprise", action="store_true")
    args = ap.parse_args()

    from benchmarks import (bench_enterprise, bench_mscm, bench_napkin,
                            bench_parallel, bench_xmr_head)

    print("name,us_per_call,derived")
    t0 = time.time()

    if args.full:
        mscm_kw = dict(
            datasets=list(__import__("repro.data", fromlist=["PAPER_SHAPES"])
                          .PAPER_SHAPES.keys()),
            max_labels=262_144, n_batch=256,
        )
    else:
        mscm_kw = dict(datasets=["eurlex-4k", "wiki10-31k", "amazon-670k"],
                       max_labels=32_768, n_batch=64)
    for line in bench_mscm.run(mscm_kw["datasets"],
                               max_labels=mscm_kw["max_labels"],
                               n_batch=mscm_kw["n_batch"]):
        print(line, flush=True)
    for line in bench_mscm.profile_share():
        print(line, flush=True)
    for line in bench_napkin.run(max_labels=mscm_kw["max_labels"]):
        print(line, flush=True)
    for line in bench_parallel.run(max_labels=mscm_kw["max_labels"],
                                   batches=(1, 4, 16, 64)):
        print(line, flush=True)
    for line in bench_xmr_head.run():
        print(line, flush=True)
    if not args.skip_enterprise:
        for line in bench_enterprise.run(n_queries=16 if not args.full else 64):
            print(line, flush=True)

    print(f"# total bench time {time.time() - t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
