"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Fast defaults; per-table flags via
``python -m benchmarks.bench_<name> --help``. ``--json PATH`` additionally
writes the rows as a JSON artifact (the CI benchmark-smoke job uploads this
as ``BENCH_ci.json`` so the perf trajectory accumulates across commits).

  Tables 1-3 / Figs 3-4  -> bench_mscm       (datasets × branching × setting)
  Table 4 / §6           -> bench_enterprise (d=4M, 1M-label tree geometry)
  Figure 5               -> bench_napkin     (per-column ref vs MSCM)
  Figure 6 / §6.1        -> bench_parallel   (batch-amortization analogue)
  §3.2 online            -> bench_serving    (micro-batched vs per-query)
  SLO frontier           -> bench_slo        (adaptive beam tiers, p99/recall)
  beyond-paper           -> bench_xmr_head   (MSCM vocab-tree LM head)
  §Roofline              -> roofline         (dry-run derived, no timing)
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def _parse_rows(lines: list) -> list:
    rows = []
    for line in lines:
        parts = line.split(",", 2)
        if len(parts) < 2:
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows.append(
            {
                "name": parts[0],
                "us_per_call": us,
                "derived": parts[2] if len(parts) > 2 else "",
            }
        )
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow; default is CI-size)")
    ap.add_argument("--skip-enterprise", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()

    from benchmarks import (bench_enterprise, bench_gateway, bench_mscm,
                            bench_napkin, bench_parallel, bench_partitioned,
                            bench_quant, bench_serving, bench_slo,
                            bench_xmr_head)

    print("name,us_per_call,derived")
    t0 = time.time()
    all_lines = []
    failures = []

    def emit(name, fn, *fn_args, **fn_kwargs) -> None:
        # One failed sub-benchmark must not silently produce a *partial*
        # artifact that passes the regression gate vacuously: record the
        # failure, keep running the rest, exit nonzero, and stamp the JSON
        # "completed": false so check_regression refuses it outright.
        try:
            lines = fn(*fn_args, **fn_kwargs)
        except (KeyboardInterrupt, SystemExit):
            raise  # a cancelled run must abort, not keep benchmarking
        except Exception as exc:
            import traceback

            traceback.print_exc()
            print(f"# FAILED {name}: {exc!r}", file=sys.stderr)
            failures.append(f"{name}: {exc!r}")
            return
        for line in lines:
            print(line, flush=True)
            all_lines.append(line)

    if args.full:
        mscm_kw = dict(
            datasets=list(__import__("repro.data", fromlist=["PAPER_SHAPES"])
                          .PAPER_SHAPES.keys()),
            max_labels=262_144, n_batch=256,
        )
    else:
        mscm_kw = dict(datasets=["eurlex-4k", "wiki10-31k", "amazon-670k"],
                       max_labels=32_768, n_batch=64)
    emit("mscm", bench_mscm.run, mscm_kw["datasets"],
         max_labels=mscm_kw["max_labels"], n_batch=mscm_kw["n_batch"])
    # Device-grouped MXU path (ISSUE 2): per-level tile accounting + the
    # bitwise-identity flag ride along in BENCH_ci.json.
    emit("mscm_grouped", bench_mscm.grouped_report,
         max_labels=mscm_kw["max_labels"], n=mscm_kw["n_batch"])
    emit("profile_share", bench_mscm.profile_share)
    emit("napkin", bench_napkin.run, max_labels=mscm_kw["max_labels"])
    emit("parallel", bench_parallel.run, max_labels=mscm_kw["max_labels"],
         batches=(1, 4, 16, 64))
    emit("serving", bench_serving.run,
         n_queries=64 if not args.full else 256)
    # Overload-safety smoke (ISSUE 3): bounded-queue admission control at
    # 1x/2x/4x capacity — the p99_bounded / shed_nonzero structural flags
    # in the guarantees row gate via check_regression.
    emit("serving_overload", bench_serving.run_overload,
         n_queries=96 if not args.full else 256)
    # Label-partitioned scatter-gather index (ISSUE 4) + pipelined overlap
    # and hot-beam cache (ISSUE 5): bitwise parity per method x sync mode,
    # memory shrink and cache flags gate via check_regression.
    emit("partitioned", bench_partitioned.run,
         n_queries=32 if not args.full else 128)
    # Cross-process fleet behind the HTTP gateway (ISSUE 6): real worker
    # subprocesses + socket RPC + JSON edge — the gateway_parity structural
    # flag (bitwise vs in-process) gates via check_regression.
    emit("gateway", bench_gateway.run,
         n_queries=32 if not args.full else 128)
    # Latency-SLO adaptive inference (ISSUE 10): per-batch beam tiers that
    # degrade instead of shed — tier parity across all serving topologies
    # (adaptive_full_beam_parity) plus the p99-vs-recall frontier flags
    # (slo_p99_bounded, recall_floor_met) gate via check_regression.
    emit("slo", bench_slo.run,
         n_queries=64 if not args.full else 192)
    # Quantized serving tiers (ISSUE 9): int8 / pruned-int8 chunk storage —
    # memory-shrink floor, recall floor and score-MAE bound ride along as
    # tolerance rows; kernel/tier parity flags gate via check_regression.
    emit("quant", bench_quant.run,
         n_queries=16 if not args.full else 64,
         beams=(10,) if not args.full else (4, 10))
    emit("xmr_head", bench_xmr_head.run)
    if not args.skip_enterprise:
        emit("enterprise", bench_enterprise.run,
             n_queries=16 if not args.full else 64)

    wall = time.time() - t0
    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                {
                    "rows": _parse_rows(all_lines),
                    "full": args.full,
                    "wall_s": round(wall, 1),
                    # Required by check_regression: a partial artifact from
                    # a crashed run must never pass the gate vacuously.
                    "completed": not failures,
                    "failures": failures,
                },
                f,
                indent=2,
            )
        print(f"# wrote {args.json}", file=sys.stderr)
    print(f"# total bench time {wall:.0f}s", file=sys.stderr)
    if failures:
        print(f"# {len(failures)} sub-benchmark(s) FAILED:", file=sys.stderr)
        for f in failures:
            print(f"#   {f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
