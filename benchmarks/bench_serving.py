"""Micro-batched online serving vs the per-query baseline.

The paper's online setting (§3.2) is one-query-at-a-time; its
batch-parallelism study (Fig. 6) shows how amortization pays at batch > 1.
This benchmark measures the piece in between — the production shape: an
async micro-batcher coalescing an online request stream into jit buckets.

Three measurements on the CI-size tree:

* ``online-baseline``  — blocking per-query ``serve_online`` (QPS floor);
* ``microbatch-closed``— closed loop: all requests in flight, size-trigger
  coalescing at batch 16 (QPS ceiling; asserts bitwise-identical results);
* ``microbatch-poisson``— open loop: Poisson arrivals at ~2x the baseline's
  capacity, reporting the Table-4 panel with queue-wait vs compute split.

Run: ``python -m benchmarks.bench_serving [--n 128] [--max-batch 16]``
"""

from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np

from benchmarks.common import build_benchmark_tree, csv_line
from repro.data.xmr_data import PAPER_SHAPES, benchmark_queries, scaled_shape
from repro.serving import (
    BatchPolicy,
    MicroBatcher,
    ServeConfig,
    ServerMetrics,
    XMRServingEngine,
)


def _build_engine(max_labels: int, max_batch: int, seed: int,
                  method: str = "auto"):
    shape = PAPER_SHAPES["eurlex-4k"]
    if shape.L > max_labels:
        shape = scaled_shape(shape, max_labels / shape.L)
    rng = np.random.default_rng(seed)
    tree = build_benchmark_tree(shape, 16, rng)
    engine = XMRServingEngine(
        tree,
        ServeConfig(ell_width=256, max_batch=max(64, max_batch),
                    method=method),
    )
    # Warm every bucket the batcher can form, so odd-size deadline batches
    # never hit a fresh jit compile mid-measurement.
    engine.warmup_buckets(shape.d, max_batch)
    return shape, engine, rng


def run(
    *,
    n_queries: int = 128,
    max_batch: int = 16,
    max_wait_ms: float = 2.0,
    max_labels: int = 4096,
    seed: int = 0,
    method: str = "auto",
) -> List[str]:
    shape, engine, rng = _build_engine(max_labels, max_batch, seed, method)
    queries = benchmark_queries(shape, n_queries, rng)
    lines = []

    # -- per-query baseline (the paper's online setting) --------------------
    t0 = time.perf_counter()
    base_s, base_l = engine.serve_online(queries)
    base_wall = time.perf_counter() - t0
    base_qps = n_queries / base_wall
    lines.append(
        csv_line(
            f"{shape.name}/serving/online-baseline",
            1e6 * base_wall / n_queries,
            f"qps={base_qps:.1f}",
        )
    )

    # -- closed-loop micro-batching ----------------------------------------
    mb = MicroBatcher(engine, BatchPolicy(max_batch, max_wait_ms))
    futs = mb.submit_csr(queries)  # all in flight before the worker starts
    t0 = time.perf_counter()
    mb.start()
    results = [f.result(timeout=120) for f in futs]
    closed_wall = time.perf_counter() - t0
    mb.stop()
    closed_qps = n_queries / closed_wall

    mb_s = np.stack([r[0] for r in results])
    mb_l = np.stack([r[1] for r in results])
    identical = bool(
        np.array_equal(mb_s, base_s) and np.array_equal(mb_l, base_l)
    )
    speedup = closed_qps / base_qps
    lines.append(
        csv_line(
            f"{shape.name}/serving/microbatch-closed",
            1e6 * closed_wall / n_queries,
            f"qps={closed_qps:.1f} speedup={speedup:.2f}x "
            f"bitwise_identical={identical} "
            f"avg_batch={mb.metrics.summary()['avg_batch']:.1f}",
        )
    )

    # -- open-loop Poisson arrivals at ~2x baseline capacity ----------------
    rate = 2.0 * base_qps
    metrics = ServerMetrics()
    mb = MicroBatcher(engine, BatchPolicy(max_batch, max_wait_ms), metrics)
    mb.start()
    arrivals = rng.exponential(1.0 / rate, size=n_queries)
    futs = []
    for i, gap in enumerate(arrivals):
        time.sleep(gap)
        futs.append(mb.submit(*queries.row(i)))
    for f in futs:
        f.result(timeout=120)
    mb.stop()
    s = metrics.summary()
    lines.append(
        csv_line(
            f"{shape.name}/serving/microbatch-poisson",
            1e3 * s["avg_ms"],
            f"rate={rate:.0f}qps p50={s['p50_ms']:.2f}ms "
            f"p95={s['p95_ms']:.2f}ms p99={s['p99_ms']:.2f}ms "
            f"wait={s['queue_wait_avg_ms']:.2f}ms "
            f"compute={s['compute_per_query_avg_ms']:.2f}ms "
            f"avg_batch={s['avg_batch']:.1f}",
        )
    )
    return lines


def main(argv=None) -> List[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--max-labels", type=int, default=4096)
    ap.add_argument("--method", default="auto",
                    help='masked-matmul method ("auto" resolves per backend;'
                         ' e.g. mscm_pallas_grouped on TPU)')
    args = ap.parse_args(argv)
    lines = run(
        n_queries=args.n,
        max_batch=args.max_batch,
        max_wait_ms=args.max_wait_ms,
        max_labels=args.max_labels,
        method=args.method,
    )
    for line in lines:
        print(line)
    return lines


if __name__ == "__main__":
    main()
