"""Micro-batched online serving vs the per-query baseline.

The paper's online setting (§3.2) is one-query-at-a-time; its
batch-parallelism study (Fig. 6) shows how amortization pays at batch > 1.
This benchmark measures the piece in between — the production shape: an
async micro-batcher coalescing an online request stream into jit buckets.

Three measurements on the CI-size tree:

* ``online-baseline``  — blocking per-query ``serve_online`` (QPS floor);
* ``microbatch-closed``— closed loop: all requests in flight, size-trigger
  coalescing at batch 16 (QPS ceiling; asserts bitwise-identical results);
* ``microbatch-poisson``— open loop: Poisson arrivals at ~2x the baseline's
  capacity, reporting the Table-4 panel with queue-wait vs compute split.

``--overload`` runs the open-loop overload study instead: Poisson arrivals
at 1×/2×/4× the measured closed-loop capacity against a *bounded* admission
queue (shed-oldest), plus a 4× run against the unbounded queue and a 4× run
with per-request deadlines. Reports goodput + P99 + shed/deadline-miss rates
per rate, and a structural guarantees row (``p99_bounded`` — bounded 4× P99
within 5× of the 1× run — and ``shed_nonzero``) that
``benchmarks/check_regression.py`` gates hard.

Run: ``python -m benchmarks.bench_serving [--n 128] [--max-batch 16]
[--overload]``
"""

from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np

from benchmarks.common import build_benchmark_tree, csv_line
from repro.data.xmr_data import PAPER_SHAPES, benchmark_queries, scaled_shape
from repro.serving import (
    AdmissionPolicy,
    BatchPolicy,
    MicroBatcher,
    ServeConfig,
    ServerMetrics,
    ServingError,
    XMRServingEngine,
)


def _build_engine(max_labels: int, max_batch: int, seed: int,
                  method: str = "auto"):
    shape = PAPER_SHAPES["eurlex-4k"]
    if shape.L > max_labels:
        shape = scaled_shape(shape, max_labels / shape.L)
    rng = np.random.default_rng(seed)
    tree = build_benchmark_tree(shape, 16, rng)
    engine = XMRServingEngine(
        tree,
        ServeConfig(ell_width=256, max_batch=max(64, max_batch),
                    method=method),
    )
    # Warm every bucket the batcher can form, so odd-size deadline batches
    # never hit a fresh jit compile mid-measurement.
    engine.warmup_buckets(shape.d, max_batch)
    return shape, engine, rng


def run(
    *,
    n_queries: int = 128,
    max_batch: int = 16,
    max_wait_ms: float = 2.0,
    max_labels: int = 4096,
    seed: int = 0,
    method: str = "auto",
) -> List[str]:
    shape, engine, rng = _build_engine(max_labels, max_batch, seed, method)
    queries = benchmark_queries(shape, n_queries, rng)
    lines = []

    # -- per-query baseline (the paper's online setting) --------------------
    t0 = time.perf_counter()
    base_s, base_l = engine.serve_online(queries)
    base_wall = time.perf_counter() - t0
    base_qps = n_queries / base_wall
    lines.append(
        csv_line(
            f"{shape.name}/serving/online-baseline",
            1e6 * base_wall / n_queries,
            f"qps={base_qps:.1f}",
        )
    )

    # -- closed-loop micro-batching ----------------------------------------
    # Buckets were warmed in _build_engine; a second warmup inside the timed
    # window would count real device batches against closed_wall.
    mb = MicroBatcher(engine, BatchPolicy(max_batch, max_wait_ms),
                      warmup_on_start=False)
    futs = mb.submit_csr(queries)  # all in flight before the worker starts
    t0 = time.perf_counter()
    mb.start()
    results = [f.result(timeout=120) for f in futs]
    closed_wall = time.perf_counter() - t0
    mb.stop()
    closed_qps = n_queries / closed_wall

    mb_s = np.stack([r[0] for r in results])
    mb_l = np.stack([r[1] for r in results])
    identical = bool(
        np.array_equal(mb_s, base_s) and np.array_equal(mb_l, base_l)
    )
    speedup = closed_qps / base_qps
    lines.append(
        csv_line(
            f"{shape.name}/serving/microbatch-closed",
            1e6 * closed_wall / n_queries,
            f"qps={closed_qps:.1f} speedup={speedup:.2f}x "
            f"bitwise_identical={identical} "
            f"avg_batch={mb.metrics.summary()['avg_batch']:.1f}",
        )
    )

    # -- open-loop Poisson arrivals at ~2x baseline capacity ----------------
    rate = 2.0 * base_qps
    s, _, _, _ = _open_loop(
        engine, queries, BatchPolicy(max_batch, max_wait_ms),
        AdmissionPolicy(None), rate, n_queries, rng,
    )
    lines.append(
        csv_line(
            f"{shape.name}/serving/microbatch-poisson",
            1e3 * s["avg_ms"],
            f"rate={rate:.0f}qps p50={s['p50_ms']:.2f}ms "
            f"p95={s['p95_ms']:.2f}ms p99={s['p99_ms']:.2f}ms "
            f"wait={s['queue_wait_avg_ms']:.2f}ms "
            f"compute={s['compute_per_query_avg_ms']:.2f}ms "
            f"avg_batch={s['avg_batch']:.1f}",
        )
    )
    return lines


def _open_loop(
    engine,
    queries,
    policy: BatchPolicy,
    admission: AdmissionPolicy,
    rate: float,
    n: int,
    rng: np.random.Generator,
):
    """Drive one open-loop Poisson run; returns (metrics summary, ok, failed,
    goodput in completed-ok queries per second of wall time)."""
    metrics = ServerMetrics()
    mb = MicroBatcher(engine, policy, metrics, admission, warmup_on_start=False)
    mb.start()
    arrivals = rng.exponential(1.0 / rate, size=n)
    t0 = time.perf_counter()
    futs = []
    t_next = t0
    for i, gap in enumerate(arrivals):
        # Open-loop pacing: sleep coarse, spin the last stretch — plain
        # time.sleep's ~100us floor silently caps the offered rate well
        # below the 4x-capacity target.
        t_next += gap
        lag = t_next - time.perf_counter()
        if lag > 1e-3:
            time.sleep(lag - 5e-4)
        while time.perf_counter() < t_next:
            pass
        futs.append(mb.submit(*queries.row(i % queries.shape[0])))
    ok = failed = 0
    for f in futs:
        try:
            f.result(timeout=300)
            ok += 1
        except ServingError:
            failed += 1
    wall = time.perf_counter() - t0
    mb.stop()
    return metrics.summary(), ok, failed, ok / max(wall, 1e-9)


def run_overload(
    *,
    n_queries: int = 256,
    max_batch: int = 16,
    max_wait_ms: float = 2.0,
    max_labels: int = 4096,
    seed: int = 0,
    method: str = "auto",
    rates=(1.0, 2.0, 4.0),
    queue_depth: int | None = None,
) -> List[str]:
    """Open-loop overload study: bounded vs unbounded queues at 1×–4× capacity.

    ``capacity`` is the *saturated* service ceiling — closed-loop QPS with
    full coalescing. Under overload a backlogged open-loop server converges
    to the same full-batch regime, so this is the honest anchor for the
    multipliers; it also means the 1× run is already critical load (open
    Poisson arrivals form smaller, less efficient batches than the closed
    loop), so a shallow bounded queue sheds a little there too — expected
    queueing behavior, not a calibration error.

    The bounded server (queue depth ``2 * max_batch`` by default, shed-oldest)
    must keep P99 e2e latency within 5× of its 1× run and shed a nonzero
    fraction at 4× — both emitted as structural flags the regression gate
    enforces. The unbounded 4× run demonstrates the failure mode this tier
    exists to prevent (P99 grows with the backlog); the deadline 4× run shows
    expired requests being dropped at dispatch instead of burning device time.
    """
    shape, engine, rng = _build_engine(max_labels, max_batch, seed, method)
    queries = benchmark_queries(shape, n_queries, rng)
    policy = BatchPolicy(max_batch, max_wait_ms)
    queue_depth = queue_depth or 2 * max_batch
    lines = []

    # Capacity = closed-loop micro-batched QPS: the saturated full-batch
    # ceiling an overloaded open-loop server converges to (see docstring).
    mb = MicroBatcher(engine, policy, warmup_on_start=False)
    futs = mb.submit_csr(queries)
    t0 = time.perf_counter()
    mb.start()
    for f in futs:
        f.result(timeout=300)
    capacity = n_queries / (time.perf_counter() - t0)
    mb.stop()

    p99 = {}
    shed_rate_at = {}
    for mult in rates:
        s, ok, failed, goodput = _open_loop(
            engine, queries, policy,
            AdmissionPolicy(queue_depth, "shed-oldest"),
            mult * capacity, n_queries, rng,
        )
        p99[mult] = s.get("p99_ms", 0.0)
        shed_rate_at[mult] = s.get("shed_rate", 0.0)
        lines.append(
            csv_line(
                f"{shape.name}/serving/overload-bounded-{mult:g}x",
                1e3 * p99[mult],  # p99 in us
                f"goodput={goodput:.0f}qps p50={s.get('p50_ms', 0):.2f}ms "
                f"p99={p99[mult]:.2f}ms shed_rate={s.get('shed_rate', 0):.3f} "
                f"deadline_miss_rate={s.get('deadline_miss_rate', 0):.3f} "
                f"ok={ok} shed={failed}",
            )
        )

    top = max(rates)
    # Unbounded queue at top rate: every request completes, P99 inherits the
    # whole backlog — the failure mode admission control removes.
    s, ok, failed, goodput = _open_loop(
        engine, queries, policy, AdmissionPolicy(None),
        top * capacity, n_queries, rng,
    )
    unb_p99 = s.get("p99_ms", 0.0)
    lines.append(
        csv_line(
            f"{shape.name}/serving/overload-unbounded-{top:g}x",
            1e3 * unb_p99,
            f"goodput={goodput:.0f}qps p99={unb_p99:.2f}ms "
            f"shed_rate={s.get('shed_rate', 0):.3f} ok={ok}",
        )
    )

    # Deadline run at top rate: unbounded queue, per-request deadline equal
    # to half the bounded queue's implied wait bound — expired requests are
    # dropped at dispatch (deadline_miss_rate > 0) instead of holding device
    # time, so goodput holds near capacity.
    deadline_ms = 1e3 * queue_depth / (2.0 * capacity) + max_wait_ms
    s, ok, failed, goodput = _open_loop(
        engine, queries, policy,
        AdmissionPolicy(None, deadline_ms=deadline_ms),
        top * capacity, n_queries, rng,
    )
    lines.append(
        csv_line(
            f"{shape.name}/serving/overload-deadline-{top:g}x",
            1e3 * s.get("p99_ms", 0.0),
            f"goodput={goodput:.0f}qps deadline={deadline_ms:.1f}ms "
            f"deadline_miss_rate={s.get('deadline_miss_rate', 0):.3f} ok={ok}",
        )
    )

    lo = min(rates)
    bounded_ok = p99[top] <= 5.0 * max(p99[lo], 1e-6)
    shed_nonzero = shed_rate_at[top] > 0.0
    lines.append(
        csv_line(
            f"{shape.name}/serving/overload-guarantees",
            p99[top] / max(p99[lo], 1e-6),  # p99 degradation ratio, top vs lo
            f"p99_bounded={bounded_ok} shed_nonzero={shed_nonzero} "
            f"p99_{lo:g}x={p99[lo]:.2f}ms p99_{top:g}x={p99[top]:.2f}ms "
            f"unbounded_p99={unb_p99:.2f}ms capacity={capacity:.0f}qps",
        )
    )
    return lines


def main(argv=None) -> List[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--max-labels", type=int, default=4096)
    ap.add_argument("--method", default="auto",
                    help='masked-matmul method ("auto" resolves per backend;'
                         ' e.g. mscm_pallas_grouped on TPU)')
    ap.add_argument("--overload", action="store_true",
                    help="open-loop overload study (bounded vs unbounded "
                         "queue at 1x/2x/4x capacity) instead of the "
                         "throughput panel")
    ap.add_argument("--queue-depth", type=int, default=None,
                    help="admission bound for --overload (default "
                         "2 * max_batch)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args(argv)
    if args.overload:
        lines = run_overload(
            n_queries=args.n,
            max_batch=args.max_batch,
            max_wait_ms=args.max_wait_ms,
            max_labels=args.max_labels,
            method=args.method,
            queue_depth=args.queue_depth,
        )
    else:
        lines = run(
            n_queries=args.n,
            max_batch=args.max_batch,
            max_wait_ms=args.max_wait_ms,
            max_labels=args.max_labels,
            method=args.method,
        )
    for line in lines:
        print(line)
    if args.json:
        import json as json_mod
        import sys as sys_mod

        from benchmarks.run import _parse_rows

        with open(args.json, "w") as f:
            json_mod.dump(
                {"rows": _parse_rows(lines), "completed": True}, f, indent=2
            )
        print(f"# wrote {args.json}", file=sys_mod.stderr)
    return lines


if __name__ == "__main__":
    main()
