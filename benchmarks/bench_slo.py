"""Latency-SLO adaptive inference: the p99-vs-recall frontier (ISSUE 10).

The admission tier (bench_serving ``--overload``) protects latency by
*shedding* whole queries. The adaptive tier serves every query and spends
recall instead: under backlog the batcher drops to a narrower beam tier, so
the p99 stays bounded while recall degrades smoothly — the frontier this
benchmark measures.

Two legs on the CI-size tree:

* **Parity** — one row per serving topology (in-process, partitioned
  ``level``, partitioned ``pipelined``, cross-process fleet): tier 0 must be
  **bitwise** identical to an engine without an SLO, and every degraded
  tier bitwise identical to the unpartitioned full tree at that tier's
  beam ("exact at the tier"). Both checks fold into the
  ``adaptive_full_beam_parity`` structural flag that
  ``benchmarks/check_regression.py`` gates hard.
* **Frontier** — open-loop Poisson arrivals at 1×/2×/4× the full-beam
  closed-loop capacity against the adaptive server (bounded shed-oldest
  queue, SLO target ≈ 2.5 full-beam batch costs). Each rate reports p99,
  measured recall@k vs the full-beam reference, the served tier mix, and
  the degraded-to-tier rate. The guarantees row carries two more gated
  flags: ``slo_p99_bounded`` (4× p99 within 5× of the 1× run — same bound
  the shedding tier is held to) and ``recall_floor_met`` — measured recall
  at every rate stays above the *worst-case-assignment* floor
  ``mean_q min_tier recall(tier, q)``, which tier-exactness makes a true
  lower bound, not a tuned tolerance.

Run: ``python -m benchmarks.bench_slo [--n 96] [--json PATH]``
"""

from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np

from benchmarks.common import build_benchmark_tree, csv_line
from repro.data.xmr_data import PAPER_SHAPES, benchmark_queries, scaled_shape
from repro.quant.contract import recall_at_k
from repro.serving import (
    AdmissionPolicy,
    BatchPolicy,
    MicroBatcher,
    PartitionConfig,
    Query,
    ServeConfig,
    ServerMetrics,
    SLOConfig,
    XMRServingEngine,
)

BEAM, TOPK, QT = 10, 10, 8
TIER_LADDER = ((5, QT), (2, QT))  # explicit degraded rungs under BEAM


def _bits(a) -> np.ndarray:
    return np.asarray(a).view(np.uint32)


def _build_world(max_labels: int, max_batch: int, seed: int, method: str):
    shape = PAPER_SHAPES["eurlex-4k"]
    if shape.L > max_labels:
        shape = scaled_shape(shape, max_labels / shape.L)
    rng = np.random.default_rng(seed)
    tree = build_benchmark_tree(shape, 16, rng)
    return shape, tree, rng


def _serve_cfg(max_batch: int, *, slo=None, partition=None) -> ServeConfig:
    kw = {}
    if slo is not None:
        kw["slo"] = slo
    if partition is not None:
        kw["partition"] = partition
    return ServeConfig(
        beam=BEAM, topk=TOPK, qt=QT, ell_width=256,
        max_batch=max(64, max_batch), **kw,
    )


def _tier_refs(tree, max_batch: int, queries):
    """Unpartitioned reference engines/panels, one per ladder rung.

    ``refs[0]`` is the full-beam no-SLO engine (the bitwise anchor and the
    recall reference); deeper entries serve the whole query set at that
    tier's beam — exact panels the adaptive tiers must reproduce bitwise.
    """
    beams = [BEAM] + [b for b, _ in TIER_LADDER]
    engines = [
        XMRServingEngine(
            tree, ServeConfig(beam=b, topk=TOPK, qt=QT, ell_width=256,
                              max_batch=max(64, max_batch))
        )
        for b in beams
    ]
    panels = [e.serve_batch(queries) for e in engines]
    return engines, panels


def _tier_parity(engine, ref_engines, xi, xv) -> bool:
    """Every tier of ``engine`` bitwise-equals its unpartitioned reference
    on one marshalled bucket (tier 0 == the no-SLO engine)."""
    ok = True
    for tier, ref in enumerate(ref_engines):
        s, l = engine._run(xi, xv, tier=tier)
        rs, rl = ref._run(xi, xv)
        ok = ok and bool(
            np.array_equal(_bits(s), _bits(rs))
            and np.array_equal(np.asarray(l), np.asarray(rl))
        )
    return ok


def _time_full_beam(engine, xi, xv, iters: int = 3) -> float:
    """Median wall seconds for one tier-0 bucket (warmed)."""
    import jax

    jax.block_until_ready(engine._run(xi, xv))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(engine._run(xi, xv))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _open_loop_adaptive(
    engine, queries, policy, admission, rate, n, rng, ref_l,
):
    """One open-loop Poisson run against the adaptive server.

    Returns ``(summary, recall, ok, shed)`` — recall@k over the completed
    queries vs the full-beam reference panel.
    """
    metrics = ServerMetrics()
    mb = MicroBatcher(engine, policy, metrics, admission,
                      warmup_on_start=False)
    mb.start()
    nq = queries.shape[0]
    futs = []
    t_next = time.perf_counter()
    for i, gap in enumerate(rng.exponential(1.0 / rate, size=n)):
        # Open-loop pacing: sleep coarse, spin the last stretch (see
        # bench_serving._open_loop for why plain sleep caps the rate).
        t_next += gap
        lag = t_next - time.perf_counter()
        if lag > 1e-3:
            time.sleep(lag - 5e-4)
        while time.perf_counter() < t_next:
            pass
        idx, val = queries.row(i % nq)
        futs.append(mb.submit(Query(idx=idx, val=val, qid=i)))
    results = [f.result(timeout=300) for f in futs]
    mb.stop()
    served = [r for r in results if r.ok]
    shed = len(results) - len(served)
    if served:
        got = np.stack([r.ids for r in served])
        ref = np.stack([ref_l[r.qid % nq] for r in served])
        recall = recall_at_k(ref, got)
    else:
        recall = 0.0
    return metrics.summary(), recall, len(served), shed


def _tier_mix(summary: dict) -> str:
    mix = summary.get("beam_tiers", {})
    if not mix:
        return "0:all"
    return "|".join(f"{t}:{n}" for t, n in sorted(mix.items()))


def run(
    *,
    n_queries: int = 96,
    max_batch: int = 16,
    max_wait_ms: float = 2.0,
    max_labels: int = 4096,
    seed: int = 0,
    method: str = "auto",
    rates=(0.5, 1.0, 2.0, 4.0),
    skip_fleet: bool = False,
) -> List[str]:
    shape, tree, rng = _build_world(max_labels, max_batch, seed, method)
    queries = benchmark_queries(shape, n_queries, rng)
    ref_engines, panels = _tier_refs(tree, max_batch, queries)
    ref_engine = ref_engines[0]
    ref_l = panels[0][1]
    lines = []

    # One marshalled bucket shared by every parity leg.
    bucket = ref_engine.bucket_for(max_batch)
    rows = np.arange(min(n_queries, max_batch))
    xi, xv = ref_engine.marshal_rows(queries, rows, bucket)

    # SLO target: ~4 full-beam bucket costs. A shallow backlog still fits
    # tier 0; a bounded-queue backlog (up to 5 batches) cannot, so overload
    # visibly walks down the ladder instead of shedding. (The capacity
    # anchor below is the *saturated* ceiling, so even the 1x run is
    # critical load — the 0.5x rate exists to show the tier-0 end of the
    # frontier.)
    cost0_ms = 1e3 * ref_engine.measure_batch_seconds(max_batch)
    target_ms = 4.0 * cost0_ms
    slo = SLOConfig(target_p99_ms=target_ms, tiers=TIER_LADDER)

    # -- parity: every topology, every tier, bitwise --------------------------
    topologies = [
        ("inprocess", None),
        ("partitioned-level",
         PartitionConfig(partitions=2, partition_sync="level")),
        ("partitioned-pipelined",
         PartitionConfig(partitions=2, partition_sync="pipelined")),
    ]
    all_parity = True
    for name, part in topologies:
        engine = XMRServingEngine(
            tree, _serve_cfg(max_batch, slo=slo, partition=part))
        parity = _tier_parity(engine, ref_engines, xi, xv)
        all_parity = all_parity and parity
        secs = _time_full_beam(engine, xi, xv)
        lines.append(
            csv_line(
                f"{shape.name}/slo/slo-parity-{name}",
                1e6 * secs / bucket,
                f"adaptive_full_beam_parity={parity} "
                f"tiers={1 + len(TIER_LADDER)} bucket={bucket}",
            )
        )

    if not skip_fleet:
        # Cross-process fleet: the tier override rides the begin header over
        # the socket RPC; tier 0 stays byte-identical on the wire.
        from repro.serving.fleet import PartitionFleet

        engine = XMRServingEngine(
            tree,
            _serve_cfg(
                max_batch, slo=slo,
                partition=PartitionConfig(partitions=2,
                                          partition_sync="pipelined"),
            ),
        )
        with PartitionFleet.launch(2, rpc_timeout_s=300.0) as fleet:
            fleet.attach(engine)
            parity = _tier_parity(engine, ref_engines, xi, xv)
            all_parity = all_parity and parity
            secs = _time_full_beam(engine, xi, xv)
        lines.append(
            csv_line(
                f"{shape.name}/slo/slo-parity-fleet",
                1e6 * secs / bucket,
                f"adaptive_full_beam_parity={parity} "
                f"tiers={1 + len(TIER_LADDER)} bucket={bucket}",
            )
        )

    # -- frontier: open-loop overload against the adaptive server -------------
    # Capacity anchor: the *full-beam* closed-loop ceiling (same anchor as
    # the shedding overload study, so the two frontiers are comparable).
    mb = MicroBatcher(ref_engine, BatchPolicy(max_batch, max_wait_ms),
                      warmup_on_start=False)
    futs = mb.submit_csr(queries)
    t0 = time.perf_counter()
    mb.start()
    for f in futs:
        f.result(timeout=300)
    capacity = n_queries / (time.perf_counter() - t0)
    mb.stop()

    # Worst-case-assignment recall floor: if every query were served at its
    # personally worst tier, mean recall would still reach this — so any
    # real tier mix must too (tiers are exact, per-query sets are fixed).
    per_query_min = None
    for _, tier_l in panels[1:]:
        r = np.array([
            recall_at_k(ref_l[i:i + 1], tier_l[i:i + 1])
            for i in range(n_queries)
        ])
        per_query_min = r if per_query_min is None else (
            np.minimum(per_query_min, r)
        )
    recall_floor = float(per_query_min.mean()) if per_query_min is not None \
        else 1.0

    adaptive = XMRServingEngine(tree, _serve_cfg(max_batch, slo=slo))
    adaptive.warmup_buckets(tree.d, max_batch)
    policy = BatchPolicy(max_batch, max_wait_ms)
    p99, recall_at = {}, {}
    floor_met = True
    for mult in rates:
        s, recall, ok, shed = _open_loop_adaptive(
            adaptive, queries, policy,
            AdmissionPolicy(4 * max_batch, "shed-oldest"),
            mult * capacity, n_queries, rng, ref_l,
        )
        p99[mult] = s.get("p99_ms", 0.0)
        recall_at[mult] = recall
        floor_met = floor_met and (ok == 0 or recall >= recall_floor - 1e-9)
        lines.append(
            csv_line(
                f"{shape.name}/slo/slo-frontier-{mult:g}x",
                1e3 * p99[mult],  # p99 in us
                f"p99={p99[mult]:.2f}ms recall={recall:.3f} "
                f"tier_mix={_tier_mix(s)} "
                f"degraded_to_tier_rate={s.get('degraded_to_tier_rate', 0.0):.3f} "
                f"shed_rate={s.get('shed_rate', 0.0):.3f} ok={ok} shed={shed}",
            )
        )

    # p99 bound anchored at the 1x (critical-load) run, same as the
    # shedding overload study — the 0.5x row informs, it does not gate.
    lo = 1.0 if 1.0 in p99 else min(rates)
    top = max(rates)
    bounded = p99[top] <= 5.0 * max(p99[lo], 1e-6)
    lines.append(
        csv_line(
            f"{shape.name}/slo/slo-guarantees",
            p99[top] / max(p99[lo], 1e-6),  # p99 degradation ratio
            f"slo_p99_bounded={bounded} "
            f"adaptive_full_beam_parity={all_parity} "
            f"recall_floor_met={floor_met} recall_floor={recall_floor:.3f} "
            f"recall_{top:g}x={recall_at[top]:.3f} "
            f"target_ms={target_ms:.1f} capacity={capacity:.0f}qps",
        )
    )
    return lines


def main(argv=None) -> List[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=96)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--max-labels", type=int, default=4096)
    ap.add_argument("--method", default="auto")
    ap.add_argument("--skip-fleet", action="store_true",
                    help="skip the cross-process fleet parity leg")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args(argv)
    lines = run(
        n_queries=args.n,
        max_batch=args.max_batch,
        max_wait_ms=args.max_wait_ms,
        max_labels=args.max_labels,
        method=args.method,
        skip_fleet=args.skip_fleet,
    )
    for line in lines:
        print(line)
    if args.json:
        import json as json_mod
        import sys as sys_mod

        from benchmarks.run import _parse_rows

        with open(args.json, "w") as f:
            json_mod.dump(
                {"rows": _parse_rows(lines), "completed": True}, f, indent=2
            )
        print(f"# wrote {args.json}", file=sys_mod.stderr)
    return lines


if __name__ == "__main__":
    main()
