"""Paper Tables 1-3 / Figures 3-4: MSCM vs vanilla across datasets,
branching factors {2, 8, 32}, batch vs online, and iterator variants.

CPU-budget scaling: label counts above ``max_labels`` are scaled down (d and
per-column nnz stay at the paper's values); the reported quantity — the
wall-time RATIO between MSCM and the vanilla per-column baseline — is
governed by traversal structure, not absolute scale. Results in
EXPERIMENTS.md §Paper-claims compare against the paper's qualitative claims
(speedups grow with branching; dense-lookup wins batch; exactness).
"""

from __future__ import annotations

import argparse
from typing import Dict, List

import numpy as np

from benchmarks.common import build_benchmark_tree, csv_line, ell_queries, time_fn
from repro.data.xmr_data import PAPER_SHAPES, scaled_shape

METHODS = ("vanilla", "mscm_dense", "mscm_searchsorted")


def run(datasets: List[str], branchings=(2, 8, 32), *, max_labels=65_536,
        n_batch=128, n_online=16, beam=10, topk=10, seed=0,
        include_pallas=False) -> List[str]:
    lines: List[str] = []
    methods = METHODS + (
        ("mscm_pallas", "mscm_pallas_grouped") if include_pallas else ()
    )
    for ds in datasets:
        shape = PAPER_SHAPES[ds]
        if shape.L > max_labels:
            shape = scaled_shape(shape, max_labels / shape.L)
        rng = np.random.default_rng(seed)
        for b in branchings:
            tree = build_benchmark_tree(shape, b, rng)
            xi, xv = ell_queries(shape, n_batch, rng, width=512)
            base: Dict[str, float] = {}
            for method in methods:
                # batch setting
                t = time_fn(
                    lambda m=method: tree.infer(xi, xv, beam=beam, topk=topk,
                                                method=m)
                )
                us_q = 1e6 * t / n_batch
                key = f"{ds}/B{b}/batch/{method}"
                base[("batch", method)] = us_q
                sp = base[("batch", "vanilla")] / us_q
                lines.append(csv_line(key, us_q, f"speedup_vs_vanilla={sp:.2f}"))
                # online setting (batch of one, amortization gone)
                xi1, xv1 = xi[:1], xv[:1]
                t1 = time_fn(
                    lambda m=method: tree.infer(xi1, xv1, beam=beam, topk=topk,
                                                method=m),
                    iters=max(3, n_online),
                )
                us_q1 = 1e6 * t1
                base[("online", method)] = us_q1
                sp1 = base[("online", "vanilla")] / us_q1
                lines.append(csv_line(f"{ds}/B{b}/online/{method}", us_q1,
                                      f"speedup_vs_vanilla={sp1:.2f}"))
            del tree
    return lines


def grouped_report(ds: str = "eurlex-4k", branching: int = 8, *, qt: int = 8,
                   beam: int = 10, topk: int = 10, n: int = 64,
                   max_labels: int = 32_768, seed: int = 0) -> List[str]:
    """Device-grouped MXU path: per-level tile accounting + batch timing.

    The grouped kernel's win is structural: the fused kernel walks a grid of
    A blocks (one [1,R]×[R,B] contraction each), while the grouped kernel
    packs the same blocks chunk-major into QT-row tiles — per level it runs
    ``tiles ≤ A/QT + C`` matmuls (each chunk wastes at most one ragged
    tile), amortizing every chunk's DMA over up to QT queries. This report
    emits that inequality per level plus wall-clock vs the dense-lookup
    batch baseline and a bitwise-equality flag.
    """
    import jax.numpy as jnp

    from repro.core import mscm as M
    from repro.core.beam import beam_step
    from repro.kernels import ops
    from repro.kernels.mscm_kernel import group_blocks_by_chunk

    shape = PAPER_SHAPES[ds]
    if shape.L > max_labels:
        shape = scaled_shape(shape, max_labels / shape.L)
    rng = np.random.default_rng(seed)
    tree = build_benchmark_tree(shape, branching, rng)
    xi, xv = ell_queries(shape, n, rng, width=512)
    lines: List[str] = []

    # Per-level tile accounting: replay the traversal with the dense-lookup
    # oracle and group each level's block list with the host reference
    # grouper (same packing as the in-jit group_blocks_device).
    xd = M.scatter_dense(xi, xv, tree.d)
    parent = jnp.zeros((n, 1), jnp.int32)
    scores = jnp.ones((n, 1), jnp.float32)
    for li, layer in enumerate(tree.layers):
        b_cur = parent.shape[1]
        bq = jnp.repeat(jnp.arange(n, dtype=jnp.int32), b_cur)
        bc = parent.reshape(-1)
        a = int(bc.shape[0])
        c = int(layer.chunk_vals.shape[0])
        tiles = len(group_blocks_by_chunk(np.asarray(bc), qt)[0])
        bound = a / qt + c
        lines.append(csv_line(
            f"{ds}/B{branching}/grouped/L{li}_tiles",
            float(tiles),
            f"fused_grid={a} bound={bound:.1f} "
            f"static_tiles={ops.grouped_tile_bound(a, qt, c)} "
            f"amortizes={tiles <= bound}",
        ))
        logits = M.mscm_dense_lookup(
            xd, layer.chunk_rows, layer.chunk_vals, bq, bc
        ).reshape(n, b_cur, tree.branching[li])
        is_last = li == len(tree.layers) - 1
        nb = min(topk if is_last else beam, tree.n_cols[li])
        parent, scores = beam_step(parent, scores, logits, tree.n_cols[li], nb)

    # Wall-clock + the paper's exactness claim, now bitwise.
    t_dense = time_fn(lambda: tree.infer(xi, xv, beam=beam, topk=topk,
                                         method="mscm_dense"))
    t_grp = time_fn(lambda: tree.infer(xi, xv, beam=beam, topk=topk,
                                       method="mscm_pallas_grouped", qt=qt))
    s0, l0 = tree.infer(xi, xv, beam=beam, topk=topk, method="mscm_dense")
    s1, l1 = tree.infer(xi, xv, beam=beam, topk=topk,
                        method="mscm_pallas_grouped", qt=qt)
    identical = bool(
        np.array_equal(np.asarray(s0), np.asarray(s1))
        and np.array_equal(np.asarray(l0), np.asarray(l1))
    )
    lines.append(csv_line(
        f"{ds}/B{branching}/batch/mscm_pallas_grouped",
        1e6 * t_grp / n,
        f"qt={qt} vs_dense={t_dense / t_grp:.2f}x "
        f"bitwise_identical={identical}",
    ))
    return lines


def profile_share(ds: str = "eurlex-4k", branching: int = 8, seed: int = 0,
                  n: int = 64) -> List[str]:
    """Paper §4 claim: the masked matmul is 90-98% of inference time.

    Measured by timing full inference vs inference with the matmul replaced
    by a free constant (everything else — beam bookkeeping, top-k — intact).
    """
    import jax
    import jax.numpy as jnp

    from repro.core.beam import beam_step

    shape = PAPER_SHAPES[ds]
    rng = np.random.default_rng(seed)
    tree = build_benchmark_tree(shape, branching, rng)
    xi, xv = ell_queries(shape, n, rng, width=512)
    t_full = time_fn(lambda: tree.infer(xi, xv, beam=10, topk=10,
                                        method="mscm_dense"))

    @jax.jit
    def skeleton(xi, xv):
        nq = xi.shape[0]
        parent = jnp.zeros((nq, 1), jnp.int32)
        scores = jnp.ones((nq, 1), jnp.float32)
        for li, layer in enumerate(tree.layers):
            bcur = parent.shape[1]
            logits = jnp.zeros((nq, bcur, tree.branching[li]), jnp.float32)
            nb = min(10, tree.n_cols[li])
            parent, scores = beam_step(parent, scores, logits, tree.n_cols[li], nb)
        return scores, parent

    t_skel = time_fn(lambda: skeleton(xi, xv))
    share = 100.0 * (t_full - t_skel) / t_full
    return [csv_line(f"{ds}/matmul_share_pct", 1e6 * t_full / n,
                     f"masked_matmul_share={share:.1f}%")]


def main(argv=None) -> List[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="*",
                    default=["eurlex-4k", "wiki10-31k", "amazon-670k"])
    ap.add_argument("--branchings", nargs="*", type=int, default=[2, 8, 32])
    ap.add_argument("--max-labels", type=int, default=65_536)
    ap.add_argument("--n-batch", type=int, default=128)
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--grouped", action="store_true",
                    help="also run the device-grouped MXU path report")
    ap.add_argument("--qt", type=int, default=8,
                    help="grouped-kernel query-tile height")
    args = ap.parse_args(argv)
    lines = run(args.datasets, tuple(args.branchings),
                max_labels=args.max_labels, n_batch=args.n_batch,
                include_pallas=args.pallas)
    if args.grouped:
        lines += grouped_report(qt=args.qt, max_labels=args.max_labels,
                                n=args.n_batch)
    lines += profile_share()
    for l in lines:
        print(l)
    return lines


if __name__ == "__main__":
    main()
