"""Label-partitioned scatter–gather serving vs the unpartitioned tree.

The enterprise claim (ISSUE 4): at 100M labels no single device holds the
tree, so ``repro.index`` splits the label space P ways. This benchmark pins
the things that make that deployable:

* ``partition_parity`` — the planner's per-level sync mode returns
  **bitwise-identical** scores and labels for every MSCM method. A
  structural flag ``check_regression`` gates hard.
* ``pipelined_parity`` — the overlapped ``sync="pipelined"`` mode (ISSUE 5:
  speculative next-level expansion reconciled against the canonical select)
  is *also* bitwise-identical, per method. Gated hard.
* ``cache_parity`` — a hot-beam cache **hit** (second pass over the same
  router beams) returns bits identical to the cold pass. Gated hard.
* ``partition_memory_balanced`` — the manifest's per-partition
  ``memory_bytes`` shrink ~1/P (within slack for the phantom pad chunk and
  the ragged tail) and the LPT placement balances columns. Also gated.

Timing rows report the scatter–gather overhead (per-level candidate
exchange) against single-tree inference on the same device — the price of
fitting a tree P× bigger than the device — and the pipelined mode's
speedup over level sync.

``--multidevice`` (CI runs it under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``) instead drives
``ServeConfig(partitions=2, shards=2)`` through the ``MicroBatcher`` on a
real (2 data × 2 model) mesh — level and pipelined sync — and emits an
``overlap_speedup`` structural flag: with partitions on their own devices,
pipelined throughput must be no worse than level-sync (the whole point of
taking the exchange off the matmul's critical path).

Run: ``python -m benchmarks.bench_partitioned [--n 48] [--partitions 2 4]
[--multidevice] [--json PATH]``
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

import jax
import numpy as np

from benchmarks.common import build_benchmark_tree, csv_line, time_fn
from repro.data.xmr_data import PAPER_SHAPES, benchmark_queries, scaled_shape
from repro.index import ScatterGatherPlanner, partition_tree, place

# Relative tolerance for the overlap gate: pipelined must be at least this
# close to level-sync throughput (it shares the arithmetic; only the
# exchange schedule differs, so parity-of-throughput is a floor, and on
# shared CI runners we leave headroom for timer noise).
OVERLAP_TOLERANCE = 1.15


def _build(max_labels: int, seed: int):
    shape = PAPER_SHAPES["eurlex-4k"]
    if shape.L > max_labels:
        shape = scaled_shape(shape, max_labels / shape.L)
    rng = np.random.default_rng(seed)
    tree = build_benchmark_tree(shape, 16, rng)
    return shape, tree, rng


def _bitwise(got, ref) -> bool:
    return bool(
        np.array_equal(np.asarray(got[0]), np.asarray(ref[0]))
        and np.array_equal(np.asarray(got[1]), np.asarray(ref[1]))
    )


def run(
    *,
    n_queries: int = 48,
    max_labels: int = 4096,
    partitions=(2, 4),
    methods=("mscm_dense", "mscm_searchsorted", "mscm_pallas_grouped"),
    beam: int = 10,
    topk: int = 10,
    seed: int = 0,
) -> List[str]:
    shape, tree, rng = _build(max_labels, seed)
    queries = benchmark_queries(shape, n_queries, rng)
    import jax.numpy as jnp

    xi, xv = map(jnp.asarray, queries.to_ell(256))
    lines = []
    for p in partitions:
        idx = partition_tree(tree, p)
        m = idx.manifest

        # -- memory: the whole point — per-device bytes shrink ~1/P --------
        # Slack covers the phantom pad chunk per level and the ragged tail.
        balanced = m.max_partition_bytes() <= 1.5 * m.total_memory_bytes / p
        lines.append(
            csv_line(
                f"{shape.name}/partitioned/P{p}-memory",
                m.max_partition_bytes() / 1e3,  # kB, reported not gated
                f"partition_memory_balanced={balanced} "
                f"max_part_kb={m.max_partition_bytes() / 1e3:.0f} "
                f"total_kb={m.total_memory_bytes / 1e3:.0f} "
                f"router_kb={m.router_memory_bytes / 1e3:.1f} "
                f"shrink={m.shrink_ratio():.2f}x level={m.level}",
            )
        )

        for method in methods:
            ref = tree.infer(xi, xv, beam=beam, topk=topk, method=method)
            ref = jax.block_until_ready(ref)
            planner = ScatterGatherPlanner(
                idx, beam=beam, topk=topk, method=method
            )
            parity = _bitwise(jax.block_until_ready(planner.infer(xi, xv)), ref)
            t_ref = time_fn(
                lambda: tree.infer(
                    xi, xv, beam=beam, topk=topk, method=method
                )
            )
            t_part = time_fn(lambda: planner.infer(xi, xv))
            planner.profile(xi, xv)  # warm the per-partition path
            prof = planner.profile(xi, xv)
            lines.append(
                csv_line(
                    f"{shape.name}/partitioned/P{p}-{method}",
                    1e6 * t_part / n_queries,
                    f"partition_parity={parity} "
                    f"overhead={t_part / t_ref:.2f}x "
                    f"part_ms={'/'.join(f'{t:.1f}' for t in prof)}",
                )
            )

            # -- pipelined (ISSUE 5): overlapped exchange, still bitwise ---
            pipe = ScatterGatherPlanner(
                idx, beam=beam, topk=topk, method=method, sync="pipelined"
            )
            pipe_parity = _bitwise(
                jax.block_until_ready(pipe.infer(xi, xv)), ref
            )
            t_pipe = time_fn(lambda: pipe.infer(xi, xv))
            lines.append(
                csv_line(
                    f"{shape.name}/pipelined/P{p}-{method}",
                    1e6 * t_pipe / n_queries,
                    f"pipelined_parity={pipe_parity} "
                    f"speedup_vs_level={t_part / t_pipe:.2f}x "
                    f"overhead={t_pipe / t_ref:.2f}x",
                )
            )

    # -- hot-beam cache: a hit must be bitwise what a cold run returns -----
    p0 = partitions[0]
    idx = partition_tree(tree, p0)
    ref = jax.block_until_ready(
        tree.infer(xi, xv, beam=beam, topk=topk, method=methods[0])
    )
    cached = ScatterGatherPlanner(
        idx, beam=beam, topk=topk, method=methods[0], sync="pipelined",
        cache_entries=256,
    )
    cold = _bitwise(jax.block_until_ready(cached.infer(xi, xv)), ref)
    hot = _bitwise(jax.block_until_ready(cached.infer(xi, xv)), ref)
    stats = cached.cache_stats()
    t_hot = time_fn(lambda: cached.infer(xi, xv))
    lines.append(
        csv_line(
            f"{shape.name}/pipelined/P{p0}-hot-beam-cache",
            1e6 * t_hot / n_queries,
            f"cache_parity={cold and hot} "
            f"hit_rate={stats['hit_rate']:.2f} entries={stats['entries']}",
        )
    )
    return lines


def run_multidevice(*, n_queries: int = 32, max_labels: int = 4096,
                    seed: int = 0) -> List[str]:
    """P=2 x shards=2 through the MicroBatcher on 4 (forced) host devices."""
    from repro.serving import (
        BatchPolicy, MicroBatcher, ServeConfig, XMRServingEngine,
    )

    n_dev = len(jax.devices())
    if n_dev < 4:
        raise SystemExit(
            f"--multidevice needs 4 devices, found {n_dev} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=4)"
        )
    shape, tree, rng = _build(max_labels, seed)
    queries = benchmark_queries(shape, n_queries, rng)

    ref_engine = XMRServingEngine(tree, ServeConfig(max_batch=64))
    ref_s, ref_l = ref_engine.serve_batch(queries)

    lines = []
    for sync, suffix, beam_cache in (
        ("level", "", 0),
        ("pipelined", "-pipelined", 64),
    ):
        engine = XMRServingEngine(
            tree, ServeConfig(
                max_batch=64, partitions=2, shards=2,
                partition_sync=sync, beam_cache=beam_cache,
            )
        )
        t0 = time.perf_counter()
        with MicroBatcher(
            engine, BatchPolicy(max_batch=16, max_wait_ms=2.0)
        ) as mb:
            res = [f.result(timeout=300) for f in mb.submit_csr(queries)]
        wall = time.perf_counter() - t0
        s = np.stack([r[0] for r in res])
        l = np.stack([r[1] for r in res])
        parity = bool(np.array_equal(s, ref_s) and np.array_equal(l, ref_l))
        summ = mb.metrics.summary()
        occ = summ.get("partition_occupancy", [])
        mesh = dict(engine.mesh.shape)
        extra = ""
        if sync == "pipelined":
            cache = summ.get("beam_cache", {})
            extra = (
                f" stall_ms={summ.get('pipeline_stall_avg_ms', 0.0):.2f}"
                f" cache_hit_rate={cache.get('hit_rate', 0.0):.2f}"
            )
        lines.append(
            csv_line(
                f"{shape.name}/partitioned/multidevice-P2xS2{suffix}",
                1e6 * wall / n_queries,
                f"partition_parity={parity} "
                f"mesh={mesh['data']}x{mesh['model']} "
                f"occupancy={'/'.join(f'{o:.2f}' for o in occ)} "
                f"devices={n_dev}" + extra,
            )
        )

    # -- overlap gate: with partitions on their own devices, taking the
    # exchange off the matmul's critical path must not cost throughput.
    # Forced host devices only execute *concurrently* when executables are
    # single-threaded (otherwise they contend for one Eigen pool and
    # serialize) — CI sets ``--xla_cpu_multi_thread_eigen=false
    # intra_op_parallelism_threads=1`` on this step; the ``eigen_mt`` field
    # flags runs where the claim is physically unmeasurable. The workload
    # is floored at 64 queries so per-level compute dominates the cheap
    # speculative selects being overlapped.
    import jax.numpy as jnp

    single_thread = "multi_thread_eigen=false" in os.environ.get(
        "XLA_FLAGS", ""
    )
    n_overlap = max(n_queries, 64)
    q_overlap = benchmark_queries(shape, n_overlap, rng)
    xi, xv = map(jnp.asarray, q_overlap.to_ell(256))
    idx = partition_tree(tree, 2)
    pm = place(idx, shards=1)
    level_pl = ScatterGatherPlanner(idx, placement=pm)
    pipe_pl = ScatterGatherPlanner(idx, placement=pm, sync="pipelined")
    # Best-of-3 of median-of-5 per mode: shared 2-core runners are noisy
    # and this is a hard structural gate, not a trend row.
    t_level = min(time_fn(lambda: level_pl.infer(xi, xv)) for _ in range(3))
    t_pipe = min(time_fn(lambda: pipe_pl.infer(xi, xv)) for _ in range(3))
    speedup = t_level / t_pipe
    # Gate only where the claim is measurable: with a shared multi-threaded
    # Eigen pool the forced host devices serialize, so a local run without
    # the flags reports the ratio but cannot honestly fail the flag (CI
    # always sets the flags; eigen_mt in the row keeps it auditable).
    ok = (not single_thread) or t_pipe <= t_level * OVERLAP_TOLERANCE
    lines.append(
        csv_line(
            f"{shape.name}/partitioned/multidevice-overlap",
            1e6 * t_pipe / n_overlap,
            f"overlap_speedup={ok} speedup={speedup:.2f}x "
            f"level_us={1e6 * t_level / n_overlap:.0f} "
            f"columns={pm.n_model} eigen_mt={not single_thread}",
        )
    )
    return lines


def main(argv=None) -> List[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--max-labels", type=int, default=4096)
    ap.add_argument("--partitions", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--beam", type=int, default=10)
    ap.add_argument("--multidevice", action="store_true",
                    help="P=2 x shards=2 MicroBatcher smoke on 4 forced "
                         "host devices instead of the single-device panel")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args(argv)
    if args.multidevice:
        lines = run_multidevice(n_queries=args.n, max_labels=args.max_labels)
    else:
        lines = run(
            n_queries=args.n, max_labels=args.max_labels,
            partitions=tuple(args.partitions), beam=args.beam,
        )
    for line in lines:
        print(line)
    if args.json:
        from benchmarks.run import _parse_rows

        with open(args.json, "w") as f:
            json.dump(
                {"rows": _parse_rows(lines), "completed": True}, f, indent=2
            )
        print(f"# wrote {args.json}", file=sys.stderr)
    return lines


if __name__ == "__main__":
    main()
