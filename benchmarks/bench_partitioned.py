"""Label-partitioned scatter–gather serving vs the unpartitioned tree.

The enterprise claim (ISSUE 4): at 100M labels no single device holds the
tree, so ``repro.index`` splits the label space P ways. This benchmark pins
the two things that make that deployable:

* ``partition_parity`` — the planner's default per-level sync mode returns
  **bitwise-identical** scores and labels for every MSCM method. A
  structural flag ``check_regression`` gates hard.
* ``partition_memory_balanced`` — the manifest's per-partition
  ``memory_bytes`` shrink ~1/P (within slack for the phantom pad chunk and
  the ragged tail) and the LPT placement balances columns. Also gated.

Timing rows report the scatter–gather overhead (per-level candidate
exchange) against single-tree inference on the same device — the price of
fitting a tree P× bigger than the device.

``--multidevice`` (CI runs it under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``) instead drives
``ServeConfig(partitions=2, shards=2)`` through the ``MicroBatcher`` on a
real (2 data × 2 model) mesh and emits the same parity flag.

Run: ``python -m benchmarks.bench_partitioned [--n 48] [--partitions 2 4]
[--multidevice] [--json PATH]``
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List

import jax
import numpy as np

from benchmarks.common import build_benchmark_tree, csv_line, time_fn
from repro.data.xmr_data import PAPER_SHAPES, benchmark_queries, scaled_shape
from repro.index import ScatterGatherPlanner, partition_tree, place


def _build(max_labels: int, seed: int):
    shape = PAPER_SHAPES["eurlex-4k"]
    if shape.L > max_labels:
        shape = scaled_shape(shape, max_labels / shape.L)
    rng = np.random.default_rng(seed)
    tree = build_benchmark_tree(shape, 16, rng)
    return shape, tree, rng


def run(
    *,
    n_queries: int = 48,
    max_labels: int = 4096,
    partitions=(2, 4),
    methods=("mscm_dense", "mscm_searchsorted", "mscm_pallas_grouped"),
    beam: int = 10,
    topk: int = 10,
    seed: int = 0,
) -> List[str]:
    shape, tree, rng = _build(max_labels, seed)
    queries = benchmark_queries(shape, n_queries, rng)
    import jax.numpy as jnp

    xi, xv = map(jnp.asarray, queries.to_ell(256))
    lines = []
    for p in partitions:
        idx = partition_tree(tree, p)
        m = idx.manifest

        # -- memory: the whole point — per-device bytes shrink ~1/P --------
        # Slack covers the phantom pad chunk per level and the ragged tail.
        balanced = m.max_partition_bytes() <= 1.5 * m.total_memory_bytes / p
        lines.append(
            csv_line(
                f"{shape.name}/partitioned/P{p}-memory",
                m.max_partition_bytes() / 1e3,  # kB, reported not gated
                f"partition_memory_balanced={balanced} "
                f"max_part_kb={m.max_partition_bytes() / 1e3:.0f} "
                f"total_kb={m.total_memory_bytes / 1e3:.0f} "
                f"router_kb={m.router_memory_bytes / 1e3:.1f} "
                f"shrink={m.shrink_ratio():.2f}x level={m.level}",
            )
        )

        for method in methods:
            ref = tree.infer(xi, xv, beam=beam, topk=topk, method=method)
            ref = jax.block_until_ready(ref)
            planner = ScatterGatherPlanner(
                idx, beam=beam, topk=topk, method=method
            )
            got = jax.block_until_ready(planner.infer(xi, xv))
            parity = bool(
                np.array_equal(np.asarray(got[0]), np.asarray(ref[0]))
                and np.array_equal(np.asarray(got[1]), np.asarray(ref[1]))
            )
            t_ref = time_fn(
                lambda: tree.infer(
                    xi, xv, beam=beam, topk=topk, method=method
                )
            )
            t_part = time_fn(lambda: planner.infer(xi, xv))
            planner.profile(xi, xv)  # warm the per-partition path
            prof = planner.profile(xi, xv)
            lines.append(
                csv_line(
                    f"{shape.name}/partitioned/P{p}-{method}",
                    1e6 * t_part / n_queries,
                    f"partition_parity={parity} "
                    f"overhead={t_part / t_ref:.2f}x "
                    f"part_ms={'/'.join(f'{t:.1f}' for t in prof)}",
                )
            )
    return lines


def run_multidevice(*, n_queries: int = 32, max_labels: int = 4096,
                    seed: int = 0) -> List[str]:
    """P=2 x shards=2 through the MicroBatcher on 4 (forced) host devices."""
    from repro.serving import (
        BatchPolicy, MicroBatcher, ServeConfig, XMRServingEngine,
    )

    n_dev = len(jax.devices())
    if n_dev < 4:
        raise SystemExit(
            f"--multidevice needs 4 devices, found {n_dev} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=4)"
        )
    shape, tree, rng = _build(max_labels, seed)
    queries = benchmark_queries(shape, n_queries, rng)

    ref_engine = XMRServingEngine(tree, ServeConfig(max_batch=64))
    ref_s, ref_l = ref_engine.serve_batch(queries)

    engine = XMRServingEngine(
        tree, ServeConfig(max_batch=64, partitions=2, shards=2)
    )
    t0 = time.perf_counter()
    with MicroBatcher(engine, BatchPolicy(max_batch=16, max_wait_ms=2.0)) as mb:
        res = [f.result(timeout=300) for f in mb.submit_csr(queries)]
    wall = time.perf_counter() - t0
    s = np.stack([r[0] for r in res])
    l = np.stack([r[1] for r in res])
    parity = bool(np.array_equal(s, ref_s) and np.array_equal(l, ref_l))
    occ = mb.metrics.summary().get("partition_occupancy", [])
    mesh = dict(engine.mesh.shape)
    return [
        csv_line(
            f"{shape.name}/partitioned/multidevice-P2xS2",
            1e6 * wall / n_queries,
            f"partition_parity={parity} mesh={mesh['data']}x{mesh['model']} "
            f"occupancy={'/'.join(f'{o:.2f}' for o in occ)} "
            f"devices={n_dev}",
        )
    ]


def main(argv=None) -> List[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--max-labels", type=int, default=4096)
    ap.add_argument("--partitions", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--beam", type=int, default=10)
    ap.add_argument("--multidevice", action="store_true",
                    help="P=2 x shards=2 MicroBatcher smoke on 4 forced "
                         "host devices instead of the single-device panel")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args(argv)
    if args.multidevice:
        lines = run_multidevice(n_queries=args.n, max_labels=args.max_labels)
    else:
        lines = run(
            n_queries=args.n, max_labels=args.max_labels,
            partitions=tuple(args.partitions), beam=args.beam,
        )
    for line in lines:
        print(line)
    if args.json:
        from benchmarks.run import _parse_rows

        with open(args.json, "w") as f:
            json.dump({"rows": _parse_rows(lines)}, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)
    return lines


if __name__ == "__main__":
    main()
