"""Paper Figure 6 / §6.1: parallel scaling.

The paper parallelizes row-chunk operations with OpenMP threads. The TPU
mapping of that claim is the data axis of the mesh: batch queries shard
embarrassingly (DESIGN.md §2). On this 1-core CPU container we measure the
amortization curve instead — per-query latency vs batch size — which is the
same economics (fixed per-call overhead + chunk reuse amortized across the
batch, paper §4's chunk-ordering amortization), and verify the MSCM-vs-
vanilla gap persists at every batch size as Fig. 6 shows for every thread
count. The sharded-inference path itself is exercised in
tests/test_distributed_xmr.py on an 8-device host mesh.
"""

from __future__ import annotations

import argparse
from typing import List

import numpy as np

from benchmarks.common import build_benchmark_tree, csv_line, ell_queries, time_fn
from repro.data.xmr_data import PAPER_SHAPES, scaled_shape


def run(ds: str = "amazon-670k", *, branching=32, batches=(1, 4, 16, 64, 256),
        max_labels=65_536, seed=0) -> List[str]:
    shape = PAPER_SHAPES[ds]
    if shape.L > max_labels:
        shape = scaled_shape(shape, max_labels / shape.L)
    rng = np.random.default_rng(seed)
    tree = build_benchmark_tree(shape, branching, rng)
    lines = []
    for n in batches:
        xi, xv = ell_queries(shape, n, rng, width=256)
        per = {}
        for method in ("vanilla", "mscm_dense"):
            t = time_fn(lambda m=method: tree.infer(xi, xv, beam=10, topk=10,
                                                    method=m))
            per[method] = 1e6 * t / n
            lines.append(csv_line(f"{ds}/batch{n}/{method}", per[method],
                                  f"batch={n}"))
        lines.append(csv_line(
            f"{ds}/batch{n}/speedup", 0.0,
            f"mscm_vs_vanilla={per['vanilla'] / per['mscm_dense']:.2f}x"))
    return lines


def main(argv=None) -> List[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="amazon-670k")
    ap.add_argument("--batches", nargs="*", type=int, default=[1, 4, 16, 64])
    args = ap.parse_args(argv)
    lines = run(args.dataset, batches=tuple(args.batches))
    for l in lines:
        print(l)
    return lines


if __name__ == "__main__":
    main()
