"""Render the rolling ``BENCH_trajectory.jsonl`` artifact as markdown.

The trajectory file accumulates one summary row per CI run (appended by
``check_regression --append-trajectory``): sha, run id, wall seconds and a
``{row name: us_per_call}`` map. This tool turns the window into a compact
markdown table — per benchmark row: first/last/min/max microseconds over
the window and the last/first drift ratio — so the perf history is readable
in a job's step summary instead of requiring an artifact download.

CI (bench-smoke) appends the output to ``$GITHUB_STEP_SUMMARY`` right after
the trend check. Exit is always 0 for an empty or missing file: the first
run on a branch has no trajectory yet, and a report must never gate.

Run: ``python -m benchmarks.trajectory_report BENCH_trajectory.jsonl
[--limit 20] [--top 40]``
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List


def load_rows(path: str) -> List[dict]:
    """Parse the JSONL window, skipping lines that fail to parse (a torn
    append from a cancelled run must not take the whole report down)."""
    out = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    doc = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if isinstance(doc, dict) and isinstance(doc.get("rows"), dict):
                    out.append(doc)
    except OSError:
        return []
    return out


def _fmt_us(v: float) -> str:
    return f"{v:,.0f}"


def render(runs: List[dict], *, top: int = 40) -> str:
    """Markdown report for a window of trajectory rows (oldest first)."""
    if not runs:
        return ("### Perf trajectory\n\n"
                "No trajectory rows yet (first run on this branch?).\n")
    latest = runs[-1]
    sha = (latest.get("sha") or "")[:9]
    lines = [
        "### Perf trajectory",
        "",
        f"{len(runs)} run(s) in window — latest `{sha or 'local'}`"
        f" (wall {latest.get('wall_s', '?')}s,"
        f" completed={latest.get('completed')})",
        "",
        "| benchmark row | first us | last us | min us | max us | last/first |",
        "|---|---:|---:|---:|---:|---:|",
    ]
    # Per-name series over the window; report names present in the latest
    # run (ordered by drift so regressions float to the top), and call out
    # names that vanished from it — a disappeared row is itself a signal.
    series: Dict[str, List[float]] = {}
    for run in runs:
        for name, us in run["rows"].items():
            series.setdefault(name, []).append(float(us))
    current = set(latest["rows"])

    def drift(name: str) -> float:
        s = series[name]
        return (s[-1] / s[0]) if s[0] > 0 else 1.0

    reported = sorted(current, key=drift, reverse=True)
    for name in reported[:top]:
        s = series[name]
        ratio = f"{drift(name):.2f}x" if s[0] > 0 else "—"
        lines.append(
            f"| `{name}` | {_fmt_us(s[0])} | {_fmt_us(s[-1])} "
            f"| {_fmt_us(min(s))} | {_fmt_us(max(s))} | {ratio} |"
        )
    if len(reported) > top:
        lines.append(f"| … {len(reported) - top} more row(s) | | | | | |")
    gone = sorted(set(series) - current)
    if gone:
        lines += ["", "Rows no longer present in the latest run: "
                  + ", ".join(f"`{n}`" for n in gone)]
    lines.append("")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trajectory", help="rolling BENCH_trajectory.jsonl")
    ap.add_argument("--limit", type=int, default=20,
                    help="use only the newest N runs (0 = all)")
    ap.add_argument("--top", type=int, default=40,
                    help="report at most N benchmark rows, worst drift first")
    args = ap.parse_args(argv)
    runs = load_rows(args.trajectory)
    if args.limit > 0:
        runs = runs[-args.limit:]
    sys.stdout.write(render(runs, top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
