"""Render the rolling ``BENCH_trajectory.jsonl`` artifact as markdown.

The trajectory file accumulates one summary row per CI run (appended by
``check_regression --append-trajectory``): sha, run id, wall seconds and a
``{row name: us_per_call}`` map. This tool turns the window into a compact
markdown table — per benchmark row: first/last/min/max microseconds over
the window and the last/first drift ratio — so the perf history is readable
in a job's step summary instead of requiring an artifact download.

CI (bench-smoke) appends the output to ``$GITHUB_STEP_SUMMARY`` right after
the trend check. Exit is always 0 for an empty or missing file: the first
run on a branch has no trajectory yet, and a report must never gate.

``--annotate`` switches the output from markdown to GitHub workflow
commands on stdout (so it must go to the job log, *not* the step-summary
redirect): one ``::warning`` per row whose last/first drift exceeds
``--drift-threshold`` (default 1.5x), upgraded to ``::error`` when the
drift also held in the previous run — two consecutive drifted rows is a
trend, not timer noise. ``::error`` alone still exits 0 (annotations on a
PR inform, the trend gate in check_regression decides); add ``--strict``
(nightly) to exit 1 on any persistent drift.

Run: ``python -m benchmarks.trajectory_report BENCH_trajectory.jsonl
[--limit 20] [--top 40] [--annotate [--strict]]``
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List


def load_rows(path: str) -> List[dict]:
    """Parse the JSONL window, skipping lines that fail to parse (a torn
    append from a cancelled run must not take the whole report down)."""
    out = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    doc = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if isinstance(doc, dict) and isinstance(doc.get("rows"), dict):
                    out.append(doc)
    except OSError:
        return []
    return out


def _fmt_us(v: float) -> str:
    return f"{v:,.0f}"


def render(runs: List[dict], *, top: int = 40) -> str:
    """Markdown report for a window of trajectory rows (oldest first)."""
    if not runs:
        return ("### Perf trajectory\n\n"
                "No trajectory rows yet (first run on this branch?).\n")
    latest = runs[-1]
    sha = (latest.get("sha") or "")[:9]
    lines = [
        "### Perf trajectory",
        "",
        f"{len(runs)} run(s) in window — latest `{sha or 'local'}`"
        f" (wall {latest.get('wall_s', '?')}s,"
        f" completed={latest.get('completed')})",
        "",
        "| benchmark row | first us | last us | min us | max us | last/first |",
        "|---|---:|---:|---:|---:|---:|",
    ]
    # Per-name series over the window; report names present in the latest
    # run (ordered by drift so regressions float to the top), and call out
    # names that vanished from it — a disappeared row is itself a signal.
    series: Dict[str, List[float]] = {}
    for run in runs:
        for name, us in run["rows"].items():
            series.setdefault(name, []).append(float(us))
    current = set(latest["rows"])

    def drift(name: str) -> float:
        s = series[name]
        return (s[-1] / s[0]) if s[0] > 0 else 1.0

    reported = sorted(current, key=drift, reverse=True)
    for name in reported[:top]:
        s = series[name]
        ratio = f"{drift(name):.2f}x" if s[0] > 0 else "—"
        lines.append(
            f"| `{name}` | {_fmt_us(s[0])} | {_fmt_us(s[-1])} "
            f"| {_fmt_us(min(s))} | {_fmt_us(max(s))} | {ratio} |"
        )
    if len(reported) > top:
        lines.append(f"| … {len(reported) - top} more row(s) | | | | | |")
    gone = sorted(set(series) - current)
    if gone:
        lines += ["", "Rows no longer present in the latest run: "
                  + ", ".join(f"`{n}`" for n in gone)]
    lines.append("")
    return "\n".join(lines)


def _escape_cmd(text: str) -> str:
    """Escape a message for a GitHub ``::workflow-command::`` data field."""
    return (text.replace("%", "%25")
                .replace("\r", "%0D")
                .replace("\n", "%0A"))


def drift_findings(runs: List[dict],
                   threshold: float = 1.5) -> List[dict]:
    """Rows in the latest run whose last/first drift exceeds ``threshold``.

    Each finding: ``{"name", "ratio", "first", "last", "runs",
    "persistent"}``. ``persistent`` means the previous run's value was
    *also* past the threshold vs the window start — two consecutive
    drifted rows is a sustained regression, one is quite possibly a noisy
    timer on a shared CI box.
    """
    if len(runs) < 2:
        return []
    series: Dict[str, List[float]] = {}
    for run in runs:
        for name, us in run["rows"].items():
            series.setdefault(name, []).append(float(us))
    findings = []
    for name in sorted(runs[-1]["rows"]):
        s = series[name]
        if len(s) < 2 or s[0] <= 0:
            continue
        ratio = s[-1] / s[0]
        if ratio <= threshold:
            continue
        findings.append({
            "name": name,
            "ratio": ratio,
            "first": s[0],
            "last": s[-1],
            "runs": len(s),
            "persistent": len(s) >= 3 and s[-2] / s[0] > threshold,
        })
    findings.sort(key=lambda f: f["ratio"], reverse=True)
    return findings


def annotate(runs: List[dict], *, threshold: float = 1.5,
             strict: bool = False) -> int:
    """Print GitHub workflow-command annotations for drifted rows.

    Returns the exit code: nonzero only when ``strict`` and at least one
    drift is persistent (held for two consecutive runs).
    """
    findings = drift_findings(runs, threshold)
    persistent = 0
    for f in findings:
        level = "error" if f["persistent"] else "warning"
        persistent += f["persistent"]
        span = ("held for 2+ consecutive runs" if f["persistent"]
                else "latest run only")
        msg = (f"{f['name']} drifted {f['ratio']:.2f}x over "
               f"{f['runs']} runs ({_fmt_us(f['first'])}us -> "
               f"{_fmt_us(f['last'])}us, {span})")
        print(f"::{level} title=Perf trajectory drift::{_escape_cmd(msg)}")
    if not findings:
        print(f"# trajectory: no row drifted past {threshold:g}x "
              f"over {len(runs)} run(s)")
    return 1 if (strict and persistent) else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trajectory", help="rolling BENCH_trajectory.jsonl")
    ap.add_argument("--limit", type=int, default=20,
                    help="use only the newest N runs (0 = all)")
    ap.add_argument("--top", type=int, default=40,
                    help="report at most N benchmark rows, worst drift first")
    ap.add_argument("--annotate", action="store_true",
                    help="emit ::warning/::error workflow commands instead "
                         "of the markdown report (send to the job log, not "
                         "the step summary)")
    ap.add_argument("--drift-threshold", type=float, default=1.5,
                    help="last/first ratio above which a row is annotated")
    ap.add_argument("--strict", action="store_true",
                    help="with --annotate: exit 1 when a drift persisted "
                         "for two consecutive runs (nightly gate)")
    args = ap.parse_args(argv)
    runs = load_rows(args.trajectory)
    if args.limit > 0:
        runs = runs[-args.limit:]
    if args.annotate:
        return annotate(runs, threshold=args.drift_threshold,
                        strict=args.strict)
    sys.stdout.write(render(runs, top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
