"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json, prints the per-cell three-term roofline,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, and flags the three most
interesting hillclimb cells (worst roofline fraction / most collective-bound
/ most paper-representative).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_cells(mesh: str = "single") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "arch" in rec:  # skip the enterprise serve record (own schema)
            cells.append(rec)
    return cells


def fraction_of_roofline(cell: Dict) -> float:
    """MODEL_FLOPS-throughput fraction if the step ran at its dominant bound:
    (model_flops / bound_time) / (chips · peak)."""
    r = cell.get("roofline", {})
    bound = r.get("bound_s", 0)
    if not bound:
        return 0.0
    from repro.launch import hw

    return (cell["model_flops"] / bound) / (cell["chips"] * hw.PEAK_FLOPS_BF16)


def table(mesh: str = "single") -> str:
    cells = load_cells(mesh)
    rows = [
        "| arch | shape | status | compute_s | memory_s | collective_s | "
        "dominant | model/HLO flops | roofline frac | mem/dev GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("status") != "ok":
            rows.append(
                f"| {c['arch']} | {c['shape']} | {c.get('status')} | - | - | - | - | - | - | - |"
            )
            continue
        r = c["roofline"]
        ratio = c.get("model_vs_hlo_flops") or 0
        mem = c.get("memory", {})
        dev_gb = (mem.get("argument_size_in_bytes", 0)
                  + mem.get("temp_size_in_bytes", 0)) / 1e9
        rows.append(
            f"| {c['arch']} | {c['shape']} | ok | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} | "
            f"{ratio:.3f} | {fraction_of_roofline(c):.3f} | {dev_gb:.1f} |"
        )
    return "\n".join(rows)


def pick_hillclimb_cells(mesh: str = "single") -> Dict[str, Dict]:
    cells = [c for c in load_cells(mesh) if c.get("status") == "ok"]
    if not cells:
        return {}
    worst = min(cells, key=fraction_of_roofline)
    coll = max(cells, key=lambda c: c["roofline"]["collective_s"]
               / max(c["roofline"]["bound_s"], 1e-12))
    return {
        "worst_fraction": {"arch": worst["arch"], "shape": worst["shape"],
                           "frac": fraction_of_roofline(worst)},
        "most_collective_bound": {"arch": coll["arch"], "shape": coll["shape"],
                                  "coll_s": coll["roofline"]["collective_s"]},
        # most representative of the paper: the sparse-ranking serving shape
        # (decode against a huge output space) on the largest-vocab arch
        "paper_representative": {"arch": "seamless-m4t-large-v2",
                                 "shape": "decode_32k",
                                 "why": "256k-label output ranking at decode"},
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args(argv)
    print(table(args.mesh))
    print()
    print("hillclimb candidates:", json.dumps(pick_hillclimb_cells(args.mesh), indent=1))


if __name__ == "__main__":
    main()
