"""Paper Figure 5: MSCM vs a NapkinXC-style reference implementation.

NapkinXC's online inference does a hash-map lookup *per column* (paper §4
item 3: "implemented on a per-column basis"). The TPU analogue of per-column
random access is our vanilla per-column searchsorted baseline; the MSCM side
is the chunked searchsorted/dense-lookup variant. The figure's claim — one
traversal per chunk beats one per column by ~an order of magnitude at larger
branching — is what this benchmark checks.
"""

from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import build_benchmark_tree, csv_line, ell_queries, time_fn
from repro.data.xmr_data import PAPER_SHAPES, scaled_shape


def run(datasets=("eurlex-4k", "wiki10-31k"), *, branching=32,
        max_labels=65_536, n=16, seed=0) -> List[str]:
    lines = []
    for ds in datasets:
        shape = PAPER_SHAPES[ds]
        if shape.L > max_labels:
            shape = scaled_shape(shape, max_labels / shape.L)
        rng = np.random.default_rng(seed)
        tree = build_benchmark_tree(shape, branching, rng)
        xi, xv = ell_queries(shape, 1, rng, width=256)
        t_ref = time_fn(lambda: tree.infer(xi, xv, beam=10, topk=10,
                                           method="vanilla"), iters=n)
        t_mscm = time_fn(lambda: tree.infer(xi, xv, beam=10, topk=10,
                                            method="mscm_searchsorted"), iters=n)
        lines.append(csv_line(f"napkin/{ds}/per_column_ref", 1e6 * t_ref, "online"))
        lines.append(csv_line(f"napkin/{ds}/mscm", 1e6 * t_mscm,
                              f"gain={t_ref / t_mscm:.2f}x"))
    return lines


def main(argv=None) -> List[str]:
    lines = run()
    for l in lines:
        print(l)
    return lines


if __name__ == "__main__":
    main()
