"""Beyond-paper: MSCM vocab-tree head vs dense lm_head at LM decode time.

Sub-linear decode over the vocabulary — the paper's beam economics applied
to an LM output layer (DESIGN.md §4). Checks exactness (beam == C reproduces
the dense argmax) and measures the latency ratio at practical beam widths.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, time_fn
from repro.models.xmr_head import VocabTreeHead


def run(*, d=1024, vocab=65_536, branching=128, n=8, beams=(4, 16, 64),
        seed=0) -> List[str]:
    key = jax.random.PRNGKey(seed)
    # cluster-structured head (real LM heads are strongly clustered; random
    # directions have meaningless centroids and defeat any routing)
    c = (vocab + branching - 1) // branching
    k1, k2 = jax.random.split(key)
    centers = jax.random.normal(k1, (c, d)) / np.sqrt(d)
    noise = jax.random.normal(k2, (c, branching, d)) / np.sqrt(d)
    head_w = (centers[:, None, :] + 0.4 * noise).reshape(c * branching, d)[:vocab].T
    tree = VocabTreeHead.from_lm_head(head_w, branching)
    h = jax.random.normal(jax.random.PRNGKey(1), (n, d))

    dense = jax.jit(lambda hh: jnp.argmax(hh @ head_w, axis=1))
    t_dense = time_fn(dense, h)
    lines = [csv_line(f"xmr_head/dense_V{vocab}", 1e6 * t_dense / n, "full softmax")]

    # exactness at full beam
    from repro.models.xmr_head import greedy_token
    full = np.asarray(dense(h))
    exact = np.asarray(greedy_token(tree, h, beam=tree.n_clusters))
    agree_full = float((full == exact).mean())

    for beam in beams:
        fn = jax.jit(lambda hh, b=beam: greedy_token(tree, hh, beam=b))
        t = time_fn(fn, h)
        agree = float((np.asarray(fn(h)) == full).mean())
        lines.append(csv_line(
            f"xmr_head/tree_beam{beam}", 1e6 * t / n,
            f"speedup={t_dense / t:.2f}x,agree={agree:.3f},agree_fullbeam={agree_full:.3f}",
        ))
    return lines


def main(argv=None) -> List[str]:
    lines = run()
    for l in lines:
        print(l)
    return lines


if __name__ == "__main__":
    main()
