"""Shared benchmark utilities: tree builders + timing harness."""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import XMRTree
from repro.data.xmr_data import XMRShape, benchmark_queries
from repro.sparse import random_sparse_csc
from repro.trees.cluster import build_tree_structure


def build_benchmark_tree(shape: XMRShape, branching: int,
                         rng: np.random.Generator,
                         *, upper_nnz: int = 64,
                         sibling_overlap: float = 0.8) -> XMRTree:
    """Random model at the dataset's dimensions (latency depends only on the
    sparsity structure, not learned values — see data/xmr_data.py)."""
    struct = build_tree_structure(shape.L, branching)
    weights = []
    for size in struct.level_sizes:
        nnz = shape.col_nnz if size == struct.level_sizes[-1] else upper_nnz
        weights.append(
            random_sparse_csc(shape.d, size, nnz, rng,
                              sibling_groups=branching,
                              sibling_overlap=sibling_overlap)
        )
    return XMRTree.from_weight_matrices(weights, branching)


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            **kwargs) -> float:
    """Median wall seconds per call (blocking on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def ell_queries(shape: XMRShape, n: int, rng: np.random.Generator,
                width: int | None = None):
    x = benchmark_queries(shape, n, rng)
    xi, xv = x.to_ell(width)
    return jnp.asarray(xi), jnp.asarray(xv)


def csv_line(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
