"""HTTP gateway over the cross-process partition fleet (ISSUE 6).

Measures the network edge end to end: real worker subprocesses (P=2,
``partition_sync="pipelined"``) exchanging beams over the socket RPC, a
MicroBatcher coalescing, and the stdlib HTTP gateway in front. Two rows on
the CI-size tree:

* ``gateway-closed`` — closed loop: a small thread pool of HTTP clients
  keeps all queries in flight; wall -> QPS. The derived field carries
  ``gateway_parity`` — every score/id served over HTTP is **bitwise**
  identical to the in-process unpartitioned engine (the house exactness
  contract across JSON, the socket RPC, and the process boundary) — which
  ``benchmarks/check_regression.py`` gates hard.
* ``gateway-poisson`` — open loop: Poisson arrivals at ~2x the closed-loop
  rate against a bounded admission queue, reporting the HTTP status mix
  (200/429/504) the edge actually answered with.

``--chaos`` (ISSUE 7) runs the fault-injection leg instead: sustained HTTP
load through a supervised P=2 fleet, SIGKILL one worker mid-flight, and
measure time-to-recovery plus the ok/degraded/failed response mix. Its row
carries two structural flags ``check_regression`` gates hard:
``recovery_bounded`` (the supervisor respawned + re-shipped the worker
within the bound, with zero failed responses) and ``degraded_parity``
(every degraded response excluded the dead label range and matched the
full-fleet reference bitwise on the labels they share).

Run: ``python -m benchmarks.bench_gateway [--n 64] [--partitions 2] [--chaos]``
"""

from __future__ import annotations

import argparse
import json
import threading
import time
import urllib.error
import urllib.request
from typing import List

import numpy as np

from benchmarks.common import build_benchmark_tree, csv_line
from repro.data.xmr_data import PAPER_SHAPES, benchmark_queries, scaled_shape
from repro.serving import (
    AdmissionConfig,
    BatchPolicy,
    FleetConfig,
    MicroBatcher,
    PartitionConfig,
    Query,
    ServeConfig,
    ServingGateway,
    XMRServingEngine,
)
from repro.serving.fleet import FleetSupervisor, PartitionFleet


def _post(url: str, doc: dict, timeout: float = 300.0):
    req = urllib.request.Request(
        url + "/v1/query", data=json.dumps(doc).encode(),
        headers={"Content-Type": "application/json"},
    )
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, json.load(resp)
    except urllib.error.HTTPError as err:
        return err.code, json.load(err)


def _drive_closed(url: str, queries, n: int, workers: int = 4):
    """All queries in flight across a small client pool; returns
    (wall seconds, results indexed by qid, status counts)."""
    results = [None] * n
    counts: dict = {}
    lock = threading.Lock()
    it = iter(range(n))

    def client():
        while True:
            with lock:
                i = next(it, None)
            if i is None:
                return
            idx, val = queries.row(i % queries.shape[0])
            code, doc = _post(url, Query(idx=idx, val=val, qid=i).to_wire())
            with lock:
                counts[code] = counts.get(code, 0) + 1
                results[i] = (code, doc)

    threads = [threading.Thread(target=client) for _ in range(workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, results, counts


def _drive_poisson(url: str, queries, n: int, rate: float,
                   rng: np.random.Generator):
    """Open-loop Poisson arrivals, one daemon thread per request (the HTTP
    client blocks, the offered rate must not); returns status counts."""
    counts: dict = {}
    lock = threading.Lock()

    def fire(i):
        idx, val = queries.row(i % queries.shape[0])
        code, _ = _post(url, Query(idx=idx, val=val, qid=i).to_wire())
        with lock:
            counts[code] = counts.get(code, 0) + 1

    threads = []
    t_next = time.perf_counter()
    for i, gap in enumerate(rng.exponential(1.0 / rate, size=n)):
        t_next += gap
        lag = t_next - time.perf_counter()
        if lag > 1e-3:
            time.sleep(lag - 5e-4)
        while time.perf_counter() < t_next:
            pass
        t = threading.Thread(target=fire, args=(i,), daemon=True)
        t.start()
        threads.append(t)
    for t in threads:
        t.join(timeout=300)
    return counts


def run(
    *,
    n_queries: int = 64,
    partitions: int = 2,
    max_batch: int = 8,
    max_wait_ms: float = 2.0,
    max_labels: int = 4096,
    seed: int = 0,
) -> List[str]:
    shape = PAPER_SHAPES["eurlex-4k"]
    if shape.L > max_labels:
        shape = scaled_shape(shape, max_labels / shape.L)
    rng = np.random.default_rng(seed)
    tree = build_benchmark_tree(shape, 16, rng)
    queries = benchmark_queries(shape, n_queries, rng)

    # In-process unpartitioned reference: the bitwise anchor.
    ref_engine = XMRServingEngine(
        tree, ServeConfig(ell_width=256, max_batch=max(64, max_batch)))
    ref_s, ref_l = ref_engine.serve_batch(queries)

    engine = XMRServingEngine(
        tree,
        ServeConfig(
            ell_width=256, max_batch=max(64, max_batch),
            admission=AdmissionConfig(queue_depth=4 * max_batch,
                                      shed_policy="reject"),
            partition=PartitionConfig(partitions=partitions,
                                      partition_sync="pipelined"),
        ),
    )
    lines = []
    with PartitionFleet.launch(partitions, rpc_timeout_s=300.0) as fleet:
        fleet.attach(engine)
        with MicroBatcher(engine, BatchPolicy(max_batch, max_wait_ms)) as mb, \
                ServingGateway(mb, fleet=fleet) as gw:
            # warm the HTTP + fleet path outside the timed window
            idx, val = queries.row(0)
            _post(gw.url, Query(idx=idx, val=val, qid=-1).to_wire())

            wall, results, counts = _drive_closed(gw.url, queries, n_queries)
            parity = counts.get(200, 0) == n_queries
            for i, (code, doc) in enumerate(results):
                if code != 200:
                    parity = False
                    continue
                j = i % queries.shape[0]
                got_s = np.asarray(doc["scores"], np.float32)
                got_l = np.asarray(doc["ids"], np.int32)
                parity = parity and bool(
                    np.array_equal(got_l, ref_l[j])
                    and np.array_equal(got_s.view(np.uint32),
                                       ref_s[j].view(np.uint32))
                )
            closed_qps = n_queries / wall
            lines.append(
                csv_line(
                    f"{shape.name}/gateway/gateway-closed",
                    1e6 * wall / n_queries,
                    f"qps={closed_qps:.1f} partitions={partitions} "
                    f"gateway_parity={parity} http_200={counts.get(200, 0)}",
                )
            )

            # Open loop at ~2x the closed-loop rate: the bounded queue may
            # shed (429) — report the status mix the edge answered with.
            pois = _drive_poisson(gw.url, queries, n_queries,
                                  2.0 * closed_qps, rng)
            served = pois.get(200, 0)
            lines.append(
                csv_line(
                    f"{shape.name}/gateway/gateway-poisson",
                    1e6 * wall / n_queries,  # closed-loop anchor for scale
                    f"rate={2.0 * closed_qps:.0f}qps http_200={served} "
                    f"http_429={pois.get(429, 0)} "
                    f"http_504={pois.get(504, 0)} "
                    f"served_frac={served / n_queries:.2f}",
                )
            )
    return lines


def run_chaos(
    *,
    n_queries: int = 64,
    max_batch: int = 8,
    max_wait_ms: float = 2.0,
    max_labels: int = 4096,
    seed: int = 0,
    recovery_bound_s: float = 60.0,
) -> List[str]:
    """Kill a worker under open-loop load; measure recovery + response mix."""
    shape = PAPER_SHAPES["eurlex-4k"]
    if shape.L > max_labels:
        shape = scaled_shape(shape, max_labels / shape.L)
    rng = np.random.default_rng(seed)
    tree = build_benchmark_tree(shape, 16, rng)
    queries = benchmark_queries(shape, n_queries, rng)
    nq = queries.shape[0]

    # Full-fleet reference (== in-process by the house contract): the
    # bitwise anchor for both degraded and post-recovery responses.
    ref_engine = XMRServingEngine(
        tree, ServeConfig(ell_width=256, max_batch=max(64, max_batch)))
    ref_s, ref_l = ref_engine.serve_batch(queries)
    ref_maps = [
        {int(ref_l[i, k]): int(ref_s[i].view(np.uint32)[k])
         for k in range(ref_l.shape[1])}
        for i in range(nq)
    ]

    engine = XMRServingEngine(
        tree,
        ServeConfig(
            ell_width=256, max_batch=max(64, max_batch),
            partition=PartitionConfig(partitions=2,
                                      partition_sync="pipelined"),
            fleet=FleetConfig(
                degraded_policy="serve_partial", poll_interval_s=0.1,
                ping_timeout_s=5.0, suspect_after=1,
                backoff_base_s=0.1, restart_budget=5,
            ),
        ),
    )

    results: list = []   # (t, code, doc)
    errors: list = []
    lock = threading.Lock()
    stop = threading.Event()
    with PartitionFleet.launch(2, rpc_timeout_s=300.0) as fleet:
        fleet.attach(engine)
        dead_lo = int(engine.index.manifest.partitions[0].label_start)
        dead_hi = int(engine.index.manifest.partitions[0].label_end)
        with FleetSupervisor(fleet, engine.config.fleet) as sup, \
                MicroBatcher(engine, BatchPolicy(max_batch, max_wait_ms)) \
                as mb, ServingGateway(mb, fleet=fleet) as gw:

            def client(tid):
                i = 0
                while not stop.is_set():
                    qi = (tid + 3 * i) % nq
                    i += 1
                    idx, val = queries.row(qi)
                    try:
                        code, doc = _post(
                            gw.url, Query(idx=idx, val=val, qid=qi).to_wire(),
                            timeout=60.0)
                    except Exception as exc:  # a hang/refused conn is a fail
                        with lock:
                            errors.append(repr(exc))
                        return
                    with lock:
                        results.append((time.monotonic(), code, doc))

            threads = [threading.Thread(target=client, args=(t,), daemon=True)
                       for t in range(3)]
            for t in threads:
                t.start()
            deadline = time.monotonic() + 60.0
            while time.monotonic() < deadline:
                with lock:
                    if len(results) >= 8:
                        break
                time.sleep(0.05)

            t_kill = time.monotonic()
            fleet.handles[0].proc.kill()  # SIGKILL mid-flight
            recovery_s = float("inf")
            while time.monotonic() < t_kill + recovery_bound_s:
                st = sup.states()["worker0"]
                if st["state"] == "up" and st["restarts"] >= 1 \
                        and not fleet.down_pids():
                    recovery_s = time.monotonic() - t_kill
                    break
                time.sleep(0.05)
            restarts = sup.states()["worker0"]["restarts"]
            time.sleep(1.0)  # collect post-recovery traffic
            stop.set()
            for t in threads:
                t.join(timeout=120)

    ok = sum(1 for _, c, d in results if c == 200 and not d.get("degraded"))
    degraded = sum(1 for _, c, d in results
                   if c == 200 and d.get("degraded"))
    failed = len(errors) + sum(1 for _, c, _ in results if c != 200)

    parity = degraded > 0  # the kill must actually surface degraded traffic
    for _, code, doc in results:
        if code != 200:
            continue
        got_s = np.asarray(doc["scores"], np.float32).view(np.uint32)
        ref_map = ref_maps[doc["qid"]]
        if doc.get("degraded"):
            parity = parity and doc["missing_labels"] == [[dead_lo, dead_hi]]
            for k, label in enumerate(doc["ids"]):
                label = int(label)
                parity = parity and not (dead_lo <= label < dead_hi)
                if label in ref_map:  # shared labels must agree bitwise
                    parity = parity and int(got_s[k]) == ref_map[label]
        else:
            for k, label in enumerate(doc["ids"]):
                parity = parity and ref_map.get(int(label)) == int(got_s[k])

    bounded = recovery_s <= recovery_bound_s and restarts >= 1 and failed == 0
    return [
        csv_line(
            f"{shape.name}/gateway/gateway-chaos",
            1e6 * min(recovery_s, recovery_bound_s),  # recovery latency, us
            f"recovery_s={recovery_s:.2f} restarts={restarts} ok={ok} "
            f"degraded={degraded} failed={failed} "
            f"recovery_bounded={bounded} degraded_parity={parity}",
        )
    ]


def main(argv=None) -> List[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--partitions", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--max-labels", type=int, default=4096)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    ap.add_argument("--chaos", action="store_true",
                    help="fault-injection leg: kill a worker under load, "
                         "measure recovery + degraded/ok/failed mix")
    args = ap.parse_args(argv)
    if args.chaos:
        lines = run_chaos(
            n_queries=args.n,
            max_batch=args.max_batch,
            max_wait_ms=args.max_wait_ms,
            max_labels=args.max_labels,
        )
    else:
        lines = run(
            n_queries=args.n,
            partitions=args.partitions,
            max_batch=args.max_batch,
            max_wait_ms=args.max_wait_ms,
            max_labels=args.max_labels,
        )
    for line in lines:
        print(line)
    if args.json:
        import sys as sys_mod

        from benchmarks.run import _parse_rows

        with open(args.json, "w") as f:
            json.dump(
                {"rows": _parse_rows(lines), "completed": True}, f, indent=2
            )
        print(f"# wrote {args.json}", file=sys_mod.stderr)
    return lines


if __name__ == "__main__":
    main()
