"""HTTP gateway over the cross-process partition fleet (ISSUE 6).

Measures the network edge end to end: real worker subprocesses (P=2,
``partition_sync="pipelined"``) exchanging beams over the socket RPC, a
MicroBatcher coalescing, and the stdlib HTTP gateway in front. Two rows on
the CI-size tree:

* ``gateway-closed`` — closed loop: a small thread pool of HTTP clients
  keeps all queries in flight; wall -> QPS. The derived field carries
  ``gateway_parity`` — every score/id served over HTTP is **bitwise**
  identical to the in-process unpartitioned engine (the house exactness
  contract across JSON, the socket RPC, and the process boundary) — which
  ``benchmarks/check_regression.py`` gates hard.
* ``gateway-poisson`` — open loop: Poisson arrivals at ~2x the closed-loop
  rate against a bounded admission queue, reporting the HTTP status mix
  (200/429/504) the edge actually answered with.

Run: ``python -m benchmarks.bench_gateway [--n 64] [--partitions 2]``
"""

from __future__ import annotations

import argparse
import json
import threading
import time
import urllib.error
import urllib.request
from typing import List

import numpy as np

from benchmarks.common import build_benchmark_tree, csv_line
from repro.data.xmr_data import PAPER_SHAPES, benchmark_queries, scaled_shape
from repro.serving import (
    AdmissionConfig,
    BatchPolicy,
    MicroBatcher,
    PartitionConfig,
    Query,
    ServeConfig,
    ServingGateway,
    XMRServingEngine,
)
from repro.serving.fleet import PartitionFleet


def _post(url: str, doc: dict, timeout: float = 300.0):
    req = urllib.request.Request(
        url + "/v1/query", data=json.dumps(doc).encode(),
        headers={"Content-Type": "application/json"},
    )
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, json.load(resp)
    except urllib.error.HTTPError as err:
        return err.code, json.load(err)


def _drive_closed(url: str, queries, n: int, workers: int = 4):
    """All queries in flight across a small client pool; returns
    (wall seconds, results indexed by qid, status counts)."""
    results = [None] * n
    counts: dict = {}
    lock = threading.Lock()
    it = iter(range(n))

    def client():
        while True:
            with lock:
                i = next(it, None)
            if i is None:
                return
            idx, val = queries.row(i % queries.shape[0])
            code, doc = _post(url, Query(idx=idx, val=val, qid=i).to_wire())
            with lock:
                counts[code] = counts.get(code, 0) + 1
                results[i] = (code, doc)

    threads = [threading.Thread(target=client) for _ in range(workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, results, counts


def _drive_poisson(url: str, queries, n: int, rate: float,
                   rng: np.random.Generator):
    """Open-loop Poisson arrivals, one daemon thread per request (the HTTP
    client blocks, the offered rate must not); returns status counts."""
    counts: dict = {}
    lock = threading.Lock()

    def fire(i):
        idx, val = queries.row(i % queries.shape[0])
        code, _ = _post(url, Query(idx=idx, val=val, qid=i).to_wire())
        with lock:
            counts[code] = counts.get(code, 0) + 1

    threads = []
    t_next = time.perf_counter()
    for i, gap in enumerate(rng.exponential(1.0 / rate, size=n)):
        t_next += gap
        lag = t_next - time.perf_counter()
        if lag > 1e-3:
            time.sleep(lag - 5e-4)
        while time.perf_counter() < t_next:
            pass
        t = threading.Thread(target=fire, args=(i,), daemon=True)
        t.start()
        threads.append(t)
    for t in threads:
        t.join(timeout=300)
    return counts


def run(
    *,
    n_queries: int = 64,
    partitions: int = 2,
    max_batch: int = 8,
    max_wait_ms: float = 2.0,
    max_labels: int = 4096,
    seed: int = 0,
) -> List[str]:
    shape = PAPER_SHAPES["eurlex-4k"]
    if shape.L > max_labels:
        shape = scaled_shape(shape, max_labels / shape.L)
    rng = np.random.default_rng(seed)
    tree = build_benchmark_tree(shape, 16, rng)
    queries = benchmark_queries(shape, n_queries, rng)

    # In-process unpartitioned reference: the bitwise anchor.
    ref_engine = XMRServingEngine(
        tree, ServeConfig(ell_width=256, max_batch=max(64, max_batch)))
    ref_s, ref_l = ref_engine.serve_batch(queries)

    engine = XMRServingEngine(
        tree,
        ServeConfig(
            ell_width=256, max_batch=max(64, max_batch),
            admission=AdmissionConfig(queue_depth=4 * max_batch,
                                      shed_policy="reject"),
            partition=PartitionConfig(partitions=partitions,
                                      partition_sync="pipelined"),
        ),
    )
    lines = []
    with PartitionFleet.launch(partitions, rpc_timeout_s=300.0) as fleet:
        fleet.attach(engine)
        with MicroBatcher(engine, BatchPolicy(max_batch, max_wait_ms)) as mb, \
                ServingGateway(mb, fleet=fleet) as gw:
            # warm the HTTP + fleet path outside the timed window
            idx, val = queries.row(0)
            _post(gw.url, Query(idx=idx, val=val, qid=-1).to_wire())

            wall, results, counts = _drive_closed(gw.url, queries, n_queries)
            parity = counts.get(200, 0) == n_queries
            for i, (code, doc) in enumerate(results):
                if code != 200:
                    parity = False
                    continue
                j = i % queries.shape[0]
                got_s = np.asarray(doc["scores"], np.float32)
                got_l = np.asarray(doc["ids"], np.int32)
                parity = parity and bool(
                    np.array_equal(got_l, ref_l[j])
                    and np.array_equal(got_s.view(np.uint32),
                                       ref_s[j].view(np.uint32))
                )
            closed_qps = n_queries / wall
            lines.append(
                csv_line(
                    f"{shape.name}/gateway/gateway-closed",
                    1e6 * wall / n_queries,
                    f"qps={closed_qps:.1f} partitions={partitions} "
                    f"gateway_parity={parity} http_200={counts.get(200, 0)}",
                )
            )

            # Open loop at ~2x the closed-loop rate: the bounded queue may
            # shed (429) — report the status mix the edge answered with.
            pois = _drive_poisson(gw.url, queries, n_queries,
                                  2.0 * closed_qps, rng)
            served = pois.get(200, 0)
            lines.append(
                csv_line(
                    f"{shape.name}/gateway/gateway-poisson",
                    1e6 * wall / n_queries,  # closed-loop anchor for scale
                    f"rate={2.0 * closed_qps:.0f}qps http_200={served} "
                    f"http_429={pois.get(429, 0)} "
                    f"http_504={pois.get(504, 0)} "
                    f"served_frac={served / n_queries:.2f}",
                )
            )
    return lines


def main(argv=None) -> List[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--partitions", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--max-labels", type=int, default=4096)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args(argv)
    lines = run(
        n_queries=args.n,
        partitions=args.partitions,
        max_batch=args.max_batch,
        max_wait_ms=args.max_wait_ms,
        max_labels=args.max_labels,
    )
    for line in lines:
        print(line)
    if args.json:
        import sys as sys_mod

        from benchmarks.run import _parse_rows

        with open(args.json, "w") as f:
            json.dump(
                {"rows": _parse_rows(lines), "completed": True}, f, indent=2
            )
        print(f"# wrote {args.json}", file=sys_mod.stderr)
    return lines


if __name__ == "__main__":
    main()
