"""Cross-process fleet + HTTP gateway: exactness, errors, failure paths.

The integration test is the ISSUE 6 acceptance pin: a P=2 partition fleet
(real worker subprocesses, ``partition_sync="pipelined"``) behind the HTTP
gateway serves results **bitwise-identical** to the in-process
unpartitioned engine — through JSON, over a socket — and a killed worker
surfaces as a typed 503 within the RPC timeout, never a hang.

The gateway's error→status mapping (429/504/400) is pinned separately on a
cheap in-process engine so the contract is exercised without subprocesses.
"""

import json
import socket
import struct
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import XMRTree
from repro.serving import (
    AdmissionPolicy,
    BatchPolicy,
    FleetConfig,
    MicroBatcher,
    PartitionConfig,
    Query,
    ServeConfig,
    ServingGateway,
    XMRServingEngine,
)
from repro.sparse import random_sparse_csr
from tests.conftest import make_tree_weights


def _post(url: str, doc: dict, timeout: float = 120.0):
    """POST JSON, returning (http_status, body_doc) for any status code."""
    req = urllib.request.Request(
        url + "/v1/query", data=json.dumps(doc).encode(),
        headers={"Content-Type": "application/json"},
    )
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, json.load(resp)
    except urllib.error.HTTPError as err:
        return err.code, json.load(err)


def _get(url: str, path: str, timeout: float = 120.0):
    try:
        with urllib.request.urlopen(url + path, timeout=timeout) as resp:
            return resp.status, json.load(resp)
    except urllib.error.HTTPError as err:
        return err.code, json.load(err)


@pytest.fixture(scope="module")
def small_setup():
    rng = np.random.default_rng(11)
    d, B = 200, 8
    ws = make_tree_weights(rng, d, [8, 64, 512], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    engine = XMRServingEngine(tree, ServeConfig(ell_width=32, max_batch=64))
    queries = random_sparse_csr(20, d, 15, rng)
    ref_s, ref_l = engine.serve_batch(queries)
    return tree, engine, queries, ref_s, ref_l


# ---------------------------------------------------------------------------
# multi-process integration: fleet + gateway, bitwise + typed 503
# ---------------------------------------------------------------------------

def test_fleet_gateway_bitwise_and_worker_failure(small_setup):
    from repro.serving.fleet import PartitionFleet

    tree, _, queries, ref_s, ref_l = small_setup
    engine = XMRServingEngine(
        tree,
        ServeConfig(
            ell_width=32, max_batch=64,
            partition=PartitionConfig(partitions=2,
                                      partition_sync="pipelined"),
            # Pin the pre-supervision semantics: a dead worker fails
            # queries typed (serve_partial is covered in test_chaos.py).
            fleet=FleetConfig(degraded_policy="reject"),
        ),
    )
    with PartitionFleet.launch(2, rpc_timeout_s=120.0) as fleet:
        fleet.attach(engine)
        assert engine.planner.transport is fleet
        assert fleet.degraded_policy == "reject"  # synced from the config
        with MicroBatcher(engine, BatchPolicy(max_batch=8, max_wait_ms=5.0)) \
                as mb, ServingGateway(mb, fleet=fleet) as gw:
            # healthy fleet
            code, doc = _get(gw.url, "/healthz")
            assert code == 200 and doc["status"] == "ok"
            assert doc["workers"] == {"worker0": True, "worker1": True}

            # every query served over HTTP is bitwise the in-process result
            for i in range(queries.shape[0]):
                idx, val = queries.row(i)
                code, doc = _post(
                    gw.url, Query(idx=idx, val=val, qid=i).to_wire()
                )
                assert code == 200 and doc["status"] == "ok", doc
                assert doc["qid"] == i and doc["v"] == 1
                got_s = np.asarray(doc["scores"], np.float32)
                got_l = np.asarray(doc["ids"], np.int32)
                assert np.array_equal(got_l, ref_l[i])
                assert np.array_equal(
                    got_s.view(np.uint32), ref_s[i].view(np.uint32)
                ), f"query {i} not bitwise"
                assert doc["timing"]["e2e_ms"] > 0

            # metrics reflect the served traffic
            code, doc = _get(gw.url, "/metrics")
            assert code == 200
            assert doc["count"] == queries.shape[0]
            assert len(doc["partition_occupancy"]) == 2

            # kill one worker: typed 503 within the timeout, not a hang
            fleet.handles[0].kill()
            idx, val = queries.row(0)
            t0 = time.perf_counter()
            code, doc = _post(gw.url, Query(idx=idx, val=val, qid=99).to_wire())
            elapsed = time.perf_counter() - t0
            assert code == 503, doc
            assert doc["status"] == "worker_unavailable"
            assert "worker0" in doc["detail"]
            assert elapsed < 60.0  # bounded: EOF beats the RPC timeout

            # health degrades, naming the dead worker
            code, doc = _get(gw.url, "/healthz")
            assert code == 503 and doc["status"] == "degraded"
            assert doc["workers"]["worker0"] is False
            assert doc["workers"]["worker1"] is True


def test_fleet_transport_requires_pipelined(small_setup):
    from repro.index import BeamTransport

    tree, *_ = small_setup

    class _Dummy(BeamTransport):
        @property
        def n_partitions(self):
            return 2

    eng_level = XMRServingEngine(
        tree, ServeConfig(ell_width=32,
                          partition=PartitionConfig(partitions=2)),
    )
    with pytest.raises(ValueError, match="pipelined"):
        eng_level.planner.set_transport(_Dummy())

    eng_cache = XMRServingEngine(
        tree,
        ServeConfig(ell_width=32,
                    partition=PartitionConfig(
                        partitions=2, partition_sync="pipelined",
                        beam_cache=4)),
    )
    with pytest.raises(ValueError, match="beam_cache"):
        eng_cache.planner.set_transport(_Dummy())

    eng = XMRServingEngine(
        tree,
        ServeConfig(ell_width=32,
                    partition=PartitionConfig(partitions=3,
                                              partition_sync="pipelined")),
    )
    with pytest.raises(ValueError, match="partitions"):
        eng.planner.set_transport(_Dummy())


# ---------------------------------------------------------------------------
# gateway error mapping on a cheap in-process engine
# ---------------------------------------------------------------------------

def test_gateway_maps_overloaded_to_429(small_setup):
    _, engine, queries, ref_s, ref_l = small_setup
    real_run = engine._run

    def slow_run(xi, xv, tier=0):
        time.sleep(0.05)  # stretch device time so the queue must fill
        return real_run(xi, xv, tier=tier)

    engine._run = slow_run
    try:
        mb = MicroBatcher(
            engine, BatchPolicy(max_batch=1, max_wait_ms=0.5),
            admission=AdmissionPolicy(max_queue_depth=1),
            warmup_on_start=False,
        ).start()
        with ServingGateway(mb) as gw:
            codes, bodies = [], []
            lock = threading.Lock()

            def fire(i):
                idx, val = queries.row(i % queries.shape[0])
                code, doc = _post(
                    gw.url, Query(idx=idx, val=val, qid=i).to_wire()
                )
                with lock:
                    codes.append(code)
                    bodies.append(doc)

            threads = [threading.Thread(target=fire, args=(i,))
                       for i in range(12)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=120)
        mb.stop()
    finally:
        engine._run = real_run
    assert codes.count(429) >= 1, codes
    assert codes.count(200) >= 1, codes
    for code, doc in zip(codes, bodies):
        if code == 429:
            assert doc["status"] == "overloaded" and "shed" in doc["detail"]
        else:
            assert code == 200
            i = doc["qid"] % queries.shape[0]
            assert np.array_equal(np.asarray(doc["ids"], np.int32), ref_l[i])


def test_gateway_maps_deadline_to_504(small_setup):
    _, engine, queries, *_ = small_setup
    with MicroBatcher(engine, BatchPolicy(max_batch=4, max_wait_ms=1.0),
                      warmup_on_start=False) as mb, ServingGateway(mb) as gw:
        idx, val = queries.row(0)
        q = Query(idx=idx, val=val, qid=1, deadline_ms=0.0)  # born expired
        code, doc = _post(gw.url, q.to_wire())
        assert code == 504, doc
        assert doc["status"] == "deadline_exceeded"
        assert "deadline exceeded" in doc["detail"]


def test_gateway_rejects_bad_requests(small_setup):
    _, engine, queries, *_ = small_setup
    with MicroBatcher(engine, warmup_on_start=False) as mb, \
            ServingGateway(mb) as gw:
        # malformed JSON
        code, doc = _post(gw.url, {"v": 1})
        assert code == 400 and doc["status"] == "invalid"
        # wrong wire version
        idx, val = queries.row(0)
        wire = Query(idx=idx, val=val).to_wire()
        wire["v"] = 99
        code, doc = _post(gw.url, wire)
        assert code == 400 and "wire version" in doc["detail"]
        # unknown paths
        assert _get(gw.url, "/nope")[0] == 404
        # healthz without a fleet
        code, doc = _get(gw.url, "/healthz")
        assert code == 200 and "workers" not in doc


# ---------------------------------------------------------------------------
# RPC failure semantics: locking, poisoning, corrupt frames, orphan reaping
# ---------------------------------------------------------------------------

class _FakeWorker:
    """Minimal frame server speaking the fleet RPC protocol.

    Replies to every op with ``{"ok": True, "op": <op>, "seq": <n>}`` where
    ``seq`` counts requests *served* — letting tests detect a stale reply
    being consumed as a fresh one. ``{"sleep": s}`` in a request header
    delays the reply past a client timeout. Like the real worker, it goes
    back to ``accept()`` when a client connection drops.
    """

    def __init__(self):
        from repro.serving.fleet.rpc import recv_frame, send_frame

        self._recv_frame, self._send_frame = recv_frame, send_frame
        self.srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.srv.bind(("127.0.0.1", 0))
        self.srv.listen(4)
        self.port = self.srv.getsockname()[1]
        self.seq = 0
        self.thread = threading.Thread(target=self._serve, daemon=True)
        self.thread.start()

    def _serve(self):
        while True:
            try:
                conn, _ = self.srv.accept()
            except OSError:
                return  # server closed
            try:
                while True:
                    header, _ = self._recv_frame(conn)
                    delay = float(header.get("sleep", 0.0))
                    if delay:
                        time.sleep(delay)
                    seq, self.seq = self.seq, self.seq + 1
                    self._send_frame(
                        conn,
                        {"ok": True, "op": header.get("op"), "seq": seq},
                        [np.asarray([seq], np.int64)] * 2,
                    )
            except (EOFError, OSError):
                pass
            finally:
                conn.close()

    def close(self):
        try:
            self.srv.close()
        except OSError:
            pass


def test_rpc_corrupt_frame_is_typed_and_closes_connection():
    from repro.serving.admission import WorkerUnavailable
    from repro.serving.fleet.rpc import MAX_FRAME_BYTES, WorkerConnection
    from repro.serving.fleet.rpc import recv_frame

    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)

    def serve():
        conn, _ = srv.accept()
        try:
            recv_frame(conn)  # consume the client's ping
            # reply with an absurd length prefix: must be refused, not
            # allocated
            conn.sendall(struct.pack(">Q", MAX_FRAME_BYTES + 1))
            time.sleep(1.0)
        finally:
            conn.close()
            srv.close()

    threading.Thread(target=serve, daemon=True).start()
    conn = WorkerConnection(
        "127.0.0.1", srv.getsockname()[1], timeout_s=10.0, name="w0"
    )
    with pytest.raises(WorkerUnavailable, match="corrupt frame"):
        conn.call("ping")
    # the desynced stream was closed: further use fails fast and typed
    with pytest.raises(WorkerUnavailable, match="connection closed"):
        conn.send("ping")
    with pytest.raises(WorkerUnavailable, match="connection closed"):
        conn.recv("ping")


def test_rpc_lock_serializes_concurrent_callers():
    """Health-check pings racing query traffic must not interleave frames."""
    from repro.serving.fleet.rpc import WorkerConnection

    w = _FakeWorker()
    conn = WorkerConnection("127.0.0.1", w.port, timeout_s=30.0, name="w0")
    errors = []

    def hammer(op, n):
        try:
            for _ in range(n):
                header, arrays = conn.call(op)
                assert header["op"] == op, f"{op} got {header['op']} reply"
                assert len(arrays) == 2
        except Exception as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=hammer, args=(op, 50))
               for op in ("begin", "ping", "step")]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    conn.close()
    w.close()
    assert not errors, errors


def test_fanout_failure_resets_streams_no_stale_replies():
    """A mid-exchange timeout must poison the fleet's streams: the next
    exchange gets fresh replies, never the abandoned batch's buffered one
    (identical shape — would be silently wrong, not an error)."""
    from repro.serving.admission import WorkerUnavailable
    from repro.serving.fleet import PartitionFleet, WorkerHandle
    from repro.serving.fleet.rpc import WorkerConnection

    a, b = _FakeWorker(), _FakeWorker()
    fleet = PartitionFleet([
        WorkerHandle(WorkerConnection(
            "127.0.0.1", w.port, timeout_s=1.0, name=f"w{i}"
        ))
        for i, w in enumerate((a, b))
    ])
    # worker0 exceeds the per-call timeout; worker1 replies promptly, so its
    # seq-0 reply is left buffered on the abandoned stream
    with pytest.raises(WorkerUnavailable):
        fleet._exchange("echo", [{"sleep": 1.5}, {}], [[], []])
    time.sleep(1.2)  # let worker0 finish the abandoned request + re-accept
    replies = fleet._exchange("echo", [{}, {}], [[], []])
    assert [h["seq"] for h, _ in replies] == [1, 1], (
        "stale reply from the aborted exchange was consumed"
    )
    for h in fleet.handles:
        h.conn.close()
    a.close()
    b.close()


def test_launch_workers_reaps_all_procs_on_failure(monkeypatch):
    """A failure at worker i must not orphan procs i..n-1."""
    import repro.serving.fleet.launcher as launcher_mod
    from repro.serving.admission import WorkerUnavailable

    spawned = []
    real_popen = launcher_mod.subprocess.Popen

    def tracking_popen(*args, **kwargs):
        proc = real_popen(*args, **kwargs)
        spawned.append(proc)
        return proc

    def failing_announce(proc, timeout_s, name):
        raise WorkerUnavailable(name, "launch", "forced announce failure")

    monkeypatch.setattr(launcher_mod.subprocess, "Popen", tracking_popen)
    monkeypatch.setattr(launcher_mod, "_read_announce", failing_announce)
    with pytest.raises(WorkerUnavailable):
        launcher_mod.launch_workers(3)
    assert len(spawned) == 3
    for proc in spawned:
        assert proc.poll() is not None, "worker process orphaned"


def test_gateway_after_shutdown_is_unavailable(small_setup):
    _, engine, queries, *_ = small_setup
    mb = MicroBatcher(engine, warmup_on_start=False).start()
    gw = ServingGateway(mb).start()
    try:
        mb.stop()  # closed queue: requests can no longer be admitted
        idx, val = queries.row(0)
        code, doc = _post(gw.url, Query(idx=idx, val=val).to_wire())
        assert code == 503, doc
        code, doc = _get(gw.url, "/healthz")
        assert code == 503 and doc["status"] == "closed"
    finally:
        gw.close()
