"""Sharded XMR inference == single-device inference (8 host devices).

Runs in a subprocess so the 8-device XLA flag never leaks into other tests.
"""

import json
import os
import subprocess
import sys


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.core import XMRTree
from repro.core.distributed import shard_leaf_level, sharded_infer
from repro.sparse import random_sparse_csc, random_sparse_csr

rng = np.random.default_rng(5)
d, B = 120, 8
Ws = [random_sparse_csc(d, 8, 10, rng, sibling_groups=B),
      random_sparse_csc(d, 64, 10, rng, sibling_groups=B),
      random_sparse_csc(d, 512, 10, rng, sibling_groups=B)]
tree = XMRTree.from_weight_matrices(Ws, B)
X = random_sparse_csr(16, d, 15, rng)
xi, xv = X.to_ell()
xi, xv = jnp.asarray(xi), jnp.asarray(xv)

ref_s, ref_l = tree.infer(xi, xv, beam=10, topk=5)

mesh = jax.make_mesh((4, 2), ("data", "model"))
upper, leaf = shard_leaf_level(tree, mesh)
with mesh:
    s, l = sharded_infer(tree, upper, leaf, xi, xv, mesh, beam=10, topk=5)

labels_match = bool((np.asarray(l) == np.asarray(ref_l)).all())
scores_close = bool(np.allclose(np.asarray(s), np.asarray(ref_s), rtol=1e-5, atol=1e-6))
print(json.dumps({"labels_match": labels_match, "scores_close": scores_close,
                  "n_devices": len(jax.devices())}))
"""


def test_sharded_inference_matches_single_device():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 8
    assert res["labels_match"], res
    assert res["scores_close"], res
