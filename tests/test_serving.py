"""Serving subsystem: vectorized marshalling, coalescing triggers, padding.

Pins the properties the async engine must not break:
1. the vectorized CSR→ELL path equals the per-row loop oracle (including a
   truncation-parity property sweep for width < nnz);
2. the RequestQueue fires on exactly the documented triggers
   (size / deadline / close-flush);
3. bucket padding is invisible — micro-batched results are bitwise-identical
   to per-query serving;
4. MicroBatcher.start() pre-warms every jit bucket (no compile in the
   serving path) and a ready batch dispatches before the worker blocks on
   the in-flight one;
5. a dispatch fault fails only its own batch — every future resolves
   exactly once and the queue keeps serving;
6. latency accounting stays honest: amortized batch averages never enter
   the per-query percentile series;
7. the ``queue_depth="auto"`` capacity probe is total — zero/slow drain
   rates and a missing deadline all resolve to a sane bound — and
   ``stop()`` during an in-flight probe waits it out instead of closing
   the queue under it.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import XMRTree
from repro.core.tree import _tree_infer
from repro.serving import (
    AdmissionPolicy,
    BatchPolicy,
    MicroBatcher,
    ServeConfig,
    XMRServingEngine,
)
from repro.serving.batcher import (
    TRIGGER_DEADLINE,
    TRIGGER_FLUSH,
    TRIGGER_SIZE,
    RequestQueue,
    _InFlight,
    _Request,
)
from repro.sparse import (
    random_sparse_csr,
    rows_to_ell,
    rows_to_ell_loop,
)
from tests.conftest import make_tree_weights

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# 1. vectorized CSR→ELL vs the per-row loop oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [None, 1, 4, 64])
def test_rows_to_ell_matches_loop(rng, width):
    x = random_sparse_csr(40, 300, 12, rng)
    for rows in (
        np.arange(40),
        np.array([0, 39, 7, 7, 20]),   # arbitrary order, duplicates
        np.zeros(0, np.int64),         # empty selection
    ):
        vi, vv = rows_to_ell(x, rows, width)
        li, lv = rows_to_ell_loop(x, rows, width)
        np.testing.assert_array_equal(vi, li)
        np.testing.assert_array_equal(vv, lv)


def test_rows_to_ell_truncation_and_sentinel(rng):
    x = random_sparse_csr(8, 100, 20, rng)
    w = 5
    idx, val = rows_to_ell(x, np.arange(8), w)
    assert idx.shape == (8, w) and val.shape == (8, w)
    for i in range(8):
        ri, rv = x.row(i)
        k = min(len(ri), w)
        np.testing.assert_array_equal(idx[i, :k], ri[:k])
        assert (idx[i, k:] == 100).all() and (val[i, k:] == 0).all()


def test_to_ell_uses_vectorized_path(rng):
    x = random_sparse_csr(25, 200, 10, rng)
    vi, vv = x.to_ell()
    li, lv = rows_to_ell_loop(x, np.arange(25), None)
    np.testing.assert_array_equal(vi, li)
    np.testing.assert_array_equal(vv, lv)


def test_rows_to_ell_empty_rows(rng):
    from repro.sparse.csr import CSR

    x = CSR.from_dense(np.zeros((3, 10), np.float32))
    idx, val = rows_to_ell(x, np.arange(3), 4)
    assert (idx == 10).all() and (val == 0).all()


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(
        n=st.integers(1, 24),
        d=st.integers(4, 300),
        nnz=st.integers(1, 40),
        width=st.integers(1, 12),
        seed=st.integers(0, 2**16),
    )
    def test_rows_to_ell_truncation_parity_property(n, d, nnz, width, seed):
        """Vectorized truncation (width < nnz) matches the per-row loop
        oracle for arbitrary shapes, widths, and row selections."""
        rng = np.random.default_rng(seed)
        x = random_sparse_csr(n, d, min(nnz, d), rng)
        sel = rng.integers(0, n, size=rng.integers(0, 2 * n))  # dups, any order
        vi, vv = rows_to_ell(x, sel, width)
        li, lv = rows_to_ell_loop(x, sel, width)
        np.testing.assert_array_equal(vi, li)
        np.testing.assert_array_equal(vv, lv)
        # truncation semantics: never wider than width, sentinel-padded tails
        assert vi.shape == (len(sel), width)
        tail_mask = vi == d
        assert (vv[tail_mask] == 0).all()
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[dev])")
    def test_rows_to_ell_truncation_parity_property():
        pass


# ---------------------------------------------------------------------------
# 2. RequestQueue coalescing triggers (tested directly, no worker thread)
# ---------------------------------------------------------------------------

def _req(t=None):
    from concurrent.futures import Future

    return _Request(
        idx=np.zeros(1, np.int32),
        val=np.zeros(1, np.float32),
        future=Future(),
        t_enqueue=time.perf_counter() if t is None else t,
    )


def test_size_trigger_fires_immediately():
    q = RequestQueue()
    for _ in range(20):
        q.put(_req())
    t0 = time.perf_counter()
    batch, trigger = q.next_batch(16, max_wait_s=10.0)
    assert trigger == TRIGGER_SIZE
    assert len(batch) == 16
    assert time.perf_counter() - t0 < 1.0  # did not wait for the deadline
    assert len(q) == 4


def test_deadline_trigger_fires_after_wait():
    q = RequestQueue()
    for _ in range(3):
        q.put(_req())
    t0 = time.perf_counter()
    batch, trigger = q.next_batch(16, max_wait_s=0.05)
    waited = time.perf_counter() - t0
    assert trigger == TRIGGER_DEADLINE
    assert len(batch) == 3
    assert waited >= 0.04  # held for the deadline, not a spurious wakeup


def test_close_flushes_partial_batch():
    q = RequestQueue()
    q.put(_req())
    q.close()
    batch, trigger = q.next_batch(16, max_wait_s=60.0)
    assert trigger == TRIGGER_FLUSH and len(batch) == 1
    batch, _ = q.next_batch(16, max_wait_s=60.0)
    assert batch is None  # closed + drained
    with pytest.raises(RuntimeError):
        q.put(_req())


def test_nonblocking_poll_returns_empty():
    q = RequestQueue()
    q.put(_req())  # present but neither trigger fired
    batch, trigger = q.next_batch(16, max_wait_s=60.0, block=False)
    assert batch == [] and trigger == ""


# ---------------------------------------------------------------------------
# 3. end-to-end micro-batching vs per-query serving
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serving_setup():
    rng = np.random.default_rng(7)
    d, B = 200, 8
    ws = make_tree_weights(rng, d, [8, 64, 512], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    engine = XMRServingEngine(tree, ServeConfig(ell_width=32, max_batch=64))
    engine.warmup(d, batch_sizes=(1, 2, 4, 8, 16))
    queries = random_sparse_csr(45, d, 15, rng)  # 45: forces a ragged tail
    ref_s, ref_l = engine.serve_online(queries)
    return engine, queries, ref_s, ref_l


def test_microbatch_bitwise_equals_per_query(serving_setup):
    engine, queries, ref_s, ref_l = serving_setup
    mb = MicroBatcher(engine, BatchPolicy(max_batch=16, max_wait_ms=5.0))
    futs = mb.submit_csr(queries)  # enqueue before start: deterministic coalescing
    mb.start()
    res = [f.result(timeout=60) for f in futs]
    mb.stop()
    np.testing.assert_array_equal(np.stack([r[0] for r in res]), ref_s)
    np.testing.assert_array_equal(np.stack([r[1] for r in res]), ref_l)
    s = mb.metrics.summary()
    assert s["count"] == queries.shape[0]
    # 45 requests at max_batch=16 → two size-triggered 16s + a 13 tail
    assert TRIGGER_SIZE in s["triggers"]
    assert max(mb.metrics.batch_sizes) == 16


def test_bucket_padding_invisible(serving_setup):
    """13 requests pad to the 16-bucket; results equal the unpadded run."""
    engine, queries, ref_s, ref_l = serving_setup
    sub = queries.slice_rows(np.arange(13))
    xi, xv = engine.marshal_rows(sub, np.arange(13), bucket=16)
    assert xi.shape[0] == 16
    s, l = engine._run(xi, xv)
    np.testing.assert_array_equal(np.asarray(s)[:13], ref_s[:13])
    np.testing.assert_array_equal(np.asarray(l)[:13], ref_l[:13])
    # padding rows are empty sentinel queries
    assert (np.asarray(xi)[13:] == queries.shape[1]).all()


def test_deadline_batches_resolve_without_size_trigger(serving_setup):
    engine, queries, ref_s, ref_l = serving_setup
    mb = MicroBatcher(engine, BatchPolicy(max_batch=16, max_wait_ms=10.0))
    mb.start()
    futs = mb.submit_csr(queries.slice_rows(np.arange(3)))
    res = [f.result(timeout=60) for f in futs]  # resolves via deadline, not size
    mb.stop()
    np.testing.assert_array_equal(np.stack([r[0] for r in res]), ref_s[:3])
    np.testing.assert_array_equal(np.stack([r[1] for r in res]), ref_l[:3])
    trig = mb.metrics.summary()["triggers"]
    assert TRIGGER_SIZE not in trig
    assert TRIGGER_DEADLINE in trig or TRIGGER_FLUSH in trig


def test_serve_batch_matches_online(serving_setup):
    engine, queries, ref_s, ref_l = serving_setup
    s, l = engine.serve_batch(queries)
    np.testing.assert_array_equal(s, ref_s)
    np.testing.assert_array_equal(l, ref_l)


def test_label_perm_applied_through_batcher(serving_setup):
    engine, queries, ref_s, ref_l = serving_setup
    perm = np.arange(engine.tree.n_labels)[::-1].copy()
    eng2 = XMRServingEngine(engine.tree, engine.config, label_perm=perm)
    with MicroBatcher(eng2, BatchPolicy(max_batch=16, max_wait_ms=5.0)) as mb:
        res = [f.result(timeout=60) for f in mb.submit_csr(queries)]
    np.testing.assert_array_equal(np.stack([r[1] for r in res]), perm[ref_l])


# ---------------------------------------------------------------------------
# 4. start() warmup + dispatch-before-finalize
# ---------------------------------------------------------------------------

def test_start_warms_buckets_no_compile_in_serving_path(serving_setup):
    """start() pre-compiles every bucket; live traffic never hits XLA."""
    engine, queries, ref_s, ref_l = serving_setup
    # distinct ell_width → distinct jit cache entries for this test alone
    eng = XMRServingEngine(engine.tree, ServeConfig(ell_width=48, max_batch=64))
    mb = MicroBatcher(eng, BatchPolicy(max_batch=8, max_wait_ms=2.0))
    before = _tree_infer._cache_size()
    mb.start()
    warmed = _tree_infer._cache_size()
    assert warmed > before  # buckets compiled up front by start()
    futs = mb.submit_csr(queries.slice_rows(np.arange(13)))  # buckets 8 + 8
    for f in futs:
        f.result(timeout=60)
    mb.stop()
    assert _tree_infer._cache_size() == warmed  # no compile after start


def test_warmup_on_start_opt_out(serving_setup):
    engine, *_ = serving_setup
    eng = XMRServingEngine(engine.tree, ServeConfig(ell_width=56, max_batch=64))
    mb = MicroBatcher(eng, BatchPolicy(max_batch=8), warmup_on_start=False)
    before = _tree_infer._cache_size()
    mb.start()
    assert _tree_infer._cache_size() == before  # opted out: nothing compiled
    mb.stop()


def test_ready_batch_dispatches_before_blocking_on_inflight(serving_setup):
    """A deadline-expired batch must come back from the worker's poll while
    the in-flight batch is still on the device — not after _finalize."""
    engine, *_ = serving_setup
    mb = MicroBatcher(engine, BatchPolicy(max_batch=16, max_wait_ms=1.0),
                      warmup_on_start=False)

    class _NeverReady:
        def is_ready(self):
            return False

    stuck = _InFlight(reqs=[], scores=_NeverReady(), labels=_NeverReady(),
                      t_dequeue=0.0, bucket=1, trigger=TRIGGER_SIZE)
    mb.queue.put(_req(t=time.perf_counter() - 1.0))  # deadline long past
    t0 = time.perf_counter()
    reqs, trigger = mb._poll_ready(stuck, 1e-3)
    assert trigger == TRIGGER_DEADLINE and len(reqs) == 1
    assert time.perf_counter() - t0 < 0.5  # did not wait on device results


# ---------------------------------------------------------------------------
# 5. fault injection: a dispatch error fails only its own batch
# ---------------------------------------------------------------------------

def test_dispatch_fault_fails_only_its_batch(serving_setup):
    engine, queries, ref_s, ref_l = serving_setup
    eng = XMRServingEngine(engine.tree, engine.config)
    calls = {"n": 0}
    real_run = eng._run

    def flaky_run(xi, xv, tier=0):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("injected device fault")
        return real_run(xi, xv, tier=tier)

    eng._run = flaky_run
    mb = MicroBatcher(eng, BatchPolicy(max_batch=16, max_wait_ms=5.0),
                      warmup_on_start=False)
    futs = mb.submit_csr(queries)  # 45 → batches 16/16/13; batch 2 faults
    mb.start()
    outcomes = []
    for i, f in enumerate(futs):
        try:
            s, l = f.result(timeout=60)
            np.testing.assert_array_equal(s, ref_s[i])
            np.testing.assert_array_equal(l, ref_l[i])
            outcomes.append("ok")
        except RuntimeError as exc:
            assert "injected device fault" in str(exc)
            outcomes.append("fault")
    # every future resolved exactly once: the faulted batch and nothing else
    assert outcomes == ["ok"] * 16 + ["fault"] * 16 + ["ok"] * 13
    # the worker survived — the queue keeps serving
    f2 = mb.submit(*queries.row(0))
    s, l = f2.result(timeout=60)
    np.testing.assert_array_equal(s, ref_s[0])
    mb.stop()


# ---------------------------------------------------------------------------
# 6. honest latency accounting
# ---------------------------------------------------------------------------

def test_amortized_batch_stats_stay_out_of_percentiles(serving_setup):
    """serve_batch's per-call average must not masquerade as per-query
    samples in the Table-4 percentile panel."""
    engine, queries, ref_s, ref_l = serving_setup
    eng = XMRServingEngine(engine.tree, engine.config)
    eng.serve_batch(queries)
    summ = eng.latency_summary()
    assert summ["count"] == 0 and "p99_ms" not in summ
    assert summ["amortized"]["calls"] == 1
    assert summ["amortized"]["queries"] == queries.shape[0]
    eng.serve_online(queries, limit=5)
    summ = eng.latency_summary()
    assert summ["count"] == 5 and "p99_ms" in summ  # 5 true per-query samples
    assert summ["amortized"]["calls"] == 1          # untouched by online mode


def test_latency_stats_record_routes_call_averages():
    from repro.serving.metrics import LatencyStats

    stats = LatencyStats()
    stats.record(0.010)                 # one true per-query sample
    stats.record(0.160, n_queries=16)   # legacy amortized record() call
    summ = stats.summary()
    assert summ["count"] == 1           # percentile series has ONE sample
    assert summ["p99_ms"] == pytest.approx(10.0)
    assert summ["amortized"]["calls"] == 1
    assert summ["amortized"]["avg_ms_per_query"] == pytest.approx(10.0)


@pytest.mark.slow
def test_poisson_stream_under_load(serving_setup):
    """Open-loop arrivals: every request resolves, metrics stay consistent."""
    engine, queries, ref_s, ref_l = serving_setup
    rng = np.random.default_rng(3)
    mb = MicroBatcher(engine, BatchPolicy(max_batch=8, max_wait_ms=1.0))
    mb.start()
    futs = []
    for i in range(queries.shape[0]):
        time.sleep(float(rng.exponential(2e-4)))
        futs.append(mb.submit(*queries.row(i)))
    res = [f.result(timeout=60) for f in futs]
    mb.stop()
    np.testing.assert_array_equal(np.stack([r[0] for r in res]), ref_s)
    s = mb.metrics.summary()
    assert s["count"] == queries.shape[0]
    assert sum(mb.metrics.batch_sizes) == queries.shape[0]


# ---------------------------------------------------------------------------
# 7. queue_depth="auto" capacity probe + lifecycle
# ---------------------------------------------------------------------------

def _auto_mb(engine, secs, monkeypatch, *, max_batch=16, deadline_ms=None):
    """Batcher with a deterministic drain-rate probe (not started)."""
    monkeypatch.setattr(
        engine, "measure_batch_seconds",
        lambda batch, iters=3, tier=0: secs,
    )
    return MicroBatcher(
        engine,
        BatchPolicy(max_batch=max_batch, max_wait_ms=2.0),
        admission=AdmissionPolicy(
            max_queue_depth="auto", deadline_ms=deadline_ms
        ),
    )


def test_auto_depth_floors_at_max_batch_when_drain_is_slow(
    serving_setup, monkeypatch
):
    """A near-zero drain rate must still admit one full bucket."""
    engine, *_ = serving_setup
    mb = _auto_mb(engine, 1e3, monkeypatch)  # 1000 s per bucket
    assert mb._auto_queue_depth() == 16


def test_auto_depth_zero_drain_time_is_finite(serving_setup, monkeypatch):
    """A probe measuring ~0 s (clock granularity) must not divide by zero
    or overflow — the bound resolves to a finite int."""
    engine, *_ = serving_setup
    mb = _auto_mb(engine, 0.0, monkeypatch)
    depth = mb._auto_queue_depth()
    assert isinstance(depth, int) and depth >= 16


def test_auto_depth_deadline_none_uses_coalescing_budget(
    serving_setup, monkeypatch
):
    """Without a per-request deadline the budget is ten deadline-trigger
    windows (10 x max_wait_ms); with one, the deadline itself."""
    engine, *_ = serving_setup
    # 16 ms per 16-query bucket -> 1000 QPS drain rate
    mb = _auto_mb(engine, 0.016, monkeypatch)
    assert mb._auto_queue_depth() == 20   # 1000 QPS * 10 * 2 ms
    mb = _auto_mb(engine, 0.016, monkeypatch, deadline_ms=50.0)
    assert mb._auto_queue_depth() == 50   # 1000 QPS * 50 ms


def test_auto_depth_sharded_bucket_floor(serving_setup, monkeypatch):
    """shards > 1 raises the bucket floor (a bucket always splits evenly
    over the mesh), which raises the measured drain rate with it."""
    engine, *_ = serving_setup
    mb = _auto_mb(engine, 0.008, monkeypatch, max_batch=2, deadline_ms=50.0)
    assert engine.bucket_for(2) == 2
    assert mb._auto_queue_depth() == 13   # 250 QPS * 50 ms, floored at 13
    monkeypatch.setattr(engine.config, "shards", 8)
    assert engine.bucket_for(2) == 8
    assert mb._auto_queue_depth() == 50   # 1000 QPS * 50 ms


def test_stop_during_auto_probe_waits_probe_out(serving_setup, monkeypatch):
    """stop() racing start()'s capacity probe must neither deadlock nor
    close the queue under the half-measured bucket: it waits for start to
    finish, then observes and joins the freshly started worker."""
    engine, *_ = serving_setup
    probe_entered = threading.Event()
    release_probe = threading.Event()

    def blocking_probe(batch, iters=3, tier=0):
        probe_entered.set()
        assert release_probe.wait(timeout=30), "probe never released"
        return 1e-3

    monkeypatch.setattr(engine, "measure_batch_seconds", blocking_probe)
    mb = MicroBatcher(
        engine,
        BatchPolicy(max_batch=16, max_wait_ms=2.0),
        admission=AdmissionPolicy(max_queue_depth="auto"),
        warmup_on_start=False,
    )
    starter = threading.Thread(target=mb.start)
    starter.start()
    assert probe_entered.wait(timeout=30)
    stopper = threading.Thread(target=mb.stop)
    stopper.start()
    # stop() is parked on the lifecycle lock: the queue must still be open
    # (closing it now would strand the probe's bucket half-measured).
    time.sleep(0.05)
    assert not mb.queue.closed
    release_probe.set()
    starter.join(timeout=30)
    stopper.join(timeout=30)
    assert not starter.is_alive() and not stopper.is_alive()
    # start completed its probe (bound resolved), stop joined the worker
    assert isinstance(mb.admission.max_queue_depth, int)
    assert mb.queue.closed and mb._thread is None
