"""Serving subsystem: vectorized marshalling, coalescing triggers, padding.

Pins the three properties the async engine must not break:
1. the vectorized CSR→ELL path equals the per-row loop oracle;
2. the RequestQueue fires on exactly the documented triggers
   (size / deadline / close-flush);
3. bucket padding is invisible — micro-batched results are bitwise-identical
   to per-query serving.
"""

import time

import numpy as np
import pytest

from repro.core import XMRTree
from repro.serving import (
    BatchPolicy,
    MicroBatcher,
    ServeConfig,
    XMRServingEngine,
)
from repro.serving.batcher import (
    TRIGGER_DEADLINE,
    TRIGGER_FLUSH,
    TRIGGER_SIZE,
    RequestQueue,
    _Request,
)
from repro.sparse import (
    random_sparse_csr,
    rows_to_ell,
    rows_to_ell_loop,
)
from tests.conftest import make_tree_weights


# ---------------------------------------------------------------------------
# 1. vectorized CSR→ELL vs the per-row loop oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [None, 1, 4, 64])
def test_rows_to_ell_matches_loop(rng, width):
    x = random_sparse_csr(40, 300, 12, rng)
    for rows in (
        np.arange(40),
        np.array([0, 39, 7, 7, 20]),   # arbitrary order, duplicates
        np.zeros(0, np.int64),         # empty selection
    ):
        vi, vv = rows_to_ell(x, rows, width)
        li, lv = rows_to_ell_loop(x, rows, width)
        np.testing.assert_array_equal(vi, li)
        np.testing.assert_array_equal(vv, lv)


def test_rows_to_ell_truncation_and_sentinel(rng):
    x = random_sparse_csr(8, 100, 20, rng)
    w = 5
    idx, val = rows_to_ell(x, np.arange(8), w)
    assert idx.shape == (8, w) and val.shape == (8, w)
    for i in range(8):
        ri, rv = x.row(i)
        k = min(len(ri), w)
        np.testing.assert_array_equal(idx[i, :k], ri[:k])
        assert (idx[i, k:] == 100).all() and (val[i, k:] == 0).all()


def test_to_ell_uses_vectorized_path(rng):
    x = random_sparse_csr(25, 200, 10, rng)
    vi, vv = x.to_ell()
    li, lv = rows_to_ell_loop(x, np.arange(25), None)
    np.testing.assert_array_equal(vi, li)
    np.testing.assert_array_equal(vv, lv)


def test_rows_to_ell_empty_rows(rng):
    from repro.sparse.csr import CSR

    x = CSR.from_dense(np.zeros((3, 10), np.float32))
    idx, val = rows_to_ell(x, np.arange(3), 4)
    assert (idx == 10).all() and (val == 0).all()


# ---------------------------------------------------------------------------
# 2. RequestQueue coalescing triggers (tested directly, no worker thread)
# ---------------------------------------------------------------------------

def _req(t=None):
    from concurrent.futures import Future

    return _Request(
        idx=np.zeros(1, np.int32),
        val=np.zeros(1, np.float32),
        future=Future(),
        t_enqueue=time.perf_counter() if t is None else t,
    )


def test_size_trigger_fires_immediately():
    q = RequestQueue()
    for _ in range(20):
        q.put(_req())
    t0 = time.perf_counter()
    batch, trigger = q.next_batch(16, max_wait_s=10.0)
    assert trigger == TRIGGER_SIZE
    assert len(batch) == 16
    assert time.perf_counter() - t0 < 1.0  # did not wait for the deadline
    assert len(q) == 4


def test_deadline_trigger_fires_after_wait():
    q = RequestQueue()
    for _ in range(3):
        q.put(_req())
    t0 = time.perf_counter()
    batch, trigger = q.next_batch(16, max_wait_s=0.05)
    waited = time.perf_counter() - t0
    assert trigger == TRIGGER_DEADLINE
    assert len(batch) == 3
    assert waited >= 0.04  # held for the deadline, not a spurious wakeup


def test_close_flushes_partial_batch():
    q = RequestQueue()
    q.put(_req())
    q.close()
    batch, trigger = q.next_batch(16, max_wait_s=60.0)
    assert trigger == TRIGGER_FLUSH and len(batch) == 1
    batch, _ = q.next_batch(16, max_wait_s=60.0)
    assert batch is None  # closed + drained
    with pytest.raises(RuntimeError):
        q.put(_req())


def test_nonblocking_poll_returns_empty():
    q = RequestQueue()
    q.put(_req())  # present but neither trigger fired
    batch, trigger = q.next_batch(16, max_wait_s=60.0, block=False)
    assert batch == [] and trigger == ""


# ---------------------------------------------------------------------------
# 3. end-to-end micro-batching vs per-query serving
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serving_setup():
    rng = np.random.default_rng(7)
    d, B = 200, 8
    ws = make_tree_weights(rng, d, [8, 64, 512], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    engine = XMRServingEngine(tree, ServeConfig(ell_width=32, max_batch=64))
    engine.warmup(d, batch_sizes=(1, 2, 4, 8, 16))
    queries = random_sparse_csr(45, d, 15, rng)  # 45: forces a ragged tail
    ref_s, ref_l = engine.serve_online(queries)
    return engine, queries, ref_s, ref_l


def test_microbatch_bitwise_equals_per_query(serving_setup):
    engine, queries, ref_s, ref_l = serving_setup
    mb = MicroBatcher(engine, BatchPolicy(max_batch=16, max_wait_ms=5.0))
    futs = mb.submit_csr(queries)  # enqueue before start: deterministic coalescing
    mb.start()
    res = [f.result(timeout=60) for f in futs]
    mb.stop()
    np.testing.assert_array_equal(np.stack([r[0] for r in res]), ref_s)
    np.testing.assert_array_equal(np.stack([r[1] for r in res]), ref_l)
    s = mb.metrics.summary()
    assert s["count"] == queries.shape[0]
    # 45 requests at max_batch=16 → two size-triggered 16s + a 13 tail
    assert TRIGGER_SIZE in s["triggers"]
    assert max(mb.metrics.batch_sizes) == 16


def test_bucket_padding_invisible(serving_setup):
    """13 requests pad to the 16-bucket; results equal the unpadded run."""
    engine, queries, ref_s, ref_l = serving_setup
    sub = queries.slice_rows(np.arange(13))
    xi, xv = engine.marshal_rows(sub, np.arange(13), bucket=16)
    assert xi.shape[0] == 16
    s, l = engine._run(xi, xv)
    np.testing.assert_array_equal(np.asarray(s)[:13], ref_s[:13])
    np.testing.assert_array_equal(np.asarray(l)[:13], ref_l[:13])
    # padding rows are empty sentinel queries
    assert (np.asarray(xi)[13:] == queries.shape[1]).all()


def test_deadline_batches_resolve_without_size_trigger(serving_setup):
    engine, queries, ref_s, ref_l = serving_setup
    mb = MicroBatcher(engine, BatchPolicy(max_batch=16, max_wait_ms=10.0))
    mb.start()
    futs = mb.submit_csr(queries.slice_rows(np.arange(3)))
    res = [f.result(timeout=60) for f in futs]  # resolves via deadline, not size
    mb.stop()
    np.testing.assert_array_equal(np.stack([r[0] for r in res]), ref_s[:3])
    np.testing.assert_array_equal(np.stack([r[1] for r in res]), ref_l[:3])
    trig = mb.metrics.summary()["triggers"]
    assert TRIGGER_SIZE not in trig
    assert TRIGGER_DEADLINE in trig or TRIGGER_FLUSH in trig


def test_serve_batch_matches_online(serving_setup):
    engine, queries, ref_s, ref_l = serving_setup
    s, l = engine.serve_batch(queries)
    np.testing.assert_array_equal(s, ref_s)
    np.testing.assert_array_equal(l, ref_l)


def test_label_perm_applied_through_batcher(serving_setup):
    engine, queries, ref_s, ref_l = serving_setup
    perm = np.arange(engine.tree.n_labels)[::-1].copy()
    eng2 = XMRServingEngine(engine.tree, engine.config, label_perm=perm)
    with MicroBatcher(eng2, BatchPolicy(max_batch=16, max_wait_ms=5.0)) as mb:
        res = [f.result(timeout=60) for f in mb.submit_csr(queries)]
    np.testing.assert_array_equal(np.stack([r[1] for r in res]), perm[ref_l])


@pytest.mark.slow
def test_poisson_stream_under_load(serving_setup):
    """Open-loop arrivals: every request resolves, metrics stay consistent."""
    engine, queries, ref_s, ref_l = serving_setup
    rng = np.random.default_rng(3)
    mb = MicroBatcher(engine, BatchPolicy(max_batch=8, max_wait_ms=1.0))
    mb.start()
    futs = []
    for i in range(queries.shape[0]):
        time.sleep(float(rng.exponential(2e-4)))
        futs.append(mb.submit(*queries.row(i)))
    res = [f.result(timeout=60) for f in futs]
    mb.stop()
    np.testing.assert_array_equal(np.stack([r[0] for r in res]), ref_s)
    s = mb.metrics.summary()
    assert s["count"] == queries.shape[0]
    assert sum(mb.metrics.batch_sizes) == queries.shape[0]
