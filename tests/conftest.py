"""Shared test fixtures/helpers.

NOTE: no XLA device-count flags here — tests see the real single CPU device.
Only launch/dryrun.py (run as a script) forces 512 placeholder devices.
"""

import numpy as np
import pytest


def _has_tpu() -> bool:
    import jax

    try:
        return any(d.platform == "tpu" for d in jax.devices())
    except RuntimeError:
        return False


def pytest_collection_modifyitems(config, items):
    """Auto-skip ``tpu``-marked tests when no TPU device is present.

    ``slow`` is a plain registered marker — deselect with ``-m "not slow"``
    (what CI does); it carries no auto-skip so a full local run still
    exercises everything.
    """
    tpu_items = [item for item in items if "tpu" in item.keywords]
    if not tpu_items or _has_tpu():
        return  # don't initialize the JAX backend unless the marker is used
    skip_tpu = pytest.mark.skip(reason="no TPU device present")
    for item in tpu_items:
        item.add_marker(skip_tpu)


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def make_tree_weights(rng, d, level_sizes, branching, nnz_per_col=10):
    """Random per-level CSC weight matrices with sibling-correlated support."""
    from repro.sparse import random_sparse_csc

    return [
        random_sparse_csc(d, L, nnz_per_col, rng, sibling_groups=branching)
        for L in level_sizes
    ]


def brute_force_scores(X_dense, weights):
    """Dense full-tree scores (paper eq. 5) — the exactness oracle."""
    sig = lambda z: 1.0 / (1.0 + np.exp(-z))
    prev = np.ones((X_dense.shape[0], 1), np.float32)
    for w in weights:
        act = sig(X_dense @ w.to_dense())
        b = act.shape[1] // prev.shape[1]
        prev = np.repeat(prev, b, axis=1) * act
    return prev
