"""v1 serving API: wire round-trips, status mapping, config shim."""

import dataclasses
import json
import warnings

import numpy as np
import pytest

from repro.serving import (
    HTTP_STATUS,
    AdmissionConfig,
    DeadlineExceeded,
    Overloaded,
    PartitionConfig,
    Query,
    QueryResult,
    ServeConfig,
    WireError,
    WorkerUnavailable,
    status_for_exception,
)
from repro.serving.api import (
    STATUS_DEADLINE_EXCEEDED,
    STATUS_INTERNAL_ERROR,
    STATUS_OK,
    STATUS_OVERLOADED,
    STATUS_WORKER_UNAVAILABLE,
)


# -- Query / QueryResult wire round-trips ----------------------------------

def _exotic_f32():
    """float32 values whose bits must survive the JSON round trip."""
    return np.asarray(
        [0.1, 1 / 3, np.float32(1e-30), np.float32(3.4e38),
         np.nextafter(np.float32(1.0), np.float32(2.0)),
         -0.0, 7.7e-7],
        np.float32,
    )


def test_query_wire_roundtrip_bitwise():
    q = Query(
        idx=np.arange(7, dtype=np.int32) * 1000,
        val=_exotic_f32(),
        qid=42, deadline_ms=12.5, priority=3,
    )
    doc = json.loads(json.dumps(q.to_wire()))  # through real JSON text
    q2 = Query.from_wire(doc)
    assert doc["v"] == 1
    assert q2.qid == 42 and q2.deadline_ms == 12.5 and q2.priority == 3
    assert q2.idx.dtype == np.int32 and q2.val.dtype == np.float32
    assert np.array_equal(q2.idx, q.idx)
    assert np.array_equal(q2.val.view(np.uint32), q.val.view(np.uint32))


def test_query_result_wire_roundtrip_bitwise():
    r = QueryResult(
        qid=7,
        ids=np.asarray([5, 1, 9], np.int32),
        scores=_exotic_f32()[:3],
        timing={"e2e_ms": 1.25},
    )
    r2 = QueryResult.from_wire(json.loads(json.dumps(r.to_wire())))
    assert r2.ok and r2.qid == 7
    assert np.array_equal(r2.ids, r.ids)
    assert np.array_equal(r2.scores.view(np.uint32), r.scores.view(np.uint32))
    assert r2.timing == {"e2e_ms": 1.25}
    # legacy StreamResult aliases
    assert r2.index == 7
    assert np.array_equal(r2.labels, r.ids)


def test_error_result_wire_roundtrip():
    exc = Overloaded(16, "reject")
    r = QueryResult.from_error(3, exc)
    assert not r.ok and r.error is exc
    r2 = QueryResult.from_wire(json.loads(json.dumps(r.to_wire())))
    assert r2.status == STATUS_OVERLOADED and not r2.ok
    assert r2.ids is None and r2.scores is None
    assert "queue depth" in r2.detail
    assert r2.error is None  # exceptions never cross the wire


def test_wire_version_rejected():
    q = Query(idx=np.asarray([1], np.int32), val=np.asarray([1.0], np.float32))
    doc = q.to_wire()
    doc["v"] = 2
    with pytest.raises(WireError, match="wire version"):
        Query.from_wire(doc)
    with pytest.raises(WireError):
        QueryResult.from_wire({"v": None, "status": "ok"})
    with pytest.raises(WireError, match="malformed"):
        Query.from_wire({"v": 1})  # missing idx/val


# -- error -> status -> HTTP code mapping ----------------------------------

@pytest.mark.parametrize(
    "exc,status,code",
    [
        (Overloaded(8, "reject"), STATUS_OVERLOADED, 429),
        (DeadlineExceeded(5.0, 1.0), STATUS_DEADLINE_EXCEEDED, 504),
        (WorkerUnavailable("worker0", "begin", "timed out"),
         STATUS_WORKER_UNAVAILABLE, 503),
        (RuntimeError("boom"), STATUS_INTERNAL_ERROR, 500),
    ],
)
def test_status_mapping(exc, status, code):
    assert status_for_exception(exc) == status
    assert HTTP_STATUS[status] == code
    r = QueryResult.from_error(0, exc)
    assert r.status == status and r.http_status == code


def test_http_status_table():
    assert HTTP_STATUS[STATUS_OK] == 200
    assert HTTP_STATUS["invalid"] == 400


def test_worker_unavailable_is_typed():
    exc = WorkerUnavailable("worker1", "step", "connection reset")
    assert exc.worker == "worker1" and exc.op == "step"
    from repro.serving import ServingError

    assert isinstance(exc, ServingError)


# -- ServeConfig redesign + deprecation shim -------------------------------

def test_nested_config_groups():
    cfg = ServeConfig(
        max_batch=64,
        admission=AdmissionConfig(queue_depth=32, shed_policy="shed-oldest",
                                  deadline_ms=50.0),
        partition=PartitionConfig(partitions=4, partition_sync="pipelined",
                                  beam_cache=8),
    )
    assert cfg.admission.queue_depth == 32
    assert cfg.partition.partitions == 4
    # flat read-side forwarding keeps pre-v1 call sites working
    assert cfg.queue_depth == 32
    assert cfg.shed_policy == "shed-oldest"
    assert cfg.deadline_ms == 50.0
    assert cfg.partitions == 4
    assert cfg.partition_level is None
    assert cfg.partition_sync == "pipelined"
    assert cfg.beam_cache == 8


def test_flat_kwargs_resolve_and_warn():
    with pytest.warns(DeprecationWarning, match="deprecated"):
        cfg = ServeConfig(
            beam=5, partitions=2, partition_sync="pipelined",
            queue_depth="auto", deadline_ms=10.0,
        )
    assert cfg.beam == 5
    assert cfg.partition.partitions == 2
    assert cfg.partition.partition_sync == "pipelined"
    assert cfg.admission.queue_depth == "auto"
    assert cfg.admission.deadline_ms == 10.0


def test_flat_kwargs_do_not_mutate_shared_group():
    shared = PartitionConfig(partitions=2)
    with pytest.warns(DeprecationWarning):
        cfg = ServeConfig(partition=shared, beam_cache=16)
    assert cfg.partition.beam_cache == 16
    assert shared.beam_cache == 0  # caller's instance untouched


def test_unknown_kwarg_raises():
    with pytest.raises(TypeError, match="unexpected keyword"):
        ServeConfig(nonsense=1)


def test_default_config_warns_nothing():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cfg = ServeConfig()
    assert cfg.partitions == 1 and cfg.queue_depth is None
    assert dataclasses.is_dataclass(cfg)
