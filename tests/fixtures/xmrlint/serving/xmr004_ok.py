"""XMR004 negative fixture: broad catches that log, re-raise, or convert."""

import logging

log = logging.getLogger(__name__)


class WorkerUnavailable(RuntimeError):
    pass


def cleanup(handles):
    for h in handles:
        try:
            h.kill()
        except Exception as exc:
            log.warning("kill failed: %s", exc)


def convert(worker):
    try:
        worker.ping()
    except Exception as exc:
        raise WorkerUnavailable(str(exc)) from exc


def record(worker, sink):
    try:
        worker.ping()
    except Exception as exc:
        sink.set_exception(exc)  # bound exception is used: compliant


def narrow(worker):
    try:
        worker.ping()
    except (OSError, ValueError):  # narrow catch: out of scope
        pass
