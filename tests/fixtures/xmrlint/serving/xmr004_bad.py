"""XMR004 positive fixture: silent broad exception swallows."""


def cleanup(handles):
    for h in handles:
        try:
            h.kill()
        except Exception:   # VIOLATION: swallowed, no log / raise / use
            pass


def poll(worker):
    try:
        worker.ping()
    except BaseException:   # VIOLATION: swallowed
        return None
