"""XMR001 negative fixture (fleet sockets): ops under the connection lock,
primitives annotated, callers exempted."""


# xmrlint: transport-primitive — callers hold the lock
def send_frame(sock, payload):
    sock.sendall(payload)


class Connection:
    def __init__(self, sock, lock):
        self.sock = sock
        self.lock = lock

    def ping(self):
        with self.lock:
            send_frame(self.sock, b"ping")
            return self.sock.recv(4)
