"""XMR001 positive fixture (fleet sockets): raw stream ops without the lock."""


class Connection:
    def __init__(self, sock, lock):
        self.sock = sock
        self.lock = lock

    def ping(self):
        self.sock.sendall(b"ping")  # VIOLATION: no 'lock' held
        return self.sock.recv(4)    # VIOLATION: no 'lock' held
