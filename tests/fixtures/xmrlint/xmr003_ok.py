"""XMR003 negative fixture: statics bounded by buckets, clamps, config."""

import functools

import jax

MAX_BATCH = 64


def bucket_for(n):
    k = 1
    while k < n:
        k *= 2
    return k


@functools.partial(jax.jit, static_argnames=("count", "width"))
def run(x, count, width=8):
    return x[:count, :width]


def serve(batch, beam):
    run(batch, count=bucket_for(len(batch)))      # bucketed: bounded
    run(batch, count=MAX_BATCH)                   # constant: bounded
    width = batch.shape[1]
    width = min(beam, width * 2)                  # clamped: bounded
    run(batch, count=MAX_BATCH, width=width)
