"""XMR001 positive fixture: guarded field touched without its lock."""

import threading


class Fleet:
    def __init__(self):
        self._state_lock = threading.Lock()
        self._down = set()  # guarded-by: _state_lock

    def mark_down(self, pid):
        self._down.add(pid)  # VIOLATION: no lock held

    def down(self):
        return sorted(self._down)  # VIOLATION: no lock held
