"""XMR002 positive fixture: host syncs and Python branches on traced values."""

import jax
import numpy as np


@jax.jit
def scores_bad(x):
    s = x * 2.0
    if s.sum() > 0:          # VIOLATION: Python branch on a tracer
        s = s + 1.0
    peak = float(s.max())    # VIOLATION: host sync under trace
    host = np.asarray(s)     # VIOLATION: np.* on a traced value
    return s, peak, host


def helper(y):
    return y.item()          # VIOLATION: reachable from the jit root


@jax.jit
def root(y):
    return helper(y)
