"""XMR002 negative fixture: static-shape branches, jnp ops, static args."""

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("mode", "k"))
def scores_ok(x, mode, k, init=None):
    n, b = x.shape                # shapes are static under trace
    if mode == "prod":            # static argument: fine to branch
        x = x * 2.0
    if init is not None:          # pytree structure: static
        x = x + init
    if x.ndim == 2 and k > 0:     # ndim static, k static
        x = x.reshape(n * b)
    return jnp.maximum(x, 0.0)


def untraced(v):
    return float(v)  # not reachable from any jit root: fine
