"""XMR005 negative fixture: ``tolerance-tier``-pragma'd measurement code.

Tier-comparison metrics (recall/MAE across quantized tiers) select top-k
scores only to *measure* drift — bitwise tie-break identity is not the
claim — so the function pragma waives the ad-hoc-selection check. Both
accepted placements: the line directly above the ``def``, or the ``def``
line itself.
"""

import jax
import jax.numpy as jnp


# xmrlint: tolerance-tier
def topk_scores(scores, k):
    vals, _ = jax.lax.top_k(jnp.asarray(scores), k)
    return vals


def score_mae(ref, got, k):  # xmrlint: tolerance-tier
    return jnp.abs(
        jax.lax.top_k(ref, k)[0] - jax.lax.top_k(got, k)[0]
    ).mean()
