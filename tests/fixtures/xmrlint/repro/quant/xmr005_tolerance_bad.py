"""XMR005 positive fixture: ``repro/quant`` is inside the checked scope and
the ``tolerance-tier`` pragma is function-scoped — a stray or detached
pragma comment must not waive the check."""

import jax

# xmrlint: tolerance-tier
# (a floating pragma comment far from any def must not waive anything)


def unmarked_select(scores, k):
    return jax.lax.top_k(scores, k)   # VIOLATION: quant scope, no pragma


# xmrlint: tolerance-tier
# pragma is two lines above the def — not attached to it

def detached_pragma(scores, k):
    return jax.lax.top_k(scores, k)   # VIOLATION: pragma not adjacent
