"""XMR005 positive fixture: sentinel equality + ad-hoc beam selection."""

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-1e30)


def mask_of(scores):
    return scores == NEG_INF          # VIOLATION: float eq on sentinel


def still_bad(scores):
    return scores != NEG_INF          # VIOLATION: != is the same hazard


def my_select(scores, k):
    return jax.lax.top_k(scores, k)   # VIOLATION: ad-hoc selection
