"""XMR005 negative fixture: mask-based sentinels, canonical helpers only."""

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-1e30)


def mask_of(scores, valid):
    return jnp.where(valid, scores, NEG_INF)  # producing mask, no equality


def ordering(scores):
    return scores > NEG_INF / 2               # ordering test: allowed


def beam_select(scores, ids, k):
    neg, idx = jax.lax.sort((-scores, ids), dimension=1, num_keys=2)
    return idx[:, :k], -neg[:, :k]


def topk_canonical(scores, ids, k):
    return jax.lax.top_k(scores, k)           # inside a canonical helper
