"""XMR003 positive fixture: raw sizes fed to jit static args."""

import functools

import jax


@functools.partial(jax.jit, static_argnames=("count",))
def run(x, count):
    return x[:count]


def serve(batch):
    n = len(batch)
    run(batch, count=n)              # VIOLATION: raw len() is unbounded
    run(batch, batch.shape[0])       # VIOLATION: raw shape, positionally
