"""XMR001 negative fixture: every guarded access holds the lock."""

import threading


class Fleet:
    def __init__(self):
        self._state_lock = threading.Lock()
        self._down = set()  # guarded-by: _state_lock

    def mark_down(self, pid):
        with self._state_lock:
            self._down.add(pid)

    def down(self):
        with self._state_lock:
            return sorted(self._down)

    def _drain(self):  # xmrlint: requires-lock=_state_lock
        self._down.clear()

    def reset(self):
        with self._state_lock:
            self._drain()

    def fan_out(self):
        self._state_lock.acquire()
        try:
            return len(self._down)
        finally:
            self._state_lock.release()
