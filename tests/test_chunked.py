"""Chunked-format (MSCM data structure) unit + property tests."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core.chunked import ChunkedLayer, ColumnELLLayer
from repro.sparse import random_sparse_csc, random_sparse_csr


def test_chunked_roundtrip_exact(rng):
    d, L, B = 64, 48, 8
    w = random_sparse_csc(d, L, 6, rng, sibling_groups=B)
    ch = ChunkedLayer.from_csc(w, B)
    dense = ch.to_dense()
    np.testing.assert_array_equal(dense[:, :L], w.to_dense())
    # padded phantom columns are exactly zero
    assert not dense[:, L:].any()


def test_chunked_shapes_and_padding(rng):
    d, L, B = 100, 30, 8  # L not divisible by B -> padded final chunk
    w = random_sparse_csc(d, L, 5, rng, sibling_groups=B)
    ch = ChunkedLayer.from_csc(w, B)
    assert ch.C == 4 and ch.n_cols == 32
    assert ch.R % 8 == 0  # sublane alignment
    assert ch.rows.dtype == np.int32 and ch.vals.dtype == np.float32
    # sentinel-padded tails
    for c in range(ch.C):
        row = ch.rows[c]
        valid = row[row < d]
        assert (np.diff(valid) > 0).all()  # sorted & unique
        assert (row[len(valid):] == d).all()


def test_sibling_overlap_improves_occupancy(rng):
    """Paper Item 2: correlated sibling support => denser chunk tiles."""
    d, L, B = 512, 256, 32
    w_corr = random_sparse_csc(d, L, 16, rng, sibling_groups=B, sibling_overlap=0.9)
    w_rand = random_sparse_csc(d, L, 16, rng, sibling_groups=1, sibling_overlap=0.0)
    occ_corr = ChunkedLayer.from_csc(w_corr, B).occupancy()
    occ_rand = ChunkedLayer.from_csc(w_rand, B).occupancy()
    assert occ_corr > occ_rand


def test_column_ell_matches_csc(rng):
    d, L, B = 64, 20, 4
    w = random_sparse_csc(d, L, 6, rng)
    col = ColumnELLLayer.from_csc(w, B)
    dense = np.zeros((d + 1, col.L), np.float32)
    for j in range(col.L):
        np.add.at(dense, (col.rows[j], j), col.vals[j])
    np.testing.assert_array_equal(dense[:d, :L], w.to_dense())


def test_csr_ell_roundtrip(rng):
    x = random_sparse_csr(7, 50, 9, rng)
    idx, val = x.to_ell()
    dense = np.zeros((7, 51), np.float32)
    np.add.at(dense, (np.arange(7)[:, None], idx), val)
    np.testing.assert_allclose(dense[:, :50], x.to_dense(), rtol=1e-6)
    assert (dense[:, 50] == 0).all()


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(8, 200),
    n_chunks=st.integers(1, 6),
    branching=st.sampled_from([2, 4, 8, 32]),
    nnz=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunked_roundtrip_property(d, n_chunks, branching, nnz, seed):
    rng = np.random.default_rng(seed)
    L = n_chunks * branching
    w = random_sparse_csc(d, L, min(nnz, d), rng, sibling_groups=branching)
    ch = ChunkedLayer.from_csc(w, branching)
    np.testing.assert_array_equal(ch.to_dense()[:, :L], w.to_dense())
    assert ch.memory_bytes() == ch.rows.nbytes + ch.vals.nbytes
