"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs; plus prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.launch.specs import make_demo_batch
from repro.models import lm as lm_lib


@pytest.fixture(scope="module")
def nprng():
    return np.random.default_rng(42)


def _setup(arch_id, nprng, batch=2, seq=16):
    cfg = reduced_config(get_config(arch_id))
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    batch_d = make_demo_batch(cfg, nprng, batch, seq)
    return cfg, params, batch_d


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_train_step(arch_id, nprng):
    cfg, params, batch = _setup(arch_id, nprng)
    logits, aux = lm_lib.forward_train(cfg, params, batch)
    tgt = batch["targets"]
    assert logits.shape == (tgt.shape[0], tgt.shape[1], cfg.vocab)
    assert jnp.isfinite(logits).all(), "NaN/inf in logits"

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm_lib.loss_fn(cfg, p, batch), has_aux=True
    )(params)
    assert jnp.isfinite(loss)
    flat = jax.tree.leaves(grads)
    assert all(jnp.isfinite(g).all() for g in flat), "NaN in grads"
    # a gradient step must change the loss (sanity that backprop flows)
    params2 = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    loss2, _ = lm_lib.loss_fn(cfg, params2, batch)
    assert abs(float(loss2) - float(loss)) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode_consistency(arch_id, nprng):
    """Greedy decode after prefill(s-1 tokens) == train-forward logits at -1."""
    cfg, params, batch = _setup(arch_id, nprng, batch=2, seq=12)
    logits_full, _ = lm_lib.forward_train(cfg, params, batch)

    prompt = {k: (v[:, :-1] if k in ("tokens", "targets") else v)
              for k, v in batch.items()}
    _, cache = lm_lib.prefill(cfg, params, prompt, max_len=16)
    last_tok = batch["tokens"][:, -1]
    if cfg.family == "vlm":
        pos = jnp.int32(batch["patch_embeds"].shape[1] + batch["tokens"].shape[1] - 1)
    else:
        pos = jnp.int32(batch["tokens"].shape[1] - 1)
    logits_dec, cache = lm_lib.decode_step(cfg, params, cache, last_tok, pos)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full[:, -1]), rtol=2e-2, atol=2e-2
    )


def test_moe_capacity_matches_dense_ref(nprng):
    """Sort/scatter MoE == dense all-experts oracle when nothing drops."""
    import dataclasses

    from repro.models import moe as moe_lib

    cfg = dataclasses.replace(
        reduced_config(get_config("qwen3-moe-235b-a22b")), capacity_factor=8.0
    )
    p = moe_lib.moe_init(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model))
    y, aux = moe_lib.moe_ffn(p, x, cfg)
    y_ref = moe_lib.moe_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-5)
    assert float(aux) > 0


def test_param_shapes_no_allocation():
    cfg = reduced_config(get_config("yi-6b"))
    shapes = lm_lib.param_shapes(cfg)
    real = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    s_tree = jax.tree.map(lambda s: (s.shape, s.dtype), shapes)
    r_tree = jax.tree.map(lambda a: (a.shape, a.dtype), real)
    assert s_tree == r_tree


def test_hymba_window_pattern():
    cfg = get_config("hymba-1.5b")
    w = np.asarray(lm_lib.layer_windows(cfg))
    assert (w == 0).sum() == 3           # 3 global layers
    assert (w[1] == cfg.sliding_window)  # the rest are SWA
