"""Overload-safe serving: admission control, deadlines, streaming.

Pins the serving tier's overload contract:
1. bounded queue depth with ``reject`` / ``shed-oldest`` policies, shed
   futures resolving with the typed ``Overloaded`` error;
2. per-request deadlines checked at *dispatch* — an expired request never
   reaches the device;
3. the streaming client API yields every submitted query exactly once, in
   completion order, surfacing shed/expired requests as error results;
4. overload accounting (offered / shed / deadline-miss) in ServerMetrics.
"""

import time

import numpy as np
import pytest

from repro.core import XMRTree
from repro.serving import (
    AdmissionPolicy,
    BatchPolicy,
    DeadlineExceeded,
    MicroBatcher,
    Overloaded,
    ServeConfig,
    ServingError,
    XMRServingEngine,
)
from repro.sparse import random_sparse_csr
from tests.conftest import make_tree_weights


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(11)
    d, B = 200, 8
    ws = make_tree_weights(rng, d, [8, 64, 512], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    engine = XMRServingEngine(tree, ServeConfig(ell_width=32, max_batch=64))
    engine.warmup_buckets(d, 16)
    queries = random_sparse_csr(40, d, 15, rng)
    ref_s, ref_l = engine.serve_online(queries)
    return engine, queries, ref_s, ref_l


def _idle_batcher(engine, admission):
    """A batcher whose worker is NOT started — the queue only fills."""
    return MicroBatcher(
        engine, BatchPolicy(max_batch=16, max_wait_ms=5.0),
        admission=admission, warmup_on_start=False,
    )


# ---------------------------------------------------------------------------
# 1. bounded queue + shed policies
# ---------------------------------------------------------------------------

def test_reject_policy_sheds_new_request(setup):
    engine, queries, *_ = setup
    mb = _idle_batcher(engine, AdmissionPolicy(max_queue_depth=2))
    futs = [mb.submit(*queries.row(i)) for i in range(4)]
    assert not futs[0].done() and not futs[1].done()  # admitted, waiting
    for f in futs[2:]:
        assert isinstance(f.exception(timeout=1), Overloaded)
    assert len(mb.queue) == 2  # queue untouched by the rejected requests
    s = mb.metrics.summary()
    assert s["offered"] == 4 and s["shed"] == 2
    assert s["shed_rate"] == pytest.approx(0.5)
    mb.queue.close()


def test_shed_oldest_policy_favors_freshness(setup):
    engine, queries, *_ = setup
    mb = _idle_batcher(
        engine, AdmissionPolicy(max_queue_depth=2, shed_policy="shed-oldest")
    )
    futs = [mb.submit(*queries.row(i)) for i in range(4)]
    # the two OLDEST were shed; the two newest are still queued
    for f in futs[:2]:
        exc = f.exception(timeout=1)
        assert isinstance(exc, Overloaded)
        assert exc.policy == "shed-oldest" and exc.queue_depth == 2
        assert isinstance(exc, ServingError)  # typed hierarchy
    assert not futs[2].done() and not futs[3].done()
    assert len(mb.queue) == 2
    mb.queue.close()


def test_admission_policy_validation():
    with pytest.raises(ValueError):
        AdmissionPolicy(shed_policy="drop-random")
    with pytest.raises(ValueError):
        AdmissionPolicy(max_queue_depth=0)


def test_admission_defaults_from_serve_config(setup):
    engine, *_ = setup
    cfg = ServeConfig(
        ell_width=32, max_batch=64,
        queue_depth=7, shed_policy="shed-oldest", deadline_ms=50.0,
    )
    eng = XMRServingEngine(engine.tree, cfg)
    mb = MicroBatcher(eng, warmup_on_start=False)
    assert mb.admission.max_queue_depth == 7
    assert mb.admission.shed_policy == "shed-oldest"
    assert mb.admission.deadline_ms == 50.0


def test_shed_requests_complete_under_sustained_overload(setup):
    """Flood a live bounded server: every future resolves, admitted results
    are bitwise-correct, and a nonzero fraction is shed."""
    engine, queries, ref_s, ref_l = setup
    real_run = engine._run

    def slow_run(xi, xv, tier=0):
        time.sleep(0.02)  # stretch device time so the queue must fill
        return real_run(xi, xv, tier=tier)

    engine._run = slow_run
    try:
        mb = MicroBatcher(
            engine, BatchPolicy(max_batch=8, max_wait_ms=1.0),
            admission=AdmissionPolicy(max_queue_depth=8,
                                      shed_policy="shed-oldest"),
            warmup_on_start=False,
        ).start()
        futs = [mb.submit(*queries.row(i % queries.shape[0]))
                for i in range(120)]
        ok = shed = 0
        for i, f in enumerate(futs):
            try:
                s, l = f.result(timeout=60)
                np.testing.assert_array_equal(s, ref_s[i % queries.shape[0]])
                np.testing.assert_array_equal(l, ref_l[i % queries.shape[0]])
                ok += 1
            except Overloaded:
                shed += 1
        mb.stop()
    finally:
        engine._run = real_run
    assert ok + shed == 120
    assert shed > 0 and ok > 0
    s = mb.metrics.summary()
    assert s["shed"] == shed and s["offered"] == 120
    assert s["shed_rate"] == pytest.approx(shed / 120)


def test_weighted_shed_prefers_low_priority(setup):
    """shed-oldest with priority classes: the victim is the oldest request
    of the LOWEST priority present, never a higher-priority one."""
    engine, queries, *_ = setup
    mb = _idle_batcher(
        engine, AdmissionPolicy(max_queue_depth=3, shed_policy="shed-oldest")
    )
    lo0 = mb.submit(*queries.row(0), priority=0)
    hi = mb.submit(*queries.row(1), priority=2)
    lo1 = mb.submit(*queries.row(2), priority=0)
    # queue full; a new priority-1 request sheds the OLDEST priority-0 one
    mid = mb.submit(*queries.row(3), priority=1)
    assert isinstance(lo0.exception(timeout=1), Overloaded)
    assert not hi.done() and not lo1.done() and not mid.done()
    # another arrival sheds the remaining priority-0 request, not hi/mid
    mid2 = mb.submit(*queries.row(4), priority=1)
    assert isinstance(lo1.exception(timeout=1), Overloaded)
    assert not hi.done() and not mid.done() and not mid2.done()
    s = mb.metrics.summary()
    assert s["shed"] == 2 and s["shed_by_priority"] == {0: 2}
    mb.queue.close()


def test_weighted_shed_rejects_outranked_arrival(setup):
    """A low-priority arrival at a queue full of higher-priority work is
    itself refused instead of displacing it."""
    engine, queries, *_ = setup
    mb = _idle_batcher(
        engine, AdmissionPolicy(max_queue_depth=2, shed_policy="shed-oldest")
    )
    hi0 = mb.submit(*queries.row(0), priority=5)
    hi1 = mb.submit(*queries.row(1), priority=5)
    lo = mb.submit(*queries.row(2), priority=1)
    assert isinstance(lo.exception(timeout=1), Overloaded)
    assert not hi0.done() and not hi1.done()
    assert len(mb.queue) == 2
    assert mb.metrics.summary()["shed_by_priority"] == {1: 1}
    mb.queue.close()


def test_priority_served_results_identical(setup):
    """Priorities steer shedding only — served results stay bitwise."""
    engine, queries, ref_s, ref_l = setup
    mb = MicroBatcher(engine, BatchPolicy(max_batch=8, max_wait_ms=1.0),
                      warmup_on_start=False).start()
    futs = [mb.submit(*queries.row(i), priority=i % 3) for i in range(10)]
    for i, f in enumerate(futs):
        s, l = f.result(timeout=60)
        np.testing.assert_array_equal(s, ref_s[i])
        np.testing.assert_array_equal(l, ref_l[i])
    mb.stop()


# ---------------------------------------------------------------------------
# 2. capacity-aware queue depth ("auto")
# ---------------------------------------------------------------------------

def test_auto_queue_depth_resolves_on_start(setup):
    """queue_depth="auto": start() derives the bound from the measured
    drain rate x the deadline budget; before start() it admits freely."""
    engine, queries, *_ = setup
    cfg = ServeConfig(ell_width=32, max_batch=64, queue_depth="auto",
                      shed_policy="shed-oldest", deadline_ms=100.0)
    eng = XMRServingEngine(engine.tree, cfg)
    mb = MicroBatcher(eng, BatchPolicy(max_batch=8, max_wait_ms=1.0))
    assert mb.admission.max_queue_depth == "auto"
    mb.start()
    depth = mb.admission.max_queue_depth
    assert isinstance(depth, int) and depth >= 8  # never below max_batch
    # the resolved bound is drain_qps * 100ms, floored at max_batch
    secs = eng.measure_batch_seconds(8)
    expect = max(8, int(np.ceil(eng.bucket_for(8) / secs * 0.1)))
    assert depth == pytest.approx(expect, rel=1.0)  # same order of magnitude
    fut = mb.submit(*queries.row(0))
    fut.result(timeout=60)
    mb.stop()


def test_auto_queue_depth_validation():
    with pytest.raises(ValueError):
        AdmissionPolicy(max_queue_depth="adaptive")
    AdmissionPolicy(max_queue_depth="auto")  # accepted


# ---------------------------------------------------------------------------
# 3. per-request deadlines, enforced at dispatch
# ---------------------------------------------------------------------------

def test_expired_request_never_reaches_device(setup):
    engine, queries, *_ = setup
    eng = XMRServingEngine(engine.tree, ServeConfig(ell_width=32, max_batch=64))
    calls = {"n": 0}
    real_run = eng._run

    def counting_run(xi, xv, tier=0):
        calls["n"] += 1
        return real_run(xi, xv, tier=tier)

    eng._run = counting_run
    mb = MicroBatcher(eng, BatchPolicy(max_batch=16, max_wait_ms=1.0),
                      warmup_on_start=False).start()
    fut = mb.submit(*queries.row(0), deadline_ms=0.0)  # born expired
    exc = fut.exception(timeout=10)
    mb.stop()
    assert isinstance(exc, DeadlineExceeded)
    assert exc.deadline_ms == pytest.approx(0.0)
    assert calls["n"] == 0  # no device time burned on the dead request
    assert mb.metrics.summary()["deadline_missed"] == 1


def test_live_requests_survive_expired_batchmates(setup):
    engine, queries, ref_s, ref_l = setup
    mb = MicroBatcher(engine, BatchPolicy(max_batch=16, max_wait_ms=2.0),
                      warmup_on_start=False).start()
    dead = mb.submit(*queries.row(0), deadline_ms=0.0)
    live = mb.submit(*queries.row(1))
    s, l = live.result(timeout=30)
    mb.stop()
    assert isinstance(dead.exception(), DeadlineExceeded)
    np.testing.assert_array_equal(s, ref_s[1])
    np.testing.assert_array_equal(l, ref_l[1])


# ---------------------------------------------------------------------------
# 3. streaming client API
# ---------------------------------------------------------------------------

def test_stream_yields_all_results_in_completion_order(setup):
    engine, queries, ref_s, ref_l = setup
    with MicroBatcher(engine, BatchPolicy(max_batch=16, max_wait_ms=2.0),
                      warmup_on_start=False) as mb:
        results = list(mb.stream(queries))
    assert len(results) == queries.shape[0]
    assert sorted(r.index for r in results) == list(range(queries.shape[0]))
    for r in results:
        assert r.ok and r.error is None
        np.testing.assert_array_equal(r.scores, ref_s[r.index])
        np.testing.assert_array_equal(r.labels, ref_l[r.index])


def test_stream_surfaces_shed_as_error_results(setup):
    engine, queries, *_ = setup
    real_run = engine._run

    def slow_run(xi, xv, tier=0):
        time.sleep(0.02)
        return real_run(xi, xv, tier=tier)

    engine._run = slow_run
    try:
        with MicroBatcher(
            engine, BatchPolicy(max_batch=8, max_wait_ms=1.0),
            admission=AdmissionPolicy(max_queue_depth=4),
            warmup_on_start=False,
        ) as mb:
            results = list(mb.stream(queries))
    finally:
        engine._run = real_run
    assert sorted(r.index for r in results) == list(range(queries.shape[0]))
    errs = [r for r in results if not r.ok]
    oks = [r for r in results if r.ok]
    assert errs and oks  # overload split the stream, but nothing vanished
    for r in errs:
        assert isinstance(r.error, Overloaded)
        assert r.scores is None and r.labels is None
