"""Label-partitioned scatter–gather index: exactness, manifest, placement.

The tentpole contract (ISSUE 4): ``partition_tree(tree, P)`` +
``ScatterGatherPlanner`` must return results **bitwise-identical** to the
unpartitioned tree — same labels, same score bits — for every MSCM method,
across P × beam × qt × score_mode, including uneven label ranges (a ragged
last partition). The low-sync ``sync="final"`` mode is pinned to its weaker
contract: the merged top-k *dominates* the exact result.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import XMRTree
from repro.index import (
    PartitionManifest,
    ScatterGatherPlanner,
    assign_partitions,
    default_split_level,
    partition_tree,
    place,
    rebalance,
    rebalance_bounds,
    reference_topk_width,
)
from repro.sparse import random_sparse_csc, random_sparse_csr
from tests.conftest import make_tree_weights

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def tree_and_queries():
    rng = np.random.default_rng(42)
    d, B = 150, 8
    ws = make_tree_weights(rng, d, [8, 64, 512], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    x = random_sparse_csr(11, d, 16, rng)
    xi, xv = map(jnp.asarray, x.to_ell())
    return tree, xi, xv


def _assert_bitwise(planner, tree, xi, xv, beam, topk, method, score_mode, qt=8):
    ref_s, ref_l = tree.infer(
        xi, xv, beam=beam, topk=topk, method=method, score_mode=score_mode,
        qt=qt,
    )
    s, l = planner.infer(xi, xv)
    np.testing.assert_array_equal(np.asarray(l), np.asarray(ref_l))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))


# ---------------------------------------------------------------------------
# 1. exact-mode bitwise parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", [
    "vanilla", "mscm_dense", "mscm_searchsorted", "mscm_pallas_grouped",
])
@pytest.mark.parametrize("n_partitions", [2, 4])
def test_partitioned_bitwise_every_method(tree_and_queries, method, n_partitions):
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, n_partitions)
    pl = ScatterGatherPlanner(idx, beam=10, topk=5, method=method)
    _assert_bitwise(pl, tree, xi, xv, 10, 5, method, "prod")


@pytest.mark.parametrize("score_mode", ["prod", "logsum"])
@pytest.mark.parametrize("beam", [1, 6])
def test_partitioned_bitwise_beam_and_mode(tree_and_queries, beam, score_mode):
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 3)
    pl = ScatterGatherPlanner(
        idx, beam=beam, topk=5, method="mscm_dense", score_mode=score_mode
    )
    _assert_bitwise(pl, tree, xi, xv, beam, 5, "mscm_dense", score_mode)


@pytest.mark.parametrize("qt", [4, 8])
def test_partitioned_bitwise_grouped_qt(tree_and_queries, qt):
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 2)
    pl = ScatterGatherPlanner(
        idx, beam=6, topk=5, method="mscm_pallas_grouped", qt=qt
    )
    _assert_bitwise(pl, tree, xi, xv, 6, 5, "mscm_pallas_grouped", "prod", qt)


def test_uneven_label_ranges(rng):
    """L not divisible by B and P not dividing the chunk count: the last
    partition is smaller (the global ragged tail lands there) and phantom
    columns never surface."""
    d, B = 90, 8
    ws = [random_sparse_csc(d, 6, 8, rng), random_sparse_csc(d, 42, 8, rng)]
    tree = XMRTree.from_weight_matrices(ws, [6, 8])
    x = random_sparse_csr(15, d, 12, rng)
    xi, xv = map(jnp.asarray, x.to_ell())
    idx = partition_tree(tree, 4)  # 6 chunks over 4 partitions: [2,1,2,1]
    sizes = [p.n_labels for p in idx.manifest.partitions]
    assert sum(sizes) == 42
    assert sizes[-1] < max(sizes)  # ragged tail: last partition is smaller
    pl = ScatterGatherPlanner(idx, beam=5, topk=7, method="mscm_dense")
    _assert_bitwise(pl, tree, xi, xv, 5, 7, "mscm_dense", "prod")
    s, l = pl.infer(xi, xv)
    assert np.asarray(l).max() < 42


def test_deeper_split_level(tree_and_queries):
    """An explicit (non-default) split level also holds the contract."""
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 4, level=2)
    assert idx.level == 2
    assert idx.head.depth == 2
    pl = ScatterGatherPlanner(idx, beam=6, topk=5, method="mscm_searchsorted")
    _assert_bitwise(pl, tree, xi, xv, 6, 5, "mscm_searchsorted", "prod")


# ---------------------------------------------------------------------------
# 2. hypothesis property: parity across P x beam x qt x score_mode
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        n_partitions=st.integers(2, 6),
        beam=st.integers(1, 12),
        qt=st.sampled_from([4, 8]),
        score_mode=st.sampled_from(["prod", "logsum"]),
        method=st.sampled_from(
            ["mscm_dense", "mscm_searchsorted", "mscm_pallas_grouped"]
        ),
        seed=st.integers(0, 2**16),
    )
    def test_partition_parity_property(
        n_partitions, beam, qt, score_mode, method, seed
    ):
        """partition(tree, P).infer == tree.infer, bitwise, for arbitrary
        P x beam x qt x score_mode draws (ISSUE 4 satellite)."""
        rng = np.random.default_rng(seed)
        d, B = 100, 6
        ws = make_tree_weights(rng, d, [6, 36, 216], B, nnz_per_col=8)
        tree = XMRTree.from_weight_matrices(ws, B)
        x = random_sparse_csr(7, d, 12, rng)
        xi, xv = map(jnp.asarray, x.to_ell())
        idx = partition_tree(tree, n_partitions)
        pl = ScatterGatherPlanner(
            idx, beam=beam, topk=5, method=method, score_mode=score_mode,
            qt=qt,
        )
        ref_s, ref_l = tree.infer(
            xi, xv, beam=beam, topk=5, method=method, score_mode=score_mode,
            qt=qt,
        )
        s, l = pl.infer(xi, xv)
        np.testing.assert_array_equal(np.asarray(l), np.asarray(ref_l))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[dev])")
    def test_partition_parity_property():
        pass


# ---------------------------------------------------------------------------
# 3. final-merge (low-sync) mode: dominance, not bitwise
# ---------------------------------------------------------------------------

def test_final_mode_dominates_exact(tree_and_queries):
    """Partition-local beams retain candidates global pruning discarded:
    every merged score must be >= its exact counterpart (recall >=)."""
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 4)
    pl = ScatterGatherPlanner(idx, beam=4, topk=5, sync="final")
    ref_s, _ = tree.infer(xi, xv, beam=4, topk=5, method="mscm_dense")
    s, l = pl.infer(xi, xv)
    assert s.shape == ref_s.shape
    assert np.all(np.asarray(s) >= np.asarray(ref_s))
    assert np.asarray(l).max() < tree.n_labels  # no phantom leaks


def test_reference_topk_width_matches_infer(tree_and_queries):
    tree, xi, xv = tree_and_queries
    for beam, topk in [(1, 10), (4, 5), (10, 10)]:
        s, _ = tree.infer(xi, xv, beam=beam, topk=topk)
        assert s.shape[1] == reference_topk_width(
            tree.n_cols, tree.branching, beam, topk
        )


# ---------------------------------------------------------------------------
# 4. manifest + extraction invariants
# ---------------------------------------------------------------------------

def test_manifest_ranges_and_memory(tree_and_queries):
    tree, *_ = tree_and_queries
    idx = partition_tree(tree, 4)
    m = idx.manifest
    # disjoint, contiguous, covering label ranges
    assert m.partitions[0].label_start == 0
    assert m.partitions[-1].label_end == tree.n_labels
    for a, b in zip(m.partitions, m.partitions[1:]):
        assert a.label_end == b.label_start
    # per-device model bytes shrink ~1/P (phantom pad chunks add slack)
    assert m.max_partition_bytes() < m.total_memory_bytes / 4 * 1.5
    assert m.shrink_ratio() > 2.0
    # hashes: content-derived, distinct per partition, stable across cuts
    hashes = [p.content_hash for p in m.partitions]
    assert len(set(hashes)) == len(hashes)
    m2 = partition_tree(tree, 4).manifest
    assert [p.content_hash for p in m2.partitions] == hashes


def test_manifest_json_roundtrip(tree_and_queries):
    tree, *_ = tree_and_queries
    m = partition_tree(tree, 3).manifest
    m2 = PartitionManifest.from_json(m.to_json())
    assert m2 == m


def test_partition_validation(tree_and_queries):
    tree, *_ = tree_and_queries
    with pytest.raises(ValueError):
        partition_tree(tree, 9, level=1)  # level 1 has only 8 chunks
    with pytest.raises(ValueError):
        partition_tree(tree, 513)  # deeper than any level's chunk count
    with pytest.raises(ValueError):
        partition_tree(tree, 0)
    with pytest.raises(ValueError):
        tree.head(0)
    with pytest.raises(ValueError):
        tree.extract(1, 5, 3)
    assert default_split_level(tree, 8) == 1
    assert default_split_level(tree, 9) == 2


def test_hit_counts(tree_and_queries):
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 4)
    pl = ScatterGatherPlanner(idx, beam=10, topk=10)
    _, l = pl.infer(xi, xv)
    hits = pl.hit_counts(np.asarray(l))
    assert hits.sum() == np.asarray(l).size
    assert len(hits) == 4


# ---------------------------------------------------------------------------
# 5. placement (LPT packing)
# ---------------------------------------------------------------------------

def test_assign_partitions_balances_memory():
    mem = [100, 90, 40, 30, 20, 10]
    out = assign_partitions(mem, 2)
    loads = [sum(m for m, b in zip(mem, out) if b == col) for col in (0, 1)]
    assert abs(loads[0] - loads[1]) <= 30  # LPT: within the smallest item-ish
    assert sorted(set(out)) == [0, 1]
    with pytest.raises(ValueError):
        assign_partitions(mem, 0)


def test_place_single_device(tree_and_queries):
    """One local device: everything packs onto one model column and the
    planner still runs (and stays bitwise) through the placement path."""
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 2)
    pm = place(idx, shards=1)
    assert pm.n_model == 1 and pm.n_data == 1
    assert pm.assignments == [0, 0]
    assert sum(pm.column_loads(idx.manifest)) == sum(
        p.memory_bytes for p in idx.manifest.partitions
    )
    pl = ScatterGatherPlanner(idx, beam=6, topk=5, placement=pm)
    _assert_bitwise(pl, tree, xi, xv, 6, 5, "mscm_dense", "prod")


def test_place_occupancy_weighting(tree_and_queries):
    """Observed load shares (not memory) drive the packing when given."""
    tree, *_ = tree_and_queries
    idx = partition_tree(tree, 4)
    # Device-free pin of the LPT-by-load behavior (CI has one device, so
    # n_model == 1 and the placement itself degenerates): a partition
    # serving ~everything must sit alone on a column while the cold ones
    # share the other — memory packing (near-equal bytes) would pair it.
    load = [int(o * 1e6) for o in (0.94, 0.02, 0.02, 0.02)]
    cols = assign_partitions(load, 2)
    assert cols.count(cols[0]) == 1
    mem_cols = assign_partitions(
        [p.memory_bytes for p in idx.manifest.partitions], 2
    )
    assert mem_cols.count(mem_cols[0]) == 2  # bytes packing pairs them
    pm = place(idx, shards=1, occupancy=[0.94, 0.02, 0.02, 0.02])
    if pm.n_model == 2:  # full path needs >= 2 local devices
        assert pm.assignments.count(pm.assignments[0]) == 1
    with pytest.raises(ValueError):
        place(idx, occupancy=[0.5, 0.5])  # wrong arity
    with pytest.raises(ValueError):
        place(idx, occupancy=[-1.0, 1.0, 0.5, 0.5])


# ---------------------------------------------------------------------------
# 6. rebalance from observed occupancy skew
# ---------------------------------------------------------------------------

def test_rebalance_bounds_uniform_is_stable(tree_and_queries):
    """Uniform observed load keeps the even cut."""
    tree, *_ = tree_and_queries
    m = partition_tree(tree, 4).manifest
    assert rebalance_bounds(m, [0.25, 0.25, 0.25, 0.25]) == [0, 2, 4, 6, 8]


def test_rebalance_shrinks_hot_partition(tree_and_queries):
    """A partition serving 2x its share gives chunks to its neighbours."""
    tree, *_ = tree_and_queries
    m = partition_tree(tree, 4).manifest  # even cut: 2 chunks each
    bounds = rebalance_bounds(m, [0.70, 0.10, 0.10, 0.10])
    assert bounds[0] == 0 and bounds[-1] == 8
    assert all(b < a for b, a in zip(bounds, bounds[1:]))
    # The hot partition's new range is narrower than its old 2 chunks.
    assert bounds[1] - bounds[0] < 2


def test_rebalance_roundtrip_stays_bitwise(tree_and_queries):
    """Re-cutting from skew changes ranges, not results."""
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 4)
    idx2 = rebalance(tree, idx.manifest, [0.55, 0.15, 0.15, 0.15])
    sizes = [p.chunk_end - p.chunk_start for p in idx2.manifest.partitions]
    assert sizes != [2, 2, 2, 2]  # the cut actually moved
    assert idx2.manifest.partitions[-1].label_end == tree.n_labels
    for sync in ("level", "pipelined"):
        pl = ScatterGatherPlanner(idx2, beam=10, topk=5, sync=sync)
        _assert_bitwise(pl, tree, xi, xv, 10, 5, "mscm_dense", "prod")


def test_rebalance_validation(tree_and_queries):
    tree, *_ = tree_and_queries
    m = partition_tree(tree, 4).manifest
    with pytest.raises(ValueError):
        rebalance_bounds(m, [0.5, 0.5])          # wrong arity
    with pytest.raises(ValueError):
        rebalance_bounds(m, [0.0, 0.0, 0.0, 0.0])  # zero total
    with pytest.raises(ValueError):
        partition_tree(tree, 4, bounds=[0, 1, 2, 8])        # wrong length
    with pytest.raises(ValueError):
        partition_tree(tree, 4, bounds=[0, 3, 3, 5, 8])     # not increasing
    with pytest.raises(ValueError):
        partition_tree(tree, 4, bounds=[1, 3, 5, 7, 8])     # not covering
