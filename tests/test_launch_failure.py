"""Launch-failure paths of the fleet launcher surface their cause.

Regression pins for the xmrlint XMR004 fixes: a failed ``launch_workers``
must (a) raise the *original* :class:`WorkerUnavailable` — never a cleanup
error masking it — and (b) log, not swallow, any failure while reaping the
partially-launched fleet. Uses fake worker processes (no subprocess spawn,
no JAX import in children) so the whole module runs in milliseconds.
"""

import json
import logging
import socket
import threading

import pytest

from repro.serving.admission import WorkerUnavailable
from repro.serving.fleet import launcher as launcher_mod
from repro.serving.fleet.launcher import launch_workers


class _FakeStdout:
    def __init__(self, line: str) -> None:
        self._line = line

    def readline(self) -> str:
        line, self._line = self._line, ""
        return line


class _FakeProc:
    """Just enough of subprocess.Popen for the launcher's failure path."""

    def __init__(self, announce_line: str, exit_code=None, kill_raises=False):
        self.stdout = _FakeStdout(announce_line)
        self.pid = 4242
        self._exit_code = exit_code
        self._kill_raises = kill_raises

    def poll(self):
        return self._exit_code

    def terminate(self):
        if self._kill_raises:
            raise RuntimeError("terminate refused (fake)")
        if self._exit_code is None:  # real Popen: no-op once exited
            self._exit_code = -15

    def kill(self):
        if self._kill_raises:
            raise RuntimeError("kill refused (fake)")
        if self._exit_code is None:
            self._exit_code = -9

    def wait(self, timeout=None):
        return self._exit_code


@pytest.fixture
def accept_socket():
    """A listening socket the 'announced' worker port points at, so the
    launcher's WorkerConnection can actually connect."""
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(4)
    accepted = []

    def _accept():
        try:
            while True:
                conn, _ = srv.accept()
                accepted.append(conn)
        except OSError:
            pass  # closed by teardown

    t = threading.Thread(target=_accept, daemon=True)
    t.start()
    yield srv.getsockname()[1]
    srv.close()
    for conn in accepted:
        conn.close()


def _fake_popen_factory(procs):
    it = iter(procs)

    def _factory(*args, **kwargs):
        return next(it)

    return _factory


def test_launch_failure_surfaces_cause(monkeypatch, accept_socket):
    """Worker 1 dying pre-announce raises WorkerUnavailable naming the exit
    code — the diagnosis the old silent cleanup used to bury."""
    announce = json.dumps({"port": accept_socket, "pid": 4242}) + "\n"
    procs = [
        _FakeProc(announce),
        _FakeProc("", exit_code=1),  # died before announcing
    ]
    monkeypatch.setattr(launcher_mod.subprocess, "Popen",
                        _fake_popen_factory(procs))
    with pytest.raises(WorkerUnavailable) as err:
        launch_workers(2, startup_timeout_s=5.0, rpc_timeout_s=5.0)
    msg = str(err.value)
    assert "no announcement" in msg
    assert "exit code 1" in msg


def test_launch_cleanup_failure_is_logged_not_masking(
    monkeypatch, accept_socket, caplog
):
    """A cleanup kill() blowing up during the reap must not replace the
    original launch error; it is logged as a warning instead."""
    announce = json.dumps({"port": accept_socket, "pid": 4242}) + "\n"
    procs = [
        _FakeProc(announce, kill_raises=True),  # reap of this one fails
        _FakeProc("", exit_code=1),
    ]
    monkeypatch.setattr(launcher_mod.subprocess, "Popen",
                        _fake_popen_factory(procs))
    with caplog.at_level(logging.WARNING, logger=launcher_mod.log.name):
        with pytest.raises(WorkerUnavailable):  # the original cause, not RuntimeError
            launch_workers(2, startup_timeout_s=5.0, rpc_timeout_s=5.0)
    assert any(
        "launch cleanup" in rec.message and "kill" in rec.message
        for rec in caplog.records
    )
