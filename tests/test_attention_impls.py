"""Chunked (flash-style) attention == naive masked attention (§Perf knob)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced_config
from repro.launch.specs import make_demo_batch
from repro.models import attention as A
from repro.models import lm as lm_lib


@settings(max_examples=12, deadline=None)
@given(
    sq=st.integers(1, 40),
    window=st.sampled_from([0, 3, 8]),
    kblock=st.sampled_from([4, 8, 16]),
    qblock=st.sampled_from([8, 32]),
    gqa=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunked_equals_naive(sq, window, kblock, qblock, gqa, seed):
    key = jax.random.PRNGKey(seed)
    b, hkv, dh = 2, 2, 8
    h = hkv * gqa
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sq, h, dh))
    k = jax.random.normal(ks[1], (b, sq, hkv, dh))
    v = jax.random.normal(ks[2], (b, sq, hkv, dh))
    mask = A.causal_window_mask(sq, sq, 0, window)
    want = A._sdpa(q, k, v, mask)
    got = A._chunked_sdpa(q, k, v, q_offset=0, window=window,
                          kblock=kblock, qblock=qblock)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch_id", ["yi-6b", "minicpm3-4b", "hymba-1.5b"])
def test_model_forward_chunked_matches_naive(arch_id):
    rng = np.random.default_rng(11)
    cfg = reduced_config(get_config(arch_id))
    cfg_c = dataclasses.replace(cfg, attn_impl="chunked", attn_kblock=8,
                                attn_qblock=8)
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_demo_batch(cfg, rng, 2, 24)
    l1, _ = lm_lib.forward_train(cfg, params, batch)
    l2, _ = lm_lib.forward_train(cfg_c, params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-3, atol=2e-3)


def test_bf16_activations_close_to_f32():
    rng = np.random.default_rng(12)
    cfg = reduced_config(get_config("yi-6b"))
    cfg_b = dataclasses.replace(cfg, activations_bf16=True)
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_demo_batch(cfg, rng, 2, 16)
    l1, _ = lm_lib.loss_fn(cfg, params, batch)
    l2, _ = lm_lib.loss_fn(cfg_b, params, batch)
    assert abs(float(l1) - float(l2)) / abs(float(l1)) < 0.05
    # grads still flow in mixed precision
    g = jax.grad(lambda p: lm_lib.loss_fn(cfg_b, p, batch)[0])(params)
    assert all(jnp.isfinite(x).all() for x in jax.tree.leaves(g))
