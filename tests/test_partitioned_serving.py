"""Partitioned serving == unpartitioned serving, over a real device mesh.

``ServeConfig(partitions=2, shards=2)`` builds a (2 data x 2 model) mesh on
4 forced host devices: each label partition lives on its own model column
with its batch dim split over the column's data replicas, behind the same
``MicroBatcher`` front end. Results must be bitwise-identical to the
unpartitioned single-device engine (ISSUE 4 acceptance). Runs in a
subprocess so the forced host-device-count XLA flag never leaks into other
tests (same pattern as tests/test_sharded_serving.py).
"""

import json
import os
import subprocess
import sys


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
from repro.core import XMRTree
from repro.serving import BatchPolicy, MicroBatcher, ServeConfig, XMRServingEngine
from repro.sparse import random_sparse_csc, random_sparse_csr

rng = np.random.default_rng(5)
d, B = 120, 8
Ws = [random_sparse_csc(d, 8, 10, rng, sibling_groups=B),
      random_sparse_csc(d, 64, 10, rng, sibling_groups=B),
      random_sparse_csc(d, 500, 10, rng, sibling_groups=B)]
tree = XMRTree.from_weight_matrices(Ws, B)
queries = random_sparse_csr(41, d, 15, rng)  # ragged tail: 41 = 16+16+9

e1 = XMRServingEngine(tree, ServeConfig(ell_width=32, max_batch=64))
ref_s, ref_l = e1.serve_batch(queries)

out = {"n_devices": len(jax.devices())}

# partitions=2, shards=1: model-parallel only (2 columns x 1 replica)
e2 = XMRServingEngine(
    tree, ServeConfig(ell_width=32, max_batch=64, partitions=2))
s2, l2 = e2.serve_batch(queries)
out["p2_batch_bitwise"] = bool(
    np.array_equal(s2, ref_s) and np.array_equal(l2, ref_l))
out["p2_mesh"] = dict(e2.mesh.shape)

# partitions=2, shards=2: model-parallel x data-parallel on all 4 devices,
# through the async micro-batching front end.
e4 = XMRServingEngine(
    tree, ServeConfig(ell_width=32, max_batch=64, partitions=2, shards=2))
out["p2s2_mesh"] = dict(e4.mesh.shape)
out["min_bucket"] = int(e4.bucket_for(1))
with MicroBatcher(e4, BatchPolicy(max_batch=16, max_wait_ms=5.0)) as mb:
    res = [f.result(timeout=120) for f in mb.submit_csr(queries)]
mb_s = np.stack([r[0] for r in res])
mb_l = np.stack([r[1] for r in res])
out["p2s2_microbatch_bitwise"] = bool(
    np.array_equal(mb_s, ref_s) and np.array_equal(mb_l, ref_l))

summ = mb.metrics.summary()
occ = summ.get("partition_occupancy", [])
out["occupancy_len"] = len(occ)
out["occupancy_sums_to_one"] = bool(abs(sum(occ) - 1.0) < 1e-6)

# manifest: per-device model bytes shrink vs the unpartitioned tree
m = e4.index.manifest
out["max_part_frac"] = m.max_partition_bytes() / m.total_memory_bytes
out["shrink_ratio"] = m.shrink_ratio()

# per-partition profile runs on the placed mesh
prof = e4.planner.profile(*e4.marshal_rows(queries, np.arange(8), 8))
out["profile_len"] = len(prof)
print(json.dumps(out))
"""


def test_partitioned_sharded_serving_bitwise():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 4
    assert res["p2_batch_bitwise"], res
    assert res["p2_mesh"] == {"data": 1, "model": 2}, res
    assert res["p2s2_microbatch_bitwise"], res
    assert res["p2s2_mesh"] == {"data": 2, "model": 2}, res
    assert res["min_bucket"] == 2  # sharded dispatch always splits evenly
    assert res["occupancy_len"] == 2, res
    assert res["occupancy_sums_to_one"], res
    # the label layer dominates: each partition holds well under 1/2 + slack
    assert res["max_part_frac"] < 0.75, res
    assert res["shrink_ratio"] > 1.3, res
    assert res["profile_len"] == 2, res
