"""Pallas MSCM kernel validation (interpret mode) against the jnp oracle.

Sweeps shapes/dtypes per the assignment; every kernel variant must match
``ref.mscm_ref`` allclose. TPU is the target; interpret=True executes the
kernel bodies on CPU. The hypothesis property sweep is skipped when
hypothesis is not installed; everything else runs everywhere.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dependency
    HAS_HYPOTHESIS = False

from repro.core import mscm as M
from repro.core.chunked import ChunkedLayer
from repro.kernels import ops
from repro.kernels import ref as ref_lib
from repro.kernels.mscm_kernel import group_blocks_by_chunk
from repro.sparse import random_sparse_csc, random_sparse_csr


def _mk(rng, n, d, C, B, nnz_w, nnz_x, A):
    w = random_sparse_csc(d, C * B, nnz_w, rng, sibling_groups=B)
    ch = ChunkedLayer.from_csc(w, B)
    x = random_sparse_csr(n, d, nnz_x, rng)
    xi, xv = x.to_ell()
    xd = M.scatter_dense(jnp.asarray(xi), jnp.asarray(xv), d)
    bq = rng.integers(0, n, size=A).astype(np.int32)
    bc = rng.integers(0, C, size=A).astype(np.int32)
    rows, vals = jnp.asarray(ch.rows), jnp.asarray(ch.vals)
    want = np.asarray(ref_lib.mscm_ref(xd, rows, vals, jnp.asarray(bq), jnp.asarray(bc)))
    return xd, rows, vals, bq, bc, want


@pytest.mark.parametrize("variant", ["fused", "pregather"])
def test_pallas_variants_basic(rng, variant):
    xd, rows, vals, bq, bc, want = _mk(rng, n=5, d=96, C=4, B=8, nnz_w=8, nnz_x=12, A=10)
    got = ops.mscm_pallas(
        xd, rows, vals, jnp.asarray(bq), jnp.asarray(bc), variant=variant, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("sort", [True, False])
def test_pallas_sort_invariance(rng, sort):
    """Chunk-sorted evaluation (paper's final §4 optimization) is a pure
    schedule change — results are identical in any block order."""
    xd, rows, vals, bq, bc, want = _mk(rng, n=4, d=64, C=6, B=4, nnz_w=6, nnz_x=9, A=12)
    got = ops.mscm_pallas(
        xd, rows, vals, jnp.asarray(bq), jnp.asarray(bc), sort=sort, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


def test_pallas_duplicate_chunks_revisit(rng):
    """Many queries hitting the same chunk (the revisit fast path)."""
    xd, rows, vals, _, _, _ = _mk(rng, n=8, d=80, C=3, B=8, nnz_w=8, nnz_x=10, A=1)
    bq = np.arange(8, dtype=np.int32)
    bc = np.zeros(8, dtype=np.int32)  # all blocks -> chunk 0
    want = np.asarray(ref_lib.mscm_ref(xd, rows, vals, jnp.asarray(bq), jnp.asarray(bc)))
    got = ops.mscm_pallas(xd, rows, vals, jnp.asarray(bq), jnp.asarray(bc), interpret=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("qt", [2, 4, 8])
def test_grouped_kernel(rng, qt):
    xd, rows, vals, bq, bc, want = _mk(rng, n=7, d=72, C=5, B=8, nnz_w=7, nnz_x=11, A=17)
    got = ops.mscm_pallas_grouped(xd, rows, vals, bq, bc, qt=qt, interpret=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


def test_grouped_bitwise_vs_dense_lookup(rng):
    """The grouped kernel's per-block result is *bitwise* the dense-lookup
    einsum — row independence of the tile matmul, pinned at kernel level."""
    xd, rows, vals, bq, bc, _ = _mk(rng, n=6, d=90, C=4, B=8, nnz_w=8, nnz_x=10, A=13)
    dense = M.mscm_dense_lookup(xd, rows, vals, jnp.asarray(bq), jnp.asarray(bc))
    got = ops.mscm_pallas_grouped(xd, rows, vals, bq, bc, qt=4, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))


@pytest.mark.parametrize("mode", ["prod", "logsum"])
def test_grouped_fused_epilogue(rng, mode):
    """σ⊗parent epilogue fused in-kernel == epilogue applied to raw logits."""
    xd, rows, vals, bq, bc, _ = _mk(rng, n=6, d=90, C=4, B=8, nnz_w=8, nnz_x=10, A=13)
    ps = jnp.asarray(rng.random(13).astype(np.float32))
    raw = M.mscm_dense_lookup(xd, rows, vals, jnp.asarray(bq), jnp.asarray(bc))
    if mode == "prod":
        want = jax.nn.sigmoid(raw) * ps[:, None]
    else:
        want = jax.nn.log_sigmoid(raw) + ps[:, None]
    got = ops.mscm_pallas_grouped(
        xd, rows, vals, bq, bc, ps, qt=4, mode=mode, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_group_blocks_by_chunk():
    bc = np.array([3, 1, 3, 3, 0, 1], np.int32)
    tile_c, tile_src = group_blocks_by_chunk(bc, qt=2)
    # every block appears exactly once
    members = tile_src[tile_src >= 0]
    assert sorted(members.tolist()) == list(range(6))
    # each tile's members share the tile's chunk
    for t in range(len(tile_c)):
        for s in tile_src[t]:
            if s >= 0:
                assert bc[s] == tile_c[t]
    # chunk 3 has 3 members -> two tiles (2 + 1 padded)
    assert (tile_c == 3).sum() == 2


@pytest.mark.parametrize("qt", [1, 2, 4, 8])
def test_group_blocks_device_matches_host(rng, qt):
    """In-jit grouping reproduces the host reference packing exactly, with
    padding tiles masked and parked on the last resident chunk."""
    for _ in range(10):
        a = int(rng.integers(1, 40))
        c = int(rng.integers(1, 12))
        bc = rng.integers(0, c, size=a).astype(np.int32)
        want_c, want_s = group_blocks_by_chunk(bc, qt)
        tc, ts, order, flat_pos = jax.jit(
            ops.group_blocks_device, static_argnums=(1, 2)
        )(jnp.asarray(bc), qt, c)
        tc, ts, order, flat_pos = map(np.asarray, (tc, ts, order, flat_pos))
        t_static = ops.grouped_tile_bound(a, qt, c)
        assert len(tc) == t_static and len(want_c) <= t_static
        nreal = len(want_c)
        np.testing.assert_array_equal(tc[:nreal], want_c)
        np.testing.assert_array_equal(ts[:nreal], want_s)
        assert (ts[nreal:] == -1).all()
        # padding tiles revisit the last real chunk (no fresh DMA on TPU)
        assert (tc[nreal:] == want_c[-1]).all()
        # flat_pos round-trips each sorted block to its tile slot
        np.testing.assert_array_equal(ts.reshape(-1)[flat_pos], order)


def test_unsort_is_gather_inverse(rng):
    """unsort == indexing through the inverse permutation (no scatter)."""
    a = 17
    order = jnp.asarray(rng.permutation(a).astype(np.int32))
    x = jnp.asarray(rng.random((a, 4)).astype(np.float32))
    got = ops.unsort(x[order], order)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_force_interpret_env(monkeypatch):
    """MSCM_FORCE_INTERPRET pins interpret mode regardless of backend."""
    monkeypatch.setenv("MSCM_FORCE_INTERPRET", "1")
    assert ops._auto_interpret(None) is True
    monkeypatch.setenv("MSCM_FORCE_INTERPRET", "0")
    assert ops._auto_interpret(None) is False
    monkeypatch.setenv("MSCM_FORCE_INTERPRET", "false")
    assert ops._auto_interpret(None) is False
    monkeypatch.delenv("MSCM_FORCE_INTERPRET")
    assert ops._auto_interpret(None) == (jax.default_backend() != "tpu")
    # explicit argument always wins
    monkeypatch.setenv("MSCM_FORCE_INTERPRET", "0")
    assert ops._auto_interpret(True) is True


if HAS_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 6),
        d=st.integers(8, 300),
        c=st.integers(1, 6),
        b=st.sampled_from([2, 8, 32]),
        nnz_w=st.integers(1, 12),
        nnz_x=st.integers(1, 16),
        a=st.integers(1, 16),
        variant=st.sampled_from(["fused", "pregather"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_pallas_property_sweep(n, d, c, b, nnz_w, nnz_x, a, variant, seed):
        rng = np.random.default_rng(seed)
        xd, rows, vals, bq, bc, want = _mk(
            rng, n=n, d=d, C=c, B=b, nnz_w=min(nnz_w, d), nnz_x=min(nnz_x, d), A=a
        )
        got = ops.mscm_pallas(
            xd, rows, vals, jnp.asarray(bq), jnp.asarray(bc), variant=variant, interpret=True
        )
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[dev])")
    def test_pallas_property_sweep():
        pass


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_dtype_sweep(rng, dtype):
    """bf16 weights path (serving quantization) stays within bf16 tolerance."""
    xd, rows, vals, bq, bc, _ = _mk(rng, n=4, d=64, C=3, B=8, nnz_w=6, nnz_x=8, A=8)
    vals16 = vals.astype(dtype)
    xd16 = xd.astype(dtype)
    want = np.asarray(
        ref_lib.mscm_ref(xd16.astype(jnp.float32), rows, vals16.astype(jnp.float32),
                         jnp.asarray(bq), jnp.asarray(bc))
    )
    got = ops.mscm_pallas(xd16, rows, vals16, jnp.asarray(bq), jnp.asarray(bc),
                          interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), want, rtol=tol, atol=tol)
