"""Sharded (data-parallel replicated) serving == single-device serving.

The engine replicates the tree over a 1-D device mesh and splits every
dispatched bucket's batch dim across the replicas; per-query arithmetic is
untouched, so results must be bitwise-identical. Runs in a subprocess so the
forced host-device-count XLA flag never leaks into other tests (same pattern
as tests/test_distributed_xmr.py).
"""

import json
import os
import subprocess
import sys


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
from repro.core import XMRTree
from repro.serving import BatchPolicy, MicroBatcher, ServeConfig, XMRServingEngine
from repro.sparse import random_sparse_csc, random_sparse_csr

rng = np.random.default_rng(5)
d, B = 120, 8
Ws = [random_sparse_csc(d, 8, 10, rng, sibling_groups=B),
      random_sparse_csc(d, 64, 10, rng, sibling_groups=B),
      random_sparse_csc(d, 512, 10, rng, sibling_groups=B)]
tree = XMRTree.from_weight_matrices(Ws, B)
queries = random_sparse_csr(45, d, 15, rng)  # ragged tail: 45 = 16+16+13

e1 = XMRServingEngine(tree, ServeConfig(ell_width=32, max_batch=64, shards=1))
ref_s, ref_l = e1.serve_batch(queries)

out = {"n_devices": len(jax.devices())}

e2 = XMRServingEngine(tree, ServeConfig(ell_width=32, max_batch=64, shards=2))
s2, l2 = e2.serve_batch(queries)
out["batch2_bitwise"] = bool(
    np.array_equal(s2, ref_s) and np.array_equal(l2, ref_l))

e4 = XMRServingEngine(tree, ServeConfig(ell_width=32, max_batch=64, shards=4))
so, lo = e4.serve_online(queries)
out["online4_bitwise"] = bool(
    np.array_equal(so, ref_s) and np.array_equal(lo, ref_l))

with MicroBatcher(e4, BatchPolicy(max_batch=16, max_wait_ms=5.0)) as mb:
    res = [f.result(timeout=120) for f in mb.submit_csr(queries)]
mb_s = np.stack([r[0] for r in res])
mb_l = np.stack([r[1] for r in res])
out["microbatch4_bitwise"] = bool(
    np.array_equal(mb_s, ref_s) and np.array_equal(mb_l, ref_l))

summ = mb.metrics.summary()
occ = summ.get("replica_occupancy", [])
out["occupancy_len"] = len(occ)
# real rows fill the bucket head: occupancy must be non-increasing by replica
out["occupancy_monotone"] = bool(
    all(occ[i] >= occ[i + 1] for i in range(len(occ) - 1)))
out["mesh_devices"] = int(np.prod(list(e4.mesh.shape.values())))

# bucket_for never forms a bucket the mesh cannot split
out["min_bucket"] = int(e4.bucket_for(1))
print(json.dumps(out))
"""


def test_sharded_serving_bitwise_matches_single_device():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 4
    assert res["batch2_bitwise"], res
    assert res["online4_bitwise"], res
    assert res["microbatch4_bitwise"], res
    assert res["occupancy_len"] == 4, res
    assert res["occupancy_monotone"], res
    assert res["mesh_devices"] == 4
    assert res["min_bucket"] == 4  # sharded dispatch always splits evenly
