"""Quantized serving tiers (ISSUE 9): storage, kernel parity, serving seams.

Pins the tier contract at every layer:

* storage — per-(chunk, column) symmetric scales bound the dequant error by
  ``scale / 2`` per weight (hypothesis property); the pruned re-pack keeps
  the heavy rows **bitwise** and only ever shrinks the pad width.
* kernel — ``mscm_pallas_grouped_q`` (in-register dequant) is bitwise what
  the exact grouped kernel returns on the dequantized f32 weights:
  quantization error comes from storage, never from the kernel.
* serving — ``tier="exact"`` stays bitwise the unquantized engine;
  ``tier="int8"`` results are topology-invariant (P, sync mode, in-process
  vs subprocess fleet) because quantization happens per partition *after*
  the split; the manifest records tier/dtype/compressed bytes (schema v2)
  and still reads v1 documents.
"""

import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.core import XMRTree
from repro.index import ScatterGatherPlanner, partition_tree
from repro.index.partition import MANIFEST_VERSION, PartitionManifest
from repro.quant import (
    QUANT_DTYPES,
    QuantizedTree,
    dequantize_layer,
    dequantize_tree,
    prune_chunks,
    quantize_index,
    quantize_layer,
    quantize_tree,
)
from repro.serving import PartitionConfig, QuantConfig, ServeConfig, XMRServingEngine
from repro.sparse import random_sparse_csr
from tests.conftest import make_tree_weights

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def quant_setup():
    rng = np.random.default_rng(29)
    d, B = 200, 8
    ws = make_tree_weights(rng, d, [8, 64, 512], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    queries = random_sparse_csr(16, d, 15, rng)
    import jax.numpy as jnp

    xi, xv = map(jnp.asarray, queries.to_ell(32))
    return tree, queries, xi, xv


def _assert_bitwise(got, ref):
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))
    s_got = np.asarray(got[0], np.float32)
    s_ref = np.asarray(ref[0], np.float32)
    assert np.array_equal(s_got.view(np.uint32), s_ref.view(np.uint32))


# ---------------------------------------------------------------------------
# 1. storage: scale math, error bound, pruned re-pack
# ---------------------------------------------------------------------------

def test_quantize_dequantize_error_bound(quant_setup):
    """Worst-case |dequant - original| <= scale / 2 per weight (int8)."""
    tree, *_ = quant_setup
    for lay in tree.layers:
        q = quantize_layer(lay)
        deq = dequantize_layer(q, d=tree.d)
        err = np.abs(
            np.asarray(deq.chunk_vals) - np.asarray(lay.chunk_vals)
        )
        bound = np.asarray(q.chunk_scales)[:, None, :] * (0.5 + 1e-5)
        assert (err <= bound).all()
        assert np.asarray(q.chunk_vals).dtype == np.int8
        # the ELL mask is never perturbed
        np.testing.assert_array_equal(
            np.asarray(q.chunk_rows), np.asarray(lay.chunk_rows)
        )


def test_zero_column_dequantizes_to_exact_zero(quant_setup):
    """All-zero columns take scale 1 (no 0/0) and reconstruct exactly 0."""
    tree, *_ = quant_setup
    lay = tree.layers[-1]
    vals = np.asarray(lay.chunk_vals).copy()
    vals[:, :, 0] = 0.0  # zero out one column per chunk
    q = quantize_layer(lay, vals=vals)
    scales = np.asarray(q.chunk_scales)
    assert (scales[:, 0] == 1.0).all()
    deq = np.asarray(dequantize_layer(q, d=tree.d).chunk_vals)
    assert (deq[:, :, 0] == 0.0).all()


def test_prune_chunks_keeps_heavy_rows_bitwise(quant_setup):
    tree, *_ = quant_setup
    lay = tree.layers[-1]
    rows = np.asarray(lay.chunk_rows)
    vals = np.asarray(lay.chunk_vals)
    keep_frac = 0.5
    new_rows, new_vals = prune_chunks(rows, vals, keep_frac, sentinel=tree.d)
    c, r_new = new_rows.shape
    assert r_new % 8 == 0 and r_new >= 8
    assert r_new <= rows.shape[1]
    for ci in range(c):
        valid = rows[ci] != tree.d
        nnz = int(valid.sum())
        expect_keep = int(np.ceil(keep_frac * nnz))
        got_valid = new_rows[ci] != tree.d
        assert int(got_valid.sum()) == expect_keep
        # survivors are exactly the top-|.| rows (stable: low index on ties)
        mag = np.where(valid, np.abs(vals[ci]).max(axis=1), -1.0)
        order = np.argsort(-mag, kind="stable")[:expect_keep]
        expect_rows = rows[ci][np.sort(order)]          # ascending row order
        np.testing.assert_array_equal(new_rows[ci][:expect_keep], expect_rows)
        # kept weights are bitwise the originals
        np.testing.assert_array_equal(
            new_vals[ci][:expect_keep], vals[ci][np.sort(order)]
        )
        # padding is sentinel/0
        assert (new_rows[ci][expect_keep:] == tree.d).all()
        assert (new_vals[ci][expect_keep:] == 0.0).all()


def test_prune_chunks_keep_frac_one_is_lossless(quant_setup):
    tree, *_ = quant_setup
    lay = tree.layers[0]
    rows = np.asarray(lay.chunk_rows)
    vals = np.asarray(lay.chunk_vals)
    new_rows, new_vals = prune_chunks(rows, vals, 1.0, sentinel=tree.d)
    for ci in range(rows.shape[0]):
        valid = rows[ci] != tree.d
        np.testing.assert_array_equal(new_rows[ci][: valid.sum()],
                                      rows[ci][valid])
        np.testing.assert_array_equal(new_vals[ci][: valid.sum()],
                                      vals[ci][valid])


def test_prune_chunks_rejects_bad_keep_frac(quant_setup):
    tree, *_ = quant_setup
    lay = tree.layers[0]
    for bad in (0.0, -0.1, 1.5):
        with pytest.raises(ValueError, match="keep_frac"):
            prune_chunks(np.asarray(lay.chunk_rows),
                         np.asarray(lay.chunk_vals), bad, sentinel=tree.d)


def test_quantized_tree_cannot_be_resplit(quant_setup):
    tree, *_ = quant_setup
    qtree = quantize_tree(tree)
    with pytest.raises(TypeError, match="quantize per partition"):
        qtree.head(1)
    with pytest.raises(TypeError, match="quantize per partition"):
        qtree.extract(1, 0, 4)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(
        c=st.integers(1, 4), r=st.integers(1, 12), b=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_error_bound_property(c, r, b, seed):
        """|dequant - v| <= scale/2 for arbitrary chunk tiles (int8)."""
        rng = np.random.default_rng(seed)
        vals = (rng.standard_normal((c, r, b)) *
                10.0 ** rng.integers(-3, 3)).astype(np.float32)
        lay = dataclasses.make_dataclass("L", ["chunk_rows", "chunk_vals"])(
            chunk_rows=np.zeros((c, r), np.int32), chunk_vals=vals,
        )
        q = quantize_layer(lay)
        scales = np.asarray(q.chunk_scales)
        deq = (np.asarray(q.chunk_vals).astype(np.float32)
               * scales[:, None, :])
        assert (np.abs(deq - vals) <= scales[:, None, :] * (0.5 + 1e-5)).all()
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[dev])")
    def test_error_bound_property():
        pass


# ---------------------------------------------------------------------------
# 2. kernel: fused dequant == dequantize-then-exact, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tier", ["int8", "int8_pruned"])
def test_kernel_parity_bitwise(quant_setup, tier):
    tree, _, xi, xv = quant_setup
    qtree = quantize_tree(tree, tier=tier)
    ref = jax.block_until_ready(
        dequantize_tree(qtree).infer(
            xi, xv, beam=10, topk=5, method="mscm_pallas_grouped"
        )
    )
    got = jax.block_until_ready(
        qtree.infer(xi, xv, beam=10, topk=5, method="mscm_pallas_grouped_q")
    )
    _assert_bitwise(got, ref)


def test_int8_recall_close_to_exact(quant_setup):
    """Not bitwise — the tolerance contract: int8 recall@5 stays high."""
    from repro.quant import recall_at_k

    tree, _, xi, xv = quant_setup
    ref = tree.infer(xi, xv, beam=10, topk=5, method="mscm_pallas_grouped")
    qtree = quantize_tree(tree, tier="int8")
    got = qtree.infer(xi, xv, beam=10, topk=5,
                      method="mscm_pallas_grouped_q")
    assert recall_at_k(ref[1], got[1]) >= 0.9


# ---------------------------------------------------------------------------
# 3. serving: exact tier untouched, tier topology-invariance, config seams
# ---------------------------------------------------------------------------

def test_exact_tier_is_bitwise_unchanged(quant_setup):
    """The default tier serves the f32 tree exactly as before this PR."""
    tree, queries, xi, xv = quant_setup
    engine = XMRServingEngine(tree, ServeConfig(ell_width=32, max_batch=64))
    assert engine.config.tier == "exact"
    ref = tree.infer(xi, xv, beam=engine.config.beam,
                     topk=engine.config.topk, method=engine.method)
    _assert_bitwise(engine.serve_batch(queries), ref)


def test_int8_engine_unpartitioned(quant_setup):
    tree, queries, *_ = quant_setup
    engine = XMRServingEngine(
        tree, ServeConfig(ell_width=32, max_batch=64,
                          quant=QuantConfig(tier="int8")),
    )
    assert engine.method == "mscm_pallas_grouped_q"
    assert isinstance(engine.tree, QuantizedTree)
    s, l = engine.serve_batch(queries)
    assert s.shape == l.shape


def test_quant_tier_with_explicit_exact_method_raises(quant_setup):
    tree, *_ = quant_setup
    with pytest.raises(ValueError, match="mscm_pallas_grouped_q"):
        XMRServingEngine(
            tree, ServeConfig(ell_width=32, method="mscm_dense",
                              quant=QuantConfig(tier="int8")),
        )


@pytest.mark.parametrize("tier", ["int8", "int8_pruned"])
def test_tier_parity_across_topologies(quant_setup, tier):
    """Same bits from P=2/P=4 x level/pipelined: quantize-per-partition
    must not depend on how the label space is split or synced."""
    tree, _, xi, xv = quant_setup
    runs = []
    for p in (2, 4):
        qidx = quantize_index(partition_tree(tree, p), tier=tier)
        for sync in ("level", "pipelined"):
            pl = ScatterGatherPlanner(
                qidx, beam=10, topk=5,
                method="mscm_pallas_grouped_q", sync=sync,
            )
            runs.append(jax.block_until_ready(pl.infer(xi, xv)))
    for r in runs[1:]:
        _assert_bitwise(r, runs[0])


def test_quantconfig_validation():
    with pytest.raises(ValueError, match="tier"):
        QuantConfig(tier="int4")
    with pytest.raises(ValueError, match="prune_keep"):
        QuantConfig(tier="int8_pruned", prune_keep=0.0)


def test_serveconfig_flat_kwarg_shim():
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        cfg = ServeConfig(tier="int8_pruned", prune_keep=0.25)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert cfg.quant.tier == "int8_pruned"
    assert cfg.tier == "int8_pruned"          # flat read property
    assert cfg.quant.prune_keep == 0.25


# ---------------------------------------------------------------------------
# 4. manifest v2 + checkpoint round-trips
# ---------------------------------------------------------------------------

def test_manifest_v2_records_tier_and_compressed_bytes(quant_setup):
    tree, *_ = quant_setup
    idx = partition_tree(tree, 2)
    qidx = quantize_index(idx, tier="int8")
    m = qidx.manifest
    assert m.version == MANIFEST_VERSION == 2
    for info, qinfo in zip(idx.manifest.partitions, m.partitions):
        assert (info.tier, info.dtype) == ("exact", "float32")
        assert (qinfo.tier, qinfo.dtype) == ("int8", "int8")
        assert qinfo.memory_bytes < info.memory_bytes
        assert qinfo.content_hash != info.content_hash
    # round-trip preserves the tier columns
    again = PartitionManifest.from_json(m.to_json())
    assert again == m


def test_manifest_reads_v1_documents(quant_setup):
    """A pre-tier manifest (no tier/dtype rows) loads with exact defaults."""
    import json

    tree, *_ = quant_setup
    m = partition_tree(tree, 2).manifest
    doc = json.loads(m.to_json())
    doc["version"] = 1
    for row in doc["partitions"]:
        del row["tier"], row["dtype"]
    v1 = PartitionManifest.from_json(json.dumps(doc))
    assert v1.version == MANIFEST_VERSION
    assert all(p.tier == "exact" and p.dtype == "float32"
               for p in v1.partitions)
    with pytest.raises(ValueError, match="version"):
        PartitionManifest.from_json(json.dumps({**doc, "version": 99}))


def test_checkpoint_roundtrip_quantized_layers(quant_setup, tmp_path):
    """QuantLayerArrays survive the npy checkpoint path with int8 intact."""
    from repro.checkpoint import Checkpointer

    tree, *_ = quant_setup
    qtree = quantize_tree(tree, tier="int8")
    ckpt = Checkpointer(str(tmp_path), async_write=False)
    ckpt.save(0, {"layers": qtree.layers})
    step, out = ckpt.restore({"layers": qtree.layers})
    assert step == 0
    restored = QuantizedTree(
        layers=out["layers"], n_cols=qtree.n_cols,
        branching=qtree.branching, d=qtree.d, tier=qtree.tier,
    )
    for a, b in zip(qtree.layers, restored.layers):
        assert np.asarray(b.chunk_vals).dtype == np.int8
        np.testing.assert_array_equal(np.asarray(a.chunk_vals),
                                      np.asarray(b.chunk_vals))
        np.testing.assert_array_equal(np.asarray(a.chunk_scales),
                                      np.asarray(b.chunk_scales))
        np.testing.assert_array_equal(np.asarray(a.chunk_rows),
                                      np.asarray(b.chunk_rows))


# ---------------------------------------------------------------------------
# 5. fleet: subprocess parity + the fp8 wire guard
# ---------------------------------------------------------------------------

def test_fleet_int8_bitwise_vs_in_process(quant_setup):
    """The acceptance pin: tier="int8" through real worker subprocesses
    returns exactly the in-process quantized engine's bits."""
    from repro.serving.fleet import PartitionFleet

    tree, queries, *_ = quant_setup
    cfg = ServeConfig(
        ell_width=32, max_batch=64,
        partition=PartitionConfig(partitions=2, partition_sync="pipelined"),
        quant=QuantConfig(tier="int8"),
    )
    ref_engine = XMRServingEngine(tree, cfg)
    assert all(p.tier == "int8" for p in ref_engine.index.manifest.partitions)
    ref = ref_engine.serve_batch(queries)

    engine = XMRServingEngine(tree, cfg)
    with PartitionFleet.launch(2, rpc_timeout_s=120.0) as fleet:
        fleet.attach(engine)
        got = engine.serve_batch(queries)
    _assert_bitwise(got, ref)


@pytest.mark.skipif("fp8" not in QUANT_DTYPES,
                    reason="jax build lacks float8_e4m3fn")
def test_fleet_rejects_fp8_wire(quant_setup):
    """fp8 serves in-process only: numpy dtype strings cannot carry
    ml_dtypes over the RPC wire, so shipping it must fail loudly."""
    from repro.serving.fleet.launcher import partition_payload

    tree, *_ = quant_setup
    qidx = quantize_index(partition_tree(tree, 2), tier="fp8")
    with pytest.raises(ValueError, match="int8"):
        partition_payload(qidx, 0, beam=10, topk=5,
                          method="mscm_pallas_grouped_q",
                          score_mode="prod", qt=8)


@pytest.mark.skipif("fp8" not in QUANT_DTYPES,
                    reason="jax build lacks float8_e4m3fn")
def test_fp8_tier_in_process(quant_setup):
    tree, _, xi, xv = quant_setup
    qtree = quantize_tree(tree, tier="fp8")
    ref = dequantize_tree(qtree).infer(
        xi, xv, beam=10, topk=5, method="mscm_pallas_grouped"
    )
    got = qtree.infer(xi, xv, beam=10, topk=5,
                      method="mscm_pallas_grouped_q")
    _assert_bitwise(got, ref)
