"""End-to-end tree inference: Algorithm 1 with every masked-matmul method.

Pins the paper's exactness claim at the system level: beam search returns
*identical* labels and scores for vanilla, MSCM (both iterators), and both
Pallas kernels.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import METHODS, XMRTree
from repro.sparse import random_sparse_csr
from tests.conftest import brute_force_scores, make_tree_weights


@pytest.fixture
def small_tree(rng):
    d, B = 150, 8
    ws = make_tree_weights(rng, d, [8, 64, 512], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    x = random_sparse_csr(12, d, 18, rng)
    xi, xv = x.to_ell()
    return tree, ws, x, jnp.asarray(xi), jnp.asarray(xv)


def test_full_beam_equals_brute_force(small_tree):
    tree, ws, x, xi, xv = small_tree
    ref = brute_force_scores(x.to_dense(), ws)
    ref_top = np.argsort(-ref, axis=1, kind="stable")[:, :5]
    ref_s = np.take_along_axis(ref, ref_top, axis=1)
    s, l = tree.infer(xi, xv, beam=512, topk=5)  # beam == L => exact search
    np.testing.assert_array_equal(np.asarray(l), ref_top)
    np.testing.assert_allclose(np.asarray(s), ref_s, rtol=1e-5, atol=1e-6)


# The quantized tier's method (suffix ``_q``) is the documented exception
# to the exact-parity claim: it needs a QuantizedTree and its contract
# (bitwise vs the exact grouped kernel on dequantized weights) lives in
# tests/test_quant.py.
@pytest.mark.parametrize(
    "method", [m for m in METHODS if not m.endswith("_q")]
)
def test_methods_identical(small_tree, method):
    """The paper's 'free of charge' claim: every exact method, same results."""
    tree, ws, x, xi, xv = small_tree
    s0, l0 = tree.infer(xi, xv, beam=10, topk=5, method="vanilla")
    s, l = tree.infer(xi, xv, beam=10, topk=5, method=method)
    np.testing.assert_array_equal(np.asarray(l), np.asarray(l0))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s0), rtol=1e-5, atol=1e-6)


def test_log_space_ranking_matches_prod(small_tree):
    tree, ws, x, xi, xv = small_tree
    s_p, l_p = tree.infer(xi, xv, beam=10, topk=5, score_mode="prod")
    s_l, l_l = tree.infer(xi, xv, beam=10, topk=5, score_mode="logsum")
    np.testing.assert_array_equal(np.asarray(l_p), np.asarray(l_l))
    np.testing.assert_allclose(np.exp(np.asarray(s_l)), np.asarray(s_p), rtol=1e-4)


def test_beam_widening_converges_to_exact(small_tree):
    """P@1 under beam search increases to exact-search P@1 as b grows."""
    tree, ws, x, xi, xv = small_tree
    ref = brute_force_scores(x.to_dense(), ws)
    exact_top1 = np.argmax(ref, axis=1)
    hits = []
    for b in (1, 4, 32, 512):
        _, l = tree.infer(xi, xv, beam=b, topk=1)
        hits.append((np.asarray(l)[:, 0] == exact_top1).mean())
    assert hits[-1] == 1.0
    assert all(hits[i] <= hits[i + 1] + 1e-9 for i in range(len(hits) - 1))


def test_online_single_query(small_tree):
    """Online setting (n=1) — the paper's second serving mode."""
    tree, ws, x, xi, xv = small_tree
    s_b, l_b = tree.infer(xi, xv, beam=10, topk=5)
    for i in range(3):
        s1, l1 = tree.infer(xi[i : i + 1], xv[i : i + 1], beam=10, topk=5)
        np.testing.assert_array_equal(np.asarray(l1)[0], np.asarray(l_b)[i])
        np.testing.assert_allclose(np.asarray(s1)[0], np.asarray(s_b)[i], rtol=1e-5)


@pytest.mark.parametrize("beam", [1, 4, 10])
@pytest.mark.parametrize("qt", [4, 8])
def test_grouped_bitwise_parity(small_tree, beam, qt):
    """ISSUE 2 acceptance: the device-grouped MXU path is *bitwise* identical
    to dense-lookup MSCM end-to-end — same labels, same score bits — across
    beam widths and query-tile heights (ragged last tiles included)."""
    tree, ws, x, xi, xv = small_tree
    s0, l0 = tree.infer(xi, xv, beam=beam, topk=5, method="mscm_dense")
    s1, l1 = tree.infer(xi, xv, beam=beam, topk=5,
                        method="mscm_pallas_grouped", qt=qt)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l0))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))


def test_grouped_bitwise_parity_logsum(small_tree):
    tree, ws, x, xi, xv = small_tree
    s0, l0 = tree.infer(xi, xv, beam=10, topk=5, method="mscm_dense",
                        score_mode="logsum")
    s1, l1 = tree.infer(xi, xv, beam=10, topk=5,
                        method="mscm_pallas_grouped", score_mode="logsum")
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l0))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))


def test_grouped_ragged_and_padded_chunks(rng):
    """L not divisible by B (padded chunks) + beam not divisible by qt
    (ragged last tile per chunk): grouped == dense bitwise, phantoms never
    surface."""
    from repro.sparse import random_sparse_csc

    d, B = 80, 8
    ws = [random_sparse_csc(d, 6, 8, rng), random_sparse_csc(d, 42, 8, rng)]
    tree = XMRTree.from_weight_matrices(ws, [6, 8])
    x = random_sparse_csr(20, d, 12, rng)
    xi, xv = map(jnp.asarray, x.to_ell())
    s0, l0 = tree.infer(xi, xv, beam=5, topk=7, method="mscm_dense")
    s1, l1 = tree.infer(xi, xv, beam=5, topk=7,
                        method="mscm_pallas_grouped", qt=4)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l0))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))
    assert np.asarray(l1).max() < 42


def test_grouped_fully_jitted(small_tree):
    """The grouped method compiles as ONE XLA program: tracing succeeds (a
    host-side grouping step would raise a TracerArrayConversionError), the
    jaxpr contains no host callbacks, and repeated same-shape calls reuse
    the compiled executable."""
    import jax

    from repro.core.tree import _tree_infer

    tree, ws, x, xi, xv = small_tree

    def run(a, b):
        return _tree_infer(
            tuple(tree.layers), tree.n_cols, tree.branching, tree.d, a, b,
            beam=4, topk=3, method="mscm_pallas_grouped",
            score_mode="prod", qt=4,
        )

    jaxpr = jax.make_jaxpr(run)(xi, xv)
    assert "callback" not in str(jaxpr), "grouped path must not leave the jit"

    if hasattr(_tree_infer, "_cache_size"):
        run(xi, xv)
        size_after_first = _tree_infer._cache_size()
        run(xi, xv)  # same shapes/statics -> no recompile
        assert _tree_infer._cache_size() == size_after_first


def test_nonuniform_branching(rng):
    d = 90
    ws = make_tree_weights(rng, d, [4, 32], 8)  # level branchings 4 then 8
    tree = XMRTree.from_weight_matrices(ws, [4, 8])
    x = random_sparse_csr(5, d, 10, rng)
    xi, xv = x.to_ell()
    ref = brute_force_scores(x.to_dense(), ws)
    _, l = tree.infer(jnp.asarray(xi), jnp.asarray(xv), beam=32, topk=1)
    np.testing.assert_array_equal(np.asarray(l)[:, 0], np.argmax(ref, axis=1))


def test_ragged_label_count(rng):
    """L not divisible by B: phantom columns must never be returned."""
    from repro.sparse import random_sparse_csc

    d, B = 80, 8
    ws = [random_sparse_csc(d, 6, 8, rng), random_sparse_csc(d, 42, 8, rng)]
    tree = XMRTree.from_weight_matrices(ws, [6, 8])
    x = random_sparse_csr(20, d, 12, rng)
    xi, xv = x.to_ell()
    _, l = tree.infer(jnp.asarray(xi), jnp.asarray(xv), beam=42, topk=10)
    assert np.asarray(l).max() < 42
