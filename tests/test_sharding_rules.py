"""Unit tests: sharding rule engine + HLO collective parser (pure host)."""

import pytest

from repro.launch.hlo_stats import _shape_bytes, collective_stats


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
HloModule test
ENTRY %main {
  %p0 = f32[4,1024]{1,0} parameter(0)
  %ag = f32[64,1024]{1,0} all-gather(%p0), replica_groups={}
  %ar.1 = f32[64,1024]{1,0} all-reduce(%ag), to_apply=%add
  %rs = f32[4,1024]{1,0} reduce-scatter(%ar.1), dimensions={0}
  %cp = f32[4,1024]{1,0} collective-permute(%rs), source_target_pairs={{0,1}}
  %ags = f32[64,1024]{1,0} all-gather-start(%p0)
  %agd = f32[64,1024]{1,0} all-gather-done(%ags)
  ROOT %out = f32[4,1024]{1,0} add(%rs, %cp)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[4,1024]{1,0}") == 4 * 1024 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[2], s32[4])") == 8 + 16
    assert _shape_bytes("pred[8]") == 8


def test_collective_stats_parses_all_kinds():
    st = collective_stats(HLO_SAMPLE)
    assert st["all-gather"]["count"] == 2  # plain + -start (done not counted)
    assert st["all-reduce"]["count"] == 1
    assert st["reduce-scatter"]["count"] == 1
    assert st["collective-permute"]["count"] == 1
    # operand resolution: all-gather operand = p0 (16 KiB)
    assert st["all-gather"]["operand_bytes"] == pytest.approx(2 * 4 * 1024 * 4)
    # all-reduce operand == result size
    assert st["all-reduce"]["operand_bytes"] == pytest.approx(64 * 1024 * 4)
    assert st["TOTAL"]["count"] == 5


# ---------------------------------------------------------------------------
# sharding rule engine (uses 8 host devices in a subprocess-free way: the
# rules only need mesh *shape* metadata, so a tiny mesh suffices)
# ---------------------------------------------------------------------------

def test_param_spec_rules():
    import jax
    from jax.sharding import PartitionSpec as P

    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.distributed.sharding import param_spec

    # 1-D -> replicated
    assert param_spec("layers/ln1", (64,), mesh) == P()
    # attention out-proj: in-feature dim on model
    spec = param_spec("layers/attn/wo", (4, 128, 64), mesh)
    assert spec[1] == "model"
    # embed: vocab on model
    spec = param_spec("embed", (1000, 64), mesh)
    assert spec[0] == "model"


def test_expert_divisibility_fallback():
    import jax
    from jax.sharding import PartitionSpec as P

    # mesh shape metadata is what matters; build a 1x1 stand-in and check
    # the rule logic via a fake mesh-like shim is overkill — instead verify
    # on the real production mesh geometry arithmetic:
    from repro.distributed.sharding import _assign

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    # qwen: E=128 divides 16 -> experts on model
    spec = _assign((94, 128, 4096, 1536), [(1, "model"), (2, "data")], m)
    assert spec[1] == "model" and spec[2] == "data"
    # grok: E=8 does NOT divide 16 -> skipped, next prefs apply
    spec = _assign((64, 8, 6144, 32768), [(1, "model"), (2, "data"), (3, None)], m)
    assert spec[1] is None and spec[2] == "data"


def test_assign_never_reuses_axis():
    from repro.distributed.sharding import _assign

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 4}

    spec = _assign((16, 16), [(0, "model"), (1, "model")], FakeMesh())
    assert spec[0] == "model" and spec[1] is None
