"""Pipelined scatter–gather execution: exactness, cache, overlap accounting.

The tentpole contract (ISSUE 5): ``sync="pipelined"`` — speculative
next-level expansion double-buffered against the coordinator's canonical
select — must be **bitwise-identical** to ``sync="level"`` (and hence to
the unpartitioned tree) for every MSCM method, across P × beam × qt ×
score_mode, including ragged trees and explicit split levels. The hot-beam
cache must never change a bit (it only skips partitions that could only
contribute ``NEG_INF``), and a cache *hit* must return exactly what the
cold run returned.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import XMRTree
from repro.index import (
    HotBeamCache,
    ScatterGatherPlanner,
    partition_tree,
    place,
)
from repro.sparse import random_sparse_csc, random_sparse_csr
from tests.conftest import make_tree_weights

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def tree_and_queries():
    rng = np.random.default_rng(7)
    d, B = 150, 8
    ws = make_tree_weights(rng, d, [8, 64, 512], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    x = random_sparse_csr(11, d, 16, rng)
    xi, xv = map(jnp.asarray, x.to_ell())
    return tree, xi, xv


def _assert_bitwise(got, ref):
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))


# ---------------------------------------------------------------------------
# 1. pipelined == level == unpartitioned, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", [
    "vanilla", "mscm_dense", "mscm_searchsorted", "mscm_pallas_grouped",
])
@pytest.mark.parametrize("n_partitions", [2, 4])
def test_pipelined_bitwise_every_method(tree_and_queries, method, n_partitions):
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, n_partitions)
    ref = tree.infer(xi, xv, beam=10, topk=5, method=method)
    pipe = ScatterGatherPlanner(
        idx, beam=10, topk=5, method=method, sync="pipelined"
    )
    _assert_bitwise(pipe.infer(xi, xv), ref)
    level = ScatterGatherPlanner(idx, beam=10, topk=5, method=method)
    _assert_bitwise(pipe.infer(xi, xv), level.infer(xi, xv))


@pytest.mark.parametrize("score_mode", ["prod", "logsum"])
@pytest.mark.parametrize("beam", [1, 6, 12])
def test_pipelined_bitwise_beam_and_mode(tree_and_queries, beam, score_mode):
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 3)
    pl = ScatterGatherPlanner(
        idx, beam=beam, topk=5, method="mscm_dense", score_mode=score_mode,
        sync="pipelined",
    )
    ref = tree.infer(
        xi, xv, beam=beam, topk=5, method="mscm_dense", score_mode=score_mode
    )
    _assert_bitwise(pl.infer(xi, xv), ref)


@pytest.mark.parametrize("qt", [4, 8])
def test_pipelined_bitwise_grouped_qt(tree_and_queries, qt):
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 2)
    pl = ScatterGatherPlanner(
        idx, beam=6, topk=5, method="mscm_pallas_grouped", qt=qt,
        sync="pipelined",
    )
    ref = tree.infer(
        xi, xv, beam=6, topk=5, method="mscm_pallas_grouped", qt=qt
    )
    _assert_bitwise(pl.infer(xi, xv), ref)


def test_pipelined_width_clamp(tree_and_queries):
    """beam=1, topk=10: the last level's candidate panel (b·B = 8) is
    narrower than topk — the merge must reproduce the reference clamp."""
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 3)
    pl = ScatterGatherPlanner(idx, beam=1, topk=10, sync="pipelined")
    ref = tree.infer(xi, xv, beam=1, topk=10)
    s, l = pl.infer(xi, xv)
    assert s.shape == ref[0].shape
    _assert_bitwise((s, l), ref)


def test_pipelined_uneven_label_ranges(rng):
    """Ragged tree (L not divisible by B, uneven chunk ranges): the junk
    id-shift and phantom parking still keep the speculation a superset."""
    d, B = 90, 8
    ws = [random_sparse_csc(d, 6, 8, rng), random_sparse_csc(d, 42, 8, rng)]
    tree = XMRTree.from_weight_matrices(ws, [6, 8])
    x = random_sparse_csr(15, d, 12, rng)
    xi, xv = map(jnp.asarray, x.to_ell())
    idx = partition_tree(tree, 4)
    pl = ScatterGatherPlanner(idx, beam=5, topk=7, sync="pipelined")
    ref = tree.infer(xi, xv, beam=5, topk=7)
    _assert_bitwise(pl.infer(xi, xv), ref)
    _, l = pl.infer(xi, xv)
    assert np.asarray(l).max() < 42


def test_pipelined_deeper_split_level(tree_and_queries):
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 4, level=2)
    pl = ScatterGatherPlanner(
        idx, beam=6, topk=5, method="mscm_searchsorted", sync="pipelined"
    )
    ref = tree.infer(xi, xv, beam=6, topk=5, method="mscm_searchsorted")
    _assert_bitwise(pl.infer(xi, xv), ref)


def test_pipelined_with_placement(tree_and_queries):
    """The placement path (explicit device hops) stays bitwise."""
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 2)
    pm = place(idx, shards=1)
    pl = ScatterGatherPlanner(idx, beam=6, topk=5, placement=pm,
                              sync="pipelined")
    ref = tree.infer(xi, xv, beam=6, topk=5)
    _assert_bitwise(pl.infer(xi, xv), ref)


def test_single_partition_pipelined(tree_and_queries):
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 1)
    pl = ScatterGatherPlanner(idx, beam=8, topk=5, sync="pipelined")
    _assert_bitwise(pl.infer(xi, xv), tree.infer(xi, xv, beam=8, topk=5))


def test_invalid_sync_mode(tree_and_queries):
    tree, *_ = tree_and_queries
    idx = partition_tree(tree, 2)
    with pytest.raises(ValueError):
        ScatterGatherPlanner(idx, sync="speculative")
    with pytest.raises(ValueError):
        # final mode never consults the cache — a silent no-op is refused.
        ScatterGatherPlanner(idx, sync="final", cache_entries=8)


# ---------------------------------------------------------------------------
# 2. hypothesis property: pipelined == level for random trees/partitions
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        n_partitions=st.integers(2, 6),
        beam=st.integers(1, 12),
        qt=st.sampled_from([4, 8]),
        score_mode=st.sampled_from(["prod", "logsum"]),
        method=st.sampled_from(
            ["mscm_dense", "mscm_searchsorted", "mscm_pallas_grouped"]
        ),
        seed=st.integers(0, 2**16),
    )
    def test_pipelined_equals_level_property(
        n_partitions, beam, qt, score_mode, method, seed
    ):
        """sync="pipelined" == sync="level", bitwise, for arbitrary
        P x beam x qt x score_mode draws (ISSUE 5 satellite)."""
        rng = np.random.default_rng(seed)
        d, B = 100, 6
        ws = make_tree_weights(rng, d, [6, 36, 216], B, nnz_per_col=8)
        tree = XMRTree.from_weight_matrices(ws, B)
        x = random_sparse_csr(7, d, 12, rng)
        xi, xv = map(jnp.asarray, x.to_ell())
        idx = partition_tree(tree, n_partitions)
        kw = dict(
            beam=beam, topk=5, method=method, score_mode=score_mode, qt=qt
        )
        level = ScatterGatherPlanner(idx, sync="level", **kw)
        pipe = ScatterGatherPlanner(idx, sync="pipelined", **kw)
        ref_s, ref_l = level.infer(xi, xv)
        s, l = pipe.infer(xi, xv)
        np.testing.assert_array_equal(np.asarray(l), np.asarray(ref_l))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[dev])")
    def test_pipelined_equals_level_property():
        pass


# ---------------------------------------------------------------------------
# 3. hot-beam cache: correctness and accounting
# ---------------------------------------------------------------------------

def test_cache_hit_bitwise_identical_to_cold(tree_and_queries):
    """A hot-beam cache hit must return exactly the cold run's bits —
    the ISSUE 5 cache-correctness pin."""
    tree, xi, xv = tree_and_queries
    idx = partition_tree(tree, 4)
    ref = tree.infer(xi, xv, beam=10, topk=5)
    pl = ScatterGatherPlanner(
        idx, beam=10, topk=5, sync="pipelined", cache_entries=32
    )
    cold = pl.infer(xi, xv)
    assert pl.cache.misses > 0
    misses_after_cold = pl.cache.misses
    hot = pl.infer(xi, xv)
    # The second pass re-routes the same beams: all hits, no new misses.
    assert pl.cache.misses == misses_after_cold
    assert pl.cache.hits >= xi.shape[0]
    _assert_bitwise(cold, ref)
    _assert_bitwise(hot, ref)
    _assert_bitwise(hot, cold)


@pytest.mark.parametrize("sync", ["level", "pipelined"])
def test_cache_partition_skip_is_bitwise(sync):
    """Queries routed into one partition's label range: the cache skips the
    other partitions entirely and no bit changes."""
    rng = np.random.default_rng(3)
    d, B = 120, 8
    ws = make_tree_weights(rng, d, [8, 64, 512], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    x = random_sparse_csr(9, d, 16, rng)
    xi, xv = map(jnp.asarray, x.to_ell())
    idx = partition_tree(tree, 4)
    ref = tree.infer(xi, xv, beam=2, topk=5)  # narrow beam -> few owners
    pl = ScatterGatherPlanner(
        idx, beam=2, topk=5, sync=sync, cache_entries=16
    )
    _assert_bitwise(pl.infer(xi, xv), ref)
    stats = pl.cache_stats()
    assert stats["misses"] > 0
    # The occupancy feed accumulated router-beam ownership.
    assert sum(stats["owner_counts"]) > 0


def test_cache_lru_eviction():
    cache = HotBeamCache(2, [0, 4, 8])
    a = np.array([[0, 1]])
    b = np.array([[4, 5]])
    c = np.array([[1, 6]])
    assert cache.active_partitions(a) == [0]
    assert cache.active_partitions(b) == [1]
    assert cache.active_partitions(c) == [0, 1]   # evicts a's entry
    assert cache.evictions == 1
    assert cache.active_partitions(b) == [1]      # still resident -> hit
    assert cache.hits == 1
    stats = cache.stats()
    assert stats["entries"] == 2 and stats["capacity"] == 2
    occ = cache.occupancy()
    assert occ.shape == (2,) and abs(occ.sum() - 1.0) < 1e-9


def test_cache_degenerate_beam_falls_back_to_all():
    cache = HotBeamCache(4, [0, 4, 8])
    # No valid id in range -> every partition stays active (safety).
    assert cache.active_partitions(np.array([[99, -1]])) == [0, 1]


def test_cache_validation():
    with pytest.raises(ValueError):
        HotBeamCache(0, [0, 4])
    with pytest.raises(ValueError):
        HotBeamCache(4, [0])


# ---------------------------------------------------------------------------
# 4. serving integration: pipelined + cache through the MicroBatcher
# ---------------------------------------------------------------------------

def test_pipelined_serving_engine_bitwise_and_metrics():
    from repro.serving import (
        BatchPolicy, MicroBatcher, ServeConfig, XMRServingEngine,
    )
    from repro.sparse import CSR

    rng = np.random.default_rng(11)
    d, B = 150, 8
    ws = make_tree_weights(rng, d, [8, 64, 512], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    queries = random_sparse_csr(20, d, 16, rng)
    assert isinstance(queries, CSR)

    ref_s, ref_l = XMRServingEngine(
        tree, ServeConfig(max_batch=32)
    ).serve_batch(queries)

    engine = XMRServingEngine(
        tree,
        ServeConfig(
            max_batch=32, partitions=2, partition_sync="pipelined",
            beam_cache=16,
        ),
    )
    with MicroBatcher(engine, BatchPolicy(max_batch=8, max_wait_ms=1.0)) as mb:
        res = [f.result(timeout=60) for f in mb.submit_csr(queries)]
    s = np.stack([r[0] for r in res])
    l = np.stack([r[1] for r in res])
    np.testing.assert_array_equal(l, ref_l)
    np.testing.assert_array_equal(s, ref_s)

    summ = mb.metrics.summary()
    # Overlap accounting: every partitioned batch records its blocked wall.
    assert "pipeline_stall_avg_ms" in summ
    assert summ["pipeline_stall_avg_ms"] >= 0.0
    # Cache accounting: cumulative counters surface in the summary.
    assert summ["beam_cache"]["misses"] >= 1
    assert 0.0 <= summ["beam_cache"]["hit_rate"] <= 1.0
    # Unpartitioned engines don't record stall.
    assert engine.beam_cache_stats() is not None


def test_unpartitioned_engine_records_no_stall():
    from repro.serving import (
        BatchPolicy, MicroBatcher, ServeConfig, XMRServingEngine,
    )

    rng = np.random.default_rng(5)
    d, B = 100, 8
    ws = make_tree_weights(rng, d, [8, 64], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    queries = random_sparse_csr(6, d, 12, rng)
    engine = XMRServingEngine(tree, ServeConfig(max_batch=8))
    with MicroBatcher(engine, BatchPolicy(max_batch=4, max_wait_ms=1.0)) as mb:
        [f.result(timeout=60) for f in mb.submit_csr(queries)]
    summ = mb.metrics.summary()
    assert "pipeline_stall_avg_ms" not in summ
    assert "beam_cache" not in summ
