"""MSCM variant correctness: every iterator == dense oracle == each other.

This pins the paper's headline claim (§4): MSCM is *exact* — it returns the
same masked product as the vanilla per-column baseline, for every iterator.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core import mscm as M
from repro.core.chunked import ChunkedLayer, ColumnELLLayer
from repro.kernels import ref as ref_lib
from repro.sparse import random_sparse_csc, random_sparse_csr


def _setup(rng, n=6, d=120, C=5, B=8, nnz_w=10, nnz_x=15, A=12):
    w = random_sparse_csc(d, C * B, nnz_w, rng, sibling_groups=B)
    ch = ChunkedLayer.from_csc(w, B)
    col = ColumnELLLayer.from_csc(w, B)
    x = random_sparse_csr(n, d, nnz_x, rng)
    xi, xv = x.to_ell()
    block_q = rng.integers(0, n, size=A).astype(np.int32)
    block_c = rng.integers(0, C, size=A).astype(np.int32)
    return w, ch, col, x, xi, xv, block_q, block_c


def _all_variants(ch, col, xi, xv, block_q, block_c, d, B):
    xd = M.scatter_dense(jnp.asarray(xi), jnp.asarray(xv), d)
    rows, vals = jnp.asarray(ch.rows), jnp.asarray(ch.vals)
    bq, bc = jnp.asarray(block_q), jnp.asarray(block_c)
    out = {
        "ref": ref_lib.mscm_ref(xd, rows, vals, bq, bc),
        "dense_lookup": M.mscm_dense_lookup(xd, rows, vals, bq, bc),
        "searchsorted": M.mscm_searchsorted(
            jnp.asarray(xi), jnp.asarray(xv), rows, vals, bq, bc, d
        ),
        "vanilla": M.vanilla_columns(
            jnp.asarray(xi), jnp.asarray(xv),
            jnp.asarray(col.rows), jnp.asarray(col.vals), bq, bc, B, d,
        ),
    }
    return {k: np.asarray(v) for k, v in out.items()}


def test_variants_match_oracle(rng):
    w, ch, col, x, xi, xv, bq, bc = _setup(rng)
    outs = _all_variants(ch, col, xi, xv, bq, bc, w.shape[0], ch.B)
    for name, val in outs.items():
        np.testing.assert_allclose(val, outs["ref"], rtol=1e-5, atol=1e-6, err_msg=name)


def test_matches_marching_pointer_oracle(rng):
    """Each block equals the paper's Algorithm 2 marching-pointer result."""
    w, ch, col, x, xi, xv, bq, bc = _setup(rng, A=8)
    d = w.shape[0]
    xd = M.scatter_dense(jnp.asarray(xi), jnp.asarray(xv), d)
    out = np.asarray(
        M.mscm_dense_lookup(xd, jnp.asarray(ch.rows), jnp.asarray(ch.vals),
                            jnp.asarray(bq), jnp.asarray(bc))
    )
    for a in range(len(bq)):
        q_idx, q_val = x.row(int(bq[a]))
        want = ref_lib.block_ref_marching(
            q_idx, q_val, ch.rows[int(bc[a])], ch.vals[int(bc[a])], d
        )
        np.testing.assert_allclose(out[a], want, rtol=1e-5, atol=1e-6)


def test_scatter_dense_sentinel_is_zero(rng):
    x = random_sparse_csr(4, 30, 5, rng)
    xi, xv = x.to_ell()
    xd = np.asarray(M.scatter_dense(jnp.asarray(xi), jnp.asarray(xv), 30))
    assert xd.shape == (4, 31)
    assert (xd[:, 30] == 0).all()
    np.testing.assert_allclose(xd[:, :30], x.to_dense(), rtol=1e-6)


def test_empty_query_rows(rng):
    """Queries with zero features score 0 on every block."""
    d, C, B = 40, 3, 4
    w = random_sparse_csc(d, C * B, 5, rng, sibling_groups=B)
    ch = ChunkedLayer.from_csc(w, B)
    xi = np.full((2, 4), d, np.int32)  # all padding
    xv = np.zeros((2, 4), np.float32)
    xd = M.scatter_dense(jnp.asarray(xi), jnp.asarray(xv), d)
    out = M.mscm_dense_lookup(
        xd, jnp.asarray(ch.rows), jnp.asarray(ch.vals),
        jnp.asarray([0, 1]), jnp.asarray([0, 2]),
    )
    assert not np.asarray(out).any()


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 8),
    d=st.integers(4, 150),
    c=st.integers(1, 5),
    b=st.sampled_from([2, 3, 8, 16]),
    nnz_w=st.integers(1, 10),
    nnz_x=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_variants_match_property(n, d, c, b, nnz_w, nnz_x, seed):
    rng = np.random.default_rng(seed)
    w, ch, col, x, xi, xv, bq, bc = _setup(
        rng, n=n, d=d, C=c, B=b,
        nnz_w=min(nnz_w, d), nnz_x=min(nnz_x, d), A=min(2 * n, n * c),
    )
    outs = _all_variants(ch, col, xi, xv, bq, bc, d, b)
    for name, val in outs.items():
        np.testing.assert_allclose(val, outs["ref"], rtol=1e-4, atol=1e-5, err_msg=name)


def test_iterator_cost_table6():
    """Complexity counters mirror paper Table 6 orderings."""
    # queries much sparser than chunks -> hash/dense beat marching
    assert M.iterator_cost("hash", 10, 1000) < M.iterator_cost("marching", 10, 1000)
    # dense lookup amortizes with batch size
    c1 = M.iterator_cost("dense", 10, 1000, n_queries=1)
    c2 = M.iterator_cost("dense", 10, 1000, n_queries=1)  # same chunk cost
    assert c1 == c2
    big_batch = M.iterator_cost("dense", 1000, 10, n_queries=100)
    online = M.iterator_cost("dense", 1000, 10, n_queries=1)
    assert big_batch < online
    # binary search: min*log(max)
    assert M.iterator_cost("binsearch", 4, 1024) == 4 * 10
    with pytest.raises(ValueError):
        M.iterator_cost("bogus", 1, 1)


def test_chunk_vs_column_traversal_counts(rng):
    """Paper Item 1: chunking traverses once per chunk, not once per column."""
    d, B = 256, 32
    w = random_sparse_csc(d, B, 16, rng, sibling_groups=B, sibling_overlap=0.9)
    ch = ChunkedLayer.from_csc(w, B)
    mscm_len, vanilla_len = M.chunk_vs_column_traversals(ch.R, w.col_nnz(), B)
    assert mscm_len < vanilla_len  # shared support => union << sum
