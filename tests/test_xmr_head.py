"""MSCM vocab-tree head: exactness and beam economics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.xmr_head import VocabTreeHead, greedy_token


@pytest.fixture(scope="module")
def head():
    d, vocab, b = 64, 1000, 16  # ragged: 1000 % 16 != 0
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    c = (vocab + b - 1) // b
    centers = jax.random.normal(k1, (c, d))
    w = centers[:, None, :] + 0.3 * jax.random.normal(k2, (c, b, d))
    w = w.reshape(c * b, d)[:vocab].T / np.sqrt(d)
    return VocabTreeHead.from_lm_head(w, b), w


def test_full_logits_match_dense(head):
    tree, w = head
    h = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    np.testing.assert_allclose(
        np.asarray(tree.full_logits(h)), np.asarray(h @ w), rtol=1e-5, atol=1e-5
    )


def test_full_beam_exact(head):
    tree, w = head
    h = jax.random.normal(jax.random.PRNGKey(2), (8, 64))
    want = np.asarray(jnp.argmax(h @ w, axis=1))
    got = np.asarray(greedy_token(tree, h, beam=tree.n_clusters))
    np.testing.assert_array_equal(got, want)


def test_padding_tokens_never_win(head):
    tree, w = head
    h = jax.random.normal(jax.random.PRNGKey(3), (16, 64))
    scores, ids = tree.decode_logits(h, beam=tree.n_clusters)
    best = np.asarray(jnp.take_along_axis(ids, jnp.argmax(scores, 1)[:, None], 1))
    assert (best < 1000).all()


def test_beam_recall_increases(head):
    tree, w = head
    h = jax.random.normal(jax.random.PRNGKey(4), (64, 64))
    want = np.asarray(jnp.argmax(h @ w, axis=1))
    agree = []
    for beam in (1, 4, 16, tree.n_clusters):
        got = np.asarray(greedy_token(tree, h, beam=beam))
        agree.append((got == want).mean())
    assert agree[-1] == 1.0
    assert agree[0] <= agree[-1]
    # structured head => even small beams route well
    assert agree[1] > 0.8
