"""Latency-SLO adaptive beam tiers: ladder, policy, and at-tier exactness.

Pins the contracts the adaptive serving tier must not break:
1. the tier ladder resolves deterministically from ``SLOConfig`` (explicit
   pairs validated, auto-halving down to ``min_beam``, 1-tuple when off)
   and the engine refuses ladders whose degraded tiers would change the
   result panel width;
2. a degraded tier is *exact at that beam*: ``engine._run(tier=k)`` is
   bitwise the unpartitioned ``tree.infer`` at the tier's beam/qt, and the
   partitioned planner's per-call ``beam``/``qt`` overrides match it too —
   in ``"level"``, ``"pipelined"``, and PartitionRunner-transport dispatch;
3. tier 0 stays bitwise-identical to an engine without an SLO (no override
   kwargs even reach the transport — the wire format is unchanged);
4. the ``BeamTierPolicy`` selector degrades with backlog/budget pressure
   and never sheds (no budget still returns the deepest tier);
5. ``QueryResult.beam_tier`` rides the v1 wire only when nonzero, and the
   micro-batcher stamps it end to end (futures, metrics).
"""

import threading
import time

import numpy as np
import pytest

from repro.core import XMRTree
from repro.index import ScatterGatherPlanner, partition_tree
from repro.serving import (
    BatchPolicy,
    MicroBatcher,
    Query,
    QueryResult,
    ServeConfig,
    SLOConfig,
    XMRServingEngine,
)
from repro.serving.fleet.launcher import partition_payload
from repro.serving.fleet.worker import PartitionRunner
from repro.serving.slo import BeamTier, BeamTierPolicy, resolve_tiers
from repro.sparse import random_sparse_csr
from tests.conftest import make_tree_weights

METHOD = "mscm_dense"


def _bits(a) -> np.ndarray:
    return np.asarray(a).view(np.uint32)


# ---------------------------------------------------------------------------
# 1. config validation + ladder resolution
# ---------------------------------------------------------------------------

def test_slo_config_validation():
    with pytest.raises(ValueError):
        SLOConfig(target_p99_ms=0.0)
    with pytest.raises(ValueError):
        SLOConfig(target_p99_ms=-3.0)
    with pytest.raises(ValueError):
        SLOConfig(min_beam=0)
    with pytest.raises(ValueError):
        SLOConfig(tiers=((0, 8),))          # non-positive beam
    with pytest.raises(ValueError):
        SLOConfig(tiers=((4, 0),))          # non-positive qt
    with pytest.raises(ValueError):
        SLOConfig(tiers=((2, 8), (4, 8)))   # beams must strictly descend
    # valid forms
    SLOConfig()
    SLOConfig(target_p99_ms=5.0)
    SLOConfig(target_p99_ms=5.0, tiers=((4, 8), (2, 8)))


def test_resolve_tiers_disabled_is_full_only():
    cfg = ServeConfig(beam=10, qt=8)
    assert resolve_tiers(cfg) == (BeamTier(10, 8),)


def test_resolve_tiers_auto_halving_ladder():
    cfg = ServeConfig(beam=10, qt=8, slo=SLOConfig(target_p99_ms=5.0))
    assert resolve_tiers(cfg) == (
        BeamTier(10, 8), BeamTier(5, 8), BeamTier(2, 8), BeamTier(1, 8)
    )
    cfg = ServeConfig(
        beam=10, qt=8, slo=SLOConfig(target_p99_ms=5.0, min_beam=4)
    )
    assert resolve_tiers(cfg) == (BeamTier(10, 8), BeamTier(5, 8))


def test_resolve_tiers_explicit_ladder():
    cfg = ServeConfig(
        beam=10, qt=8,
        slo=SLOConfig(target_p99_ms=5.0, tiers=((6, 8), (3, 4))),
    )
    assert resolve_tiers(cfg) == (
        BeamTier(10, 8), BeamTier(6, 8), BeamTier(3, 4)
    )
    # an explicit tier at least as wide as the full beam is a config error
    cfg = ServeConfig(
        beam=10, qt=8, slo=SLOConfig(target_p99_ms=5.0, tiers=((10, 8),))
    )
    with pytest.raises(ValueError, match="narrower"):
        resolve_tiers(cfg)


def test_engine_rejects_width_changing_tier():
    """A tier whose beam shrinks the result panel must be refused at build.

    Geometry: n_cols (4, 16), branching (4, 4), topk=10. Full beam 10
    reaches width min(10, 16, 4*4) = 10; tier beam 2 reaches
    min(10, 16, 2*4) = 8 != 10 — per-batch result shapes would differ.
    """
    rng = np.random.default_rng(3)
    ws = make_tree_weights(rng, 48, [4, 16], 4)
    tree = XMRTree.from_weight_matrices(ws, 4)
    cfg = ServeConfig(
        beam=10, topk=10, method=METHOD, ell_width=16,
        slo=SLOConfig(target_p99_ms=50.0, tiers=((2, 8),)),
    )
    with pytest.raises(ValueError, match="width"):
        XMRServingEngine(tree, cfg)
    # beam 4 keeps width 10 (min(10, 16, 4*4)); accepted
    ok = ServeConfig(
        beam=10, topk=10, method=METHOD, ell_width=16,
        slo=SLOConfig(target_p99_ms=50.0, tiers=((4, 8),)),
    )
    eng = XMRServingEngine(tree, ok)
    assert eng.tiers == (BeamTier(10, 8), BeamTier(4, 8))


# ---------------------------------------------------------------------------
# 2. BeamTierPolicy selection
# ---------------------------------------------------------------------------

def _policy(costs, target_ms=10.0, bucket=16):
    tiers = tuple(BeamTier(8 >> k, 8) for k in range(len(costs)))
    pol = BeamTierPolicy(tiers, target_ms=target_ms, bucket=bucket)
    it = iter(costs)
    return pol.calibrate(lambda k: next(it))


def test_policy_uncalibrated_always_full():
    pol = BeamTierPolicy(
        (BeamTier(8, 8), BeamTier(4, 8)), target_ms=10.0, bucket=16
    )
    assert not pol.calibrated
    assert pol.select(queue_depth=10_000, budget_ms=0.01) == 0


def test_policy_select_degrades_with_backlog():
    pol = _policy([4.0, 2.0, 1.0], target_ms=10.0, bucket=16)
    # empty queue: one batch at full beam fits 10ms
    assert pol.select(queue_depth=0, budget_ms=None) == 0
    # 2 buckets queued ahead -> 3 batches: 3*4 > 10, 3*2 <= 10 -> tier 1
    assert pol.select(queue_depth=32, budget_ms=None) == 1
    # deep backlog (6 batches: 6*2 > 10, 6*1 <= 10): only tier 2 fits
    assert pol.select(queue_depth=80, budget_ms=None) == 2
    # nothing fits: degrade to the deepest tier, never shed
    assert pol.select(queue_depth=10_000, budget_ms=None) == 2
    assert pol.select(queue_depth=0, budget_ms=0.0) == 2
    assert pol.select(queue_depth=0, budget_ms=-5.0) == 2


def test_policy_budget_tightens_but_never_exceeds_target():
    pol = _policy([4.0, 2.0, 1.0], target_ms=10.0, bucket=16)
    # a per-request budget below the target bites
    assert pol.select(queue_depth=0, budget_ms=3.0) == 1
    # a budget above the target is clamped to the target
    assert pol.select(queue_depth=32, budget_ms=1e9) == 1


def test_policy_calibration_clamps_monotone():
    # probe jitter measuring a narrower beam as slower must be clamped
    pol = _policy([2.0, 3.0, 1.0])
    assert pol.cost_ms == [2.0, 2.0, 1.0]


def test_policy_constructor_validation():
    with pytest.raises(ValueError):
        BeamTierPolicy((), target_ms=10.0, bucket=16)
    with pytest.raises(ValueError):
        BeamTierPolicy((BeamTier(8, 8),), target_ms=0.0, bucket=16)
    with pytest.raises(ValueError):
        BeamTierPolicy((BeamTier(8, 8),), target_ms=10.0, bucket=0)


# ---------------------------------------------------------------------------
# 3. at-tier bitwise exactness (in-process, partitioned, transport)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tier_world():
    rng = np.random.default_rng(11)
    d, B = 128, 4
    ws = make_tree_weights(rng, d, [4, 16, 64], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    cfg = ServeConfig(
        beam=4, topk=8, method=METHOD, ell_width=24, max_batch=16,
        slo=SLOConfig(target_p99_ms=100.0, tiers=((2, 8),)),
    )
    engine = XMRServingEngine(tree, cfg)
    queries = random_sparse_csr(16, d, 12, rng)
    xi, xv = engine.marshal_rows(queries, np.arange(16), 16)
    return tree, cfg, engine, xi, xv


def _tree_ref(tree, xi, xv, beam, qt=8):
    return tree.infer(
        xi, xv, beam=beam, topk=8, method=METHOD, score_mode="prod", qt=qt
    )


def test_engine_tier_dispatch_bitwise_exact_at_tier(tier_world):
    tree, cfg, engine, xi, xv = tier_world
    s0, l0 = engine._run(xi, xv, tier=0)
    ref_s, ref_l = _tree_ref(tree, xi, xv, beam=4)
    np.testing.assert_array_equal(_bits(s0), _bits(ref_s))
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(ref_l))
    s1, l1 = engine._run(xi, xv, tier=1)
    deg_s, deg_l = _tree_ref(tree, xi, xv, beam=2)
    np.testing.assert_array_equal(_bits(s1), _bits(deg_s))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(deg_l))
    # same panel width at every tier (the build-time validation's promise)
    assert np.asarray(s0).shape == np.asarray(s1).shape


def test_tier0_bitwise_identical_to_no_slo_engine(tier_world):
    tree, cfg, engine, xi, xv = tier_world
    plain = XMRServingEngine(
        tree,
        ServeConfig(beam=4, topk=8, method=METHOD, ell_width=24, max_batch=16),
    )
    assert len(plain.tiers) == 1
    s_a, l_a = engine._run(xi, xv, tier=0)
    s_b, l_b = plain._run(xi, xv)
    np.testing.assert_array_equal(_bits(s_a), _bits(s_b))
    np.testing.assert_array_equal(np.asarray(l_a), np.asarray(l_b))


def test_degraded_tier_composes_with_quant_tier(tier_world):
    # Storage tier (QuantConfig) and beam tier (SLOConfig) are orthogonal:
    # a degraded tier on a quantized engine is bitwise the quantized
    # engine's own result at the narrower beam — the two error sources
    # never compound within a tier (the README's composition claim).
    from repro.serving import QuantConfig

    tree, cfg, engine, xi, xv = tier_world
    slo = ServeConfig(
        beam=4, topk=8, method="auto", ell_width=24, max_batch=16,
        quant=QuantConfig(tier="int8"),
        slo=SLOConfig(target_p99_ms=100.0, tiers=((2, 8),)),
    )
    q_slo = XMRServingEngine(tree, slo)
    for tier, beam in ((0, 4), (1, 2)):
        plain = XMRServingEngine(
            tree,
            ServeConfig(beam=beam, topk=8, method="auto", ell_width=24,
                        max_batch=16, quant=QuantConfig(tier="int8")),
        )
        s_a, l_a = q_slo._run(xi, xv, tier=tier)
        s_b, l_b = plain._run(xi, xv)
        np.testing.assert_array_equal(_bits(s_a), _bits(s_b))
        np.testing.assert_array_equal(np.asarray(l_a), np.asarray(l_b))


@pytest.mark.parametrize("sync", ["level", "pipelined"])
def test_planner_beam_override_bitwise_exact(tier_world, sync):
    tree, cfg, engine, xi, xv = tier_world
    idx = partition_tree(tree, 2, level=1)
    pl = ScatterGatherPlanner(
        idx, beam=4, topk=8, method=METHOD, qt=8, sync=sync
    )
    deg_s, deg_l = _tree_ref(tree, xi, xv, beam=2)
    s, l = pl.infer(xi, xv, beam=2, qt=8)
    np.testing.assert_array_equal(_bits(s), _bits(deg_s))
    np.testing.assert_array_equal(np.asarray(l), np.asarray(deg_l))
    # the override is per-call: the next default call is full-beam again
    ref_s, ref_l = _tree_ref(tree, xi, xv, beam=4)
    s, l = pl.infer(xi, xv)
    np.testing.assert_array_equal(_bits(s), _bits(ref_s))
    np.testing.assert_array_equal(np.asarray(l), np.asarray(ref_l))


class _HeaderSpyTransport:
    """PartitionRunner-backed transport recording begin's tier overrides."""

    def __init__(self, runners):
        self._runners = runners
        self.begin_overrides = []

    @property
    def n_partitions(self):
        return len(self._runners)

    def down_partitions(self):
        return []

    def begin(self, x_idx, x_val, parent_ids, scores, *, beam=None, qt=None):
        self.begin_overrides.append((beam, qt))
        return [
            r.begin(x_idx, x_val, parent_ids, scores, beam=beam, qt=qt)
            for r in self._runners
        ]

    def step(self, level, winner_ids):
        return [r.step(level, winner_ids) for r in self._runners]


def test_transport_tier_override_bitwise_exact_and_tier0_headerless(
    tier_world,
):
    tree, cfg, engine, xi, xv = tier_world
    idx = partition_tree(tree, 2, level=1)
    runners = [
        PartitionRunner(*partition_payload(
            idx, pid, beam=4, topk=8, method=METHOD
        ))
        for pid in range(2)
    ]
    spy = _HeaderSpyTransport(runners)
    pl = ScatterGatherPlanner(
        idx, beam=4, topk=8, method=METHOD, qt=8, sync="pipelined",
        transport=spy,
    )
    # full-beam call: no override kwargs reach the transport (wire parity)
    ref_s, ref_l = _tree_ref(tree, xi, xv, beam=4)
    s, l = pl.infer(xi, xv)
    assert spy.begin_overrides == [(None, None)]
    np.testing.assert_array_equal(_bits(s), _bits(ref_s))
    np.testing.assert_array_equal(np.asarray(l), np.asarray(ref_l))
    # degraded-tier call: override rides begin, results exact at that beam
    deg_s, deg_l = _tree_ref(tree, xi, xv, beam=2)
    s, l = pl.infer(xi, xv, beam=2)
    assert spy.begin_overrides[-1] == (2, None)
    np.testing.assert_array_equal(_bits(s), _bits(deg_s))
    np.testing.assert_array_equal(np.asarray(l), np.asarray(deg_l))
    # and the next full-beam call restores the loaded settings
    s, l = pl.infer(xi, xv)
    assert spy.begin_overrides[-1] == (None, None)
    np.testing.assert_array_equal(_bits(s), _bits(ref_s))


# ---------------------------------------------------------------------------
# 4. wire schema
# ---------------------------------------------------------------------------

def test_beam_tier_wire_roundtrip():
    r = QueryResult(
        qid=7, ids=np.arange(3, dtype=np.int32),
        scores=np.ones(3, np.float32), beam_tier=2,
    )
    doc = r.to_wire()
    assert doc["beam_tier"] == 2
    back = QueryResult.from_wire(doc)
    assert back.beam_tier == 2 and back.ok


def test_beam_tier_zero_absent_from_wire():
    r = QueryResult(
        qid=1, ids=np.arange(3, dtype=np.int32),
        scores=np.ones(3, np.float32),
    )
    doc = r.to_wire()
    assert "beam_tier" not in doc  # tier-0 wire is byte-identical to pre-SLO
    assert QueryResult.from_wire(doc).beam_tier == 0


# ---------------------------------------------------------------------------
# 5. micro-batcher end to end
# ---------------------------------------------------------------------------

def test_batcher_selects_degraded_tier_under_pressure(tier_world, monkeypatch):
    """Pre-filled queue + costs that cannot meet the target at full beam
    force the policy off tier 0; results carry ``beam_tier`` and the
    metrics summary grows the per-tier panel."""
    tree, cfg, engine, xi, xv = tier_world
    rng = np.random.default_rng(5)
    queries = random_sparse_csr(48, 128, 12, rng)

    # Deterministic calibration: full beam is too slow for the target with
    # any backlog, tier 1 always fits.
    costs = {0: 80.0, 1: 0.01}
    monkeypatch.setattr(
        engine, "measure_batch_seconds",
        lambda batch, iters=3, tier=0: 1e-3 * costs[tier],
    )
    mb = MicroBatcher(engine, BatchPolicy(max_batch=16, max_wait_ms=2.0))
    futs = []
    for i in range(queries.shape[0]):
        ri, rv = queries.row(i)
        futs.append(mb.submit(Query(idx=ri, val=rv, qid=i)))
    mb.start()
    res = [f.result(timeout=60) for f in futs]
    mb.stop()
    assert all(r.ok for r in res)
    assert mb.tier_policy is not None and mb.tier_policy.calibrated
    tiers = {r.beam_tier for r in res}
    assert 1 in tiers  # backlogged batches degraded instead of shedding
    summary = mb.metrics.summary()
    assert summary["shed"] == 0
    assert summary["degraded_to_tier"] > 0
    assert 0.0 < summary["degraded_to_tier_rate"] <= 1.0
    assert set(summary["beam_tiers"]) <= {"0", "1"}


def test_batcher_full_beam_results_identical_with_and_without_slo(
    tier_world, monkeypatch
):
    """With ample budget the SLO engine serves tier 0 — results are bitwise
    the same as a batcher over a no-SLO engine."""
    tree, cfg, engine, xi, xv = tier_world
    rng = np.random.default_rng(9)
    queries = random_sparse_csr(12, 128, 12, rng)
    # Cheap calibrated costs so every batch fits the target at full beam.
    monkeypatch.setattr(
        engine, "measure_batch_seconds",
        lambda batch, iters=3, tier=0: 1e-6,
    )
    plain = XMRServingEngine(
        tree,
        ServeConfig(beam=4, topk=8, method=METHOD, ell_width=24, max_batch=16),
    )
    out = {}
    for name, eng in (("slo", engine), ("plain", plain)):
        mb = MicroBatcher(eng, BatchPolicy(max_batch=16, max_wait_ms=2.0))
        mb.start()
        futs = mb.submit_csr(queries)
        out[name] = [f.result(timeout=60) for f in futs]
        mb.stop()
    for (s_a, l_a), (s_b, l_b) in zip(out["slo"], out["plain"]):
        np.testing.assert_array_equal(_bits(s_a), _bits(s_b))
        np.testing.assert_array_equal(np.asarray(l_a), np.asarray(l_b))
