"""Runtime substrate tests: optimizers, checkpointing, fault handling, data."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data.lm_data import PrefetchingLoader, batch_at_step
from repro.distributed.fault import (
    StepWatchdog,
    TransientError,
    elastic_device_counts,
    run_with_retries,
)
from repro.optim.optimizers import (
    adafactor,
    adamw,
    clip_by_global_norm,
    ef_compress,
    ef_init,
    warmup_cosine,
)


# -- optimizers -------------------------------------------------------------

def _quad_problem():
    params = {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array(4.0)}
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    return params, loss


@pytest.mark.parametrize("opt_fn", [adamw, adafactor])
def test_optimizers_descend(opt_fn):
    opt = opt_fn()
    params, loss = _quad_problem()
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.float32(0.1))
    assert float(loss(params)) < 0.2 * l0


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"m": jnp.zeros((64, 32)), "v1d": jnp.zeros((7,))}
    state = opt.init(params)
    assert state["v"]["m"]["vr"].shape == (64,)
    assert state["v"]["m"]["vc"].shape == (32,)
    assert state["v"]["v1d"]["v"].shape == (7,)  # small tensors unfactored


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 100.0)}
    c = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(jnp.linalg.norm(c["a"])), 1.0, rtol=1e-5)


def test_warmup_cosine_schedule():
    lrs = [float(warmup_cosine(jnp.int32(s), peak=1.0, warmup=10, total=100))
           for s in range(0, 101, 10)]
    assert lrs[0] == 0.0 and np.isclose(lrs[1], 1.0)
    assert all(lrs[i] >= lrs[i + 1] - 1e-6 for i in range(1, len(lrs) - 1))
    assert lrs[-1] >= 0.1 - 1e-6  # floor


def test_ef_compression_preserves_signal():
    """Error feedback: compressed stream + residual reconstructs the sum."""
    rng = np.random.default_rng(0)
    grads = [{"g": jnp.asarray(rng.standard_normal(128), jnp.float32)}
             for _ in range(20)]
    res = ef_init(grads[0])
    total_true = np.zeros(128)
    total_comp = np.zeros(128)
    for g in grads:
        comp, res = ef_compress(g, res)
        total_true += np.asarray(g["g"])
        total_comp += np.asarray(comp["g"], dtype=np.float64)
    # residual carries the outstanding error
    np.testing.assert_allclose(
        total_comp + np.asarray(res["g"]), total_true, rtol=1e-3, atol=1e-3
    )


# -- checkpointing ----------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_write=False)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.int32(7)}}
    ck.save(10, state)
    ck.save(20, state)
    ck.save(30, state)
    assert ck.list_steps() == [20, 30]  # keep=2 retention
    step, restored = ck.restore(state)
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_async_and_atomic(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=True)
    state = {"params": {"w": jnp.ones((4,))}}
    ck.save(1, state)
    ck.wait()
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    step, restored = ck.restore(state)
    assert step == 1


def test_checkpoint_elastic_restore_to_other_structure(tmp_path):
    """Mesh-independent format: restore is pure logical arrays."""
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(5, {"params": {"w": jnp.arange(8.0)}})
    _, restored = ck.restore({"params": {"w": jnp.zeros(8, jnp.float32)}})
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.arange(8.0, dtype=np.float32))


# -- fault tolerance ---------------------------------------------------------

def test_watchdog_flags_stragglers():
    import time

    wd = StepWatchdog(straggler_factor=3.0)
    for i in range(12):
        wd.start()
        time.sleep(0.02 if i != 10 else 0.2)
        wd.stop()
    assert 10 in wd.stragglers
    assert wd.summary()["stragglers"] >= 1


def test_run_with_retries():
    calls = {"n": 0}

    def step():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientError("boom")

    retried = []
    run_with_retries(step, on_retry=lambda a, e: retried.append(a))
    assert calls["n"] == 3 and retried == [0, 1]

    def always_fails():
        raise TransientError("nope")

    with pytest.raises(TransientError):
        run_with_retries(always_fails, max_retries=1)


def test_elastic_device_counts():
    assert elastic_device_counts(512, 16)[:3] == [512, 496, 480]
    assert all(n % 16 == 0 for n in elastic_device_counts(100, 16))


# -- data pipeline -----------------------------------------------------------

def test_data_determinism_and_resume():
    from repro.configs import get_config, reduced_config

    cfg = reduced_config(get_config("yi-6b"))
    b1 = batch_at_step(cfg, seed=3, step=7, host=0, n_hosts=1, batch=4, seq=16)
    b2 = batch_at_step(cfg, seed=3, step=7, host=0, n_hosts=1, batch=4, seq=16)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at_step(cfg, seed=3, step=8, host=0, n_hosts=1, batch=4, seq=16)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_prefetching_loader_matches_pure_fn():
    from repro.configs import get_config, reduced_config

    cfg = reduced_config(get_config("yi-6b"))
    loader = PrefetchingLoader(cfg, seed=1, batch=2, seq=8, start_step=5)
    try:
        step, batch = next(loader)
        assert step == 5
        want = batch_at_step(cfg, seed=1, step=5, host=0, n_hosts=1, batch=2, seq=8)
        np.testing.assert_array_equal(batch["tokens"], want["tokens"])
    finally:
        loader.close()
