"""SSM mixer equivalence properties: chunked == recurrent == stepwise."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.models import ssm as S
from repro.models.common import ArchConfig


def _cfg(d=48, h=3, dh=16, n=8, ff=96):
    return ArchConfig(
        name="t", family="ssm", n_layers=1, d_model=d, n_heads=0, n_kv_heads=0,
        head_dim=0, d_ff=ff, vocab=100, attn_type="none",
        ssm_heads=h, ssm_head_dim=dh, ssm_state=n,
    )


@settings(max_examples=10, deadline=None)
@given(t=st.integers(1, 50), seed=st.integers(0, 2**31 - 1),
       chunk=st.sampled_from([4, 16, 32]))
def test_wkv6_chunked_equals_recurrent(t, seed, chunk):
    cfg = _cfg()
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    b, h, dh = 2, cfg.ssm_heads, cfg.ssm_head_dim
    r, k, v = (jax.random.normal(ks[i], (b, t, h, dh)) * 0.5 for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, t, h, dh)) * 0.3)
    u = jax.random.normal(ks[4], (h, dh)) * 0.3
    s0 = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, h, dh, dh)) * 0.2
    o1, s1 = S.wkv6_recurrent(r, k, v, logw, u, s0)
    o2, s2 = S.wkv6_chunked(r, k, v, logw, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(t=st.integers(1, 50), seed=st.integers(0, 2**31 - 1),
       chunk=st.sampled_from([8, 32]))
def test_ssd_chunked_equals_recurrent(t, seed, chunk):
    cfg = _cfg()
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    b, h, dh, n = 2, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xv = jax.random.normal(ks[0], (b, t, h, dh))
    B = jax.random.normal(ks[1], (b, t, n)) * 0.5
    C = jax.random.normal(ks[2], (b, t, n)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, t, h)))
    logdecay = -dt * 0.5
    D = jnp.ones((h, dh))
    s0 = jax.random.normal(ks[4], (b, h, n, dh)) * 0.2
    o1, s1 = S.ssd_recurrent(xv, B, C, dt, logdecay, D, s0)
    o2, s2 = S.ssd_chunked(xv, B, C, dt, logdecay, D, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


def test_state_carry_across_segments():
    """Processing [0:T] == processing [0:T/2] then [T/2:T] with carried state."""
    cfg = _cfg()
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 24, cfg.d_model)) * 0.5
    p = S.rwkv_time_mix_init(jax.random.PRNGKey(4), cfg)
    xp = jnp.zeros((2, cfg.d_model))
    st0 = jnp.zeros((2, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_head_dim))
    y_full, _, s_full = S.rwkv_time_mix(p, x, xp, st0, cfg, mode="chunked")
    y1, xp1, s1 = S.rwkv_time_mix(p, x[:, :12], xp, st0, cfg, mode="chunked")
    y2, _, s2 = S.rwkv_time_mix(p, x[:, 12:], xp1, s1, cfg, mode="chunked")
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full),
        rtol=2e-4, atol=2e-4,
    )
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=2e-4, atol=2e-4)
