"""The benchmark regression gate: completed manifest, trend mode, trajectory.

Pins the ISSUE 5 CI satellites at the unit level: a partial benchmark
artifact must never pass vacuously, structural flags gate in every mode,
trend mode warns (not gates) on run-over-run timing drift, and the
trajectory appender emits one well-formed JSONL row per run.
"""

import json

import pytest

from benchmarks import check_regression as cr


def _doc(rows, completed=True, **extra):
    doc = {"rows": rows, "completed": completed}
    doc.update(extra)
    return doc


def _row(name, us, derived=""):
    return {"name": name, "us_per_call": us, "derived": derived}


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def test_incomplete_artifact_fails(tmp_path):
    cur = _write(tmp_path, "cur.json", _doc([_row("a", 1.0)], completed=False,
                                            failures=["serving: boom"]))
    assert cr.main([cur, "--baseline", str(tmp_path / "none.json")]) == 1


def test_missing_completed_key_fails(tmp_path):
    cur = _write(tmp_path, "cur.json", {"rows": [_row("a", 1.0)]})
    assert cr.main([cur, "--baseline", str(tmp_path / "none.json")]) == 1


def test_completed_artifact_passes(tmp_path):
    cur = _write(tmp_path, "cur.json",
                 _doc([_row("a", 1.0, "pipelined_parity=True")]))
    assert cr.main([cur, "--baseline", str(tmp_path / "none.json")]) == 0


@pytest.mark.parametrize("flag", [
    "pipelined_parity", "overlap_speedup", "cache_parity",
    "partition_parity", "bitwise_identical",
])
def test_structural_flag_gates_every_mode(tmp_path, flag):
    cur = _write(tmp_path, "cur.json", _doc([_row("a", 1.0, f"{flag}=False")]))
    prev = _write(tmp_path, "prev.json", _doc([_row("a", 1.0)]))
    # Baseline mode, missing-baseline mode, and trend mode all gate.
    assert cr.main([cur, "--baseline", prev]) == 1
    assert cr.main([cur, "--baseline", str(tmp_path / "none.json")]) == 1
    assert cr.main([cur, "--trend", prev]) == 1


def test_trend_mode_warns_but_does_not_gate_timing(tmp_path, capsys):
    cur = _write(tmp_path, "cur.json", _doc([_row("a", 10.0)]))
    prev = _write(tmp_path, "prev.json", _doc([_row("a", 1.0)]))
    assert cr.main([cur, "--trend", prev]) == 0  # 10x drift: warn only
    out = capsys.readouterr().out
    assert "SLOWER" in out and "warning only" in out


def test_trend_mode_reports_but_does_not_gate_missing_rows(tmp_path, capsys):
    """A renamed/retired structural row only fails against the *committed*
    baseline (which the PR regenerates), never against the previous run's
    artifact — otherwise the rename could not land at all."""
    cur = _write(tmp_path, "cur.json", _doc([_row("new-name", 1.0)]))
    prev = _write(tmp_path, "prev.json",
                  _doc([_row("old-name", 1.0, "partition_parity=True")]))
    assert cr.main([cur, "--trend", prev]) == 0
    assert "MISSING STRUCTURAL ROW" in capsys.readouterr().out


def test_trend_mode_counter_drift_warns_only(tmp_path, capsys):
    """Counter growth gates against the committed baseline (which a PR can
    regenerate) but only warns against the previous run's artifact."""
    cur = _write(tmp_path, "cur.json", _doc([_row("grouped_tiles", 20.0)]))
    prev = _write(tmp_path, "prev.json", _doc([_row("grouped_tiles", 10.0)]))
    assert cr.main([cur, "--trend", prev]) == 0
    assert "COUNTER REGRESSION" in capsys.readouterr().out
    assert cr.main([cur, "--baseline", prev]) == 1  # committed-baseline gate


def test_trend_mode_missing_previous_soft_skips(tmp_path, capsys):
    cur = _write(tmp_path, "cur.json", _doc([_row("a", 1.0)]))
    assert cr.main([cur, "--trend", str(tmp_path / "gone.json")]) == 0
    assert "skipped" in capsys.readouterr().out


def test_baseline_timing_gate_still_strict_only(tmp_path):
    cur = _write(tmp_path, "cur.json", _doc([_row("a", 10.0)]))
    base = _write(tmp_path, "base.json", _doc([_row("a", 1.0)]))
    assert cr.main([cur, "--baseline", base]) == 0
    assert cr.main([cur, "--baseline", base, "--strict"]) == 1


def test_missing_structural_row_fails(tmp_path):
    cur = _write(tmp_path, "cur.json", _doc([_row("a", 1.0)]))
    base = _write(tmp_path, "base.json",
                  _doc([_row("a", 1.0),
                        _row("b", 1.0, "partition_parity=True")]))
    assert cr.main([cur, "--baseline", base]) == 1


def test_trajectory_append(tmp_path, monkeypatch):
    monkeypatch.setenv("GITHUB_SHA", "abc123")
    monkeypatch.setenv("GITHUB_RUN_ID", "42")
    cur = _write(tmp_path, "cur.json",
                 _doc([_row("a", 1.5)], wall_s=12.5))
    traj = tmp_path / "BENCH_trajectory.jsonl"
    assert cr.main([cur, "--baseline", str(tmp_path / "none.json"),
                    "--append-trajectory", str(traj)]) == 0
    assert cr.main([cur, "--baseline", str(tmp_path / "none.json"),
                    "--append-trajectory", str(traj)]) == 0
    lines = traj.read_text().strip().splitlines()
    assert len(lines) == 2  # one row per run, appended
    row = json.loads(lines[0])
    assert row["sha"] == "abc123" and row["run_id"] == "42"
    assert row["completed"] is True and row["wall_s"] == 12.5
    assert row["rows"] == {"a": 1.5}
