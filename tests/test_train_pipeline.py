"""Full substrate pipeline: cluster -> train -> sparsify -> serve -> P@k."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic_labeled_dataset
from repro.metrics import precision_at_k, recall_at_k
from repro.serving import ServeConfig, XMRServingEngine
from repro.trees import build_clustered_tree, build_tree_structure, pifa_embeddings
from repro.trees.train import train_xmr_model


@pytest.fixture(scope="module")
def trained():
    rng = np.random.default_rng(7)
    ds = synthetic_labeled_dataset(
        rng, n_labels=128, d=256, n_train=768, n_test=192, query_nnz=14
    )
    model = train_xmr_model(
        ds.x_train, ds.y_train, ds.n_labels, branching=8, rng=rng, nnz_per_col=48,
        steps=120,
    )
    return ds, model


def test_tree_structure_shapes():
    t = build_tree_structure(100, 8)
    assert t.level_sizes == (8, 64, 512)
    assert (t.label_perm[:100] == np.arange(100)).all()
    assert (t.label_perm[100:] == -1).all()
    # ancestors nest properly
    leaf = np.arange(512)
    a1 = t.ancestor_at_level(leaf, 1)
    a0 = t.ancestor_at_level(leaf, 0)
    assert (a1 // 8 == a0).all()


def test_pifa_embeddings_normalized(rng):
    ds = synthetic_labeled_dataset(rng, n_labels=32, d=64, n_train=128, n_test=8)
    emb = pifa_embeddings(ds.x_train, ds.y_train, 32)
    norms = np.linalg.norm(emb, axis=1)
    assert ((norms < 1e-6) | (np.abs(norms - 1) < 1e-5)).all()


def test_clustering_groups_similar_labels(rng):
    ds = synthetic_labeled_dataset(
        rng, n_labels=64, d=128, n_train=512, n_test=8, n_groups=8
    )
    t = build_clustered_tree(ds.x_train, ds.y_train, 64, 8, rng)
    assert sorted(int(x) for x in t.label_perm if x >= 0) == list(range(64))


def test_trained_model_beats_chance(trained):
    ds, model = trained
    xi, xv = ds.x_test.to_ell(64)
    scores, labels = model.predict(jnp.asarray(xi), jnp.asarray(xv), beam=16, topk=5)
    p1 = precision_at_k(labels, ds.y_test, 1)
    r5 = recall_at_k(labels, ds.y_test, 5)
    assert p1 > 0.25          # chance is ~1/128
    assert r5 > p1 * 0.5
    assert scores.shape == labels.shape == (len(ds.y_test), 5)


def test_serving_engine_modes(trained):
    ds, model = trained
    eng = XMRServingEngine(
        model.tree,
        ServeConfig(beam=16, topk=5, ell_width=64),
        label_perm=model.structure.label_perm,
    )
    eng.warmup(ds.d, batch_sizes=(1, 64))
    s_b, l_b = eng.serve_batch(ds.x_test)
    s_o, l_o = eng.serve_online(ds.x_test, limit=16)
    np.testing.assert_array_equal(l_o, l_b[:16])
    np.testing.assert_allclose(s_o, s_b[:16], rtol=1e-5)
    summ = eng.latency_summary()
    assert summ["count"] > 0 and summ["p99_ms"] >= summ["p50_ms"]


def test_serving_methods_agree(trained):
    ds, model = trained
    outs = {}
    for method in ("vanilla", "mscm_dense", "mscm_searchsorted", "mscm_pallas"):
        eng = XMRServingEngine(
            model.tree, ServeConfig(beam=16, topk=5, ell_width=64, method=method)
        )
        _, labels = eng.serve_batch(ds.x_test)
        outs[method] = labels
    base = outs["vanilla"]
    for m, l in outs.items():
        np.testing.assert_array_equal(l, base, err_msg=m)
