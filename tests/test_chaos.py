"""Chaos suite: fault injection, degraded serving, supervised recovery.

Covers the ISSUE 7 robustness layer end to end:

* :class:`FaultInjector` semantics (drop/delay/truncate/corrupt frames,
  kill-on-Nth-exchange) against both a fake frame server and real workers;
* the degraded-exactness contract — under any dead partition, every score
  served from the survivors is **bitwise-equal** to the exhaustive
  full-tree score for that label (pinned deterministically and, when
  hypothesis is installed, as a property over random trees/queries);
* the `degraded` v1 wire field, `/healthz` 200-degraded semantics, and the
  `degraded_served` metric;
* the :class:`FleetSupervisor` state machine (UP → SUSPECT → RESTARTING →
  UP / FAILED), driven deterministically through ``poll_once`` on a stub
  fleet and against a real worker process it must respawn and re-ship;
* (slow) the acceptance pin: SIGKILL one of P=2 workers under sustained
  HTTP load — zero non-200s, degraded responses survivor-exact, bounded
  recovery, post-recovery bitwise-identical to the in-process engine.
"""

import functools
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import XMRTree
from repro.index import BeamTransport, ScatterGatherPlanner, partition_tree
from repro.serving import (
    BatchPolicy,
    FleetConfig,
    MicroBatcher,
    PartitionConfig,
    Query,
    ServeConfig,
    ServingGateway,
    XMRServingEngine,
)
from repro.serving.admission import WorkerUnavailable
from repro.serving.fleet import (
    STATE_FAILED,
    STATE_RESTARTING,
    STATE_SUSPECT,
    STATE_UP,
    FaultInjector,
    FleetSupervisor,
    PartitionFleet,
    partition_payload,
)
from repro.serving.fleet.rpc import WorkerConnection
from repro.serving.fleet.worker import PartitionRunner
from repro.sparse import random_sparse_csr
from tests.conftest import make_tree_weights
from tests.test_fleet_gateway import _FakeWorker, _get, _post

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

METHOD = "mscm_dense"


def _bits(x) -> np.ndarray:
    return np.ascontiguousarray(np.asarray(x, np.float32)).view(np.uint32)


def _assert_survivor_exact(s, l, missing_ranges, exhaustive_maps):
    """Every returned label avoids the dead ranges and scores bitwise-match
    the exhaustive full-tree reference (``exhaustive_maps[row][label]``)."""
    s = np.asarray(s)
    l = np.asarray(l)
    for row in range(l.shape[0]):
        bits = _bits(s[row])
        for k in range(l.shape[1]):
            label = int(l[row, k])
            for lo, hi in missing_ranges:
                assert not (lo <= label < hi), (
                    f"row {row}: label {label} from dead range [{lo},{hi})"
                )
            assert bits[k] == exhaustive_maps[row][label], (
                f"row {row} label {label}: degraded score not bitwise-equal"
            )


# ---------------------------------------------------------------------------
# shared world: tree, queries, references
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chaos_setup():
    rng = np.random.default_rng(23)
    d, B = 160, 6
    ws = make_tree_weights(rng, d, [6, 36, 216], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    queries = random_sparse_csr(12, d, 12, rng)
    # house reference: unpartitioned engine, default beam/topk
    ref = XMRServingEngine(tree, ServeConfig(ell_width=32, max_batch=64))
    ref_s, ref_l = ref.serve_batch(queries)
    # exhaustive reference: beam >= n_cols at every level makes the beam
    # search exact, giving the true score of EVERY label per query — the
    # oracle degraded scores must match bitwise
    ex = XMRServingEngine(
        tree, ServeConfig(ell_width=32, max_batch=64, beam=216, topk=216)
    )
    ex_s, ex_l = ex.serve_batch(queries)
    exhaustive = [
        {
            int(ex_l[i, k]): int(_bits(ex_s[i])[k])
            for k in range(ex_l.shape[1])
        }
        for i in range(queries.shape[0])
    ]
    return tree, queries, ref_s, ref_l, exhaustive


def _partitioned_engine(tree, partitions, **fleet_kw):
    return XMRServingEngine(
        tree,
        ServeConfig(
            ell_width=32, max_batch=64,
            partition=PartitionConfig(
                partitions=partitions, partition_sync="pipelined"
            ),
            fleet=FleetConfig(**fleet_kw),
        ),
    )


# ---------------------------------------------------------------------------
# FaultInjector semantics against a fake frame server (no subprocesses)
# ---------------------------------------------------------------------------

def test_fault_drop_swallows_frame_then_recovers():
    w = _FakeWorker()
    fault = FaultInjector().rule("drop", op="ping", nth=1)
    conn = WorkerConnection(
        "127.0.0.1", w.port, timeout_s=0.5, name="w0", fault=fault
    )
    with pytest.raises(WorkerUnavailable, match="timed out"):
        conn.call("ping")  # request never reached the server
    assert w.seq == 0
    conn.reconnect()
    header, _ = conn.call("ping")  # rule consumed: second call is clean
    assert header["ok"]
    conn.close()
    w.close()


def test_fault_delay_stalls_the_call():
    w = _FakeWorker()
    fault = FaultInjector().rule("delay", phase="recv", op="ping", seconds=0.3)
    conn = WorkerConnection(
        "127.0.0.1", w.port, timeout_s=10.0, name="w0", fault=fault
    )
    t0 = time.perf_counter()
    header, _ = conn.call("ping")
    assert header["ok"]
    assert time.perf_counter() - t0 >= 0.3
    conn.close()
    w.close()


def test_fault_truncate_desyncs_and_closes_stream():
    w = _FakeWorker()
    fault = FaultInjector().rule("truncate", op="ping", nth=1)
    conn = WorkerConnection(
        "127.0.0.1", w.port, timeout_s=5.0, name="w0", fault=fault
    )
    with pytest.raises(WorkerUnavailable, match="connection closed"):
        conn.call("ping")  # half a frame went out, stream closed locally
    conn.reconnect()
    header, _ = conn.call("ping")  # server dropped the bad conn, re-accepted
    assert header["ok"]
    conn.close()
    w.close()


def test_fault_rules_validate():
    with pytest.raises(ValueError, match="unknown fault action"):
        FaultInjector().rule("explode")
    with pytest.raises(ValueError, match="only apply on send"):
        FaultInjector().rule("corrupt", phase="recv")


# ---------------------------------------------------------------------------
# supervisor state machine, driven deterministically on a stub fleet
# ---------------------------------------------------------------------------

class _StubConn:
    def __init__(self, handle):
        self.lock = threading.RLock()
        self.timeout_s = 1.0
        self._handle = handle

    def call(self, op, header=None, arrays=(), timeout_s=None):
        if not self._handle.ping_ok:
            raise WorkerUnavailable("stub", op, "injected probe failure")
        return {"ok": True}, []


class _StubHandle:
    def __init__(self):
        self.dead = False
        self.ping_ok = True
        self.conn = _StubConn(self)

    def alive(self):
        return not self.dead


class _StubFleet:
    """Duck-typed PartitionFleet exposing exactly what the supervisor uses."""

    def __init__(self, n=1, respawn_failures=0):
        self._state_lock = threading.Lock()
        self._down = set()
        self.handles = [_StubHandle() for _ in range(n)]
        self.degraded_policy = "serve_partial"
        self.supervisor = None
        self.respawn_calls = 0
        self.respawn_failures = respawn_failures  # first k calls raise

    def mark_down(self, pid):
        with self._state_lock:
            self._down.add(pid)

    def respawn_worker(self, pid):
        self.respawn_calls += 1
        if self.respawn_calls <= self.respawn_failures:
            raise WorkerUnavailable(f"worker{pid}", "launch", "forced failure")
        with self._state_lock:
            self._down.discard(pid)
        self.handles[pid].dead = False
        self.handles[pid].ping_ok = True


def _state(sup, pid=0):
    return sup.states()[f"worker{pid}"]["state"]


def test_supervisor_suspect_recovers_without_restart():
    fleet = _StubFleet()
    sup = FleetSupervisor(fleet, FleetConfig(suspect_after=3))
    fleet.handles[0].ping_ok = False
    sup.poll_once()
    assert _state(sup) == STATE_SUSPECT
    fleet.handles[0].ping_ok = True  # transient blip clears
    sup.poll_once()
    assert _state(sup) == STATE_UP
    assert fleet.respawn_calls == 0


def test_supervisor_backoff_and_restart_after_probe_failures():
    fleet = _StubFleet(respawn_failures=2)
    sup = FleetSupervisor(
        fleet,
        FleetConfig(suspect_after=2, backoff_base_s=0.02, restart_budget=5),
    )
    fleet.handles[0].ping_ok = False
    sup.poll_once()
    assert _state(sup) == STATE_SUSPECT
    sup.poll_once()  # second consecutive failure: restart + mark down
    assert _state(sup) == STATE_RESTARTING
    assert fleet._down == {0}
    sup.poll_once()  # attempt 1 fails -> backoff 0.02s
    assert _state(sup) == STATE_RESTARTING and fleet.respawn_calls == 1
    sup.poll_once()  # still inside backoff: no attempt burned
    assert fleet.respawn_calls == 1
    time.sleep(0.03)
    sup.poll_once()  # attempt 2 fails -> backoff doubles
    assert fleet.respawn_calls == 2
    time.sleep(0.05)
    sup.poll_once()  # attempt 3 succeeds
    assert _state(sup) == STATE_UP
    assert fleet.respawn_calls == 3
    assert fleet._down == set()
    assert sup.states()["worker0"]["restarts"] == 3


def test_supervisor_dead_process_skips_suspect_and_budget_exhausts():
    fleet = _StubFleet(respawn_failures=10**9)  # respawn never succeeds
    sup = FleetSupervisor(
        fleet,
        FleetConfig(restart_budget=2, backoff_base_s=0.0, backoff_max_s=0.0),
    )
    fleet.handles[0].dead = True
    sup.poll_once()  # dead process: straight to RESTARTING
    assert _state(sup) == STATE_RESTARTING
    for _ in range(5):
        sup.poll_once()
    assert _state(sup) == STATE_FAILED  # budget of 2 burned, terminal
    assert fleet.respawn_calls == 2
    calls = fleet.respawn_calls
    sup.poll_once()
    assert fleet.respawn_calls == calls  # FAILED is terminal
    assert sup.metrics()["failed"] == 1


# ---------------------------------------------------------------------------
# degraded serving over a real fleet: kill-on-Nth, wire, cascade
# ---------------------------------------------------------------------------

def test_degraded_serving_end_to_end(chaos_setup):
    tree, queries, ref_s, ref_l, exhaustive = chaos_setup
    engine = _partitioned_engine(tree, 3)  # serve_partial default
    with PartitionFleet.launch(3, rpc_timeout_s=120.0) as fleet:
        fleet.attach(engine)
        assert fleet.degraded_policy == "serve_partial"
        ranges = [
            (int(p.label_start), int(p.label_end))
            for p in engine.index.manifest.partitions
        ]

        # full fleet: bitwise house contract, no degraded stamp
        s, l = engine.serve_batch(queries)
        np.testing.assert_array_equal(np.asarray(l), ref_l)
        np.testing.assert_array_equal(_bits(s), _bits(ref_s))
        assert engine.last_degraded() is None

        # a corrupt frame must not kill the real worker
        h2 = fleet.handles[2]
        h2.conn.fault = FaultInjector().rule("corrupt", op="ping", nth=1)
        with pytest.raises(WorkerUnavailable):
            h2.conn.call("ping")
        h2.conn.fault = None
        h2.conn.reconnect()
        header, _ = h2.conn.call("ping")
        assert header["ok"] and h2.alive(), "worker died on a corrupt frame"

        # kill worker0 on its first `step` send: the batch degrades
        # mid-exchange and is replayed over the survivors
        h0 = fleet.handles[0]
        h0.conn.fault = FaultInjector().rule(
            "kill", op="step", nth=1, callback=lambda: h0.kill(grace_s=0.0)
        )
        s, l = engine.serve_batch(queries)
        info = engine.last_degraded()
        assert info is not None and info["partitions"] == [0]
        assert [tuple(r) for r in info["label_ranges"]] == [ranges[0]]
        assert fleet.down_pids() == [0]
        _assert_survivor_exact(s, l, [ranges[0]], exhaustive)

        # v1 wire + health/metrics semantics while degraded
        with MicroBatcher(engine, BatchPolicy(max_batch=4, max_wait_ms=2.0)) \
                as mb, ServingGateway(mb, fleet=fleet) as gw:
            idx, val = queries.row(0)
            code, doc = _post(gw.url, Query(idx=idx, val=val, qid=0).to_wire())
            assert code == 200 and doc["status"] == "ok", doc
            assert doc["degraded"] is True
            assert doc["missing_labels"] == [list(ranges[0])]
            got_bits = _bits(np.asarray(doc["scores"], np.float32))
            for k, label in enumerate(doc["ids"]):
                assert got_bits[k] == exhaustive[0][int(label)]
            code, hdoc = _get(gw.url, "/healthz")
            assert code == 200, hdoc  # serve_partial: LB keeps routing
            assert hdoc["status"] == "degraded"
            assert hdoc["workers"]["worker0"] is False
            assert hdoc["degraded_policy"] == "serve_partial"
            code, mdoc = _get(gw.url, "/metrics")
            assert code == 200 and mdoc["degraded_served"] >= 1

        # cascade: worker1 dies too; the next batch degrades mid-flight
        # and completes from the single survivor
        fleet.handles[1].kill(grace_s=0.0)
        s, l = engine.serve_batch(queries)
        info = engine.last_degraded()
        assert info is not None and info["partitions"] == [0, 1]
        assert sorted(fleet.down_pids()) == [0, 1]
        _assert_survivor_exact(s, l, [ranges[0], ranges[1]], exhaustive)

        # no survivors: typed failure, never a hang
        fleet.handles[2].kill(grace_s=0.0)
        t0 = time.perf_counter()
        with pytest.raises(WorkerUnavailable):
            engine.serve_batch(queries)
        assert time.perf_counter() - t0 < 60.0


def test_supervisor_restarts_real_worker_and_restores_exactness(chaos_setup):
    tree, queries, ref_s, ref_l, _ = chaos_setup
    engine = _partitioned_engine(tree, 2)
    cfg = FleetConfig(
        poll_interval_s=0.05, ping_timeout_s=2.0, suspect_after=1,
        backoff_base_s=0.05, restart_budget=5,
    )
    with PartitionFleet.launch(2, rpc_timeout_s=120.0) as fleet:
        fleet.attach(engine)
        with FleetSupervisor(fleet, cfg) as sup:
            assert fleet.supervisor is sup
            s, l = engine.serve_batch(queries)
            np.testing.assert_array_equal(_bits(s), _bits(ref_s))

            fleet.handles[0].proc.kill()  # SIGKILL behind the fleet's back
            deadline = time.monotonic() + 120.0
            while time.monotonic() < deadline:
                st_ = sup.states()["worker0"]
                if st_["state"] == STATE_UP and st_["restarts"] >= 1 \
                        and not fleet.down_pids():
                    break
                time.sleep(0.05)
            st_ = sup.states()["worker0"]
            assert st_["state"] == STATE_UP and st_["restarts"] >= 1, st_

            # post-recovery: full-fleet results are bitwise the in-process
            # reference again, with no degraded stamp
            s, l = engine.serve_batch(queries)
            assert engine.last_degraded() is None
            np.testing.assert_array_equal(np.asarray(l), ref_l)
            np.testing.assert_array_equal(_bits(s), _bits(ref_s))
            assert sup.metrics()["up"] == 2


# ---------------------------------------------------------------------------
# degraded-exactness property (in-process runners; no subprocesses)
# ---------------------------------------------------------------------------

class _InProcTransport(BeamTransport):
    """PartitionRunner-backed transport with a controllable down-set.

    Mirrors the fleet's degraded protocol semantics: the down-set is fixed
    at ``begin`` and only the survivors' beams reach the coordinator.
    """

    def __init__(self, runners, down=()):
        self._runners = runners
        self.down = set(down)
        self._live = None

    @property
    def n_partitions(self):
        return len(self._runners)

    def down_partitions(self):
        return sorted(self.down)

    def begin(self, x_idx, x_val, parent_ids, scores):
        self._live = [
            p for p in range(len(self._runners)) if p not in self.down
        ]
        return [
            self._runners[p].begin(x_idx, x_val, parent_ids, scores)
            for p in self._live
        ]

    def step(self, level, winner_ids):
        return [self._runners[p].step(level, winner_ids) for p in self._live]


@functools.lru_cache(maxsize=None)
def _property_world():
    rng = np.random.default_rng(7)
    d, B = 96, 4
    ws = make_tree_weights(rng, d, [4, 16, 64], B)
    tree = XMRTree.from_weight_matrices(ws, B)
    idx = partition_tree(tree, 3)
    runners = [
        PartitionRunner(*partition_payload(
            idx, pid, beam=5, topk=5, method=METHOD
        ))
        for pid in range(3)
    ]
    return tree, idx, runners


def _check_single_dead_partition(dead: int, seed: int) -> None:
    tree, idx, runners = _property_world()
    rng = np.random.default_rng(seed)
    x = random_sparse_csr(5, tree.d, 10, rng)
    xi, xv = map(jnp.asarray, x.to_ell())
    planner = ScatterGatherPlanner(
        idx, beam=5, topk=5, method=METHOD, sync="pipelined",
        transport=_InProcTransport(runners, down={dead}),
    )
    s, l = planner.infer(xi, xv)
    info = planner.last_degraded
    assert info is not None and info["partitions"] == [dead]
    ex_s, ex_l = tree.infer(
        xi, xv, beam=64, topk=64, method=METHOD, score_mode="prod", qt=8
    )
    ex_l, ex_bits = np.asarray(ex_l), _bits(ex_s)
    exhaustive = [
        {int(ex_l[i, k]): int(ex_bits[i, k]) for k in range(ex_l.shape[1])}
        for i in range(ex_l.shape[0])
    ]
    dead_range = info["label_ranges"]
    _assert_survivor_exact(s, l, dead_range, exhaustive)


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(dead=st.integers(0, 2), seed=st.integers(0, 2**16))
    def test_degraded_exactness_property(dead, seed):
        """Under any single dead partition, every degraded-mode score is
        bitwise-equal to the exhaustive full-tree score for its label, and
        no returned label belongs to the dead range (ISSUE 7 satellite)."""
        _check_single_dead_partition(dead, seed)
else:
    @pytest.mark.parametrize("dead", [0, 1, 2])
    def test_degraded_exactness_property(dead):
        """Deterministic fallback when hypothesis is not installed."""
        _check_single_dead_partition(dead, seed=17)


# ---------------------------------------------------------------------------
# acceptance pin (slow): SIGKILL under sustained HTTP load, bounded recovery
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_chaos_gateway_kill_under_load_recovers_bitwise(chaos_setup):
    tree, queries, ref_s, ref_l, exhaustive = chaos_setup
    n = queries.shape[0]
    engine = _partitioned_engine(tree, 2)
    cfg = FleetConfig(
        poll_interval_s=0.05, ping_timeout_s=2.0, suspect_after=1,
        backoff_base_s=0.05, restart_budget=5,
    )
    worker0_range = None
    results = []
    errors = []
    lock = threading.Lock()
    stop = threading.Event()

    with PartitionFleet.launch(2, rpc_timeout_s=120.0) as fleet:
        fleet.attach(engine)
        worker0_range = (
            int(engine.index.manifest.partitions[0].label_start),
            int(engine.index.manifest.partitions[0].label_end),
        )
        with FleetSupervisor(fleet, cfg) as sup, \
                MicroBatcher(engine,
                             BatchPolicy(max_batch=4, max_wait_ms=2.0)) \
                as mb, ServingGateway(mb, fleet=fleet) as gw:

            def client(tid):
                i = 0
                while not stop.is_set():
                    qi = (tid + 3 * i) % n
                    i += 1
                    idx, val = queries.row(qi)
                    try:
                        code, doc = _post(
                            gw.url,
                            Query(idx=idx, val=val, qid=qi).to_wire(),
                            timeout=30.0,
                        )
                    except Exception as exc:  # noqa: BLE001 — a hang IS the bug
                        with lock:
                            errors.append(exc)
                        return
                    with lock:
                        results.append((time.monotonic(), code, doc))

            threads = [
                threading.Thread(target=client, args=(t,), daemon=True)
                for t in range(3)
            ]
            for t in threads:
                t.start()

            deadline = time.monotonic() + 60.0
            while time.monotonic() < deadline:
                with lock:
                    if len(results) >= 10:
                        break
                time.sleep(0.05)
            with lock:
                assert len(results) >= 10, "load never ramped"

            t_kill = time.monotonic()
            fleet.handles[0].proc.kill()  # hard SIGKILL mid-flight

            # bounded recovery: supervisor respawns + re-ships worker0
            recover_deadline = t_kill + 90.0
            while time.monotonic() < recover_deadline:
                st_ = sup.states()["worker0"]
                if st_["state"] == STATE_UP and st_["restarts"] >= 1 \
                        and not fleet.down_pids():
                    break
                time.sleep(0.05)
            t_recovered = time.monotonic()
            st_ = sup.states()["worker0"]
            assert st_["state"] == STATE_UP and st_["restarts"] >= 1, (
                f"no recovery within bound: {st_}"
            )

            time.sleep(1.0)  # collect post-recovery traffic
            stop.set()
            for t in threads:
                t.join(timeout=60)

    assert not errors, f"client-visible hang/failure: {errors[:3]}"
    assert results
    codes = [c for _, c, _ in results]
    assert set(codes) == {200}, f"non-200 under chaos: {sorted(set(codes))}"

    degraded = [
        (ts, doc) for ts, _, doc in results
        if doc.get("degraded") and ts >= t_kill
    ]
    assert degraded, "kill never surfaced a degraded response"
    for _, doc in degraded:
        assert doc["missing_labels"] == [list(worker0_range)]
        got_bits = _bits(np.asarray(doc["scores"], np.float32))
        for k, label in enumerate(doc["ids"]):
            label = int(label)
            assert not (worker0_range[0] <= label < worker0_range[1])
            assert got_bits[k] == exhaustive[doc["qid"]][label]

    post = [
        doc for ts, _, doc in results
        if ts > t_recovered and not doc.get("degraded")
    ]
    assert post, "no full-fleet responses after recovery"
    for doc in post:
        qi = doc["qid"]
        np.testing.assert_array_equal(
            np.asarray(doc["ids"], np.int32), ref_l[qi]
        )
        np.testing.assert_array_equal(
            _bits(np.asarray(doc["scores"], np.float32)), _bits(ref_s[qi])
        )
