"""xmrlint: every rule catches its seeded fixture and passes its clean twin;
suppressions, baseline round-trips, the CLI, and the repo-is-clean gate.

The golden fixtures live under ``tests/fixtures/xmrlint/`` — one ``*_bad``
(seeded violations, line-pinned below) and one ``*_ok`` (idiomatic
compliant code) per rule. Recursive discovery skips the fixture tree, so
the repo-wide gate and these tests never fight; fixtures are linted by
naming them explicitly, exactly like the CLI would.
"""

import io
import json
import sys
from pathlib import Path

import pytest

from tools.xmrlint import Baseline, all_rules, lint_paths, main
from tools.xmrlint.core import BAD_SUPPRESSION_ID, ModuleContext, run_rules

REPO = Path(__file__).resolve().parents[1]
FIX = REPO / "tests" / "fixtures" / "xmrlint"


def lint(*relpaths, rules=None, baseline=None):
    new, old, stale, n = lint_paths(
        [FIX / r for r in relpaths], root=REPO, rules=rules, baseline=baseline
    )
    return new


def rules_of(violations):
    return {v.rule for v in violations}


# -- one positive + one negative per rule ------------------------------------

def test_xmr001_guarded_fields_positive():
    found = lint("xmr001_bad.py")
    assert rules_of(found) == {"XMR001"}
    assert len(found) == 2  # unlocked add + unlocked read
    assert all("guarded-by" in v.message for v in found)


def test_xmr001_guarded_fields_negative():
    assert lint("xmr001_ok.py") == []


def test_xmr001_fleet_sockets_positive():
    found = lint("serving/fleet/sockets_bad.py")
    assert rules_of(found) == {"XMR001"}
    assert len(found) == 2  # sendall + recv
    assert all("per-connection lock" in v.message for v in found)


def test_xmr001_fleet_sockets_negative():
    assert lint("serving/fleet/sockets_ok.py") == []


def test_xmr002_trace_safety_positive():
    found = lint("xmr002_bad.py")
    assert rules_of(found) == {"XMR002"}
    lines = {v.line for v in found}
    assert 10 in lines  # if s.sum() > 0
    assert 12 in lines  # float(s.max())
    assert 13 in lines  # np.asarray(s)
    assert 18 in lines  # helper's .item(), reachable from root


def test_xmr002_trace_safety_negative():
    assert lint("xmr002_ok.py") == []


def test_xmr003_recompile_hazard_positive():
    found = lint("xmr003_bad.py")
    assert rules_of(found) == {"XMR003"}
    assert len(found) == 2  # len() kwarg + shape positional
    assert all("bucket" in v.message for v in found)


def test_xmr003_recompile_hazard_negative():
    assert lint("xmr003_ok.py") == []


def test_xmr004_exception_discipline_positive():
    found = lint("serving/xmr004_bad.py")
    assert rules_of(found) == {"XMR004"}
    assert len(found) == 2  # except Exception: pass + except BaseException


def test_xmr004_exception_discipline_negative():
    assert lint("serving/xmr004_ok.py") == []


def test_xmr004_scoped_to_serving_and_index(tmp_path):
    # the same swallow outside serving//index/ is out of scope
    src = (FIX / "serving" / "xmr004_bad.py").read_text()
    other = tmp_path / "elsewhere.py"
    other.write_text(src)
    new, _, _, _ = lint_paths([other], root=tmp_path)
    assert new == []


def test_xmr005_parity_discipline_positive():
    found = lint("repro/core/xmr005_bad.py")
    assert rules_of(found) == {"XMR005"}
    assert len(found) == 3  # ==, !=, ad-hoc top_k


def test_xmr005_parity_discipline_negative():
    assert lint("repro/core/xmr005_ok.py") == []


def test_xmr005_tolerance_tier_pragma_exempts_measurement_code():
    # Quantized-tier metric helpers (recall/MAE across tiers) measure score
    # drift; the function pragma waives the ad-hoc-selection check for them
    # in both accepted placements (line above the def, the def line itself).
    assert lint("repro/quant/xmr005_tolerance_ok.py") == []


def test_xmr005_tolerance_tier_pragma_is_function_scoped():
    # repro/quant is inside the checked scope, and a floating or detached
    # pragma comment must not waive anything — only the def line or the
    # line directly above it attach.
    found = lint("repro/quant/xmr005_tolerance_bad.py")
    assert rules_of(found) == {"XMR005"}
    assert len(found) == 2  # unmarked select + detached pragma


# -- suppressions -------------------------------------------------------------

def _ctx(tmp_path, source, name="mod.py"):
    f = tmp_path / name
    f.write_text(source)
    return ModuleContext.from_file(f, tmp_path)


XMR005_EQ = "NEG_INF = -1e30\n\ndef f(s):\n    return s == NEG_INF{comment}\n"


def test_inline_suppression_with_justification_silences(tmp_path):
    ctx = _ctx(tmp_path, XMR005_EQ.format(
        comment="  # xmrlint: disable=XMR005 -- mask unavailable here"
    ))
    assert run_rules(ctx, all_rules().values()) == []


def test_bare_suppression_is_itself_reported(tmp_path):
    ctx = _ctx(tmp_path, XMR005_EQ.format(
        comment="  # xmrlint: disable=XMR005"
    ))
    found = run_rules(ctx, all_rules().values())
    # the bare disable silences nothing AND is flagged as XMR000
    assert rules_of(found) == {BAD_SUPPRESSION_ID, "XMR005"}


def test_standalone_suppression_covers_next_statement(tmp_path):
    ctx = _ctx(
        tmp_path,
        "NEG_INF = -1e30\n\ndef f(s):\n"
        "    # xmrlint: disable=XMR005 -- fixture exercises the comment form\n"
        "    return s == NEG_INF\n",
    )
    assert run_rules(ctx, all_rules().values()) == []


# -- baseline -----------------------------------------------------------------

def test_baseline_round_trip(tmp_path):
    found = lint("repro/core/xmr005_bad.py")
    assert found
    base = Baseline.from_violations(found, justification="fixture pin")
    path = tmp_path / "baseline.json"
    base.save(path)
    loaded = Baseline.load(path)
    assert all(loaded.contains(v) for v in found)
    # baselined findings no longer gate; nothing is stale
    new = lint("repro/core/xmr005_bad.py", baseline=loaded)
    assert new == []


def test_baseline_fingerprint_survives_line_drift(tmp_path):
    src = "NEG_INF = -1e30\n\ndef f(s):\n    return s == NEG_INF\n"
    before = run_rules(_ctx(tmp_path, src), all_rules().values())
    base = Baseline.from_violations(before, justification="pin")
    drifted = "NEG_INF = -1e30\n\n# a new comment\n\ndef f(s):\n    return s == NEG_INF\n"
    after = run_rules(_ctx(tmp_path, drifted), all_rules().values())
    assert [v.line for v in after] != [v.line for v in before]
    assert all(base.contains(v) for v in after)


def test_baseline_rejects_missing_justification(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({
        "version": 1,
        "entries": [{"rule": "XMR005", "path": "x.py", "fingerprint": "ab",
                     "justification": "  "}],
    }))
    with pytest.raises(ValueError, match="justification"):
        Baseline.load(path)


def test_stale_baseline_entries_reported():
    base = Baseline([{
        "rule": "XMR005", "path": "repro/core/gone.py",
        "fingerprint": "deadbeefdeadbeef", "justification": "was fixed",
    }])
    new, old, stale, _ = lint_paths(
        [FIX / "repro/core/xmr005_ok.py"], root=REPO, baseline=base
    )
    assert new == [] and old == []
    assert [e["fingerprint"] for e in stale] == ["deadbeefdeadbeef"]


# -- CLI ----------------------------------------------------------------------

def _run_cli(argv, capsys):
    code = main(argv)
    return code, capsys.readouterr().out


def test_cli_json_format_and_exit_code(capsys):
    code, out = _run_cli(
        [str(FIX / "repro/core/xmr005_bad.py"), "--format=json",
         "--no-baseline"],
        capsys,
    )
    assert code == 1
    doc = json.loads(out)
    assert doc["counts"] == {"XMR005": 3}
    assert {v["rule"] for v in doc["violations"]} == {"XMR005"}


def test_cli_select_limits_rules(capsys):
    code, out = _run_cli(
        [str(FIX / "xmr002_bad.py"), str(FIX / "xmr003_bad.py"),
         "--select=XMR003", "--no-baseline", "--format=json"],
        capsys,
    )
    assert code == 1
    doc = json.loads(out)
    assert set(doc["counts"]) == {"XMR003"}


def test_cli_unknown_rule_is_usage_error(capsys):
    assert main(["--select=XMR999"]) == 2


# -- the gate itself ----------------------------------------------------------

def test_repo_is_clean_end_to_end():
    """The CI gate invariant: the real tree lints clean against the
    committed baseline (which is empty — keep it that way)."""
    baseline = Baseline.load(REPO / "tools" / "xmrlint" / "baseline.json")
    assert baseline.entries == [], (
        "baseline.json grew entries; fix the violations instead"
    )
    new, _, stale, n_files = lint_paths(
        [REPO / "src", REPO / "tests", REPO / "benchmarks"],
        root=REPO, baseline=baseline,
    )
    assert n_files > 50
    assert new == [], "\n".join(v.text() for v in new)
    assert stale == []


def test_fixture_tree_is_skipped_by_discovery():
    new, _, _, n_files = lint_paths([REPO / "tests"], root=REPO)
    assert all("fixtures/xmrlint" not in v.path for v in new)
