"""xmrlint framework: rule registry, module context, suppressions, baseline.

The pieces every rule builds on:

* :class:`ModuleContext` — one parsed file: source, AST (with parent links),
  the comment map (``tokenize``-accurate, so comments inside expressions are
  attributed to their physical line), inline suppressions, and module-level
  pragmas.
* :class:`Violation` — one finding, with a *fingerprint* that is stable
  under line drift (it hashes the rule, path, and normalized source line —
  not the line number), so baseline entries survive unrelated edits.
* :class:`Baseline` — the committed fix-me file: known violations that are
  temporarily accepted. Every entry carries a justification; the gate fails
  on violations not in the baseline and warns on stale entries.
* :func:`register` / :func:`all_rules` — the rule registry. A rule is a
  class with ``id``/``name``/``description`` and a ``check(ctx)`` generator;
  adding rule six means writing one module under ``tools/xmrlint/rules/``
  and importing it from the package ``__init__``.

Suppression policy (enforced here, not per-rule): an inline
``# xmrlint: disable=XMR00N -- <justification>`` silences matching rules on
that physical line (or on the following statement line when the comment
stands alone). The justification is **required**: a bare ``disable=`` is
itself reported as ``XMR000 bad-suppression`` and does not silence anything.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple, Type

#: ``# xmrlint: disable=XMR001[,XMR002] -- justification``
_DISABLE_RE = re.compile(
    r"#\s*xmrlint:\s*disable=(?P<rules>[A-Z0-9, ]+?)"
    r"(?:\s*--\s*(?P<why>\S.*))?\s*$"
)
#: ``# xmrlint: <pragma>`` module/function pragmas (e.g. ``single-threaded``,
#: ``transport-primitive``, ``requires-lock=_cond``).
_PRAGMA_RE = re.compile(r"#\s*xmrlint:\s*(?!disable=)([A-Za-z][\w=.-]*)")

BAD_SUPPRESSION_ID = "XMR000"


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding. ``fingerprint`` identifies it across line drift."""

    rule: str
    path: str       # repo-relative posix path
    line: int       # 1-based
    col: int        # 0-based
    message: str
    fingerprint: str

    def text(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: {self.rule} {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class Rule:
    """Base class for one lint rule.

    Subclasses set ``id`` (``XMR00N``), ``name`` (kebab-case), and
    ``description``, and implement :meth:`check` as a generator of
    :class:`Violation`. Use :meth:`violation` so fingerprints stay uniform.
    """

    id: str = "XMR999"
    name: str = "unnamed"
    description: str = ""

    def applies_to(self, ctx: "ModuleContext") -> bool:
        return True

    def check(self, ctx: "ModuleContext") -> Iterator[Violation]:
        raise NotImplementedError
        yield  # pragma: no cover

    def violation(
        self, ctx: "ModuleContext", node: ast.AST, message: str
    ) -> Violation:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return make_violation(self.id, ctx, line, col, message)


def make_violation(
    rule_id: str, ctx: "ModuleContext", line: int, col: int, message: str
) -> Violation:
    return Violation(
        rule=rule_id,
        path=ctx.relpath,
        line=line,
        col=col,
        message=message,
        fingerprint=ctx.fingerprint(rule_id, line),
    )


_REGISTRY: Dict[str, Rule] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    """Class decorator: add a rule to the registry (id must be unique)."""
    rule = cls()
    if rule.id in _REGISTRY:
        raise ValueError(f"duplicate rule id {rule.id}")
    _REGISTRY[rule.id] = rule
    return cls


def all_rules() -> Dict[str, Rule]:
    """The registry, importing the built-in rule package on first use."""
    import tools.xmrlint.rules  # noqa: F401  (registers on import)

    return dict(_REGISTRY)


class ModuleContext:
    """One parsed source file plus everything rules need to inspect it."""

    def __init__(self, path: Path, relpath: str, source: str) -> None:
        self.path = path
        self.relpath = relpath
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=relpath)
        _attach_parents(self.tree)
        #: physical line -> comment text (including the leading ``#``)
        self.comments: Dict[int, str] = {}
        #: physical lines that hold *only* a comment (no code tokens)
        self._comment_only: Set[int] = set()
        self._tokenize_comments()
        #: line -> rule ids validly suppressed on that line
        self.suppressions: Dict[int, Set[str]] = {}
        #: ``XMR000``: disables with no justification
        self.bad_suppressions: List[Violation] = []
        self._collect_suppressions()
        #: module-level pragmas (``# xmrlint: <word>`` in the first 10 lines
        #: or anywhere at column 0 before any code)
        self.pragmas: Set[str] = self._module_pragmas()

    # -- comments / pragmas --------------------------------------------------
    def _tokenize_comments(self) -> None:
        code_lines: Set[int] = set()
        try:
            for tok in tokenize.generate_tokens(io.StringIO(self.source).readline):
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
                elif tok.type not in (
                    tokenize.NL,
                    tokenize.NEWLINE,
                    tokenize.INDENT,
                    tokenize.DEDENT,
                    tokenize.ENDMARKER,
                ):
                    for ln in range(tok.start[0], tok.end[0] + 1):
                        code_lines.add(ln)
        except tokenize.TokenError:  # unterminated string etc.; ast parsed OK
            pass
        self._comment_only = set(self.comments) - code_lines

    def comment_on(self, line: int) -> str:
        """The comment attached to ``line`` (same line, ``""`` if none)."""
        return self.comments.get(line, "")

    def function_pragmas(self, fn: ast.AST) -> Set[str]:
        """Pragmas on a function: its ``def`` line or the line just above."""
        out: Set[str] = set()
        lineno = getattr(fn, "lineno", None)
        if lineno is None:
            return out
        deco_floor = min(
            [lineno] + [d.lineno for d in getattr(fn, "decorator_list", [])]
        )
        for ln in (lineno, deco_floor - 1):
            for m in _PRAGMA_RE.finditer(self.comment_on(ln)):
                out.add(m.group(1))
        return out

    def _module_pragmas(self) -> Set[str]:
        out: Set[str] = set()
        first_code = min(
            (n.lineno for n in self.tree.body if not _is_docstring(n)),
            default=len(self.lines) + 1,
        )
        for ln, comment in self.comments.items():
            if ln <= first_code or ln in self._comment_only:
                for m in _PRAGMA_RE.finditer(comment):
                    out.add(m.group(1))
        return out

    # -- suppressions --------------------------------------------------------
    def _collect_suppressions(self) -> None:
        for ln, comment in sorted(self.comments.items()):
            m = _DISABLE_RE.search(comment)
            if not m:
                continue
            rules = {r.strip() for r in m.group("rules").split(",") if r.strip()}
            why = (m.group("why") or "").strip()
            if not why:
                self.bad_suppressions.append(
                    make_violation(
                        BAD_SUPPRESSION_ID, self, ln, 0,
                        "suppression without justification: write "
                        "'# xmrlint: disable=XMR00N -- <why this is safe>'",
                    )
                )
                continue
            target = ln
            if ln in self._comment_only:
                # standalone comment suppresses the next code line
                target = self._next_code_line(ln)
            self.suppressions.setdefault(target, set()).update(rules)

    def _next_code_line(self, ln: int) -> int:
        for nxt in range(ln + 1, len(self.lines) + 1):
            if nxt in self._comment_only or not self.lines[nxt - 1].strip():
                continue
            return nxt
        return ln

    def suppressed(self, v: Violation) -> bool:
        return v.rule in self.suppressions.get(v.line, set())

    # -- fingerprints --------------------------------------------------------
    def fingerprint(self, rule_id: str, line: int) -> str:
        norm = ""
        if 1 <= line <= len(self.lines):
            norm = "".join(self.lines[line - 1].split())
        occurrence = sum(
            1
            for prior in range(1, line)
            if "".join(self.lines[prior - 1].split()) == norm
        )
        key = f"{rule_id}:{self.relpath}:{norm}:{occurrence}"
        return hashlib.sha256(key.encode()).hexdigest()[:16]

    @classmethod
    def from_file(cls, path: Path, root: Path) -> "ModuleContext":
        try:
            rel = path.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = path.as_posix()
        return cls(path, rel, path.read_text(encoding="utf-8"))


class Baseline:
    """The committed fix-me file: accepted violations by fingerprint.

    Schema (JSON)::

        {"version": 1,
         "entries": [{"rule": "XMR001", "path": "src/…", "fingerprint": "…",
                      "justification": "why this is temporarily accepted"}]}

    Matching is by ``(rule, path, fingerprint)`` so entries survive line
    drift but die with the offending code. ``justification`` is mandatory —
    the loader refuses entries without one.
    """

    VERSION = 1

    def __init__(self, entries: Optional[Sequence[dict]] = None) -> None:
        self.entries: List[dict] = list(entries or [])
        self._keys: Set[Tuple[str, str, str]] = {
            (e["rule"], e["path"], e["fingerprint"]) for e in self.entries
        }

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not path.exists():
            return cls()
        doc = json.loads(path.read_text(encoding="utf-8"))
        if doc.get("version") != cls.VERSION:
            raise ValueError(
                f"{path}: baseline version {doc.get('version')!r} != {cls.VERSION}"
            )
        entries = doc.get("entries", [])
        for e in entries:
            missing = {"rule", "path", "fingerprint", "justification"} - set(e)
            if missing:
                raise ValueError(
                    f"{path}: baseline entry {e!r} missing {sorted(missing)}"
                )
            if not str(e["justification"]).strip():
                raise ValueError(
                    f"{path}: baseline entry for {e['rule']} at {e['path']} "
                    "has an empty justification"
                )
        return cls(entries)

    def save(self, path: Path) -> None:
        doc = {"version": self.VERSION, "entries": self.entries}
        path.write_text(json.dumps(doc, indent=2) + "\n", encoding="utf-8")

    def contains(self, v: Violation) -> bool:
        return (v.rule, v.path, v.fingerprint) in self._keys

    def stale_entries(self, violations: Sequence[Violation]) -> List[dict]:
        """Entries whose violation no longer exists (should be deleted)."""
        live = {(v.rule, v.path, v.fingerprint) for v in violations}
        return [
            e
            for e in self.entries
            if (e["rule"], e["path"], e["fingerprint"]) not in live
        ]

    @classmethod
    def from_violations(
        cls, violations: Sequence[Violation], justification: str
    ) -> "Baseline":
        return cls(
            [
                {
                    "rule": v.rule,
                    "path": v.path,
                    "fingerprint": v.fingerprint,
                    "line": v.line,  # informational; matching ignores it
                    "message": v.message,
                    "justification": justification,
                }
                for v in violations
            ]
        )


# -- shared AST helpers -------------------------------------------------------


def _attach_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.xmr_parent = node  # type: ignore[attr-defined]


def parent(node: ast.AST) -> Optional[ast.AST]:
    return getattr(node, "xmr_parent", None)


def ancestors(node: ast.AST) -> Iterator[ast.AST]:
    cur = parent(node)
    while cur is not None:
        yield cur
        cur = parent(cur)


def enclosing_function(node: ast.AST) -> Optional[ast.AST]:
    for anc in ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return anc
    return None


def enclosing_class(node: ast.AST) -> Optional[ast.ClassDef]:
    for anc in ancestors(node):
        if isinstance(anc, ast.ClassDef):
            return anc
    return None


def attr_tail(node: ast.AST) -> Optional[str]:
    """The last segment of a Name/Attribute chain (``a.b._lock`` → ``_lock``)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for pure Name/Attribute chains, else None."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def _is_docstring(node: ast.stmt) -> bool:
    return (
        isinstance(node, ast.Expr)
        and isinstance(node.value, ast.Constant)
        and isinstance(node.value.value, str)
    )


def iter_functions(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node  # type: ignore[misc]


def run_rules(
    ctx: ModuleContext, rules: Iterable[Rule]
) -> List[Violation]:
    """All unsuppressed findings for one module (bad suppressions included)."""
    out: List[Violation] = list(ctx.bad_suppressions)
    for rule in rules:
        if not rule.applies_to(ctx):
            continue
        for v in rule.check(ctx):
            if not ctx.suppressed(v):
                out.append(v)
    return out
