"""XMR003 — bounded cardinality for jit static arguments.

Every distinct value of a ``static_argnames`` argument compiles a fresh XLA
program. The serving stack keeps the jit cache bounded by construction:
batch sizes go through the power-of-two bucket tiers
(``XMRServingEngine.bucket_for``), and every other static is a config knob
or per-tree constant. A call site that feeds a static parameter a raw
``len(...)`` / ``x.shape[...]`` / ``x.size`` value — unbounded cardinality
under live traffic — is a jit-cache explosion waiting for a traffic pattern,
which this rule flags at the call site.

Detection is per-module: jitted callables are recognized the same way as in
XMR002 (decorator or ``jax.jit(f, …)`` / ``functools.partial(jax.jit, …)``
assignment), positional arguments are mapped through the wrapped function's
signature, and an expression is *unbounded* when it derives from ``len()``,
``.shape``, ``.size`` or ``.nbytes`` — directly or through a local variable
— without passing through a recognized bucketing call (a function whose
name contains ``bucket``, ``pow2``, ``power_of_two``, ``tier`` or
``quantize``). Constants, config attributes, and plain parameters are
bounded by presumption: the rule targets the one hazard class this repo has
actually shipped guards for (raw batch sizes bypassing the bucket tiers).
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Set

from tools.xmrlint.core import (
    ModuleContext,
    Rule,
    Violation,
    dotted_name,
    enclosing_function,
    register,
)
from tools.xmrlint.rules.xmr002_trace_safety import _JitRoots, _param_names

_BUCKETING_RE = re.compile(r"bucket|pow2|power_of_two|tier|quantiz", re.I)
_UNBOUNDED_ATTRS = {"shape", "size", "nbytes"}


def _is_bucketing_call(node: ast.Call) -> bool:
    name = dotted_name(node.func) or ""
    return bool(_BUCKETING_RE.search(name.split(".")[-1]))


class _BoundednessScope:
    """Tracks which local names derive from unbounded size expressions."""

    def __init__(self) -> None:
        self.unbounded: Set[str] = set()

    def is_unbounded(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Call):
            if _is_bucketing_call(node):
                return False  # bucketing collapses cardinality
            fname = dotted_name(node.func)
            if fname == "len":
                return True
            if fname == "min":
                # min() against any bounded value is a clamp: an integer
                # size clamped to k takes at most k+1 distinct values.
                return all(self.is_unbounded(a) for a in node.args)
            return any(self.is_unbounded(a) for a in node.args) or any(
                kw.value is not None and self.is_unbounded(kw.value)
                for kw in node.keywords
            )
        if isinstance(node, ast.Attribute):
            if node.attr in _UNBOUNDED_ATTRS:
                return True
            return False  # config/tree attributes: bounded per deployment
        if isinstance(node, ast.Subscript):
            return self.is_unbounded(node.value)
        if isinstance(node, ast.Name):
            return node.id in self.unbounded
        if isinstance(node, (ast.BinOp,)):
            return self.is_unbounded(node.left) or self.is_unbounded(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_unbounded(node.operand)
        if isinstance(node, ast.IfExp):
            return self.is_unbounded(node.body) or self.is_unbounded(node.orelse)
        return False

    def track(self, fn: ast.AST, until_line: int) -> None:
        """Replay local assignments textually before a call site, in order.

        Order matters: ``width = parent_ids.shape[1]`` followed by the beam
        recurrence ``width = min(next_b, width * branching)`` leaves the name
        *bounded* — the clamp re-assignment closest above the call wins.
        """
        assigns = [
            node
            for node in ast.walk(fn)
            if isinstance(node, ast.Assign)
            and getattr(node, "lineno", 0) <= until_line
        ]
        for node in sorted(assigns, key=lambda n: n.lineno):
            unbounded = self.is_unbounded(node.value)
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        if unbounded:
                            self.unbounded.add(n.id)
                        else:
                            self.unbounded.discard(n.id)


@register
class RecompileHazardRule(Rule):
    id = "XMR003"
    name = "recompile-hazard"
    description = (
        "jit static_argnames arguments must have bounded cardinality — "
        "route raw sizes through the power-of-two bucket tiers"
    )

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        roots = _JitRoots(ctx)
        if not roots.roots:
            return
        signatures: Dict[str, List[str]] = {
            name: [a.arg for a in _param_names(roots.functions[name])]
            for name in roots.roots
            if name in roots.functions
        }
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted_name(node.func)
            if callee is None or callee not in roots.roots:
                continue
            statics = roots.roots[callee]
            if not statics:
                continue
            params = signatures.get(callee, [])
            yield from self._check_site(ctx, node, callee, statics, params)

    def _check_site(
        self,
        ctx: ModuleContext,
        call: ast.Call,
        callee: str,
        statics: Set[str],
        params: List[str],
    ) -> Iterator[Violation]:
        scope = _BoundednessScope()
        fn = enclosing_function(call)
        if fn is not None:
            scope.track(fn, getattr(call, "lineno", 10**9))
        bindings = []
        for i, arg in enumerate(call.args):
            if i < len(params) and params[i] in statics:
                bindings.append((params[i], arg))
        for kw in call.keywords:
            if kw.arg in statics:
                bindings.append((kw.arg, kw.value))
        for name, value in bindings:
            if scope.is_unbounded(value):
                yield self.violation(
                    ctx, value,
                    f"static arg '{name}' of jitted '{callee}' receives an "
                    "unbounded-cardinality size expression — every distinct "
                    "value compiles a fresh XLA program; route it through "
                    "the power-of-two bucket tiers (e.g. bucket_for())",
                )
