"""Built-in rule passes. Importing this package registers every rule.

To add rule six: create ``xmr006_your_rule.py`` defining a
``@register``-decorated :class:`~tools.xmrlint.core.Rule` subclass, import
it below, write a positive + negative fixture under
``tests/fixtures/xmrlint/``, and document the id in ``tools/xmrlint/README.md``.
"""

from tools.xmrlint.rules import (  # noqa: F401
    xmr001_lock_discipline,
    xmr002_trace_safety,
    xmr003_recompile_hazard,
    xmr004_exception_discipline,
    xmr005_parity_discipline,
)
