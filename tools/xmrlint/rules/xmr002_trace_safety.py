"""XMR002 — zero-host-callback purity for jit-reachable functions.

The grouped serving path compiles the whole traversal as ONE XLA program
(pinned dynamically by ``test_grouped_fully_jitted``); this rule makes the
contract a *compile-time* property: functions reachable from a ``jax.jit``
root in the same module must not

* call ``.item()`` / ``.tolist()`` (device→host sync),
* call ``float()`` / ``bool()`` / ``int()`` on a traced value
  (``TracerConversionError`` at best, silent recompiles at worst),
* call ``np.*`` on a traced value (host round-trip; breaks tracing),
* branch in Python (``if`` / ``while`` / ``assert`` / ternary) on a traced
  value.

Tracedness is a deliberately simple intraprocedural taint pass:

* jit roots: parameters are traced unless named in ``static_argnames``;
* helpers reached from a root: parameters are traced unless their name
  appears in any of the module's ``static_argnames`` tuples, they are
  annotated ``int``/``str``/``bool``, or they default to a str/bool
  constant (the ``method=``/``score_mode=`` idiom);
* ``x.shape`` / ``x.ndim`` / ``x.dtype`` / ``len(x)`` of a traced value are
  *static* (JAX guarantees concrete shapes under trace), and ``is None`` /
  ``is not None`` tests are static pytree structure — both are exempt.

Single-module scope keeps the pass honest: a cross-module helper is either
jitted itself (then it is a root in its own module) or trivially host-side.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from tools.xmrlint.core import (
    ModuleContext,
    Rule,
    Violation,
    dotted_name,
    register,
)

_STATIC_ANNOTATIONS = {"int", "str", "bool"}
_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes", "sharding"}
_HOST_SYNC_METHODS = {"item", "tolist"}
_HOST_CASTS = {"float", "bool", "int"}
#: np attributes that are constants/dtypes/types — never host callbacks.
_NP_SAFE_ATTRS = {
    "float32", "float64", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool_", "dtype", "ndarray",
    "nan", "inf", "pi", "newaxis", "generic", "number", "integer",
    "floating",
}
_NUMPY_ALIASES = {"np", "numpy"}


def _is_jax_jit(node: ast.AST) -> bool:
    """True for ``jax.jit`` / bare ``jit`` references."""
    name = dotted_name(node)
    return name in ("jax.jit", "jit")


def _static_names_from_call(call: ast.Call) -> Set[str]:
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for v in ast.walk(kw.value):
                if isinstance(v, ast.Constant) and isinstance(v.value, str):
                    out.add(v.value)
    return out


def _jit_partial(call: ast.Call) -> Optional[Set[str]]:
    """``functools.partial(jax.jit, static_argnames=…)`` → static names."""
    if dotted_name(call.func) in ("functools.partial", "partial") and call.args:
        if _is_jax_jit(call.args[0]):
            return _static_names_from_call(call)
    return None


class _JitRoots:
    """jit roots in one module: function name -> static param names."""

    def __init__(self, ctx: ModuleContext) -> None:
        self.roots: Dict[str, Set[str]] = {}
        self.static_union: Set[str] = set()
        self.functions: Dict[str, ast.FunctionDef] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.setdefault(node.name, node)
                statics = self._decorated_statics(node)
                if statics is not None:
                    self.roots[node.name] = statics
        for node in ast.walk(ctx.tree):
            # name = jax.jit(f, …) / functools.partial(jax.jit, …)(f)
            if not isinstance(node, ast.Assign) or not isinstance(
                node.value, ast.Call
            ):
                continue
            call = node.value
            statics: Optional[Set[str]] = None
            target_fn: Optional[str] = None
            if _is_jax_jit(call.func) and call.args:
                statics = _static_names_from_call(call)
                target_fn = dotted_name(call.args[0])
            elif isinstance(call.func, ast.Call):
                partial_statics = _jit_partial(call.func)
                if partial_statics is not None and call.args:
                    statics = partial_statics
                    target_fn = dotted_name(call.args[0])
            if statics is not None and target_fn and "." not in target_fn:
                if target_fn in self.functions:
                    self.roots[target_fn] = statics
        for s in self.roots.values():
            self.static_union |= s

    def _decorated_statics(self, fn: ast.AST) -> Optional[Set[str]]:
        for deco in getattr(fn, "decorator_list", []):
            if _is_jax_jit(deco):
                return set()
            if isinstance(deco, ast.Call):
                if _is_jax_jit(deco.func):
                    return _static_names_from_call(deco)
                partial_statics = _jit_partial(deco)
                if partial_statics is not None:
                    return partial_statics
                # shard_map-decorated bodies trace like jit bodies
                if dotted_name(deco.func) in ("shard_map", "jax.experimental.shard_map.shard_map"):
                    return _static_names_from_call(deco)
        return None

    def reachable(self) -> Set[str]:
        """Functions reachable from any root through same-module calls."""
        calls: Dict[str, Set[str]] = {}
        for name, fn in self.functions.items():
            out: Set[str] = set()
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    callee = dotted_name(node.func)
                    if callee in self.functions:
                        out.add(callee)
            calls[name] = out
        seen: Set[str] = set()
        frontier: List[str] = list(self.roots)
        while frontier:
            cur = frontier.pop()
            if cur in seen:
                continue
            seen.add(cur)
            frontier.extend(calls.get(cur, ()))
        return seen


def _param_names(fn: ast.FunctionDef) -> List[ast.arg]:
    a = fn.args
    return [*a.posonlyargs, *a.args, *a.kwonlyargs]


def _static_params(
    fn: ast.FunctionDef, declared: Optional[Set[str]], static_union: Set[str]
) -> Set[str]:
    params = _param_names(fn)
    static: Set[str] = set()
    defaults: Dict[str, ast.expr] = {}
    a = fn.args
    pos = [*a.posonlyargs, *a.args]
    for arg, d in zip(reversed(pos), reversed(a.defaults)):
        defaults[arg.arg] = d
    for arg, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None:
            defaults[arg.arg] = d
    for arg in params:
        name = arg.arg
        if declared is not None and name in declared:
            static.add(name)
            continue
        if declared is None:
            ann = arg.annotation
            if (
                name in static_union
                or (isinstance(ann, ast.Name) and ann.id in _STATIC_ANNOTATIONS)
            ):
                static.add(name)
                continue
            d = defaults.get(name)
            if isinstance(d, ast.Constant) and isinstance(d.value, (str, bool)):
                static.add(name)
    return static


class _Taint:
    """Order-sensitive traced-name tracking through one function body."""

    def __init__(self, traced: Set[str]) -> None:
        self.traced = set(traced)

    def mentions_traced(self, node: ast.AST) -> bool:
        """Does ``node`` reference a traced name, ignoring static projections
        (``.shape``/``.ndim``/…, ``len()``, ``is None`` comparisons)?"""
        if isinstance(node, ast.Attribute) and node.attr in _SHAPE_ATTRS:
            return False
        if isinstance(node, ast.Call):
            fname = dotted_name(node.func)
            if fname in ("len", "isinstance", "type", "getattr", "hasattr"):
                return False
        if isinstance(node, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops
        ):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.traced
        return any(self.mentions_traced(c) for c in ast.iter_child_nodes(node))

    def _mark(self, target: ast.AST, traced: bool) -> None:
        for n in ast.walk(target):
            if isinstance(n, ast.Name):
                if traced:
                    self.traced.add(n.id)
                else:
                    self.traced.discard(n.id)

    def assign(self, target: ast.AST, value: ast.AST) -> None:
        self._mark(target, self.mentions_traced(value))

    def for_targets(self, target: ast.AST, it: ast.AST) -> None:
        if isinstance(it, ast.Call):
            fname = dotted_name(it.func)
            if fname == "range":
                self._mark(target, False)
                return
            if fname == "enumerate" and isinstance(target, ast.Tuple) and it.args:
                elts = target.elts
                if len(elts) == 2:
                    self._mark(elts[0], False)
                    self._mark(elts[1], self.mentions_traced(it.args[0]))
                    return
        self._mark(target, self.mentions_traced(it))


@register
class TraceSafetyRule(Rule):
    id = "XMR002"
    name = "trace-safety"
    description = (
        "jit-reachable functions must not host-sync (.item/float/bool/np.*)"
        " or branch in Python on traced values"
    )

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        roots = _JitRoots(ctx)
        if not roots.roots:
            return
        reachable = roots.reachable()
        for name in sorted(reachable):
            fn = roots.functions[name]
            declared = roots.roots.get(name)
            static = _static_params(fn, declared, roots.static_union)
            traced = {a.arg for a in _param_names(fn)} - static
            yield from self._check_function(ctx, fn, traced)

    def _check_function(
        self, ctx: ModuleContext, fn: ast.FunctionDef, traced: Set[str]
    ) -> Iterator[Violation]:
        taint = _Taint(traced)
        yield from self._walk_block(ctx, fn.body, taint, fn.name)

    def _walk_block(
        self, ctx: ModuleContext, body, taint: "_Taint", fname: str
    ) -> Iterator[Violation]:
        for stmt in body:
            yield from self._walk_stmt(ctx, stmt, taint, fname)

    def _walk_stmt(
        self, ctx: ModuleContext, stmt: ast.stmt, taint: "_Taint", fname: str
    ) -> Iterator[Violation]:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return  # nested defs are separate scopes (closures handled as static)
        for expr in _stmt_exprs(stmt):
            yield from self._check_expr(ctx, expr, taint, fname)
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                taint.assign(t, stmt.value)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            taint.assign(stmt.target, stmt.value)
        elif isinstance(stmt, ast.AugAssign):
            if taint.mentions_traced(stmt.value):
                taint._mark(stmt.target, True)
        elif isinstance(stmt, (ast.If, ast.While)):
            if taint.mentions_traced(stmt.test):
                kind = "if" if isinstance(stmt, ast.If) else "while"
                yield self.violation(
                    ctx, stmt,
                    f"python '{kind}' on a traced value in jit-reachable "
                    f"'{fname}' — use lax.cond/jnp.where (or mark the "
                    "argument static)",
                )
            yield from self._walk_block(ctx, stmt.body, taint, fname)
            yield from self._walk_block(ctx, stmt.orelse, taint, fname)
            return
        elif isinstance(stmt, ast.Assert):
            if taint.mentions_traced(stmt.test):
                yield self.violation(
                    ctx, stmt,
                    f"python 'assert' on a traced value in jit-reachable "
                    f"'{fname}' — use checkify or a static property",
                )
        elif isinstance(stmt, ast.For):
            taint.for_targets(stmt.target, stmt.iter)
            yield from self._walk_block(ctx, stmt.body, taint, fname)
            yield from self._walk_block(ctx, stmt.orelse, taint, fname)
            return
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            yield from self._walk_block(ctx, stmt.body, taint, fname)
            return
        elif isinstance(stmt, ast.Try):
            for blk in (stmt.body, stmt.orelse, stmt.finalbody):
                yield from self._walk_block(ctx, blk, taint, fname)
            for h in stmt.handlers:
                yield from self._walk_block(ctx, h.body, taint, fname)
            return

    def _check_expr(
        self, ctx: ModuleContext, expr: ast.AST, taint: "_Taint", fname: str
    ) -> Iterator[Violation]:
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                yield from self._check_call(ctx, node, taint, fname)
            elif isinstance(node, ast.IfExp) and taint.mentions_traced(node.test):
                yield self.violation(
                    ctx, node,
                    f"python ternary on a traced value in jit-reachable "
                    f"'{fname}' — use jnp.where",
                )

    def _check_call(
        self, ctx: ModuleContext, node: ast.Call, taint: "_Taint", fname: str
    ) -> Iterator[Violation]:
        func = node.func
        if (
            isinstance(func, ast.Attribute)
            and func.attr in _HOST_SYNC_METHODS
        ):
            yield self.violation(
                ctx, node,
                f".{func.attr}() in jit-reachable '{fname}' forces a "
                "device→host sync under trace",
            )
            return
        if (
            isinstance(func, ast.Name)
            and func.id in _HOST_CASTS
            and node.args
            and taint.mentions_traced(node.args[0])
        ):
            yield self.violation(
                ctx, node,
                f"{func.id}() on a traced value in jit-reachable "
                f"'{fname}' raises TracerConversionError under jit",
            )
            return
        if (
            isinstance(func, ast.Attribute)
            and isinstance(func.value, ast.Name)
            and func.value.id in _NUMPY_ALIASES
            and func.attr not in _NP_SAFE_ATTRS
            and any(taint.mentions_traced(a) for a in node.args)
        ):
            yield self.violation(
                ctx, node,
                f"np.{func.attr}() on a traced value in jit-reachable "
                f"'{fname}' — use jnp (numpy forces a host round-trip)",
            )


def _stmt_exprs(stmt: ast.stmt):
    """Expressions evaluated by a statement, excluding nested blocks."""
    for field in ("value", "test", "iter", "exc", "msg"):
        v = getattr(stmt, field, None)
        if isinstance(v, ast.AST):
            yield v
