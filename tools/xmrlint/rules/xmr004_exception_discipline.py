"""XMR004 — typed-exception discipline in the serving/index namespaces.

The v1 wire maps *typed* serving errors to HTTP statuses
(``Overloaded``→429, ``DeadlineExceeded``→504, ``WorkerUnavailable``→503);
an ``except Exception:`` that silently swallows breaks that contract — the
launcher's partial-launch cleanup once ate the very failure that explained
a dead fleet. In ``serving/`` and ``index/`` modules, a broad handler
(``except Exception`` / ``except BaseException``) must do at least one of:

* **re-raise** (a bare ``raise`` or ``raise X from e`` anywhere in the body),
* **log** (any ``log``/``logger``/``logging`` call, ``warnings.warn``, or a
  ``traceback.print_*``),
* **use the caught exception** — bind it (``as exc``) and reference it in
  the body: converting to a typed error, failing a future
  (``set_exception(exc)``), or recording it in diagnostic state all count.

A handler that binds nothing and does none of the above is a silent
swallow. The fix is usually three tokens: bind the exception and log it.
"""

from __future__ import annotations

import ast
from typing import Iterator

from tools.xmrlint.core import ModuleContext, Rule, Violation, register

_BROAD = {"Exception", "BaseException"}
_LOG_ROOTS = {"log", "logger", "logging", "warnings", "traceback"}
_SCOPES = ("serving/", "index/")


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    names = []
    if isinstance(t, ast.Name):
        names = [t.id]
    elif isinstance(t, ast.Tuple):
        names = [e.id for e in t.elts if isinstance(e, ast.Name)]
    return any(n in _BROAD for n in names)


def _reraises(handler: ast.ExceptHandler) -> bool:
    return any(isinstance(n, ast.Raise) for n in ast.walk(handler))


def _logs(handler: ast.ExceptHandler) -> bool:
    for node in ast.walk(handler):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        root = None
        if isinstance(f, ast.Attribute):
            cur = f
            while isinstance(cur, ast.Attribute):
                cur = cur.value
            if isinstance(cur, ast.Name):
                root = cur.id
        elif isinstance(f, ast.Name):
            root = f.id
        if root in _LOG_ROOTS:
            return True
    return False


def _uses_bound_exc(handler: ast.ExceptHandler) -> bool:
    if handler.name is None:
        return False
    for node in ast.walk(handler):
        if isinstance(node, ast.Name) and node.id == handler.name:
            if isinstance(node.ctx, ast.Load):
                return True
    return False


@register
class ExceptionDisciplineRule(Rule):
    id = "XMR004"
    name = "typed-exception-discipline"
    description = (
        "broad 'except Exception' in serving/index must re-raise, log, or "
        "convert to a typed serving error — never swallow silently"
    )

    def applies_to(self, ctx: ModuleContext) -> bool:
        return any(s in ctx.relpath for s in _SCOPES)

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler) or not _is_broad(node):
                continue
            if _reraises(node) or _logs(node) or _uses_bound_exc(node):
                continue
            yield self.violation(
                ctx, node,
                "broad exception handler swallows the failure silently — "
                "log the cause, re-raise, or convert to a typed serving "
                "error (WorkerUnavailable / ServingError)",
            )
