"""XMR001 — lock discipline on annotated fields and fleet socket paths.

Two checks, both born from the PR-6 frame-interleaving bug (a health-check
ping racing a beam exchange on the same socket):

**Guarded fields.** A field declared with a trailing ``# guarded-by: <lock>``
comment::

    self._down: Set[int] = set()   # guarded-by: _state_lock

may only be read or written while that lock is held. "Held" is judged
lexically: the access sits inside a ``with <…>.<lock>:`` block, or the
enclosing function calls ``<…>.<lock>.acquire(…)`` (the try/finally fan-out
pattern), or the function is annotated ``# xmrlint: requires-lock=<lock>``
(the obligation moves to its callers, which this rule then checks at every
intra-class call site). ``__init__`` is exempt — construction happens-before
publication.

**Fleet socket discipline.** In ``serving/fleet`` modules, raw stream
operations (``.sendall``/``.recv``/``.recv_into`` and the frame helpers
``send_frame``/``recv_frame``) must run under a lock named ``lock`` — the
per-connection ``WorkerConnection.lock`` convention — so two threads can
never interleave frames on one socket. A module that is single-threaded by
design (the worker's accept loop) opts out with a module-level
``# xmrlint: single-threaded`` pragma; bottom-layer helpers that *implement*
the transport are annotated ``# xmrlint: transport-primitive`` (their callers
carry the obligation).

The check is intraprocedural and name-based (the lock is matched by its
final attribute segment), which is exactly as strong as the convention it
enforces: annotate the field, and every unlocked touch becomes a finding.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, Optional, Set

from tools.xmrlint.core import (
    ModuleContext,
    Rule,
    Violation,
    ancestors,
    attr_tail,
    dotted_name,
    register,
)

GUARDED_BY_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][\w.]*)")
_REQUIRES_LOCK = "requires-lock="
_RAW_SOCKET_OPS = {"sendall", "recv", "recv_into"}
_FRAME_HELPERS = {"send_frame", "recv_frame"}


def _lock_tail(spec: str) -> str:
    return spec.split(".")[-1]


def _with_locks(node: ast.AST) -> Set[str]:
    """Lock names (final segments) of every enclosing ``with`` item."""
    held: Set[str] = set()
    for anc in ancestors(node):
        if isinstance(anc, (ast.With, ast.AsyncWith)):
            for item in anc.items:
                tail = attr_tail(item.context_expr)
                if tail:
                    held.add(tail)
    return held


def _function_acquires(fn: ast.AST) -> Set[str]:
    """Locks the function calls ``.acquire()`` on anywhere in its body."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "acquire"
        ):
            tail = attr_tail(node.func.value)
            if tail:
                out.add(tail)
    return out


def _enclosing_functions(node: ast.AST):
    for anc in ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield anc


@register
class LockDisciplineRule(Rule):
    id = "XMR001"
    name = "lock-discipline"
    description = (
        "fields annotated '# guarded-by: <lock>' may only be touched under "
        "that lock; raw socket ops on fleet paths need the connection lock"
    )

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        for cls in ast.walk(ctx.tree):
            if isinstance(cls, ast.ClassDef):
                yield from self._check_class(ctx, cls)
        if (
            "serving/fleet" in ctx.relpath
            and "single-threaded" not in ctx.pragmas
        ):
            yield from self._check_sockets(ctx)

    # -- guarded fields ------------------------------------------------------
    def _guards(self, ctx: ModuleContext, cls: ast.ClassDef) -> Dict[str, str]:
        """field name -> lock tail, from '# guarded-by:' declarations."""
        guards: Dict[str, str] = {}
        for node in ast.walk(cls):
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign):
                targets = [node.target]
            else:
                continue
            m = GUARDED_BY_RE.search(ctx.comment_on(node.lineno))
            if not m:
                continue
            lock = _lock_tail(m.group(1))
            for t in targets:
                if (
                    isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"
                ):
                    guards[t.attr] = lock
                elif isinstance(t, ast.Name):  # class-level / dataclass field
                    guards[t.id] = lock
        return guards

    def _requires(self, ctx: ModuleContext, cls: ast.ClassDef) -> Dict[str, str]:
        """method name -> lock tail, from '# xmrlint: requires-lock=' pragmas."""
        out: Dict[str, str] = {}
        for node in cls.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for pragma in ctx.function_pragmas(node):
                    if pragma.startswith(_REQUIRES_LOCK):
                        out[node.name] = _lock_tail(pragma[len(_REQUIRES_LOCK):])
        return out

    def _held(
        self,
        ctx: ModuleContext,
        node: ast.AST,
        lock: str,
        requires: Dict[str, str],
    ) -> bool:
        if lock in _with_locks(node):
            return True
        for fn in _enclosing_functions(node):
            if fn.name == "__init__":
                return True
            if lock in _function_acquires(fn):
                return True
            if requires.get(fn.name) == lock:
                return True
            for pragma in ctx.function_pragmas(fn):
                if pragma == f"{_REQUIRES_LOCK}{lock}":
                    return True
        return False

    def _check_class(
        self, ctx: ModuleContext, cls: ast.ClassDef
    ) -> Iterator[Violation]:
        guards = self._guards(ctx, cls)
        requires = self._requires(ctx, cls)
        if not guards and not requires:
            return
        for node in ast.walk(cls):
            # self.<guarded-field> loads and stores
            if (
                isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and node.attr in guards
            ):
                lock = guards[node.attr]
                if not self._held(ctx, node, lock, requires):
                    yield self.violation(
                        ctx, node,
                        f"'self.{node.attr}' is guarded-by '{lock}' but "
                        f"accessed without holding it (wrap in 'with "
                        f"…{lock}:' or annotate the function "
                        f"'# xmrlint: requires-lock={lock}')",
                    )
            # calls to requires-lock methods must themselves hold the lock
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "self"
                and node.func.attr in requires
            ):
                lock = requires[node.func.attr]
                if not self._held(ctx, node, lock, requires):
                    yield self.violation(
                        ctx, node,
                        f"call to 'self.{node.func.attr}()' requires lock "
                        f"'{lock}' to be held by the caller",
                    )

    # -- fleet socket discipline ---------------------------------------------
    def _check_sockets(self, ctx: ModuleContext) -> Iterator[Violation]:
        primitives: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if "transport-primitive" in ctx.function_pragmas(node):
                    primitives.add(node.name)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            op: Optional[str] = None
            if (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in _RAW_SOCKET_OPS
            ):
                op = dotted_name(node.func) or node.func.attr
            elif (
                isinstance(node.func, ast.Name)
                and node.func.id in (_FRAME_HELPERS | primitives)
            ):
                op = node.func.id
            if op is None:
                continue
            fns = list(_enclosing_functions(node))
            if not fns:
                continue
            if any(f.name in primitives for f in fns):
                continue  # the primitive itself; callers carry the lock
            held = "lock" in _with_locks(node) or any(
                "lock" in _function_acquires(f) for f in fns
            )
            if not held:
                yield self.violation(
                    ctx, node,
                    f"raw stream operation '{op}' on a fleet path outside "
                    "the per-connection lock — a concurrent ping can "
                    "interleave frames with a beam exchange (hold "
                    "'conn.lock', or mark the module "
                    "'# xmrlint: single-threaded' / the helper "
                    "'# xmrlint: transport-primitive')",
                )
