"""XMR005 — bitwise-parity discipline: sentinels and canonical selection.

The house contract is bitwise identity across every serving path (grouped
kernel, partitions, pipelined fleet, the JSON wire). Two statically
checkable ways to break it:

**Sentinel equality.** ``NEG_INF`` is a *score value* (-1e30), not a tag:
masked entries are re-derived through ``jnp.where`` every level, and real
scores can reach it through arithmetic. ``x == NEG_INF`` / ``x != NEG_INF``
is therefore a latent logic error everywhere — membership must come from
the mask that produced the sentinel (or an ordering test), never from
float equality.

**Ad-hoc beam selection.** Canonical ``(score desc, id asc)`` tie-breaking
lives in exactly three helpers: ``beam_select`` (the two-key sort),
``_local_select`` (the id-presorted ``top_k`` whose lowest-index tie-break
*is* the canonical order), and ``topk_canonical``/``merge_topk`` (the merge
primitive). A raw ``lax.top_k`` or ``lax.sort`` selection anywhere else in
the serving stack (``repro/core``, ``repro/index``, ``repro/serving``,
``repro/quant``) can disagree with them on ties — exactly the class of
drift the partition/fleet parity tests exist to catch, caught here before
it compiles.

One narrow escape hatch: a ``# xmrlint: tolerance-tier`` pragma on the
``def`` line (or the line directly above) marks a function as *measurement*
code for the quantized tier's tolerance contract — it compares scores
across tiers, where bitwise tie-break identity is not the claim being made
— and exempts it from the ad-hoc-selection check. The pragma is
function-scoped on purpose: a module-wide waiver would silently cover
serving-path code added later to the same file.
"""

from __future__ import annotations

import ast
from typing import Iterator

from tools.xmrlint.core import (
    ModuleContext,
    Rule,
    Violation,
    dotted_name,
    enclosing_function,
    register,
)

_SENTINELS = {"NEG_INF"}
#: Functions allowed to call lax.top_k / lax.sort directly — the canonical
#: selection helpers whose tie-break semantics the parity tests pin.
_CANONICAL_FNS = {"beam_select", "_local_select", "merge_topk", "topk_canonical"}
_SELECT_CALLS = {"top_k", "sort"}
_STACK_SCOPES = (
    "repro/core/", "repro/index/", "repro/serving/", "repro/quant/",
)
#: Function pragma exempting tier-comparison *measurement* code from the
#: ad-hoc-selection check (see module docstring). Function-scoped only.
_TOLERANCE_PRAGMA = "tolerance-tier"


def _is_sentinel(node: ast.AST) -> bool:
    name = dotted_name(node)
    return name is not None and name.split(".")[-1] in _SENTINELS


@register
class ParityDisciplineRule(Rule):
    id = "XMR005"
    name = "parity-discipline"
    description = (
        "no float == against NEG_INF sentinels; beam selection via lax."
        "top_k/sort only inside the canonical helpers"
    )

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        yield from self._check_sentinel_eq(ctx)
        if any(s in ctx.relpath for s in _STACK_SCOPES):
            yield from self._check_adhoc_select(ctx)

    def _check_sentinel_eq(self, ctx: ModuleContext) -> Iterator[Violation]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Compare):
                continue
            if not any(isinstance(op, (ast.Eq, ast.NotEq)) for op in node.ops):
                continue
            operands = [node.left, *node.comparators]
            if any(_is_sentinel(o) for o in operands):
                yield self.violation(
                    ctx, node,
                    "float equality against the NEG_INF sentinel — masked "
                    "entries are re-derived scores, not tags; use the "
                    "producing mask (or an ordering test) instead",
                )

    def _check_adhoc_select(self, ctx: ModuleContext) -> Iterator[Violation]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name is None:
                continue
            parts = name.split(".")
            if parts[-1] not in _SELECT_CALLS:
                continue
            if "lax" not in parts[:-1]:
                continue  # jnp.sort on host-side prep etc. is out of scope
            fn = enclosing_function(node)
            if fn is not None and fn.name in _CANONICAL_FNS:
                continue
            if fn is not None and _TOLERANCE_PRAGMA in ctx.function_pragmas(fn):
                continue
            yield self.violation(
                ctx, node,
                f"ad-hoc beam selection via {name} outside the canonical "
                "helpers (beam_select/_local_select/topk_canonical) — its "
                "tie-break order can disagree with the bitwise parity "
                "contract; route through the canonical helpers",
            )
