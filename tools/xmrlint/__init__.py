"""xmrlint: repo-specific static analysis for the XMR serving stack.

A small, stdlib-only (``ast`` + ``tokenize``) lint framework whose rules
encode the invariants this codebase's serving fleet actually depends on —
lock discipline on the beam-exchange RPC, zero-host-callback jit purity,
bounded jit-cache cardinality, typed-exception contracts on the v1 wire,
and canonical beam-selection parity. See ``tools/xmrlint/README.md`` for
the rule catalogue and annotation conventions.

Usage::

    python -m tools.xmrlint src tests benchmarks
    python -m tools.xmrlint --format=json --baseline tools/xmrlint/baseline.json src
"""

from tools.xmrlint.core import (  # noqa: F401
    Baseline,
    ModuleContext,
    Rule,
    Violation,
    all_rules,
    register,
)
from tools.xmrlint.runner import lint_paths, main  # noqa: F401

__version__ = "1.0.0"
