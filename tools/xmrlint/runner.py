"""File discovery, the lint driver, reporters, and the CLI entry point.

``lint_paths`` is the programmatic API the tests use; ``main`` is what
``python -m tools.xmrlint`` calls. Exit codes: 0 clean, 1 violations (or
stale baseline entries under ``--strict-baseline``), 2 usage/parse errors.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Tuple

from tools.xmrlint.core import (
    Baseline,
    ModuleContext,
    Rule,
    Violation,
    all_rules,
    run_rules,
)

#: Directory names never descended into during recursive discovery.
_SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules", ".mypy_cache"}
#: Fixture trees carry *seeded* violations; recursive discovery skips them,
#: but naming a fixture file explicitly on the CLI still lints it (that is
#: how the test suite drives each rule).
_SKIP_REL = ("tests/fixtures/xmrlint",)


def discover(paths: Sequence[Path], root: Path) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        if p.is_file():
            out.append(p)
            continue
        if not p.is_dir():
            raise FileNotFoundError(f"no such file or directory: {p}")
        for f in sorted(p.rglob("*.py")):
            if any(part in _SKIP_DIRS for part in f.parts):
                continue
            rel = _relpath(f, root)
            if any(rel.startswith(skip) for skip in _SKIP_REL):
                continue
            out.append(f)
    return out


def _relpath(path: Path, root: Path) -> str:
    try:
        return path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def lint_paths(
    paths: Sequence[Path],
    *,
    root: Optional[Path] = None,
    rules: Optional[Iterable[Rule]] = None,
    baseline: Optional[Baseline] = None,
) -> Tuple[List[Violation], List[Violation], List[dict], int]:
    """Lint ``paths``; returns ``(new, baselined, stale_entries, n_files)``.

    ``new`` are violations not covered by the baseline (these gate CI);
    ``baselined`` are matched by a baseline entry; ``stale_entries`` are
    baseline entries whose violation no longer exists.
    """
    root = root or Path.cwd()
    active = list((rules if rules is not None else all_rules().values()))
    files = discover([Path(p) for p in paths], root)
    violations: List[Violation] = []
    errors: List[str] = []
    for f in files:
        try:
            ctx = ModuleContext.from_file(f, root)
        except (SyntaxError, UnicodeDecodeError) as exc:
            errors.append(f"{_relpath(f, root)}: unparseable: {exc}")
            continue
        violations.extend(run_rules(ctx, active))
    if errors:
        raise SyntaxError("; ".join(errors))
    violations.sort(key=lambda v: (v.path, v.line, v.col, v.rule))
    base = baseline or Baseline()
    new = [v for v in violations if not base.contains(v)]
    old = [v for v in violations if base.contains(v)]
    return new, old, base.stale_entries(violations), len(files)


def _report_text(
    new: List[Violation], old: List[Violation], stale: List[dict],
    n_files: int, out,
) -> None:
    for v in new:
        print(v.text(), file=out)
    for e in stale:
        print(
            f"{e['path']}: stale baseline entry for {e['rule']} "
            f"(fingerprint {e['fingerprint']}) — the violation is gone; "
            "delete the entry",
            file=out,
        )
    summary = (
        f"xmrlint: {n_files} file(s), {len(new)} violation(s)"
        + (f", {len(old)} baselined" if old else "")
        + (f", {len(stale)} stale baseline entrie(s)" if stale else "")
    )
    print(summary, file=out)


def _report_json(
    new: List[Violation], old: List[Violation], stale: List[dict],
    n_files: int, out,
) -> None:
    doc = {
        "version": 1,
        "files": n_files,
        "violations": [v.to_json() for v in new],
        "baselined": [v.to_json() for v in old],
        "stale_baseline_entries": stale,
        "counts": _counts(new),
    }
    json.dump(doc, out, indent=2)
    out.write("\n")


def _counts(violations: List[Violation]) -> dict:
    counts: dict = {}
    for v in violations:
        counts[v.rule] = counts.get(v.rule, 0) + 1
    return counts


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.xmrlint",
        description="Repo-specific static analysis for the XMR serving stack.",
    )
    ap.add_argument("paths", nargs="*", default=["src"], help="files or directories")
    ap.add_argument(
        "--format", choices=("text", "json"), default="text", dest="fmt"
    )
    ap.add_argument(
        "--baseline",
        default=str(Path(__file__).parent / "baseline.json"),
        help="baseline-suppression file (default: tools/xmrlint/baseline.json)",
    )
    ap.add_argument(
        "--no-baseline", action="store_true",
        help="ignore the baseline file (report everything)",
    )
    ap.add_argument(
        "--write-baseline", action="store_true",
        help="write current violations to the baseline file and exit 0; "
        "edit in the mandatory per-entry justifications afterwards",
    )
    ap.add_argument(
        "--select", default="",
        help="comma-separated rule ids to run (default: all)",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule catalogue"
    )
    ap.add_argument(
        "--strict-baseline", action="store_true",
        help="stale baseline entries fail the run (CI keeps the file honest)",
    )
    args = ap.parse_args(argv)

    registry = all_rules()
    if args.list_rules:
        for rid in sorted(registry):
            r = registry[rid]
            print(f"{rid}  {r.name}\n    {r.description}")
        return 0

    rules: Optional[List[Rule]] = None
    if args.select:
        wanted = [s.strip() for s in args.select.split(",") if s.strip()]
        unknown = [w for w in wanted if w not in registry]
        if unknown:
            print(f"unknown rule id(s): {', '.join(unknown)}", file=sys.stderr)
            return 2
        rules = [registry[w] for w in wanted]

    baseline_path = Path(args.baseline)
    try:
        baseline = (
            Baseline() if (args.no_baseline or args.write_baseline)
            else Baseline.load(baseline_path)
        )
    except (ValueError, json.JSONDecodeError) as exc:
        print(f"bad baseline: {exc}", file=sys.stderr)
        return 2

    try:
        new, old, stale, n_files = lint_paths(
            args.paths, rules=rules, baseline=baseline
        )
    except (FileNotFoundError, SyntaxError) as exc:
        print(str(exc), file=sys.stderr)
        return 2

    if args.write_baseline:
        Baseline.from_violations(
            new, justification="TODO: justify or fix (entry written by "
            "--write-baseline)"
        ).save(baseline_path)
        print(
            f"wrote {len(new)} entrie(s) to {baseline_path}; fill in real "
            "justifications before committing",
        )
        return 0

    if args.fmt == "json":
        _report_json(new, old, stale, n_files, sys.stdout)
    else:
        _report_text(new, old, stale, n_files, sys.stdout)
    if new:
        return 1
    if stale and args.strict_baseline:
        return 1
    return 0
