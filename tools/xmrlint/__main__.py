"""``python -m tools.xmrlint`` entry point."""

import sys

from tools.xmrlint.runner import main

if __name__ == "__main__":
    sys.exit(main())
