"""Minimal numpy CSR/CSC/ELL sparse utilities.

The hot inference path never touches scipy: model weights and queries are
converted once, at load time, into static-shape ELL tensors that JAX/Pallas
can consume. These classes exist for model construction, training-time data
handling, and tests.

Conventions
-----------
* ELL padding uses a *sentinel index* equal to the logical dimension size
  (i.e. one past the last valid index) and value 0.0. Dense lookup tables are
  therefore allocated with one extra trailing slot so gathers at the sentinel
  read 0.
* All index arrays are int32 (TPU-native), values float32 unless stated.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class CSR:
    """Compressed sparse row matrix (queries are stored this way, paper §4)."""

    indptr: np.ndarray   # [n + 1] int64
    indices: np.ndarray  # [nnz]   int32, sorted within each row
    data: np.ndarray     # [nnz]   float32
    shape: Tuple[int, int]

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_dense(cls, x: np.ndarray) -> "CSR":
        n, d = x.shape
        indptr = np.zeros(n + 1, dtype=np.int64)
        idx_list, val_list = [], []
        for i in range(n):
            (nz,) = np.nonzero(x[i])
            idx_list.append(nz.astype(np.int32))
            val_list.append(x[i, nz].astype(np.float32))
            indptr[i + 1] = indptr[i] + len(nz)
        indices = np.concatenate(idx_list) if idx_list else np.zeros(0, np.int32)
        data = np.concatenate(val_list) if val_list else np.zeros(0, np.float32)
        return cls(indptr, indices, data, (n, d))

    @classmethod
    def from_rows(cls, rows_idx, rows_val, shape) -> "CSR":
        """Build from per-row (sorted) index/value arrays."""
        n = len(rows_idx)
        indptr = np.zeros(n + 1, dtype=np.int64)
        for i, r in enumerate(rows_idx):
            indptr[i + 1] = indptr[i] + len(r)
        indices = (np.concatenate(rows_idx) if n else np.zeros(0)).astype(np.int32)
        data = (np.concatenate(rows_val) if n else np.zeros(0)).astype(np.float32)
        return cls(indptr, indices, data, shape)

    # -- accessors ---------------------------------------------------------
    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[i], self.indptr[i + 1]
        return self.indices[s:e], self.data[s:e]

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def to_dense(self) -> np.ndarray:
        n, d = self.shape
        out = np.zeros((n, d), dtype=np.float32)
        for i in range(n):
            idx, val = self.row(i)
            out[i, idx] = val
        return out

    def to_ell(self, width: int | None = None) -> Tuple[np.ndarray, np.ndarray]:
        """Pad rows to a common width.

        Returns (idx [n, Q] int32 padded with sentinel=d, val [n, Q] f32
        padded with 0). Row indices stay sorted; the sentinel (== d) sorts
        last, preserving sortedness — required by the searchsorted iterator.

        An explicit ``width`` TRUNCATES longer rows (the serving-engine
        semantics: query nnz is capped at ingest); width=None fits the
        longest row exactly.
        """
        return rows_to_ell(self, np.arange(self.shape[0]), width)

    def slice_rows(self, sel: np.ndarray) -> "CSR":
        rows_i = [self.row(i)[0] for i in sel]
        rows_v = [self.row(i)[1] for i in sel]
        return CSR.from_rows(rows_i, rows_v, (len(sel), self.shape[1]))


def rows_to_ell(
    csr: CSR,
    rows: np.ndarray,
    width: int | None = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized CSR→ELL marshalling for an arbitrary row selection.

    The serving hot path: one fancy-indexed gather over ``csr.indices`` /
    ``csr.data`` instead of a per-row Python loop, so marshalling a
    micro-batch costs O(batch · width) numpy work with no interpreter
    round-trips. Semantics match :meth:`CSR.to_ell` restricted to ``rows``:
    sentinel index ``d``, zero values, rows truncated at ``width``.
    """
    rows = np.asarray(rows, dtype=np.int64)
    n, d = len(rows), csr.shape[1]
    starts = csr.indptr[rows]
    nnz = csr.indptr[rows + 1] - starts
    q = int(width) if width is not None else int(nnz.max(initial=0))
    q = max(q, 1)
    if n == 0 or csr.indices.size == 0:
        return (np.full((n, q), d, np.int32), np.zeros((n, q), np.float32))
    offs = np.arange(q, dtype=np.int64)
    valid = offs[None, :] < np.minimum(nnz, q)[:, None]      # [n, q]
    src = np.where(valid, starts[:, None] + offs[None, :], 0)
    idx = np.where(valid, csr.indices[src], d).astype(np.int32)
    val = np.where(valid, csr.data[src], 0.0).astype(np.float32)
    return idx, val


def rows_to_ell_loop(
    csr: CSR,
    rows: np.ndarray,
    width: int | None = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row reference implementation of :func:`rows_to_ell` (test oracle)."""
    rows = np.asarray(rows, dtype=np.int64)
    n, d = len(rows), csr.shape[1]
    if width is not None:
        q = int(width)
    else:
        nnz = csr.indptr[rows + 1] - csr.indptr[rows]
        q = int(nnz.max(initial=0))
    q = max(q, 1)
    idx = np.full((n, q), d, dtype=np.int32)
    val = np.zeros((n, q), dtype=np.float32)
    for i, r in enumerate(rows):
        ri, rv = csr.row(int(r))
        k = min(len(ri), q)
        idx[i, :k] = ri[:k]
        val[i, :k] = rv[:k]
    return idx, val


@dataclasses.dataclass
class CSC:
    """Compressed sparse column matrix (ranker weights, paper §4)."""

    indptr: np.ndarray   # [ncols + 1]
    indices: np.ndarray  # [nnz] row indices, sorted within each column
    data: np.ndarray     # [nnz]
    shape: Tuple[int, int]  # (d, L)

    @classmethod
    def from_dense(cls, w: np.ndarray) -> "CSC":
        t = CSR.from_dense(np.ascontiguousarray(w.T))
        return cls(t.indptr, t.indices, t.data, (w.shape[0], w.shape[1]))

    @classmethod
    def from_cols(cls, cols_idx, cols_val, shape) -> "CSC":
        t = CSR.from_rows(cols_idx, cols_val, (shape[1], shape[0]))
        return cls(t.indptr, t.indices, t.data, shape)

    def col(self, j: int) -> Tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[j], self.indptr[j + 1]
        return self.indices[s:e], self.data[s:e]

    def col_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def to_dense(self) -> np.ndarray:
        d, L = self.shape
        out = np.zeros((d, L), dtype=np.float32)
        for j in range(L):
            idx, val = self.col(j)
            out[idx, j] = val
        return out

    def to_col_ell(self, width: int | None = None) -> Tuple[np.ndarray, np.ndarray]:
        """Per-column ELL (the *vanilla*, unchunked layout used as baseline).

        Returns (rows [L, Rc] padded with sentinel=d, vals [L, Rc]).
        """
        d, L = self.shape
        rc = int(width) if width is not None else int(self.col_nnz().max(initial=0))
        rc = max(rc, 1)
        rows = np.full((L, rc), d, dtype=np.int32)
        vals = np.zeros((L, rc), dtype=np.float32)
        for j in range(L):
            ci, cv = self.col(j)
            rows[j, : len(ci)] = ci
            vals[j, : len(ci)] = cv
        return rows, vals


def random_sparse_csr(
    n: int,
    d: int,
    nnz_per_row: int,
    rng: np.random.Generator,
    *,
    zipf_a: float = 1.3,
    value_scale: float = 1.0,
) -> CSR:
    """Synthetic TFIDF-like sparse queries: Zipf-distributed feature ids.

    Mirrors the long-tailed feature-frequency structure of the paper's
    bag-of-words datasets (eurlex-4k … amazon-3m).
    """
    rows_i, rows_v = [], []
    for _ in range(n):
        k = max(1, int(rng.poisson(nnz_per_row)))
        k = min(k, d)
        # Zipf over feature ids, clipped to d, deduplicated.
        raw = (rng.zipf(zipf_a, size=3 * k + 8) - 1) % d
        idx = np.unique(raw)[:k].astype(np.int32)
        idx.sort()
        val = (rng.standard_normal(len(idx)).astype(np.float32)) * value_scale
        # TFIDF values are positive; keep a positive-ish distribution.
        val = np.abs(val) + 0.05
        rows_i.append(idx)
        rows_v.append(val.astype(np.float32))
    return CSR.from_rows(rows_i, rows_v, (n, d))


def random_sparse_csc(
    d: int,
    L: int,
    nnz_per_col: int,
    rng: np.random.Generator,
    *,
    sibling_groups: int | None = None,
    sibling_overlap: float = 0.8,
) -> CSC:
    """Synthetic ranker weights with *sibling support correlation* (paper Item 2).

    Columns are generated in groups of ``sibling_groups`` (the branching
    factor): each group draws a shared support pool and each sibling keeps a
    random ``sibling_overlap`` fraction of it plus its own private indices.

    Vectorized per group so million-label benchmark models build in seconds.
    """
    group = max(1, sibling_groups or 1)
    pool_size = min(d, max(1, int(nnz_per_col / max(sibling_overlap, 1e-3))))
    n_shared = int(round(nnz_per_col * sibling_overlap))
    n_priv = max(0, nnz_per_col - n_shared)

    cols_i, cols_v = [], []
    for g0 in range(0, L, group):
        gcols = min(group, L - g0)
        shared = rng.choice(d, size=pool_size, replace=False)
        # each sibling keeps a random subset of the shared pool
        keep = rng.random((gcols, pool_size)).argsort(axis=1)[:, :n_shared]
        take = shared[keep]                                   # [gcols, n_shared]
        priv = rng.integers(0, d, size=(gcols, n_priv)) if n_priv else None
        for j in range(gcols):
            parts = [take[j]] if n_shared else []
            if priv is not None:
                parts.append(priv[j])
            idx = np.unique(np.concatenate(parts)) if parts else np.zeros(0, np.int64)
            cols_i.append(idx.astype(np.int32))
            cols_v.append(rng.standard_normal(len(idx)).astype(np.float32))
    return CSC.from_cols(cols_i, cols_v, (d, L))
