from repro.sparse.csr import (
    CSC,
    CSR,
    random_sparse_csc,
    random_sparse_csr,
    rows_to_ell,
    rows_to_ell_loop,
)

__all__ = [
    "CSR",
    "CSC",
    "random_sparse_csr",
    "random_sparse_csc",
    "rows_to_ell",
    "rows_to_ell_loop",
]
