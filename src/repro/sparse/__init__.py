from repro.sparse.csr import CSC, CSR, random_sparse_csc, random_sparse_csr

__all__ = ["CSR", "CSC", "random_sparse_csr", "random_sparse_csc"]
