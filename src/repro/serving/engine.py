"""Batched XMR serving engine.

Implements the paper's two production settings (§3.2):
* **batch** — a matrix of queries served in one shot;
* **online** — queries served one-by-one (batch size 1).

The engine owns jit-cache hygiene (batch sizes are bucketed to powers of two,
query nnz padded to a fixed ELL width) and records wall-clock statistics in
the form the paper reports (avg / P95 / P99, Table 4) — per-query samples
for the online setting, amortized call averages for the batch setting, kept
as distinct series so percentiles stay honest.

Query marshalling is the vectorized CSR→ELL path in
:func:`repro.sparse.csr.rows_to_ell`; ``serve_batch`` double-buffers so host
marshalling of chunk *i+1* overlaps device execution of chunk *i* (JAX
dispatch is asynchronous — we only block when the *previous* chunk's results
are consumed). The async micro-batching front-end lives in
:mod:`repro.serving.batcher`.

Sharded dispatch (``ServeConfig(shards=N)``): the tree is replicated over a
1-D data mesh of N local devices (:func:`repro.distributed.sharding
.replica_mesh`) and every dispatched bucket's batch dim is split across the
replicas, so one formed micro-batch occupies all N devices instead of
serializing on one. Per-query arithmetic is untouched by the split —
results stay bitwise-identical to single-device serving (pinned by
tests/test_sharded_serving.py).

Partitioned dispatch (``ServeConfig(partitions=P)``): the tree is split into
P label-contiguous sub-trees over a ``("data", "model")`` mesh
(:mod:`repro.index`) and every dispatch runs the scatter-gather planner —
per-device model bytes shrink ~1/P while results stay bitwise-identical in
the ``partition_sync="level"`` (default) and ``"pipelined"`` modes;
``"pipelined"`` overlaps each level's beam exchange with the next level's
MSCM matmul via speculative expansion, and ``beam_cache=N`` adds the
hot-beam LRU that skips partitions owning no surviving router-beam row.
Composes with ``shards=N``: model-parallel partitions x data-parallel
replicas behind one batcher.
"""

from __future__ import annotations

import time
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import XMRTree
from repro.serving.config import (
    AdmissionConfig,
    PartitionConfig,
    QuantConfig,
    ServeConfig,
)
from repro.serving.metrics import LatencyStats
from repro.serving.slo import BeamTier, resolve_tiers
from repro.sparse.csr import CSR, rows_to_ell

__all__ = [
    "AdmissionConfig",
    "PartitionConfig",
    "QuantConfig",
    "ServeConfig",
    "XMRServingEngine",
    "resolve_method",
]


def resolve_method(method: str) -> str:
    """Resolve ``"auto"`` to the best batch method for the active backend.

    On TPU that is the device-grouped MXU-tiled Pallas kernel (the paper's
    batch-mode fast path, fully inside the ``_tree_infer`` jit); elsewhere
    the dense-lookup einsum path — Pallas interpret mode is for validation,
    not speed.
    """
    if method != "auto":
        return method
    return (
        "mscm_pallas_grouped"
        if jax.default_backend() == "tpu"
        else "mscm_dense"
    )


def _bucket(n: int, max_batch: int) -> int:
    b = 1
    while b < n and b < max_batch:
        b *= 2
    return min(b, max_batch)


class XMRServingEngine:
    def __init__(self, tree: XMRTree, config: ServeConfig | None = None,
                 label_perm: Optional[np.ndarray] = None):
        self.config = config or ServeConfig()
        self.method = resolve_method(self.config.method)
        qc = self.config.quant
        if qc.tier != "exact":
            # Compressed tiers store int8/fp8 chunk tiles + scale rows; the
            # quantized grouped kernel is the only method that can read
            # them. "auto" resolves there; an explicit exact method is a
            # config contradiction, not something to silently override.
            if self.config.method not in ("auto", "mscm_pallas_grouped_q"):
                raise ValueError(
                    f"quant tier {qc.tier!r} serves via "
                    f"method='mscm_pallas_grouped_q'; got explicit "
                    f"method={self.config.method!r}"
                )
            self.method = "mscm_pallas_grouped_q"
        # Adaptive beam-tier ladder (tier 0 = the configured full beam; a
        # 1-tuple unless slo.target_p99_ms is set). Degraded tiers must
        # reach the same result width as the full beam or QueryResult
        # shapes would change per batch — validate against the *original*
        # tree geometry before any quantize/partition reassignment below.
        self.tiers: Tuple[BeamTier, ...] = resolve_tiers(self.config)
        if len(self.tiers) > 1:
            from repro.index.planner import reference_topk_width

            c = self.config
            full_w = reference_topk_width(
                tree.n_cols, tree.branching, c.beam, c.topk
            )
            for t in self.tiers[1:]:
                w = reference_topk_width(
                    tree.n_cols, tree.branching, t.beam, c.topk
                )
                if w != full_w:
                    raise ValueError(
                        f"beam tier {t.beam} yields top-k width {w} != "
                        f"full-beam width {full_w}; widen the tier or "
                        f"raise slo min_beam"
                    )
        self.label_perm = label_perm  # leaf position -> original label id
        self.stats = LatencyStats()
        self.mesh = None
        self._batch_sharding = None
        self.index = None
        self.placement = None
        self.planner = None
        shards = self.config.shards
        if shards > 1 and shards & (shards - 1):
            raise ValueError(
                f"shards={shards} must be a power of two (buckets are)"
            )
        if shards > self.config.max_batch:
            raise ValueError(
                f"shards={shards} exceeds max_batch={self.config.max_batch}"
            )
        if qc.tier != "exact" and self.config.partition.partitions == 1:
            # Unpartitioned compressed serving: quantize the whole tree (the
            # QuantizedTree rides the same device_put/infer machinery, so
            # the shards>1 replication below works unchanged).
            from repro.quant import quantize_tree

            tree = quantize_tree(
                tree, tier=qc.tier, prune_keep=qc.prune_keep
            )
        if self.config.partition.partitions > 1:
            # Label-partitioned dispatch: the tree is cut into P sub-trees
            # placed over a ("data", "model") mesh; every _run goes through
            # the scatter-gather planner (model-parallel x data-parallel,
            # bitwise-identical in the default "level" sync mode).
            from repro.index import ScatterGatherPlanner, partition_tree, place

            c, pc = self.config, self.config.partition
            self.index = partition_tree(
                tree, pc.partitions, level=pc.partition_level
            )
            if qc.tier != "exact":
                # Quantize per partition *after* the cut: the router head
                # stays exact f32 (its beam feeds every partition) and the
                # manifest's memory_bytes/content_hash describe the
                # compressed bytes placement actually balances.
                from repro.quant import quantize_index

                self.index = quantize_index(
                    self.index, tier=qc.tier, prune_keep=qc.prune_keep
                )
            self.placement = place(self.index, shards=shards)
            self.planner = ScatterGatherPlanner(
                self.index,
                beam=c.beam,
                topk=c.topk,
                method=self.method,
                score_mode=c.score_mode,
                qt=c.qt,
                sync=pc.partition_sync,
                placement=self.placement,
                cache_entries=pc.beam_cache,
            )
            self.mesh = self.placement.mesh
        elif shards > 1:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.distributed.sharding import replica_mesh

            self.mesh = replica_mesh(shards)
            # Replicate the tree once; every dispatch then splits its batch
            # dim over the mesh's data axis.
            tree = tree.device_put(NamedSharding(self.mesh, P()))
            self._batch_sharding = NamedSharding(self.mesh, P("data", None))
        self.tree = tree

    # -- query marshalling --------------------------------------------------
    def marshal_rows(self, queries: CSR, rows: np.ndarray, bucket: int
                     ) -> Tuple[jax.Array, jax.Array]:
        """Vectorized ELL marshalling padded up to a jit bucket.

        Padding rows use the sentinel index ``d`` and value 0, i.e. empty
        queries — the bucket tail is sliced off by the caller.
        """
        w = self.config.ell_width
        d = queries.shape[1]
        idx, val = rows_to_ell(queries, rows, w)
        if bucket > len(rows):
            pad = bucket - len(rows)
            idx = np.concatenate([idx, np.full((pad, w), d, np.int32)])
            val = np.concatenate([val, np.zeros((pad, w), np.float32)])
        return jnp.asarray(idx), jnp.asarray(val)

    def bucket_for(self, n: int) -> int:
        """Power-of-two jit bucket for ``n`` queries.

        Never below ``shards`` so a sharded dispatch always splits evenly
        over the mesh (both are powers of two).
        """
        return max(_bucket(n, self.config.max_batch), self.config.shards)

    def bucket_key(self, n: int, tier: int = 0) -> Tuple[int, int]:
        """jit-cache key for a dispatch: ``(bucket, beam_tier)``.

        Every (power-of-two bucket, tier) pair compiles its own
        ``_tree_infer`` entry — both coordinates are bounded static sets
        (buckets by ``max_batch``, tiers by the SLO ladder), so the cache
        stays XMR003-clean and ``warmup_buckets`` can enumerate it fully.
        """
        return (self.bucket_for(n), int(tier))

    def _run(self, xi: jax.Array, xv: jax.Array, tier: int = 0):
        c = self.config
        t = self.tiers[tier]
        if self.planner is not None:
            # Scatter-gather over the label partitions; the planner owns all
            # device placement (per-partition batch sharding included). The
            # tier's beam/qt ride as per-call overrides only when degraded,
            # so the tier-0 path (and its wire traffic) is byte-identical
            # to an engine without an SLO configured.
            if tier:
                return self.planner.infer(xi, xv, beam=t.beam, qt=t.qt)
            return self.planner.infer(xi, xv)
        if self._batch_sharding is not None:
            xi = jax.device_put(xi, self._batch_sharding)
            xv = jax.device_put(xv, self._batch_sharding)
        return self.tree.infer(
            xi, xv, beam=t.beam, topk=c.topk, method=self.method,
            score_mode=c.score_mode, qt=t.qt,
        )

    # -- serving modes --------------------------------------------------
    def warmup(self, d: int, batch_sizes: Sequence[int] = (1,),
               tier: int = 0) -> None:
        for b in batch_sizes:
            bb = self.bucket_for(b)
            xi = jnp.full((bb, self.config.ell_width), d, jnp.int32)
            xv = jnp.zeros((bb, self.config.ell_width), jnp.float32)
            s, l = self._run(xi, xv, tier=tier)
            jax.block_until_ready((s, l))

    def warmup_buckets(self, d: int, max_batch: int,
                       tiers: Optional[Sequence[int]] = None) -> None:
        """Warm every jit bucket a batcher capped at ``max_batch`` can form.

        Covers all power-of-two buckets up to ``bucket_for(max_batch)``
        inclusive — note the cap itself need not be a power of two (a
        size-triggered batch of 24 pads to bucket 32), and sharded engines
        never form a bucket below ``shards``. With an SLO ladder, every
        ``(bucket, tier)`` cache key is warmed (the full cross product is
        bounded), so a degraded dispatch never pays a live compile.
        """
        sizes, b = [], self.config.shards or 1
        target = self.bucket_for(max_batch)
        while b <= target:
            sizes.append(b)
            b *= 2
        for tier in tiers if tiers is not None else range(len(self.tiers)):
            self.warmup(d, sizes, tier=tier)

    def serve_batch(self, queries: CSR) -> Tuple[np.ndarray, np.ndarray]:
        """Batch setting: all queries at once (bucketed into max_batch chunks).

        Double-buffered: chunk *i+1* is marshalled on the host while the
        device executes chunk *i*. Because chunks overlap, per-chunk wall
        times are not individually meaningful — one amortized per-query
        average is recorded per call, in the stats' *amortized* series so it
        never pollutes the per-query percentile panel.
        """
        n = queries.shape[0]
        out_s, out_l = [], []

        def finalize(pending) -> None:
            s, l, count = pending
            jax.block_until_ready((s, l))
            out_s.append(np.asarray(s)[:count])
            out_l.append(np.asarray(l)[:count])

        t_start = time.perf_counter()
        pending = None
        i = 0
        while i < n:
            count = min(self.config.max_batch, n - i)
            bucket = self.bucket_for(count)
            xi, xv = self.marshal_rows(queries, np.arange(i, i + count), bucket)
            s, l = self._run(xi, xv)  # async dispatch
            if pending is not None:
                finalize(pending)
            pending = (s, l, count)
            i += count
        if pending is not None:
            finalize(pending)
        self.stats.record_amortized(time.perf_counter() - t_start, n)
        scores = np.concatenate(out_s)
        leaves = np.concatenate(out_l)
        return scores, self._map_labels(leaves)

    def serve_online(self, queries: CSR, limit: int | None = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Online setting: one query at a time, per-query latency recorded."""
        n = queries.shape[0] if limit is None else min(limit, queries.shape[0])
        out_s, out_l = [], []
        bucket = self.bucket_for(1)  # 1 unsharded; >= shards on a mesh
        for i in range(n):
            xi, xv = self.marshal_rows(queries, np.arange(i, i + 1), bucket)
            t0 = time.perf_counter()
            s, l = self._run(xi, xv)
            jax.block_until_ready((s, l))
            self.stats.record(time.perf_counter() - t0)
            out_s.append(np.asarray(s)[0])
            out_l.append(np.asarray(l)[0])
        scores = np.stack(out_s)
        leaves = np.stack(out_l)
        return scores, self._map_labels(leaves)

    def _map_labels(self, leaves: np.ndarray) -> np.ndarray:
        if self.label_perm is None:
            return leaves
        return self.label_perm[leaves]

    def partition_hit_counts(self, leaves: np.ndarray) -> Optional[np.ndarray]:
        """Per-partition result share for a batch of *raw* leaf ids
        (pre-``label_perm``); None when serving unpartitioned."""
        if self.planner is None:
            return None
        return self.planner.hit_counts(leaves)

    def beam_cache_stats(self) -> Optional[dict]:
        """Cumulative hot-beam cache accounting (None when off/unpartitioned)."""
        if self.planner is None:
            return None
        return self.planner.cache_stats()

    def last_degraded(self) -> Optional[dict]:
        """Degraded-batch info from the most recent dispatch.

        ``None`` when every partition served the batch (or the engine is
        unpartitioned); else ``{"partitions": [...], "label_ranges":
        [(lo, hi), ...]}`` — see :attr:`ScatterGatherPlanner.last_degraded`.
        Callers must read this synchronously after the dispatch that
        produced it (the batcher snapshots it per in-flight batch).
        """
        if self.planner is None:
            return None
        return getattr(self.planner, "last_degraded", None)

    def measure_batch_seconds(self, batch: int, iters: int = 3,
                              tier: int = 0) -> float:
        """Median wall seconds for one ``batch``-sized dispatch (warmed).

        The drain-rate probe behind ``queue_depth="auto"``: sentinel (empty)
        queries traverse the same levels and sorts as real ones, so the
        figure bounds the device-side service time per bucket. With
        ``tier > 0`` the probe runs at that beam tier — the same
        measurement calibrates the :class:`~repro.serving.slo
        .BeamTierPolicy` cost model.
        """
        bucket = self.bucket_for(batch)
        d = self.tree.d
        xi = jnp.full((bucket, self.config.ell_width), d, jnp.int32)
        xv = jnp.zeros((bucket, self.config.ell_width), jnp.float32)
        jax.block_until_ready(self._run(xi, xv, tier=tier))  # warm bucket
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(self._run(xi, xv, tier=tier))
            times.append(time.perf_counter() - t0)
        return float(np.median(times))

    def latency_summary(self) -> dict:
        return self.stats.summary()
