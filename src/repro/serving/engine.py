"""Batched XMR serving engine.

Implements the paper's two production settings (§3.2):
* **batch** — a matrix of queries served in one shot;
* **online** — queries served one-by-one (batch size 1).

The engine owns jit-cache hygiene (batch sizes are bucketed to powers of two,
query nnz padded to a fixed ELL width) and records per-query wall-clock
statistics in the form the paper reports (avg / P95 / P99, Table 4).
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import XMRTree
from repro.sparse.csr import CSR


@dataclasses.dataclass
class ServeConfig:
    beam: int = 10
    topk: int = 10
    method: str = "mscm_dense"
    ell_width: int = 256          # query nnz cap (pad/truncate)
    max_batch: int = 256
    score_mode: str = "prod"


@dataclasses.dataclass
class LatencyStats:
    per_query_ms: List[float] = dataclasses.field(default_factory=list)

    def record(self, total_s: float, n_queries: int) -> None:
        self.per_query_ms.append(1e3 * total_s / max(n_queries, 1))

    def summary(self) -> dict:
        if not self.per_query_ms:
            return {"count": 0}
        arr = np.asarray(self.per_query_ms)
        return {
            "count": len(arr),
            "avg_ms": float(arr.mean()),
            "p50_ms": float(np.percentile(arr, 50)),
            "p95_ms": float(np.percentile(arr, 95)),
            "p99_ms": float(np.percentile(arr, 99)),
        }


def _bucket(n: int, max_batch: int) -> int:
    b = 1
    while b < n and b < max_batch:
        b *= 2
    return min(b, max_batch)


class XMRServingEngine:
    def __init__(self, tree: XMRTree, config: ServeConfig | None = None,
                 label_perm: Optional[np.ndarray] = None):
        self.tree = tree
        self.config = config or ServeConfig()
        self.label_perm = label_perm  # leaf position -> original label id
        self.stats = LatencyStats()

    # -- query marshalling --------------------------------------------------
    def _to_ell(self, queries: CSR, start: int, count: int) -> Tuple[jax.Array, jax.Array]:
        w = self.config.ell_width
        d = queries.shape[1]
        idx = np.full((count, w), d, np.int32)
        val = np.zeros((count, w), np.float32)
        for i in range(count):
            ri, rv = queries.row(start + i)
            k = min(len(ri), w)
            idx[i, :k] = ri[:k]
            val[i, :k] = rv[:k]
        return jnp.asarray(idx), jnp.asarray(val)

    def _run(self, xi: jax.Array, xv: jax.Array):
        c = self.config
        return self.tree.infer(
            xi, xv, beam=c.beam, topk=c.topk, method=c.method, score_mode=c.score_mode
        )

    # -- serving modes --------------------------------------------------
    def warmup(self, d: int, batch_sizes: Sequence[int] = (1,)) -> None:
        for b in batch_sizes:
            bb = _bucket(b, self.config.max_batch)
            xi = jnp.full((bb, self.config.ell_width), d, jnp.int32)
            xv = jnp.zeros((bb, self.config.ell_width), jnp.float32)
            s, l = self._run(xi, xv)
            jax.block_until_ready((s, l))

    def serve_batch(self, queries: CSR) -> Tuple[np.ndarray, np.ndarray]:
        """Batch setting: all queries at once (bucketed into max_batch chunks)."""
        n = queries.shape[0]
        out_s, out_l = [], []
        i = 0
        while i < n:
            count = min(self.config.max_batch, n - i)
            bucket = _bucket(count, self.config.max_batch)
            xi, xv = self._to_ell(queries, i, count)
            if bucket > count:  # pad to the jit bucket
                d = queries.shape[1]
                xi = jnp.concatenate(
                    [xi, jnp.full((bucket - count, xi.shape[1]), d, jnp.int32)]
                )
                xv = jnp.concatenate(
                    [xv, jnp.zeros((bucket - count, xv.shape[1]), jnp.float32)]
                )
            t0 = time.perf_counter()
            s, l = self._run(xi, xv)
            jax.block_until_ready((s, l))
            self.stats.record(time.perf_counter() - t0, count)
            out_s.append(np.asarray(s)[:count])
            out_l.append(np.asarray(l)[:count])
            i += count
        scores = np.concatenate(out_s)
        leaves = np.concatenate(out_l)
        return scores, self._map_labels(leaves)

    def serve_online(self, queries: CSR, limit: int | None = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Online setting: one query at a time, per-query latency recorded."""
        n = queries.shape[0] if limit is None else min(limit, queries.shape[0])
        out_s, out_l = [], []
        for i in range(n):
            xi, xv = self._to_ell(queries, i, 1)
            t0 = time.perf_counter()
            s, l = self._run(xi, xv)
            jax.block_until_ready((s, l))
            self.stats.record(time.perf_counter() - t0, 1)
            out_s.append(np.asarray(s)[0])
            out_l.append(np.asarray(l)[0])
        scores = np.stack(out_s)
        leaves = np.stack(out_l)
        return scores, self._map_labels(leaves)

    def _map_labels(self, leaves: np.ndarray) -> np.ndarray:
        if self.label_perm is None:
            return leaves
        return self.label_perm[leaves]

    def latency_summary(self) -> dict:
        return self.stats.summary()
