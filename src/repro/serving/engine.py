"""Batched XMR serving engine.

Implements the paper's two production settings (§3.2):
* **batch** — a matrix of queries served in one shot;
* **online** — queries served one-by-one (batch size 1).

The engine owns jit-cache hygiene (batch sizes are bucketed to powers of two,
query nnz padded to a fixed ELL width) and records per-query wall-clock
statistics in the form the paper reports (avg / P95 / P99, Table 4).

Query marshalling is the vectorized CSR→ELL path in
:func:`repro.sparse.csr.rows_to_ell`; ``serve_batch`` double-buffers so host
marshalling of chunk *i+1* overlaps device execution of chunk *i* (JAX
dispatch is asynchronous — we only block when the *previous* chunk's results
are consumed). The async micro-batching front-end lives in
:mod:`repro.serving.batcher`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import XMRTree
from repro.serving.metrics import LatencyStats
from repro.sparse.csr import CSR, rows_to_ell


@dataclasses.dataclass
class ServeConfig:
    beam: int = 10
    topk: int = 10
    method: str = "auto"          # "auto" resolves per backend (see engine)
    ell_width: int = 256          # query nnz cap (pad/truncate)
    max_batch: int = 256
    score_mode: str = "prod"
    qt: int = 8                   # grouped-kernel query-tile height


def resolve_method(method: str) -> str:
    """Resolve ``"auto"`` to the best batch method for the active backend.

    On TPU that is the device-grouped MXU-tiled Pallas kernel (the paper's
    batch-mode fast path, fully inside the ``_tree_infer`` jit); elsewhere
    the dense-lookup einsum path — Pallas interpret mode is for validation,
    not speed.
    """
    if method != "auto":
        return method
    return (
        "mscm_pallas_grouped"
        if jax.default_backend() == "tpu"
        else "mscm_dense"
    )


def _bucket(n: int, max_batch: int) -> int:
    b = 1
    while b < n and b < max_batch:
        b *= 2
    return min(b, max_batch)


class XMRServingEngine:
    def __init__(self, tree: XMRTree, config: ServeConfig | None = None,
                 label_perm: Optional[np.ndarray] = None):
        self.tree = tree
        self.config = config or ServeConfig()
        self.method = resolve_method(self.config.method)
        self.label_perm = label_perm  # leaf position -> original label id
        self.stats = LatencyStats()

    # -- query marshalling --------------------------------------------------
    def _to_ell(self, queries: CSR, start: int, count: int) -> Tuple[jax.Array, jax.Array]:
        idx, val = rows_to_ell(
            queries, np.arange(start, start + count), self.config.ell_width
        )
        return jnp.asarray(idx), jnp.asarray(val)

    def marshal_rows(self, queries: CSR, rows: np.ndarray, bucket: int
                     ) -> Tuple[jax.Array, jax.Array]:
        """Vectorized ELL marshalling padded up to a jit bucket.

        Padding rows use the sentinel index ``d`` and value 0, i.e. empty
        queries — the bucket tail is sliced off by the caller.
        """
        w = self.config.ell_width
        d = queries.shape[1]
        idx, val = rows_to_ell(queries, rows, w)
        if bucket > len(rows):
            pad = bucket - len(rows)
            idx = np.concatenate([idx, np.full((pad, w), d, np.int32)])
            val = np.concatenate([val, np.zeros((pad, w), np.float32)])
        return jnp.asarray(idx), jnp.asarray(val)

    def bucket_for(self, n: int) -> int:
        return _bucket(n, self.config.max_batch)

    def _run(self, xi: jax.Array, xv: jax.Array):
        c = self.config
        return self.tree.infer(
            xi, xv, beam=c.beam, topk=c.topk, method=self.method,
            score_mode=c.score_mode, qt=c.qt,
        )

    # -- serving modes --------------------------------------------------
    def warmup(self, d: int, batch_sizes: Sequence[int] = (1,)) -> None:
        for b in batch_sizes:
            bb = _bucket(b, self.config.max_batch)
            xi = jnp.full((bb, self.config.ell_width), d, jnp.int32)
            xv = jnp.zeros((bb, self.config.ell_width), jnp.float32)
            s, l = self._run(xi, xv)
            jax.block_until_ready((s, l))

    def warmup_buckets(self, d: int, max_batch: int) -> None:
        """Warm every jit bucket a batcher capped at ``max_batch`` can form.

        Covers all power-of-two buckets up to ``bucket_for(max_batch)``
        inclusive — note the cap itself need not be a power of two (a
        size-triggered batch of 24 pads to bucket 32).
        """
        sizes, b = [], 1
        target = self.bucket_for(max_batch)
        while b <= target:
            sizes.append(b)
            b *= 2
        self.warmup(d, sizes)

    def serve_batch(self, queries: CSR) -> Tuple[np.ndarray, np.ndarray]:
        """Batch setting: all queries at once (bucketed into max_batch chunks).

        Double-buffered: chunk *i+1* is marshalled on the host while the
        device executes chunk *i*. Because chunks overlap, per-chunk wall
        times are not individually meaningful — one amortized per-query
        latency is recorded per call (the paper's batch-setting metric).
        """
        n = queries.shape[0]
        out_s, out_l = [], []

        def finalize(pending) -> None:
            s, l, count = pending
            jax.block_until_ready((s, l))
            out_s.append(np.asarray(s)[:count])
            out_l.append(np.asarray(l)[:count])

        t_start = time.perf_counter()
        pending = None
        i = 0
        while i < n:
            count = min(self.config.max_batch, n - i)
            bucket = _bucket(count, self.config.max_batch)
            xi, xv = self.marshal_rows(queries, np.arange(i, i + count), bucket)
            s, l = self._run(xi, xv)  # async dispatch
            if pending is not None:
                finalize(pending)
            pending = (s, l, count)
            i += count
        if pending is not None:
            finalize(pending)
        self.stats.record(time.perf_counter() - t_start, n)
        scores = np.concatenate(out_s)
        leaves = np.concatenate(out_l)
        return scores, self._map_labels(leaves)

    def serve_online(self, queries: CSR, limit: int | None = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Online setting: one query at a time, per-query latency recorded."""
        n = queries.shape[0] if limit is None else min(limit, queries.shape[0])
        out_s, out_l = [], []
        for i in range(n):
            xi, xv = self._to_ell(queries, i, 1)
            t0 = time.perf_counter()
            s, l = self._run(xi, xv)
            jax.block_until_ready((s, l))
            self.stats.record(time.perf_counter() - t0, 1)
            out_s.append(np.asarray(s)[0])
            out_l.append(np.asarray(l)[0])
        scores = np.stack(out_s)
        leaves = np.stack(out_l)
        return scores, self._map_labels(leaves)

    def _map_labels(self, leaves: np.ndarray) -> np.ndarray:
        if self.label_perm is None:
            return leaves
        return self.label_perm[leaves]

    def latency_summary(self) -> dict:
        return self.stats.summary()
