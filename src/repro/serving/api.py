"""Typed request/response currency + the v1 wire schema.

:class:`Query` and :class:`QueryResult` are the single request/response
types across the serving surface: ``MicroBatcher.submit`` accepts either a
``Query`` or the legacy ``(idx, val)`` pair, ``stream`` yields
``QueryResult``, and the HTTP gateway (:mod:`repro.serving.gateway`) speaks
exactly their wire form — there is no separate "wire DTO" that could drift
from the Python objects.

Wire schema (JSON, versioned):

* every document carries ``"v": 1`` (:data:`WIRE_VERSION`); a gateway or
  client seeing a different version refuses rather than misparses;
* feature ids are ``int32``, feature values and scores ``float32``. JSON
  carries them as numbers — exact for int32, and exact for float32 too:
  Python serializes the float64 *exact widening* of each float32 with
  ``repr`` (shortest round-trip), and narrowing back to float32 recovers
  the original bits. This is what lets the gateway keep the house
  bitwise-exactness contract over a JSON wire.

Error mapping: a failed request's :class:`QueryResult` carries a ``status``
string (:data:`STATUS_*` constants) instead of raising. The gateway maps
statuses to HTTP codes via :data:`HTTP_STATUS`; in-process callers branch on
``result.ok`` / ``result.status`` and can still reach the typed exception
via ``result.error``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.admission import (
    DeadlineExceeded,
    Overloaded,
    WorkerUnavailable,
)

WIRE_VERSION = 1

STATUS_OK = "ok"
STATUS_OVERLOADED = "overloaded"
STATUS_DEADLINE_EXCEEDED = "deadline_exceeded"
STATUS_WORKER_UNAVAILABLE = "worker_unavailable"
STATUS_INVALID = "invalid"
STATUS_INTERNAL_ERROR = "internal_error"

#: Gateway status-code mapping — the serving tier's public error contract.
HTTP_STATUS: Dict[str, int] = {
    STATUS_OK: 200,
    STATUS_OVERLOADED: 429,
    STATUS_DEADLINE_EXCEEDED: 504,
    STATUS_WORKER_UNAVAILABLE: 503,
    STATUS_INVALID: 400,
    STATUS_INTERNAL_ERROR: 500,
}


def status_for_exception(exc: BaseException) -> str:
    """Map a typed serving exception to its wire status string."""
    if isinstance(exc, Overloaded):
        return STATUS_OVERLOADED
    if isinstance(exc, DeadlineExceeded):
        return STATUS_DEADLINE_EXCEEDED
    if isinstance(exc, WorkerUnavailable):
        return STATUS_WORKER_UNAVAILABLE
    return STATUS_INTERNAL_ERROR


class WireError(ValueError):
    """A wire document failed validation (bad version / missing fields)."""


def _check_version(doc: dict, what: str) -> None:
    if not isinstance(doc, dict):
        raise WireError(f"{what}: expected a JSON object, got {type(doc).__name__}")
    v = doc.get("v")
    if v != WIRE_VERSION:
        raise WireError(f"{what}: wire version {v!r} != {WIRE_VERSION}")


@dataclasses.dataclass
class Query:
    """One sparse query: sorted feature ids + values, plus request options.

    ``qid`` is a caller-chosen correlation id echoed back on the
    :class:`QueryResult` (``stream`` uses the submission index).
    """

    idx: np.ndarray                      # int32 [nnz] sorted feature ids
    val: np.ndarray                      # f32 [nnz]
    qid: int = 0
    deadline_ms: Optional[float] = None  # per-request latency budget
    priority: int = 0                    # higher = survives weighted shedding

    def __post_init__(self) -> None:
        self.idx = np.asarray(self.idx, np.int32)
        self.val = np.asarray(self.val, np.float32)
        if self.idx.shape != self.val.shape or self.idx.ndim != 1:
            raise WireError(
                f"idx/val must be equal-length 1-D arrays; got "
                f"{self.idx.shape} / {self.val.shape}"
            )

    def to_wire(self) -> dict:
        doc: dict = {
            "v": WIRE_VERSION,
            "qid": int(self.qid),
            "idx": [int(i) for i in self.idx],
            "val": [float(x) for x in self.val],
        }
        if self.deadline_ms is not None:
            doc["deadline_ms"] = float(self.deadline_ms)
        if self.priority:
            doc["priority"] = int(self.priority)
        return doc

    @classmethod
    def from_wire(cls, doc: dict) -> "Query":
        _check_version(doc, "Query")
        try:
            return cls(
                idx=np.asarray(doc["idx"], np.int32),
                val=np.asarray(doc["val"], np.float32),
                qid=int(doc.get("qid", 0)),
                deadline_ms=(
                    float(doc["deadline_ms"])
                    if doc.get("deadline_ms") is not None else None
                ),
                priority=int(doc.get("priority", 0)),
            )
        except (KeyError, TypeError, ValueError) as exc:
            raise WireError(f"Query: malformed document ({exc})") from exc


@dataclasses.dataclass
class QueryResult:
    """One completed request: top-k ids/scores, status, and timing.

    A failed request is a ``QueryResult`` too — ``status`` names the typed
    failure (see :data:`HTTP_STATUS`), ``ids``/``scores`` are None, and
    ``error`` (in-process only; never on the wire) holds the exception.
    ``timing`` carries wall-clock milliseconds (``e2e_ms`` at minimum).

    ``degraded`` marks a *successful* partial result: one or more
    partitions were down, the ranking covers only the surviving label
    ranges (scores for those labels are still bitwise-exact), and
    ``missing_labels`` lists the unsearched ``[lo, hi)`` global label
    ranges. Degraded results keep ``status == "ok"`` / HTTP 200 — the
    request did not fail, the index was partially unavailable.

    ``beam_tier`` is the adaptive-SLO analogue: tier 0 (the default, and
    omitted from the wire) is the configured full beam — bitwise-identical
    to a server without an SLO; tier > 0 means the batch was served at a
    narrower beam to hold the latency target, so the ranking is exact *at
    that beam* but may recall less than the full-beam ranking. Like
    ``degraded``, it keeps ``status == "ok"``.
    """

    qid: int
    ids: Optional[np.ndarray]        # int32 [k] label ids
    scores: Optional[np.ndarray]     # f32 [k]
    status: str = STATUS_OK
    timing: Dict[str, float] = dataclasses.field(default_factory=dict)
    error: Optional[BaseException] = None
    detail: str = ""
    degraded: bool = False
    missing_labels: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list
    )
    beam_tier: int = 0

    @property
    def ok(self) -> bool:
        return self.status == STATUS_OK

    @property
    def http_status(self) -> int:
        return HTTP_STATUS.get(self.status, 500)

    # Back-compat aliases for the pre-v1 ``StreamResult`` tuple fields.
    @property
    def index(self) -> int:
        return self.qid

    @property
    def labels(self) -> Optional[np.ndarray]:
        return self.ids

    @classmethod
    def from_error(
        cls, qid: int, exc: BaseException,
        timing: Optional[Dict[str, float]] = None,
    ) -> "QueryResult":
        return cls(
            qid=qid, ids=None, scores=None,
            status=status_for_exception(exc),
            timing=timing or {}, error=exc, detail=str(exc),
        )

    def to_wire(self) -> dict:
        doc: dict = {
            "v": WIRE_VERSION,
            "qid": int(self.qid),
            "status": self.status,
            "timing": {k: float(v) for k, v in self.timing.items()},
        }
        if self.ok:
            doc["ids"] = [int(i) for i in np.asarray(self.ids)]
            doc["scores"] = [float(s) for s in np.asarray(self.scores)]
            if self.degraded:
                doc["degraded"] = True
                doc["missing_labels"] = [
                    [int(lo), int(hi)] for lo, hi in self.missing_labels
                ]
            if self.beam_tier:
                doc["beam_tier"] = int(self.beam_tier)
        else:
            doc["detail"] = self.detail
        return doc

    @classmethod
    def from_wire(cls, doc: dict) -> "QueryResult":
        _check_version(doc, "QueryResult")
        try:
            status = str(doc["status"])
            ok = status == STATUS_OK
            return cls(
                qid=int(doc.get("qid", 0)),
                ids=np.asarray(doc["ids"], np.int32) if ok else None,
                scores=np.asarray(doc["scores"], np.float32) if ok else None,
                status=status,
                timing=dict(doc.get("timing", {})),
                detail=str(doc.get("detail", "")),
                degraded=bool(doc.get("degraded", False)),
                missing_labels=[
                    (int(lo), int(hi))
                    for lo, hi in doc.get("missing_labels", [])
                ],
                beam_tier=int(doc.get("beam_tier", 0)),
            )
        except (KeyError, TypeError, ValueError) as exc:
            raise WireError(f"QueryResult: malformed document ({exc})") from exc
