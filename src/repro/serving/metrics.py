"""Serving-side metrics.

:class:`LatencyStats` is the per-query wall-clock recorder the paper's
Table 4 reports (avg / P50 / P95 / P99). Per-query samples and amortized
batch-call averages are kept in *separate* series: percentiles over call
averages are not per-query percentiles, and conflating them (as an early
version of ``serve_batch`` did) silently mislabels the Table-4 panel.

:class:`ServerMetrics` extends it for the async micro-batching engine: each
request decomposes into queue-wait (enqueue → batch formed) and compute
(batch dispatch → results ready), plus whole-run throughput (QPS/goodput),
coalescing diagnostics (size vs deadline trigger, bucket occupancy),
overload accounting (shed rate, deadline-miss rate), and — when dispatch is
sharded over a device mesh — per-replica occupancy.

Partitioned dispatch adds overlap accounting: ``pipeline_stall_ms`` is the
wall time the worker spent *blocked* on a dispatched batch's device results
after host dispatch returned — the residual the pipelined scatter–gather
mode exists to shrink (compare it across ``partition_sync="level"`` vs
``"pipelined"`` under the same load) — and ``beam_cache`` carries the
hot-beam LRU's cumulative hit/miss accounting from the planner.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List

import numpy as np


def _percentiles(arr: np.ndarray) -> Dict[str, float]:
    return {
        "avg_ms": float(arr.mean()),
        "p50_ms": float(np.percentile(arr, 50)),
        "p95_ms": float(np.percentile(arr, 95)),
        "p99_ms": float(np.percentile(arr, 99)),
    }


@dataclasses.dataclass
class LatencyStats:
    """Latency recorder with per-query and amortized series kept distinct.

    ``record`` takes one true per-query wall-clock sample (the online
    setting); ``record_amortized`` takes a whole batch call's wall time and
    query count (the batch setting, where overlapped chunks make individual
    per-query times meaningless). ``summary()`` reports percentiles only
    over the per-query series; amortized data appears under its own key.
    """

    per_query_ms: List[float] = dataclasses.field(default_factory=list)
    amortized_ms: List[float] = dataclasses.field(default_factory=list)
    amortized_queries: int = 0

    def record(self, query_s: float, n_queries: int = 1) -> None:
        """Record per-query latency samples.

        ``n_queries > 1`` is an amortized call average, not a per-query
        sample — routed to the amortized series so ``summary()``'s p95/p99
        stay honest percentiles over individual query latencies.
        """
        if n_queries > 1:
            self.record_amortized(query_s, n_queries)
        else:
            self.per_query_ms.append(1e3 * query_s)

    def record_amortized(self, total_s: float, n_queries: int) -> None:
        """Record one batch call: total wall time over ``n_queries``."""
        self.amortized_ms.append(1e3 * total_s / max(n_queries, 1))
        self.amortized_queries += n_queries

    def summary(self) -> dict:
        out: dict = {"count": len(self.per_query_ms)}
        if self.per_query_ms:
            out.update(_percentiles(np.asarray(self.per_query_ms)))
        if self.amortized_ms:
            arr = np.asarray(self.amortized_ms)
            out["amortized"] = {
                "calls": len(arr),
                "queries": self.amortized_queries,
                "avg_ms_per_query": float(arr.mean()),
            }
        return out


def _replica_rows(count: int, bucket: int, shards: int) -> List[int]:
    """Real (non-padding) rows each replica holds for one dispatched bucket.

    The bucket splits evenly over the mesh's data axis; real rows occupy the
    bucket head, so padding concentrates on the trailing replicas.
    """
    per = bucket // max(shards, 1)
    return [int(np.clip(count - r * per, 0, per)) for r in range(shards)]


@dataclasses.dataclass
class ServerMetrics:
    """End-to-end request accounting for the micro-batching server.

    Thread-safe: the batcher worker records batches while client threads
    submit (shed/deadline counters) and read summaries.
    """

    queue_wait_ms: List[float] = dataclasses.field(default_factory=list)
    compute_ms: List[float] = dataclasses.field(default_factory=list)
    e2e_ms: List[float] = dataclasses.field(default_factory=list)
    batch_sizes: List[int] = dataclasses.field(default_factory=list)
    bucket_sizes: List[int] = dataclasses.field(default_factory=list)
    triggers: List[str] = dataclasses.field(default_factory=list)
    batch_shards: List[int] = dataclasses.field(default_factory=list)
    partition_hits: List[np.ndarray] = dataclasses.field(default_factory=list)
    pipeline_stall_ms: List[float] = dataclasses.field(default_factory=list)
    beam_cache: Dict[str, float] = dataclasses.field(default_factory=dict)
    offered: int = 0
    shed: int = 0
    shed_by_priority: Dict[int, int] = dataclasses.field(default_factory=dict)
    deadline_missed: int = 0
    degraded_served: int = 0  # successful queries answered from a partial fleet
    # Per-beam-tier completed-query counts (tier 0 = full beam); populated
    # only by engines with an SLO ladder, so legacy summaries are unchanged.
    tier_queries: Dict[int, int] = dataclasses.field(default_factory=dict)
    _t_first: float | None = None
    _t_last: float | None = None
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False
    )

    # -- overload accounting (client/worker threads) ------------------------
    def record_offered(self) -> None:
        with self._lock:
            self.offered += 1

    def record_shed(self, priority: int = 0) -> None:
        with self._lock:
            self.shed += 1
            self.shed_by_priority[priority] = (
                self.shed_by_priority.get(priority, 0) + 1
            )

    def record_deadline_miss(self) -> None:
        with self._lock:
            self.deadline_missed += 1

    def record_degraded(self, n_queries: int) -> None:
        """Count queries served degraded (partial fleet, survivor-exact)."""
        with self._lock:
            self.degraded_served += n_queries

    # -- batch accounting (worker thread) -----------------------------------
    def record_batch(
        self,
        *,
        t_enqueue: List[float],
        t_dequeue: float,
        t_done: float,
        bucket: int,
        trigger: str,
        shards: int = 1,
        partition_hits=None,
        stall_ms: float | None = None,
        cache_stats: dict | None = None,
        tier: int = 0,
    ) -> None:
        """Record one dispatched micro-batch of len(t_enqueue) requests.

        ``partition_hits`` (per-partition result counts from the engine's
        label-partitioned planner) feeds the partition-occupancy panel;
        ``stall_ms`` is the worker's blocked-on-device wall for this batch
        (partitioned dispatch only) and ``cache_stats`` the planner's
        *cumulative* hot-beam cache counters (latest snapshot wins).
        ``tier`` is the beam tier the batch was dispatched at (0 = full).
        """
        compute = 1e3 * (t_done - t_dequeue)
        with self._lock:
            self.tier_queries[tier] = (
                self.tier_queries.get(tier, 0) + len(t_enqueue)
            )
            if partition_hits is not None:
                self.partition_hits.append(np.asarray(partition_hits))
            if stall_ms is not None:
                self.pipeline_stall_ms.append(stall_ms)
            if cache_stats is not None:
                self.beam_cache = dict(cache_stats)
            for te in t_enqueue:
                self.queue_wait_ms.append(1e3 * (t_dequeue - te))
                self.e2e_ms.append(1e3 * (t_done - te))
            self.compute_ms.append(compute)
            self.batch_sizes.append(len(t_enqueue))
            self.bucket_sizes.append(bucket)
            self.triggers.append(trigger)
            self.batch_shards.append(shards)
            first = min(t_enqueue)
            if self._t_first is None or first < self._t_first:
                self._t_first = first
            if self._t_last is None or t_done > self._t_last:
                self._t_last = t_done

    @property
    def count(self) -> int:
        with self._lock:
            return len(self.e2e_ms)

    def summary(self) -> dict:
        with self._lock:
            if not self.e2e_ms:
                out = {"count": 0}
                if self.offered:
                    out["offered"] = self.offered
                    out["shed"] = self.shed
                    out["shed_rate"] = self.shed / self.offered
                    if self.shed_by_priority:
                        out["shed_by_priority"] = dict(
                            sorted(self.shed_by_priority.items())
                        )
                    out["deadline_missed"] = self.deadline_missed
                    out["deadline_miss_rate"] = self.deadline_missed / self.offered
                if self.degraded_served:
                    out["degraded_served"] = self.degraded_served
                return out
            e2e = np.asarray(self.e2e_ms)
            wait = np.asarray(self.queue_wait_ms)
            comp = np.asarray(self.compute_ms)
            sizes = np.asarray(self.batch_sizes)
            wall_s = max(self._t_last - self._t_first, 1e-9)
            trig = {
                t: self.triggers.count(t) for t in sorted(set(self.triggers))
            }
            out = {
                "count": len(e2e),
                **_percentiles(e2e),
                "queue_wait_avg_ms": float(wait.mean()),
                "compute_avg_ms": float(comp.mean()),
                "compute_per_query_avg_ms": float(
                    comp.sum() / max(sizes.sum(), 1)
                ),
                # e2e_ms only holds completed requests, so qps IS goodput
                "qps": float(len(e2e) / wall_s),
                "batches": len(sizes),
                "avg_batch": float(sizes.mean()),
                "triggers": trig,
            }
            offered = max(self.offered, len(e2e))
            out["offered"] = offered
            out["shed"] = self.shed
            out["shed_rate"] = self.shed / offered
            if self.shed_by_priority:
                out["shed_by_priority"] = dict(
                    sorted(self.shed_by_priority.items())
                )
            out["deadline_missed"] = self.deadline_missed
            out["deadline_miss_rate"] = self.deadline_missed / offered
            if self.degraded_served:
                out["degraded_served"] = self.degraded_served
                out["degraded_rate"] = self.degraded_served / offered
            if any(t > 0 for t in self.tier_queries):
                # Adaptive-SLO panel: how traffic split across the beam
                # ladder, and what fraction was degraded below full beam
                # (served, not shed — the knob the tier policy trades).
                out["beam_tiers"] = {
                    str(t): int(n)
                    for t, n in sorted(self.tier_queries.items())
                }
                to_tier = sum(
                    n for t, n in self.tier_queries.items() if t > 0
                )
                out["degraded_to_tier"] = int(to_tier)
                out["degraded_to_tier_rate"] = to_tier / max(len(e2e), 1)
            if self.partition_hits:
                hits = np.sum(self.partition_hits, axis=0).astype(float)
                total = max(hits.sum(), 1.0)
                out["partition_occupancy"] = [
                    round(float(h / total), 4) for h in hits
                ]
            if self.pipeline_stall_ms:
                stall = np.asarray(self.pipeline_stall_ms)
                out["pipeline_stall_avg_ms"] = float(stall.mean())
                out["pipeline_stall_p99_ms"] = float(np.percentile(stall, 99))
            if self.beam_cache:
                out["beam_cache"] = dict(self.beam_cache)
            max_shards = max(self.batch_shards, default=1)
            if max_shards > 1:
                occ = np.zeros(max_shards)
                for count, bucket, shards in zip(
                    self.batch_sizes, self.bucket_sizes, self.batch_shards
                ):
                    rows = _replica_rows(count, bucket, shards)
                    per = bucket // shards
                    for r in range(max_shards):
                        occ[r] += (rows[r] / per) if r < shards else 0.0
                out["replica_occupancy"] = [
                    round(float(o / len(self.batch_sizes)), 4) for o in occ
                ]
            return out

    def table4_row(self, name: str) -> str:
        """One line in the paper's Table-4 latency panel format."""
        s = self.summary()
        if not s["count"]:
            return f"{name:24s} (no requests)"
        return (
            f"{name:24s} avg {s['avg_ms']:7.3f} ms/q   "
            f"p50 {s['p50_ms']:7.3f}   p95 {s['p95_ms']:7.3f}   "
            f"p99 {s['p99_ms']:7.3f}   "
            f"wait {s['queue_wait_avg_ms']:6.3f}   "
            f"compute {s['compute_per_query_avg_ms']:6.3f}   "
            f"{s['qps']:8.1f} QPS   "
            f"shed {100 * s['shed_rate']:5.1f}%   "
            f"miss {100 * s['deadline_miss_rate']:5.1f}%"
        )
