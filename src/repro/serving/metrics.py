"""Serving-side metrics.

:class:`LatencyStats` is the per-query wall-clock recorder the paper's
Table 4 reports (avg / P50 / P95 / P99). :class:`ServerMetrics` extends it
for the async micro-batching engine: each request is decomposed into
queue-wait (enqueue → batch formed) and compute (batch dispatch → results
ready), plus whole-run throughput (QPS) and per-batch coalescing
diagnostics (size vs deadline trigger, bucket occupancy).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List

import numpy as np


def _percentiles(arr: np.ndarray) -> Dict[str, float]:
    return {
        "avg_ms": float(arr.mean()),
        "p50_ms": float(np.percentile(arr, 50)),
        "p95_ms": float(np.percentile(arr, 95)),
        "p99_ms": float(np.percentile(arr, 99)),
    }


@dataclasses.dataclass
class LatencyStats:
    per_query_ms: List[float] = dataclasses.field(default_factory=list)

    def record(self, total_s: float, n_queries: int) -> None:
        self.per_query_ms.append(1e3 * total_s / max(n_queries, 1))

    def summary(self) -> dict:
        if not self.per_query_ms:
            return {"count": 0}
        arr = np.asarray(self.per_query_ms)
        return {"count": len(arr), **_percentiles(arr)}


@dataclasses.dataclass
class ServerMetrics:
    """End-to-end request accounting for the micro-batching server.

    Thread-safe: the batcher worker records batches while client threads
    read summaries.
    """

    queue_wait_ms: List[float] = dataclasses.field(default_factory=list)
    compute_ms: List[float] = dataclasses.field(default_factory=list)
    e2e_ms: List[float] = dataclasses.field(default_factory=list)
    batch_sizes: List[int] = dataclasses.field(default_factory=list)
    bucket_sizes: List[int] = dataclasses.field(default_factory=list)
    triggers: List[str] = dataclasses.field(default_factory=list)
    _t_first: float | None = None
    _t_last: float | None = None
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False
    )

    def record_batch(
        self,
        *,
        t_enqueue: List[float],
        t_dequeue: float,
        t_done: float,
        bucket: int,
        trigger: str,
    ) -> None:
        """Record one dispatched micro-batch of len(t_enqueue) requests."""
        compute = 1e3 * (t_done - t_dequeue)
        with self._lock:
            for te in t_enqueue:
                self.queue_wait_ms.append(1e3 * (t_dequeue - te))
                self.e2e_ms.append(1e3 * (t_done - te))
            self.compute_ms.append(compute)
            self.batch_sizes.append(len(t_enqueue))
            self.bucket_sizes.append(bucket)
            self.triggers.append(trigger)
            first = min(t_enqueue)
            if self._t_first is None or first < self._t_first:
                self._t_first = first
            if self._t_last is None or t_done > self._t_last:
                self._t_last = t_done

    @property
    def count(self) -> int:
        with self._lock:
            return len(self.e2e_ms)

    def summary(self) -> dict:
        with self._lock:
            if not self.e2e_ms:
                return {"count": 0}
            e2e = np.asarray(self.e2e_ms)
            wait = np.asarray(self.queue_wait_ms)
            comp = np.asarray(self.compute_ms)
            sizes = np.asarray(self.batch_sizes)
            wall_s = max(self._t_last - self._t_first, 1e-9)
            trig = {
                t: self.triggers.count(t) for t in sorted(set(self.triggers))
            }
            return {
                "count": len(e2e),
                **_percentiles(e2e),
                "queue_wait_avg_ms": float(wait.mean()),
                "compute_avg_ms": float(comp.mean()),
                "compute_per_query_avg_ms": float(
                    comp.sum() / max(sizes.sum(), 1)
                ),
                "qps": float(len(e2e) / wall_s),
                "batches": len(sizes),
                "avg_batch": float(sizes.mean()),
                "triggers": trig,
            }

    def table4_row(self, name: str) -> str:
        """One line in the paper's Table-4 latency panel format."""
        s = self.summary()
        if not s["count"]:
            return f"{name:24s} (no requests)"
        return (
            f"{name:24s} avg {s['avg_ms']:7.3f} ms/q   "
            f"p50 {s['p50_ms']:7.3f}   p95 {s['p95_ms']:7.3f}   "
            f"p99 {s['p99_ms']:7.3f}   "
            f"wait {s['queue_wait_avg_ms']:6.3f}   "
            f"compute {s['compute_per_query_avg_ms']:6.3f}   "
            f"{s['qps']:8.1f} QPS"
        )
