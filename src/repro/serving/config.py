"""v1 serving configuration: nested groups + a flat-kwarg back-compat shim.

``ServeConfig`` had grown 15 flat knobs across four concerns. The v1 surface
groups them by who consumes them:

* inference knobs stay top-level on :class:`ServeConfig` (``beam``,
  ``topk``, ``method``, ``ell_width``, ``max_batch``, ``score_mode``,
  ``qt``, ``shards``) — the engine reads these on every dispatch;
* :class:`AdmissionConfig` — the overload policy the :class:`~repro.serving
  .batcher.MicroBatcher` applies at the queue boundary;
* :class:`PartitionConfig` — the label-partitioned dispatch topology
  (:mod:`repro.index`);
* :class:`FleetConfig` — cross-process fleet resilience knobs;
* :class:`QuantConfig` — the compressed-weight storage tier
  (:mod:`repro.quant`): ``tier="exact"`` serves the f32 tree unchanged,
  the other tiers quantize the (partitioned) weights at engine build;
* :class:`SLOConfig` — latency-SLO adaptive inference: a ladder of
  degraded beam tiers the batcher may pick per dispatched batch when the
  queue backs up (:mod:`repro.serving.slo`). Off by default
  (``target_p99_ms=None``): every batch serves the full configured beam.

Back compat: the pre-v1 flat kwargs (``queue_depth=``, ``partitions=``, …)
still work — ``ServeConfig`` routes them into the right nested group and
emits a :class:`DeprecationWarning` — and the read side keeps flat
*properties* (``config.partitions`` forwards to
``config.partition.partitions``) so existing call sites and benches keep
working unchanged. New code should write the nested form::

    ServeConfig(
        max_batch=64,
        partition=PartitionConfig(partitions=2, partition_sync="pipelined"),
        admission=AdmissionConfig(queue_depth="auto", deadline_ms=50.0),
    )
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional, Tuple, Union


@dataclasses.dataclass
class AdmissionConfig:
    """Overload policy consumed by the :class:`MicroBatcher` front end."""

    queue_depth: Union[int, str, None] = None  # bound | "auto" | unbounded
    shed_policy: str = "reject"                # "reject" | "shed-oldest"
    deadline_ms: Optional[float] = None        # default per-request deadline


@dataclasses.dataclass
class PartitionConfig:
    """Label-partitioned dispatch topology (:mod:`repro.index`)."""

    partitions: int = 1                    # label-space partitions
    partition_level: Optional[int] = None  # split level (None = auto)
    # "level"     — per-level exchange, bitwise-exact
    # "pipelined" — exchange overlapped with the next level's MSCM via
    #               speculative expansion; still bitwise-exact (and the only
    #               mode the cross-process fleet transport supports)
    # "final"     — one merge, no per-level sync; dominates, not bitwise
    partition_sync: str = "level"
    beam_cache: int = 0                    # hot-beam LRU entries (0 = off)


#: Valid :attr:`FleetConfig.degraded_policy` values.
DEGRADED_POLICIES = ("serve_partial", "reject")


@dataclasses.dataclass
class FleetConfig:
    """Cross-process fleet resilience: degraded serving + supervision.

    ``degraded_policy`` decides what a partition loss mid-query means:

    * ``"serve_partial"`` (default) — complete the beam exchange over the
      surviving partitions and stamp the result ``degraded`` with the
      unsearched label ranges; survivor scores stay bitwise-exact.
    * ``"reject"`` — fail the query with a typed ``worker_unavailable``
      (the pre-supervision behavior).

    The remaining knobs tune :class:`~repro.serving.fleet.FleetSupervisor`:
    how often it sweeps the fleet, how long one liveness probe may take,
    how many consecutive failed probes turn ``SUSPECT`` into a restart, and
    the exponential backoff / attempt budget of the respawn loop.
    """

    degraded_policy: str = "serve_partial"
    poll_interval_s: float = 0.5   # supervisor sweep cadence
    ping_timeout_s: float = 2.0    # per-worker probe bound
    suspect_after: int = 2         # failed probes before a restart
    backoff_base_s: float = 0.25   # delay after the first failed respawn
    backoff_max_s: float = 10.0    # backoff doubles up to this cap
    restart_budget: int = 5        # respawn attempts before FAILED

    def __post_init__(self) -> None:
        if self.degraded_policy not in DEGRADED_POLICIES:
            raise ValueError(
                f"degraded_policy={self.degraded_policy!r}; choose from "
                f"{DEGRADED_POLICIES}"
            )


#: Valid :attr:`QuantConfig.tier` values. ``"fp8"`` needs a jax build with
#: ``float8_e4m3fn``; availability is checked when the tree is quantized
#: (:func:`repro.quant.quantize_tree`), not here — config stays import-light.
QUANT_TIERS = ("exact", "int8", "int8_pruned", "fp8")


@dataclasses.dataclass
class QuantConfig:
    """Compressed-weight storage tier (:mod:`repro.quant`).

    ``tier``:

    * ``"exact"`` (default) — f32 weights, bitwise-identical serving; the
      engine behaves exactly as before this config existed.
    * ``"int8"`` — per-(chunk, column) symmetric int8 weights + f32 scales,
      served through ``method="mscm_pallas_grouped_q"`` (dequantize
      in-register). ~4× smaller partitions; accuracy is a *measured
      contract* (recall@k floor / score-MAE bound, ``benchmarks/
      bench_quant.py``), not a bitwise claim.
    * ``"int8_pruned"`` — int8 plus a magnitude-pruned ELL re-pack keeping
      the top ``prune_keep`` fraction of each chunk's rows (pad width R
      shrinks too).
    * ``"fp8"`` — fp8-e4m3 storage where the backend has the dtype
      (in-process serving only; the fleet wire is int8/f32).
    """

    tier: str = "exact"
    prune_keep: float = 0.5  # row fraction kept by the pruned re-pack

    def __post_init__(self) -> None:
        if self.tier not in QUANT_TIERS:
            raise ValueError(
                f"tier={self.tier!r}; choose from {QUANT_TIERS}"
            )
        if not 0.0 < self.prune_keep <= 1.0:
            raise ValueError(
                f"prune_keep must be in (0, 1]; got {self.prune_keep}"
            )


@dataclasses.dataclass
class SLOConfig:
    """Latency-SLO adaptive inference (:mod:`repro.serving.slo`).

    ``target_p99_ms=None`` (default) disables adaptive tiering: the engine
    exposes a single tier — the configured full ``(beam, qt)`` — and the
    batcher never degrades, so serving stays bitwise-identical to a config
    without this group. With a target set, the batcher picks a per-batch
    beam tier from queue depth and the batch's remaining deadline budget:
    tier 0 is always the full beam; deeper tiers trade recall for drain
    rate instead of shedding whole queries.

    ``tiers`` pins the degraded ladder explicitly as ``(beam, qt)`` pairs
    with strictly descending beams, all narrower than the configured full
    beam. Empty (default) auto-derives a halving ladder ``beam//2,
    beam//4, …`` down to ``min_beam`` at the configured ``qt``. Every tier
    must preserve the full-beam output panel width (the engine validates
    against the tree geometry at build) so a degraded result is narrower
    in *search*, never in *shape*.
    """

    target_p99_ms: Optional[float] = None  # None = adaptive tiering off
    tiers: Tuple[Tuple[int, int], ...] = ()  # explicit (beam, qt) ladder
    min_beam: int = 1                      # auto-ladder floor

    def __post_init__(self) -> None:
        if self.target_p99_ms is not None and self.target_p99_ms <= 0:
            raise ValueError(
                f"target_p99_ms must be positive; got {self.target_p99_ms}"
            )
        if self.min_beam < 1:
            raise ValueError(f"min_beam must be >= 1; got {self.min_beam}")
        prev = None
        for pair in self.tiers:
            if len(tuple(pair)) != 2:
                raise ValueError(
                    f"tiers entries are (beam, qt) pairs; got {pair!r}"
                )
            b, q = int(pair[0]), int(pair[1])
            if b < 1 or q < 1:
                raise ValueError(
                    f"tier (beam={b}, qt={q}) must be positive"
                )
            if prev is not None and b >= prev:
                raise ValueError(
                    f"tier beams must be strictly descending; got "
                    f"{[int(p[0]) for p in self.tiers]}"
                )
            prev = b


_ADMISSION_FIELDS = frozenset(
    f.name for f in dataclasses.fields(AdmissionConfig)
)
_PARTITION_FIELDS = frozenset(
    f.name for f in dataclasses.fields(PartitionConfig)
)
_FLEET_FIELDS = frozenset(
    f.name for f in dataclasses.fields(FleetConfig)
)
_QUANT_FIELDS = frozenset(
    f.name for f in dataclasses.fields(QuantConfig)
)
_SLO_FIELDS = frozenset(
    f.name for f in dataclasses.fields(SLOConfig)
)


@dataclasses.dataclass(init=False)
class ServeConfig:
    """Engine + serving-tier configuration (see the module docstring)."""

    beam: int = 10
    topk: int = 10
    method: str = "auto"          # "auto" resolves per backend (see engine)
    ell_width: int = 256          # query nnz cap (pad/truncate)
    max_batch: int = 256
    score_mode: str = "prod"
    qt: int = 8                   # grouped-kernel query-tile height
    shards: int = 1               # data-parallel device replicas per dispatch
    admission: AdmissionConfig = dataclasses.field(
        default_factory=AdmissionConfig
    )
    partition: PartitionConfig = dataclasses.field(
        default_factory=PartitionConfig
    )
    fleet: FleetConfig = dataclasses.field(default_factory=FleetConfig)
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    slo: SLOConfig = dataclasses.field(default_factory=SLOConfig)

    def __init__(
        self,
        beam: int = 10,
        topk: int = 10,
        method: str = "auto",
        ell_width: int = 256,
        max_batch: int = 256,
        score_mode: str = "prod",
        qt: int = 8,
        shards: int = 1,
        admission: AdmissionConfig | None = None,
        partition: PartitionConfig | None = None,
        fleet: FleetConfig | None = None,
        quant: QuantConfig | None = None,
        slo: SLOConfig | None = None,
        **flat: Any,
    ) -> None:
        self.beam = beam
        self.topk = topk
        self.method = method
        self.ell_width = ell_width
        self.max_batch = max_batch
        self.score_mode = score_mode
        self.qt = qt
        self.shards = shards
        self.admission = admission if admission is not None else AdmissionConfig()
        self.partition = partition if partition is not None else PartitionConfig()
        self.fleet = fleet if fleet is not None else FleetConfig()
        self.quant = quant if quant is not None else QuantConfig()
        self.slo = slo if slo is not None else SLOConfig()
        if flat:
            adm = {k: v for k, v in flat.items() if k in _ADMISSION_FIELDS}
            prt = {k: v for k, v in flat.items() if k in _PARTITION_FIELDS}
            flt = {k: v for k, v in flat.items() if k in _FLEET_FIELDS}
            qnt = {k: v for k, v in flat.items() if k in _QUANT_FIELDS}
            slk = {k: v for k, v in flat.items() if k in _SLO_FIELDS}
            unknown = (
                set(flat) - set(adm) - set(prt) - set(flt) - set(qnt)
                - set(slk)
            )
            if unknown:
                raise TypeError(
                    f"ServeConfig got unexpected keyword argument(s) "
                    f"{sorted(unknown)}"
                )
            warnings.warn(
                f"flat ServeConfig kwarg(s) "
                f"{sorted(adm) + sorted(prt) + sorted(flt) + sorted(qnt) + sorted(slk)} "
                "are deprecated; pass admission=AdmissionConfig(...) / "
                "partition=PartitionConfig(...) / fleet=FleetConfig(...) / "
                "quant=QuantConfig(...) / slo=SLOConfig(...) instead",
                DeprecationWarning,
                stacklevel=2,
            )
            # replace(), not setattr: never mutate a caller-shared group.
            if adm:
                self.admission = dataclasses.replace(self.admission, **adm)
            if prt:
                self.partition = dataclasses.replace(self.partition, **prt)
            if flt:
                self.fleet = dataclasses.replace(self.fleet, **flt)
            if qnt:
                self.quant = dataclasses.replace(self.quant, **qnt)
            if slk:
                self.slo = dataclasses.replace(self.slo, **slk)

    # -- flat read-side forwarding (pre-v1 call sites) ----------------------
    @property
    def queue_depth(self) -> Union[int, str, None]:
        return self.admission.queue_depth

    @property
    def shed_policy(self) -> str:
        return self.admission.shed_policy

    @property
    def deadline_ms(self) -> Optional[float]:
        return self.admission.deadline_ms

    @property
    def partitions(self) -> int:
        return self.partition.partitions

    @property
    def partition_level(self) -> Optional[int]:
        return self.partition.partition_level

    @property
    def partition_sync(self) -> str:
        return self.partition.partition_sync

    @property
    def beam_cache(self) -> int:
        return self.partition.beam_cache

    @property
    def degraded_policy(self) -> str:
        return self.fleet.degraded_policy

    @property
    def tier(self) -> str:
        return self.quant.tier

    @property
    def prune_keep(self) -> float:
        return self.quant.prune_keep

    @property
    def target_p99_ms(self) -> Optional[float]:
        return self.slo.target_p99_ms
