"""v1 serving configuration: nested groups + a flat-kwarg back-compat shim.

``ServeConfig`` had grown 15 flat knobs across four concerns. The v1 surface
groups them by who consumes them:

* inference knobs stay top-level on :class:`ServeConfig` (``beam``,
  ``topk``, ``method``, ``ell_width``, ``max_batch``, ``score_mode``,
  ``qt``, ``shards``) — the engine reads these on every dispatch;
* :class:`AdmissionConfig` — the overload policy the :class:`~repro.serving
  .batcher.MicroBatcher` applies at the queue boundary;
* :class:`PartitionConfig` — the label-partitioned dispatch topology
  (:mod:`repro.index`).

Back compat: the pre-v1 flat kwargs (``queue_depth=``, ``partitions=``, …)
still work — ``ServeConfig`` routes them into the right nested group and
emits a :class:`DeprecationWarning` — and the read side keeps flat
*properties* (``config.partitions`` forwards to
``config.partition.partitions``) so existing call sites and benches keep
working unchanged. New code should write the nested form::

    ServeConfig(
        max_batch=64,
        partition=PartitionConfig(partitions=2, partition_sync="pipelined"),
        admission=AdmissionConfig(queue_depth="auto", deadline_ms=50.0),
    )
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional, Union


@dataclasses.dataclass
class AdmissionConfig:
    """Overload policy consumed by the :class:`MicroBatcher` front end."""

    queue_depth: Union[int, str, None] = None  # bound | "auto" | unbounded
    shed_policy: str = "reject"                # "reject" | "shed-oldest"
    deadline_ms: Optional[float] = None        # default per-request deadline


@dataclasses.dataclass
class PartitionConfig:
    """Label-partitioned dispatch topology (:mod:`repro.index`)."""

    partitions: int = 1                    # label-space partitions
    partition_level: Optional[int] = None  # split level (None = auto)
    # "level"     — per-level exchange, bitwise-exact
    # "pipelined" — exchange overlapped with the next level's MSCM via
    #               speculative expansion; still bitwise-exact (and the only
    #               mode the cross-process fleet transport supports)
    # "final"     — one merge, no per-level sync; dominates, not bitwise
    partition_sync: str = "level"
    beam_cache: int = 0                    # hot-beam LRU entries (0 = off)


#: Valid :attr:`FleetConfig.degraded_policy` values.
DEGRADED_POLICIES = ("serve_partial", "reject")


@dataclasses.dataclass
class FleetConfig:
    """Cross-process fleet resilience: degraded serving + supervision.

    ``degraded_policy`` decides what a partition loss mid-query means:

    * ``"serve_partial"`` (default) — complete the beam exchange over the
      surviving partitions and stamp the result ``degraded`` with the
      unsearched label ranges; survivor scores stay bitwise-exact.
    * ``"reject"`` — fail the query with a typed ``worker_unavailable``
      (the pre-supervision behavior).

    The remaining knobs tune :class:`~repro.serving.fleet.FleetSupervisor`:
    how often it sweeps the fleet, how long one liveness probe may take,
    how many consecutive failed probes turn ``SUSPECT`` into a restart, and
    the exponential backoff / attempt budget of the respawn loop.
    """

    degraded_policy: str = "serve_partial"
    poll_interval_s: float = 0.5   # supervisor sweep cadence
    ping_timeout_s: float = 2.0    # per-worker probe bound
    suspect_after: int = 2         # failed probes before a restart
    backoff_base_s: float = 0.25   # delay after the first failed respawn
    backoff_max_s: float = 10.0    # backoff doubles up to this cap
    restart_budget: int = 5        # respawn attempts before FAILED

    def __post_init__(self) -> None:
        if self.degraded_policy not in DEGRADED_POLICIES:
            raise ValueError(
                f"degraded_policy={self.degraded_policy!r}; choose from "
                f"{DEGRADED_POLICIES}"
            )


_ADMISSION_FIELDS = frozenset(
    f.name for f in dataclasses.fields(AdmissionConfig)
)
_PARTITION_FIELDS = frozenset(
    f.name for f in dataclasses.fields(PartitionConfig)
)
_FLEET_FIELDS = frozenset(
    f.name for f in dataclasses.fields(FleetConfig)
)


@dataclasses.dataclass(init=False)
class ServeConfig:
    """Engine + serving-tier configuration (see the module docstring)."""

    beam: int = 10
    topk: int = 10
    method: str = "auto"          # "auto" resolves per backend (see engine)
    ell_width: int = 256          # query nnz cap (pad/truncate)
    max_batch: int = 256
    score_mode: str = "prod"
    qt: int = 8                   # grouped-kernel query-tile height
    shards: int = 1               # data-parallel device replicas per dispatch
    admission: AdmissionConfig = dataclasses.field(
        default_factory=AdmissionConfig
    )
    partition: PartitionConfig = dataclasses.field(
        default_factory=PartitionConfig
    )
    fleet: FleetConfig = dataclasses.field(default_factory=FleetConfig)

    def __init__(
        self,
        beam: int = 10,
        topk: int = 10,
        method: str = "auto",
        ell_width: int = 256,
        max_batch: int = 256,
        score_mode: str = "prod",
        qt: int = 8,
        shards: int = 1,
        admission: AdmissionConfig | None = None,
        partition: PartitionConfig | None = None,
        fleet: FleetConfig | None = None,
        **flat: Any,
    ) -> None:
        self.beam = beam
        self.topk = topk
        self.method = method
        self.ell_width = ell_width
        self.max_batch = max_batch
        self.score_mode = score_mode
        self.qt = qt
        self.shards = shards
        self.admission = admission if admission is not None else AdmissionConfig()
        self.partition = partition if partition is not None else PartitionConfig()
        self.fleet = fleet if fleet is not None else FleetConfig()
        if flat:
            adm = {k: v for k, v in flat.items() if k in _ADMISSION_FIELDS}
            prt = {k: v for k, v in flat.items() if k in _PARTITION_FIELDS}
            flt = {k: v for k, v in flat.items() if k in _FLEET_FIELDS}
            unknown = set(flat) - set(adm) - set(prt) - set(flt)
            if unknown:
                raise TypeError(
                    f"ServeConfig got unexpected keyword argument(s) "
                    f"{sorted(unknown)}"
                )
            warnings.warn(
                f"flat ServeConfig kwarg(s) "
                f"{sorted(adm) + sorted(prt) + sorted(flt)} are "
                "deprecated; pass admission=AdmissionConfig(...) / "
                "partition=PartitionConfig(...) / fleet=FleetConfig(...) "
                "instead",
                DeprecationWarning,
                stacklevel=2,
            )
            # replace(), not setattr: never mutate a caller-shared group.
            if adm:
                self.admission = dataclasses.replace(self.admission, **adm)
            if prt:
                self.partition = dataclasses.replace(self.partition, **prt)
            if flt:
                self.fleet = dataclasses.replace(self.fleet, **flt)

    # -- flat read-side forwarding (pre-v1 call sites) ----------------------
    @property
    def queue_depth(self) -> Union[int, str, None]:
        return self.admission.queue_depth

    @property
    def shed_policy(self) -> str:
        return self.admission.shed_policy

    @property
    def deadline_ms(self) -> Optional[float]:
        return self.admission.deadline_ms

    @property
    def partitions(self) -> int:
        return self.partition.partitions

    @property
    def partition_level(self) -> Optional[int]:
        return self.partition.partition_level

    @property
    def partition_sync(self) -> str:
        return self.partition.partition_sync

    @property
    def beam_cache(self) -> int:
        return self.partition.beam_cache

    @property
    def degraded_policy(self) -> str:
        return self.fleet.degraded_policy
