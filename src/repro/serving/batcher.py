"""Async micro-batching front-end for the XMR serving engine.

Production online serving (the paper's §3.2 "online" setting under real
traffic) is not one query at a time: a real-time batcher sits in front of
the tree scorer and coalesces in-flight requests so device dispatch overhead
is amortized — the same economics as the paper's batch-parallelism study
(Fig. 6). This module provides that front-end:

* :class:`RequestQueue` — thread-safe queue with the two classic coalescing
  triggers: **size** (``max_batch`` requests waiting) and **deadline** (the
  oldest request has waited ``max_wait_ms``).
* :class:`MicroBatcher` — a worker thread that drains the queue, marshals
  each micro-batch through the vectorized CSR→ELL path into the engine's
  power-of-two jit buckets, and resolves per-request futures. Dispatch is
  double-buffered: because JAX dispatch is asynchronous, batch *i+1* is
  marshalled on the host while the device executes batch *i*.

Results are bitwise-identical to per-query serving: bucket padding rows are
empty sentinel queries and the padded tail is sliced off before futures
resolve (pinned by tests/test_serving.py).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.serving.engine import XMRServingEngine
from repro.serving.metrics import ServerMetrics
from repro.sparse.csr import CSR

TRIGGER_SIZE = "size"
TRIGGER_DEADLINE = "deadline"
TRIGGER_FLUSH = "flush"


@dataclasses.dataclass
class BatchPolicy:
    """Coalescing policy: dispatch when either trigger fires."""

    max_batch: int = 16       # size trigger
    max_wait_ms: float = 2.0  # deadline trigger (oldest request's max wait)


@dataclasses.dataclass
class _Request:
    idx: np.ndarray           # sorted feature ids, int32
    val: np.ndarray           # float32 values
    future: Future
    t_enqueue: float


class RequestQueue:
    """Thread-safe request queue with size/deadline batch formation."""

    def __init__(self) -> None:
        self._q: deque[_Request] = deque()
        self._cond = threading.Condition()
        self._closed = False

    def __len__(self) -> int:
        with self._cond:
            return len(self._q)

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def put(self, req: _Request) -> None:
        with self._cond:
            if self._closed:
                raise RuntimeError("RequestQueue is closed")
            self._q.append(req)
            self._cond.notify_all()

    def close(self) -> None:
        """No further puts; pending requests are still drained."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def _pop(self, k: int) -> List[_Request]:
        out = []
        while self._q and len(out) < k:
            out.append(self._q.popleft())
        return out

    def next_batch(
        self, max_batch: int, max_wait_s: float, *, block: bool = True
    ) -> Tuple[Optional[List[_Request]], str]:
        """Form the next micro-batch.

        Returns ``(requests, trigger)``. ``(None, "")`` means closed and
        drained. With ``block=False``, returns ``([], "")`` immediately when
        no trigger has fired yet (used by the double-buffered worker to
        overlap marshalling with device compute).
        """
        with self._cond:
            while True:
                if self._q:
                    if len(self._q) >= max_batch:
                        return self._pop(max_batch), TRIGGER_SIZE
                    if self._closed:
                        return self._pop(max_batch), TRIGGER_FLUSH
                    deadline = self._q[0].t_enqueue + max_wait_s
                    now = time.perf_counter()
                    if now >= deadline:
                        return self._pop(max_batch), TRIGGER_DEADLINE
                    if not block:
                        return [], ""
                    self._cond.wait(timeout=deadline - now)
                else:
                    if self._closed:
                        return None, ""
                    if not block:
                        return [], ""
                    self._cond.wait(timeout=0.1)


@dataclasses.dataclass
class _InFlight:
    reqs: List[_Request]
    scores: jax.Array
    labels: jax.Array
    t_dequeue: float
    bucket: int
    trigger: str


class MicroBatcher:
    """Coalescing async server over an :class:`XMRServingEngine`.

    Usage::

        with MicroBatcher(engine, BatchPolicy(max_batch=16)) as mb:
            futs = [mb.submit(idx, val) for idx, val in requests]
            results = [f.result() for f in futs]   # (scores, labels) each
    """

    def __init__(
        self,
        engine: XMRServingEngine,
        policy: BatchPolicy | None = None,
        metrics: ServerMetrics | None = None,
    ) -> None:
        self.engine = engine
        self.policy = policy or BatchPolicy()
        if self.policy.max_batch > engine.config.max_batch:
            raise ValueError(
                f"policy.max_batch={self.policy.max_batch} exceeds engine "
                f"max_batch={engine.config.max_batch}"
            )
        self.metrics = metrics or ServerMetrics()
        self.queue = RequestQueue()
        self._thread: threading.Thread | None = None

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "MicroBatcher":
        if self._thread is not None:
            raise RuntimeError("MicroBatcher already started")
        if self.queue.closed:
            raise RuntimeError("MicroBatcher cannot be restarted after stop()")
        self._thread = threading.Thread(
            target=self._worker, name="xmr-microbatcher", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop accepting requests, drain the queue, join the worker."""
        self.queue.close()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client API ---------------------------------------------------------
    def submit(self, idx: np.ndarray, val: np.ndarray) -> Future:
        """Enqueue one sparse query; resolves to (scores [k], labels [k])."""
        fut: Future = Future()
        self.queue.put(
            _Request(
                idx=np.asarray(idx, np.int32),
                val=np.asarray(val, np.float32),
                future=fut,
                t_enqueue=time.perf_counter(),
            )
        )
        return fut

    def submit_csr(self, queries: CSR) -> List[Future]:
        return [self.submit(*queries.row(i)) for i in range(queries.shape[0])]

    # -- worker -------------------------------------------------------------
    def _dispatch(self, reqs: List[_Request], trigger: str) -> _InFlight:
        t_dequeue = time.perf_counter()
        d = self.engine.tree.d
        sub = CSR.from_rows(
            [r.idx for r in reqs], [r.val for r in reqs], (len(reqs), d)
        )
        bucket = self.engine.bucket_for(len(reqs))
        xi, xv = self.engine.marshal_rows(sub, np.arange(len(reqs)), bucket)
        s, l = self.engine._run(xi, xv)  # async dispatch — do not block here
        return _InFlight(reqs, s, l, t_dequeue, bucket, trigger)

    def _finalize(self, inflight: _InFlight) -> None:
        jax.block_until_ready((inflight.scores, inflight.labels))
        t_done = time.perf_counter()
        s = np.asarray(inflight.scores)
        l = self.engine._map_labels(np.asarray(inflight.labels))
        for i, req in enumerate(inflight.reqs):
            req.future.set_result((s[i], l[i]))
        self.metrics.record_batch(
            t_enqueue=[r.t_enqueue for r in inflight.reqs],
            t_dequeue=inflight.t_dequeue,
            t_done=t_done,
            bucket=inflight.bucket,
            trigger=inflight.trigger,
        )

    def _fail(self, reqs: List[_Request], exc: BaseException) -> None:
        for r in reqs:
            if not r.future.done():
                r.future.set_exception(exc)

    def _worker(self) -> None:
        p = self.policy
        wait_s = 1e-3 * p.max_wait_ms
        pending: _InFlight | None = None
        while True:
            if pending is None:
                reqs, trigger = self.queue.next_batch(p.max_batch, wait_s)
                if reqs is None:
                    break
                try:
                    pending = self._dispatch(reqs, trigger)
                except BaseException as exc:  # noqa: BLE001 — fail the batch, keep serving
                    self._fail(reqs, exc)
            else:
                reqs, trigger = self.queue.next_batch(
                    p.max_batch, wait_s, block=False
                )
                nxt = None
                if reqs:
                    try:
                        nxt = self._dispatch(reqs, trigger)
                    except BaseException as exc:  # noqa: BLE001
                        self._fail(reqs, exc)
                try:
                    self._finalize(pending)
                except BaseException as exc:  # noqa: BLE001
                    self._fail(pending.reqs, exc)
                pending = nxt
        if pending is not None:
            try:
                self._finalize(pending)
            except BaseException as exc:  # noqa: BLE001
                self._fail(pending.reqs, exc)
