"""Async micro-batching front-end for the XMR serving engine.

Production online serving (the paper's §3.2 "online" setting under real
traffic) is not one query at a time: a real-time batcher sits in front of
the tree scorer and coalesces in-flight requests so device dispatch overhead
is amortized — the same economics as the paper's batch-parallelism study
(Fig. 6). This module provides that front-end:

* :class:`RequestQueue` — thread-safe queue with the two classic coalescing
  triggers: **size** (``max_batch`` requests waiting) and **deadline** (the
  oldest request has waited ``max_wait_ms``), gated by an optional
  :class:`~repro.serving.admission.AdmissionController` so queue depth stays
  bounded under overload.
* :class:`MicroBatcher` — a worker thread that drains the queue, marshals
  each micro-batch through the vectorized CSR→ELL path into the engine's
  power-of-two jit buckets, and resolves per-request futures. Dispatch is
  double-buffered: because JAX dispatch is asynchronous, batch *i+1* is
  marshalled on the host while the device executes batch *i* — and a batch
  whose trigger fires while batch *i* is still on the device is dispatched
  *before* the worker blocks on batch *i*'s results.

Results are bitwise-identical to per-query serving: bucket padding rows are
empty sentinel queries and the padded tail is sliced off before futures
resolve (pinned by tests/test_serving.py). Overload semantics (bounded
queue, shed policies, per-request deadlines) live in
:mod:`repro.serving.admission`; requests shed or expired resolve their
futures with typed errors and never reach the device.
"""

from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Iterable, Iterator, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.serving.admission import AdmissionController, AdmissionPolicy
from repro.serving.api import Query, QueryResult
from repro.serving.engine import XMRServingEngine
from repro.serving.metrics import ServerMetrics
from repro.serving.slo import BeamTierPolicy
from repro.sparse.csr import CSR

TRIGGER_SIZE = "size"
TRIGGER_DEADLINE = "deadline"
TRIGGER_FLUSH = "flush"

# Spin interval while waiting for either a coalescing trigger or the
# in-flight batch's device results, whichever comes first.
_POLL_S = 5e-5


@dataclasses.dataclass
class BatchPolicy:
    """Coalescing policy: dispatch when either trigger fires."""

    max_batch: int = 16       # size trigger
    max_wait_ms: float = 2.0  # deadline trigger (oldest request's max wait)


@dataclasses.dataclass
class _Request:
    idx: np.ndarray           # sorted feature ids, int32
    val: np.ndarray           # float32 values
    future: Future
    t_enqueue: float
    t_deadline: Optional[float] = None  # absolute perf_counter deadline
    priority: int = 0         # higher = more important (weighted shedding)


class RequestQueue:
    """Thread-safe request queue with size/deadline batch formation.

    With an :class:`AdmissionController`, ``put`` applies the shed policy
    under the queue lock (depth check atomic with the append); a shed
    request's future resolves with ``Overloaded`` instead of enqueueing.
    """

    def __init__(self, admission: AdmissionController | None = None) -> None:
        self._q: deque[_Request] = deque()  # guarded-by: _cond
        self._cond = threading.Condition()
        self._closed = False
        self._admission = admission

    def __len__(self) -> int:
        with self._cond:
            return len(self._q)

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def put(self, req: _Request) -> None:
        with self._cond:
            if self._closed:
                raise RuntimeError("RequestQueue is closed")
            if self._admission is not None and not self._admission.admit(
                self._q, req
            ):
                return  # shed: future already holds Overloaded
            self._q.append(req)
            self._cond.notify_all()

    def close(self) -> None:
        """No further puts; pending requests are still drained."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def _pop(self, k: int) -> List[_Request]:  # xmrlint: requires-lock=_cond
        out = []
        while self._q and len(out) < k:
            out.append(self._q.popleft())
        return out

    def next_batch(
        self, max_batch: int, max_wait_s: float, *, block: bool = True
    ) -> Tuple[Optional[List[_Request]], str]:
        """Form the next micro-batch.

        Returns ``(requests, trigger)``. ``(None, "")`` means closed and
        drained. With ``block=False``, returns ``([], "")`` immediately when
        no trigger has fired yet (used by the double-buffered worker to
        overlap marshalling with device compute).
        """
        with self._cond:
            while True:
                if self._q:
                    if len(self._q) >= max_batch:
                        return self._pop(max_batch), TRIGGER_SIZE
                    if self._closed:
                        return self._pop(max_batch), TRIGGER_FLUSH
                    deadline = self._q[0].t_enqueue + max_wait_s
                    now = time.perf_counter()
                    if now >= deadline:
                        return self._pop(max_batch), TRIGGER_DEADLINE
                    if not block:
                        return [], ""
                    self._cond.wait(timeout=deadline - now)
                else:
                    if self._closed:
                        return None, ""
                    if not block:
                        return [], ""
                    self._cond.wait(timeout=0.1)


@dataclasses.dataclass
class _InFlight:
    reqs: List[_Request]
    scores: jax.Array
    labels: jax.Array
    t_dequeue: float
    bucket: int
    trigger: str
    # Snapshot of engine.last_degraded() taken at dispatch: the planner's
    # attribute is per-dispatch mutable state, and double-buffering means
    # the *next* batch dispatches before this one finalizes.
    degraded: Optional[dict] = None
    # Beam tier this batch was dispatched at (0 = full beam).
    tier: int = 0


def _device_ready(inflight: _InFlight) -> bool:
    """True when the in-flight batch's device results are ready.

    Falls back to True (immediate, blocking finalize — the old behavior)
    on jax versions whose arrays lack ``is_ready``.
    """
    try:
        return bool(inflight.scores.is_ready() and inflight.labels.is_ready())
    except AttributeError:
        return True


# ``stream`` used to yield an ad-hoc (index, scores, labels, error) tuple
# type; the v1 surface yields :class:`~repro.serving.api.QueryResult`, whose
# ``index``/``labels`` properties alias ``qid``/``ids`` so pre-v1 consumers
# keep working. The old name stays importable.
StreamResult = QueryResult


class MicroBatcher:
    """Coalescing async server over an :class:`XMRServingEngine`.

    Usage::

        with MicroBatcher(engine, BatchPolicy(max_batch=16)) as mb:
            futs = [mb.submit(idx, val) for idx, val in requests]
            results = [f.result() for f in futs]   # (scores, labels) each

    Overload policy comes from ``admission`` (or, by default, the engine's
    ``ServeConfig`` queue-depth/shed/deadline knobs); ``start()`` warms every
    jit bucket the policy can form so the first live batch never pays an XLA
    compile inside its latency budget (``warmup_on_start=False`` opts out).
    """

    def __init__(
        self,
        engine: XMRServingEngine,
        policy: BatchPolicy | None = None,
        metrics: ServerMetrics | None = None,
        admission: AdmissionPolicy | None = None,
        *,
        warmup_on_start: bool = True,
    ) -> None:
        self.engine = engine
        self.policy = policy or BatchPolicy()
        if self.policy.max_batch > engine.config.max_batch:
            raise ValueError(
                f"policy.max_batch={self.policy.max_batch} exceeds engine "
                f"max_batch={engine.config.max_batch}"
            )
        self.metrics = metrics or ServerMetrics()
        adm = engine.config.admission
        self.admission = admission or AdmissionPolicy(
            max_queue_depth=adm.queue_depth,
            shed_policy=adm.shed_policy,
            deadline_ms=adm.deadline_ms,
        )
        self._controller = AdmissionController(self.admission, self.metrics)
        self.queue = RequestQueue(self._controller)
        self.warmup_on_start = warmup_on_start
        self._thread: threading.Thread | None = None
        #: Adaptive beam-tier selector; built + calibrated by ``start()``
        #: when the engine has an SLO ladder, else None (always tier 0).
        self.tier_policy: Optional[BeamTierPolicy] = None
        # Serializes start()/stop(): stop() during start()'s warmup or
        # auto-depth/tier probes must wait for the probe to finish (never
        # close the queue under a half-measured bucket) and must observe
        # the started thread to join it — not race past a None _thread.
        self._lifecycle = threading.Lock()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "MicroBatcher":
        with self._lifecycle:
            if self._thread is not None:
                raise RuntimeError("MicroBatcher already started")
            if self.queue.closed:
                raise RuntimeError(
                    "MicroBatcher cannot be restarted after stop()"
                )
            if self.warmup_on_start:
                self.engine.warmup_buckets(
                    self.engine.tree.d, self.policy.max_batch
                )
            if len(self.engine.tiers) > 1:
                # Calibrate the tier ladder with the same drain-rate probe
                # auto queue depth uses, one run per tier (which also warms
                # each tier's jit bucket before live traffic can pick it).
                self.tier_policy = BeamTierPolicy(
                    self.engine.tiers,
                    target_ms=float(self.engine.config.slo.target_p99_ms),
                    bucket=self.engine.bucket_for(self.policy.max_batch),
                ).calibrate(self._probe_cost_ms)
            if self.admission.max_queue_depth == "auto":
                self.admission.max_queue_depth = self._auto_queue_depth()
            self._thread = threading.Thread(
                target=self._worker, name="xmr-microbatcher", daemon=True
            )
            self._thread.start()
        return self

    def _probe_cost_ms(self, tier: int = 0) -> float:
        """Measured wall ms to serve one full coalescing bucket at ``tier``.

        The shared drain-rate probe: ``queue_depth="auto"`` divides the
        bucket by it for the admission bound, and the
        :class:`~repro.serving.slo.BeamTierPolicy` runs it once per tier
        for its cost model — one measurement path, two consumers.
        """
        return 1e3 * self.engine.measure_batch_seconds(
            self.policy.max_batch, tier=tier
        )

    def _auto_queue_depth(self) -> int:
        """Capacity-aware admission bound: measured drain rate x deadline.

        Probes the device-side service time of one full coalescing bucket
        (buckets are warm by now — ``measure_batch_seconds`` re-warms if
        not) and bounds the queue at the number of requests the device can
        clear within the latency budget: the policy deadline when one is
        set, else ten deadline-trigger windows (a queue deeper than that
        cannot meet the coalescing latency the policy encodes). Never below
        ``max_batch`` so a full bucket can always form.
        """
        secs = 1e-3 * self._probe_cost_ms()
        bucket = self.engine.bucket_for(self.policy.max_batch)
        drain_qps = bucket / max(secs, 1e-9)
        budget_ms = self.admission.deadline_ms
        if budget_ms is None:
            budget_ms = 10.0 * self.policy.max_wait_ms
        return max(
            self.policy.max_batch, int(np.ceil(drain_qps * budget_ms * 1e-3))
        )

    def stop(self) -> None:
        """Stop accepting requests, drain the queue, join the worker.

        Safe to call concurrently with :meth:`start`: the lifecycle lock
        makes stop wait for start's warmup/probe sequence to complete, so
        the queue can never close under an in-flight probe and the freshly
        started worker is always observed and joined.
        """
        with self._lifecycle:
            self.queue.close()
            if self._thread is not None:
                self._thread.join()
                self._thread = None

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client API ---------------------------------------------------------
    def submit(
        self,
        idx: Union[np.ndarray, Query],
        val: Optional[np.ndarray] = None,
        *,
        deadline_ms: Optional[float] = None,
        priority: int = 0,
    ) -> Future:
        """Enqueue one sparse query.

        Two call forms:

        * ``submit(Query(...))`` — the v1 form. Resolves to a
          :class:`~repro.serving.api.QueryResult` and **never raises**:
          shed/expired/failed requests come back with the typed failure
          encoded in ``result.status`` (and the exception on
          ``result.error``), plus end-to-end wall time in
          ``result.timing["e2e_ms"]``. This is the currency the gateway
          serves over HTTP.
        * ``submit(idx, val)`` — the legacy form. Resolves to a
          ``(scores [k], labels [k])`` tuple; failures resolve the future
          with the typed exception (``future.result()`` raises).

        Always returns a Future — a request shed by admission control comes
        back already resolved. ``deadline_ms`` overrides the policy's
        default per-request deadline; ``priority`` (higher = more
        important) steers weighted shedding under the ``shed-oldest``
        policy: low-priority requests are sacrificed first.
        """
        if isinstance(idx, Query):
            if val is not None:
                raise TypeError("submit(Query) takes no positional val")
            q = idx
            t0 = time.perf_counter()
            inner = self._submit_arrays(
                q.idx, q.val,
                deadline_ms=q.deadline_ms if deadline_ms is None else deadline_ms,
                priority=q.priority or priority,
            )
            out: Future = Future()

            def _wrap(f: Future, qid: int = q.qid) -> None:
                timing = {"e2e_ms": 1e3 * (time.perf_counter() - t0)}
                exc = f.exception()
                if exc is not None:
                    out.set_result(QueryResult.from_error(qid, exc, timing))
                else:
                    s, l = f.result()
                    info = getattr(f, "degraded_info", None)
                    out.set_result(QueryResult(
                        qid=qid, ids=l, scores=s, timing=timing,
                        degraded=info is not None,
                        missing_labels=(
                            list(info["label_ranges"]) if info else []
                        ),
                        beam_tier=getattr(f, "beam_tier", 0),
                    ))

            inner.add_done_callback(_wrap)
            return out
        return self._submit_arrays(
            idx, val, deadline_ms=deadline_ms, priority=priority
        )

    def _submit_arrays(
        self,
        idx: np.ndarray,
        val: np.ndarray,
        *,
        deadline_ms: Optional[float],
        priority: int,
    ) -> Future:
        self.metrics.record_offered()
        t_enqueue = time.perf_counter()
        req = _Request(
            idx=np.asarray(idx, np.int32),
            val=np.asarray(val, np.float32),
            future=Future(),
            t_enqueue=t_enqueue,
            t_deadline=(
                t_enqueue + 1e-3 * deadline_ms if deadline_ms is not None else None
            ),
            priority=priority,
        )
        self._controller.stamp_deadline(req)
        self.queue.put(req)
        return req.future

    def submit_csr(self, queries: CSR) -> List[Future]:
        return [self.submit(*queries.row(i)) for i in range(queries.shape[0])]

    def stream(
        self,
        queries: Union[CSR, Iterable[Tuple[np.ndarray, np.ndarray]]],
        *,
        deadline_ms: Optional[float] = None,
    ) -> Iterator[QueryResult]:
        """Submit all queries, yield :class:`QueryResult` in completion order.

        Each result's ``qid`` is its submission index. Completion order is
        whatever the coalescing worker produces — early batches stream back
        while later queries are still queued, and shed / expired requests
        surface immediately as error-status results (``result.ok`` False,
        ``result.error`` holding the typed exception) instead of blocking
        the stream behind slower successes.
        """
        if isinstance(queries, CSR):
            pairs = (queries.row(i) for i in range(queries.shape[0]))
        else:
            pairs = iter(queries)
        done: queue_mod.Queue = queue_mod.Queue()
        n = 0
        for i, (idx, val) in enumerate(pairs):
            fut = self.submit(
                Query(idx=idx, val=val, qid=i, deadline_ms=deadline_ms)
            )
            fut.add_done_callback(lambda f: done.put(f))
            n += 1
        for _ in range(n):
            yield done.get().result()

    # -- worker -------------------------------------------------------------
    def _select_tier(self, reqs: List[_Request], t_dequeue: float) -> int:
        """Beam tier for a batch formed now (0 without an SLO ladder).

        The budget is the SLO target minus the oldest request's queue wait,
        tightened by the earliest per-request deadline when any is set —
        the batch must finish within whichever is sooner.
        """
        if self.tier_policy is None:
            return 0
        budget = self.tier_policy.target_ms - 1e3 * (
            t_dequeue - min(r.t_enqueue for r in reqs)
        )
        deadlines = [r.t_deadline for r in reqs if r.t_deadline is not None]
        if deadlines:
            budget = min(budget, 1e3 * (min(deadlines) - t_dequeue))
        return self.tier_policy.select(
            queue_depth=len(self.queue), budget_ms=budget
        )

    def _dispatch(self, reqs: List[_Request], trigger: str) -> _InFlight:
        t_dequeue = time.perf_counter()
        tier = self._select_tier(reqs, t_dequeue)
        d = self.engine.tree.d
        sub = CSR.from_rows(
            [r.idx for r in reqs], [r.val for r in reqs], (len(reqs), d)
        )
        bucket = self.engine.bucket_for(len(reqs))
        xi, xv = self.engine.marshal_rows(sub, np.arange(len(reqs)), bucket)
        # async dispatch — do not block here
        s, l = self.engine._run(xi, xv, tier=tier)
        return _InFlight(
            reqs, s, l, t_dequeue, bucket, trigger,
            degraded=self.engine.last_degraded(),
            tier=tier,
        )

    def _try_dispatch(
        self, reqs: List[_Request], trigger: str
    ) -> Optional[_InFlight]:
        """Expire dead requests, dispatch the survivors, fail on error.

        Deadline checks happen here — at dispatch, not enqueue — so an
        expired request never burns device time, and returns None when the
        whole batch expired (nothing to dispatch).
        """
        live = self._controller.expire(reqs)
        if not live:
            return None
        try:
            return self._dispatch(live, trigger)
        except BaseException as exc:  # noqa: BLE001 — fail the batch, keep serving
            self._fail(live, exc)
            return None

    def _finalize(self, inflight: _InFlight) -> None:
        # For partitioned dispatch, the blocked wall below is the pipeline
        # stall: host dispatch already returned, so everything the worker
        # waits on here is device time the scatter-gather exchange failed
        # to overlap (the figure sync="pipelined" exists to shrink).
        t_wait = time.perf_counter()
        jax.block_until_ready((inflight.scores, inflight.labels))
        t_done = time.perf_counter()
        partitioned = self.engine.planner is not None
        s = np.asarray(inflight.scores)
        leaves = np.asarray(inflight.labels)
        l = self.engine._map_labels(leaves)
        for i, req in enumerate(inflight.reqs):
            if inflight.degraded is not None:
                # Attribute channel to the v1 wrapper: set before
                # set_result because done-callbacks fire synchronously.
                req.future.degraded_info = inflight.degraded
            if inflight.tier:
                req.future.beam_tier = inflight.tier
            req.future.set_result((s[i], l[i]))
        if inflight.degraded is not None:
            self.metrics.record_degraded(len(inflight.reqs))
        # Partition occupancy uses raw leaves (pre-label_perm) and only the
        # real rows — bucket padding tails are sentinel junk.
        hits = self.engine.partition_hit_counts(leaves[: len(inflight.reqs)])
        self.metrics.record_batch(
            t_enqueue=[r.t_enqueue for r in inflight.reqs],
            t_dequeue=inflight.t_dequeue,
            t_done=t_done,
            bucket=inflight.bucket,
            trigger=inflight.trigger,
            shards=self.engine.config.shards,
            partition_hits=hits,
            stall_ms=1e3 * (t_done - t_wait) if partitioned else None,
            cache_stats=self.engine.beam_cache_stats(),
            tier=inflight.tier,
        )

    def _fail(self, reqs: List[_Request], exc: BaseException) -> None:
        for r in reqs:
            if not r.future.done():
                r.future.set_exception(exc)

    def _poll_ready(
        self, pending: _InFlight, wait_s: float
    ) -> Tuple[Optional[List[_Request]], str]:
        """Wait for a trigger OR the in-flight results, whichever first.

        Returns a formed batch (trigger fired / closed-flush) the moment it
        is ready so it can be dispatched *before* the worker blocks on
        ``pending`` — otherwise a deadline-triggered batch would wait a full
        extra device-batch time behind ``_finalize``. Returns ``([], "")``
        once ``pending``'s device results are ready with no trigger fired.
        """
        p = self.policy
        while True:
            reqs, trigger = self.queue.next_batch(
                p.max_batch, wait_s, block=False
            )
            if reqs is None or reqs:
                return reqs, trigger
            if _device_ready(pending):
                return [], ""
            time.sleep(_POLL_S)

    def _worker(self) -> None:
        p = self.policy
        wait_s = 1e-3 * p.max_wait_ms
        pending: _InFlight | None = None
        while True:
            if pending is None:
                reqs, trigger = self.queue.next_batch(p.max_batch, wait_s)
                if reqs is None:
                    break
                pending = self._try_dispatch(reqs, trigger)
            else:
                reqs, trigger = self._poll_ready(pending, wait_s)
                # Double-buffer: the ready batch goes on the device first;
                # only then block on the previous batch's results.
                nxt = self._try_dispatch(reqs, trigger) if reqs else None
                try:
                    self._finalize(pending)
                except BaseException as exc:  # noqa: BLE001
                    self._fail(pending.reqs, exc)
                pending = nxt
        if pending is not None:
            try:
                self._finalize(pending)
            except BaseException as exc:  # noqa: BLE001
                self._fail(pending.reqs, exc)
