"""Launch + drive a fleet of partition worker processes.

:func:`launch_workers` generalizes the subprocess-mesh pattern from the
test suite into a reusable launcher: each worker is a real OS process
(``python -m repro.serving.fleet.worker``) with its own JAX runtime, bound
to an ephemeral localhost port it announces on stdout. On multi-host
deployments the same :class:`PartitionFleet` client drives workers started
out-of-band — pass ``(host, port)`` pairs to :meth:`PartitionFleet.connect`.

:class:`PartitionFleet` implements the planner's
:class:`~repro.index.planner.BeamTransport` protocol: ``load`` ships each
partition's sliced layer tensors to its worker once, and ``begin``/``step``
exchange only the tiny per-level ``[n, w]`` beams. Requests are fanned out
to every worker *before* any reply is collected, so the P workers compute
concurrently. Any dead or hung worker surfaces as the typed
:class:`~repro.serving.admission.WorkerUnavailable` (per-call socket
timeouts — never a hang), which the batcher turns into failed futures and
the gateway maps to HTTP 503.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.index.partition import PartitionedIndex
from repro.index.planner import BeamTransport
from repro.serving.admission import WorkerUnavailable
from repro.serving.fleet.rpc import WorkerConnection


class WorkerHandle:
    """One fleet worker: the process (when launched locally) + connection."""

    def __init__(
        self,
        conn: WorkerConnection,
        proc: Optional[subprocess.Popen] = None,
        name: Optional[str] = None,
    ) -> None:
        self.conn = conn
        self.proc = proc
        self.name = name or conn.name

    def alive(self) -> bool:
        return self.proc is None or self.proc.poll() is None

    def kill(self) -> None:
        """Hard-kill the worker process (fault-injection / teardown)."""
        if self.proc is not None and self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait(timeout=30)
        self.conn.close()


def _read_announce(proc: subprocess.Popen, timeout_s: float, name: str) -> dict:
    """Read the worker's one-line JSON announcement with a hard timeout."""
    out: List[str] = []

    def _read() -> None:
        out.append(proc.stdout.readline())

    t = threading.Thread(target=_read, daemon=True)
    t.start()
    t.join(timeout_s)
    if t.is_alive() or not out or not out[0].strip():
        proc.kill()
        raise WorkerUnavailable(
            name, "launch",
            f"no announcement within {timeout_s:.0f}s "
            f"(exit code {proc.poll()})",
        )
    return json.loads(out[0])


def launch_workers(
    n: int,
    *,
    host: str = "127.0.0.1",
    env: Optional[dict] = None,
    startup_timeout_s: float = 120.0,
    rpc_timeout_s: float = 120.0,
) -> List[WorkerHandle]:
    """Spawn ``n`` local worker processes and connect to each.

    The child environment inherits the parent's (so ``JAX_PLATFORMS``,
    ``MSCM_FORCE_INTERPRET`` etc. propagate) with the directory containing
    the ``repro`` package prepended to ``PYTHONPATH`` — workers import the
    same code the parent runs, whatever the parent's install mode.
    """
    import repro

    pkg_root = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    child_env = dict(os.environ if env is None else env)
    prev = child_env.get("PYTHONPATH", "")
    child_env["PYTHONPATH"] = (
        pkg_root + (os.pathsep + prev if prev else "")
    )
    procs: List[subprocess.Popen] = []
    handles: List[WorkerHandle] = []
    try:
        for _ in range(n):
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.serving.fleet.worker",
                 "--host", host, "--port", "0"],
                stdout=subprocess.PIPE, text=True, env=child_env,
            ))
        for pid, proc in enumerate(procs):
            name = f"worker{pid}"
            ann = _read_announce(proc, startup_timeout_s, name)
            conn = WorkerConnection(
                host, int(ann["port"]), timeout_s=rpc_timeout_s, name=name
            )
            handles.append(WorkerHandle(conn, proc, name))
    except BaseException:
        # Reap EVERY spawned process, including those not yet wrapped in a
        # WorkerHandle — a failure at worker i must not orphan i..n-1 as
        # live JAX processes bound to ports. handles[j] wraps procs[j], so
        # the unwrapped tail is exactly procs[len(handles):].
        for h in handles:
            try:
                h.kill()
            except Exception:
                pass
        tail = procs[len(handles):]
        for proc in tail:
            if proc.poll() is None:
                proc.kill()
        for proc in tail:
            try:
                proc.wait(timeout=30)
            except Exception:
                pass
        raise
    return handles


class PartitionFleet(BeamTransport):
    """Cross-process partition workers behind the planner's transport API."""

    def __init__(self, handles: Sequence[WorkerHandle]) -> None:
        if not handles:
            raise ValueError("a fleet needs at least one worker")
        self.handles = list(handles)
        self._closed = False

    # -- construction -------------------------------------------------------
    @classmethod
    def launch(
        cls,
        n: int,
        *,
        host: str = "127.0.0.1",
        env: Optional[dict] = None,
        startup_timeout_s: float = 120.0,
        rpc_timeout_s: float = 120.0,
    ) -> "PartitionFleet":
        """Spawn ``n`` local worker processes (one per partition)."""
        return cls(launch_workers(
            n, host=host, env=env,
            startup_timeout_s=startup_timeout_s, rpc_timeout_s=rpc_timeout_s,
        ))

    @classmethod
    def connect(
        cls,
        addresses: Sequence[Tuple[str, int]],
        *,
        rpc_timeout_s: float = 120.0,
    ) -> "PartitionFleet":
        """Attach to already-running workers (the multi-host deployment)."""
        return cls([
            WorkerHandle(WorkerConnection(
                h, p, timeout_s=rpc_timeout_s, name=f"worker{i}@{h}:{p}"
            ))
            for i, (h, p) in enumerate(addresses)
        ])

    # -- BeamTransport ------------------------------------------------------
    @property
    def n_partitions(self) -> int:
        return len(self.handles)

    def _reset_connections(self) -> None:
        """Poison recovery: give every worker a fresh, in-sync stream.

        After an abandoned exchange, replies from the still-healthy workers
        may sit buffered on their sockets; the next call's recv would
        consume one as its own (identical ``[n, w]`` shapes — silently
        wrong results, not an error). Reconnecting drops those streams;
        workers keep their loaded partition across client connections. A
        dead worker's connection stays closed and surfaces as the typed
        ``WorkerUnavailable`` on next use.
        """
        for h in self.handles:
            try:
                h.conn.reconnect()
            except WorkerUnavailable:
                pass

    def _exchange(
        self, op: str, headers: Sequence[dict],
        arrays: Sequence[Sequence[np.ndarray]],
    ) -> List[Tuple[dict, List[np.ndarray]]]:
        """Locked fan-out: send to every worker first, then collect replies.

        Sends complete before any recv so the P workers overlap; replies
        are collected in partition order (the merge is order-independent,
        but determinism keeps debugging sane). Every connection's lock is
        held for the whole exchange so a concurrent health-check ping
        cannot interleave frames with the beam protocol. If any send/recv
        fails, the in-flight exchange is abandoned and every connection is
        reset before the error propagates — undrained replies must never
        be consumed by the next request.
        """
        for h in self.handles:
            h.conn.lock.acquire()
        try:
            try:
                for h, hd, arr in zip(self.handles, headers, arrays):
                    h.conn.send(op, hd, arr)
                return [h.conn.recv(op) for h in self.handles]
            except BaseException:
                self._reset_connections()
                raise
        finally:
            for h in self.handles:
                h.conn.lock.release()

    def _fanout(
        self, op: str, headers: Sequence[dict],
        arrays: Sequence[Sequence[np.ndarray]],
    ) -> List[Tuple[np.ndarray, np.ndarray]]:
        return [
            (reply[0], reply[1])
            for _, reply in self._exchange(op, headers, arrays)
        ]

    def begin(self, x_idx, x_val, parent_ids, scores):
        n = self.n_partitions
        return self._fanout(
            "begin", [{}] * n, [[x_idx, x_val, parent_ids, scores]] * n
        )

    def step(self, level, winner_ids):
        n = self.n_partitions
        return self._fanout("step", [{"level": int(level)}] * n,
                            [[winner_ids]] * n)

    # -- loading / attaching ------------------------------------------------
    def load(
        self,
        index: PartitionedIndex,
        *,
        beam: int,
        topk: int,
        method: str,
        score_mode: str = "prod",
        qt: int = 8,
    ) -> None:
        """Ship each partition's sliced layers + metadata to its worker."""
        if index.n_partitions != self.n_partitions:
            raise ValueError(
                f"index has {index.n_partitions} partitions, fleet has "
                f"{self.n_partitions} workers"
            )
        headers = []
        arrays = []
        for part, info in zip(index.parts, index.manifest.partitions):
            headers.append({
                "pid": info.pid,
                "level": index.level,
                "n_cols": list(index.n_cols),
                "branching": list(index.branching),
                "d": index.d,
                "chunk_start": info.chunk_start,
                "beam": beam, "topk": topk, "method": method,
                "score_mode": score_mode, "qt": qt,
                "part_n_cols": list(part.n_cols),
            })
            arrays.append([
                np.asarray(t)
                for lay in part.layers
                for t in (lay.chunk_rows, lay.chunk_vals,
                          lay.col_rows, lay.col_vals)
            ])
        self._exchange("load", headers, arrays)

    def attach(self, engine) -> "PartitionFleet":
        """Serve ``engine``'s partitions from this fleet's workers.

        The engine must be partitioned with ``partition_sync="pipelined"``
        (the only exchange the transport protocol covers) and no hot-beam
        cache. Ships the partitions, then routes the planner's per-level
        partition work through this fleet — the coordinator keeps only the
        router head and the tiny beam merges.
        """
        if engine.planner is None:
            raise ValueError("engine is unpartitioned; nothing to serve remotely")
        c = engine.config
        engine.planner.set_transport(self)
        self.load(
            engine.index,
            beam=c.beam, topk=c.topk, method=engine.method,
            score_mode=c.score_mode, qt=c.qt,
        )
        engine.fleet = self
        return self

    # -- health / lifecycle -------------------------------------------------
    def ping(self, timeout_s: float = 5.0) -> Dict[str, bool]:
        """Per-worker liveness: one bounded RPC each, False on any failure.

        Safe to call concurrently with query traffic: ``call`` holds the
        per-connection lock across its send+recv pair, so a ping can wait
        behind an in-flight exchange but never interleave with it. A
        failed ping closes the (now desynced) stream; a best-effort
        reconnect repairs it so one slow probe does not take a live
        worker out of rotation.
        """
        out = {}
        for h in self.handles:
            try:
                h.conn.call("ping", timeout_s=min(timeout_s, h.conn.timeout_s))
                out[h.name] = True
            except (WorkerUnavailable, RuntimeError):
                out[h.name] = False
                try:
                    h.conn.reconnect()
                except WorkerUnavailable:
                    pass
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for h in self.handles:
            try:
                h.conn.call("shutdown")
            except (WorkerUnavailable, RuntimeError):
                pass
            h.kill()

    def __enter__(self) -> "PartitionFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
