"""Launch + drive a fleet of partition worker processes.

:func:`launch_workers` generalizes the subprocess-mesh pattern from the
test suite into a reusable launcher: each worker is a real OS process
(``python -m repro.serving.fleet.worker``) with its own JAX runtime, bound
to an ephemeral localhost port it announces on stdout. On multi-host
deployments the same :class:`PartitionFleet` client drives workers started
out-of-band — pass ``(host, port)`` pairs to :meth:`PartitionFleet.connect`.

:class:`PartitionFleet` implements the planner's
:class:`~repro.index.planner.BeamTransport` protocol: ``load`` ships each
partition's sliced layer tensors to its worker once, and ``begin``/``step``
exchange only the tiny per-level ``[n, w]`` beams. Requests are fanned out
to every worker *before* any reply is collected, so the P workers compute
concurrently. Any dead or hung worker surfaces as the typed
:class:`~repro.serving.admission.WorkerUnavailable` (per-call socket
timeouts — never a hang), which the batcher turns into failed futures and
the gateway maps to HTTP 503.

Failure handling: what a dead worker means is a policy choice
(:attr:`PartitionFleet.degraded_policy`). Under ``"reject"`` every query
fails typed until the worker returns. Under ``"serve_partial"`` (default)
a beam exchange that loses a partition marks it down and raises
:class:`~repro.index.planner.TransportDegraded`; the planner replays the
batch over the survivors, so the query completes with an explicitly
degraded, survivor-exact partial ranking. Recovery is the
:class:`~repro.serving.fleet.supervisor.FleetSupervisor`'s job: it
respawns the process (:meth:`PartitionFleet.respawn_worker` re-ships the
partition via the stored load spec) and returns the pid to rotation.
"""

from __future__ import annotations

import json
import logging
import os
import subprocess
import sys
import threading
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.index.partition import PartitionedIndex
from repro.index.planner import BeamTransport, TransportDegraded
from repro.serving.admission import WorkerUnavailable
from repro.serving.config import DEGRADED_POLICIES
from repro.serving.fleet.rpc import WorkerConnection

log = logging.getLogger(__name__)


class WorkerHandle:
    """One fleet worker: the process (when launched locally) + connection."""

    def __init__(
        self,
        conn: WorkerConnection,
        proc: Optional[subprocess.Popen] = None,
        name: Optional[str] = None,
    ) -> None:
        self.conn = conn
        self.proc = proc
        self.name = name or conn.name

    def alive(self) -> bool:
        return self.proc is None or self.proc.poll() is None

    def kill(self, grace_s: float = 2.0) -> None:
        """Stop the worker: SIGTERM, a grace window, then SIGKILL; reap.

        The grace period lets the worker exit cleanly (close its listening
        socket, flush) instead of dying mid-frame; ``grace_s=0`` is an
        immediate hard kill for fault injection. The process is always
        reaped — no zombies for the supervisor's liveness poll to misread.
        """
        proc = self.proc
        if proc is not None and proc.poll() is None:
            if grace_s > 0:
                proc.terminate()
                try:
                    proc.wait(timeout=grace_s)
                except subprocess.TimeoutExpired:
                    proc.kill()
            else:
                proc.kill()
            try:
                proc.wait(timeout=30)
            except subprocess.TimeoutExpired:
                pass
        self.conn.close()


def _read_announce(proc: subprocess.Popen, timeout_s: float, name: str) -> dict:
    """Read the worker's one-line JSON announcement with a hard timeout."""
    out: List[str] = []

    def _read() -> None:
        out.append(proc.stdout.readline())

    t = threading.Thread(target=_read, daemon=True)
    t.start()
    t.join(timeout_s)
    if t.is_alive() or not out or not out[0].strip():
        proc.kill()
        raise WorkerUnavailable(
            name, "launch",
            f"no announcement within {timeout_s:.0f}s "
            f"(exit code {proc.poll()})",
        )
    return json.loads(out[0])


def launch_workers(
    n: int,
    *,
    host: str = "127.0.0.1",
    env: Optional[dict] = None,
    startup_timeout_s: float = 120.0,
    rpc_timeout_s: float = 120.0,
) -> List[WorkerHandle]:
    """Spawn ``n`` local worker processes and connect to each.

    The child environment inherits the parent's (so ``JAX_PLATFORMS``,
    ``MSCM_FORCE_INTERPRET`` etc. propagate) with the directory containing
    the ``repro`` package prepended to ``PYTHONPATH`` — workers import the
    same code the parent runs, whatever the parent's install mode.
    """
    import repro

    pkg_root = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    child_env = dict(os.environ if env is None else env)
    prev = child_env.get("PYTHONPATH", "")
    child_env["PYTHONPATH"] = (
        pkg_root + (os.pathsep + prev if prev else "")
    )
    procs: List[subprocess.Popen] = []
    handles: List[WorkerHandle] = []
    try:
        for _ in range(n):
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.serving.fleet.worker",
                 "--host", host, "--port", "0"],
                stdout=subprocess.PIPE, text=True, env=child_env,
            ))
        for pid, proc in enumerate(procs):
            name = f"worker{pid}"
            ann = _read_announce(proc, startup_timeout_s, name)
            conn = WorkerConnection(
                host, int(ann["port"]), timeout_s=rpc_timeout_s, name=name
            )
            handles.append(WorkerHandle(conn, proc, name))
    except BaseException:  # noqa: BLE001 — reap the partial fleet, then re-raise
        # Reap EVERY spawned process, including those not yet wrapped in a
        # WorkerHandle — a failure at worker i must not orphan i..n-1 as
        # live JAX processes bound to ports. handles[j] wraps procs[j], so
        # the unwrapped tail is exactly procs[len(handles):].
        for h in handles:
            try:
                h.kill()
            except Exception as exc:  # noqa: BLE001 — reap all before re-raising
                log.warning("launch cleanup: kill(%s) failed: %s", h.name, exc)
        tail = procs[len(handles):]
        for proc in tail:
            if proc.poll() is None:
                proc.kill()
        for proc in tail:
            try:
                proc.wait(timeout=30)
            except Exception as exc:  # noqa: BLE001 — reap all before re-raising
                log.warning(
                    "launch cleanup: wait(pid=%s) failed: %s", proc.pid, exc
                )
        raise
    return handles


def partition_payload(
    index: PartitionedIndex,
    pid: int,
    *,
    beam: int,
    topk: int,
    method: str,
    score_mode: str = "prod",
    qt: int = 8,
) -> Tuple[dict, List[np.ndarray]]:
    """One partition's ``load`` wire payload (header + flattened layers).

    This is exactly what :meth:`PartitionFleet.load` ships to worker
    ``pid`` — shared so the supervisor's re-ship path and in-process
    :class:`~repro.serving.fleet.worker.PartitionRunner` tests build
    bit-identical worker state.
    """
    part = index.parts[pid]
    info = index.manifest.partitions[pid]
    tier = getattr(part, "tier", "exact")
    header = {
        "pid": info.pid,
        "level": index.level,
        "n_cols": list(index.n_cols),
        "branching": list(index.branching),
        "d": index.d,
        "chunk_start": info.chunk_start,
        "beam": beam, "topk": topk, "method": method,
        "score_mode": score_mode, "qt": qt,
        "part_n_cols": list(part.n_cols),
        "tier": tier,
    }
    if tier != "exact":
        # Quantized partitions ship three tensors per layer: the exact ELL
        # mask, the int8 weights, and the f32 scale rows. The RPC frame
        # format round-trips dtypes via numpy dtype strings, which excludes
        # the ml_dtypes fp8 family — fp8 is an in-process tier only.
        arrays = []
        for lay in part.layers:
            q = np.asarray(lay.chunk_vals)
            if q.dtype != np.int8:
                raise ValueError(
                    f"fleet wire carries int8 quantized weights only; "
                    f"partition {pid} stores {q.dtype} (tier={tier!r}) — "
                    "serve fp8 in-process"
                )
            arrays += [np.asarray(lay.chunk_rows), q,
                       np.asarray(lay.chunk_scales)]
    else:
        arrays = [
            np.asarray(t)
            for lay in part.layers
            for t in (lay.chunk_rows, lay.chunk_vals, lay.col_rows,
                      lay.col_vals)
        ]
    return header, arrays


class PartitionFleet(BeamTransport):
    """Cross-process partition workers behind the planner's transport API."""

    def __init__(
        self,
        handles: Sequence[WorkerHandle],
        *,
        degraded_policy: str = "serve_partial",
    ) -> None:
        if not handles:
            raise ValueError("a fleet needs at least one worker")
        if degraded_policy not in DEGRADED_POLICIES:
            raise ValueError(
                f"degraded_policy={degraded_policy!r}; choose from "
                f"{DEGRADED_POLICIES}"
            )
        self.handles = list(handles)
        self._closed = False
        self.degraded_policy = degraded_policy
        #: Set by :meth:`FleetSupervisor.start`; read by the gateway.
        self.supervisor = None
        # Guards the down-set, handle swaps, and batch snapshots. Never
        # held while a socket is in flight.
        self._state_lock = threading.Lock()
        self._down: Set[int] = set()  # guarded-by: _state_lock
        # (pids, handles) snapshotted at begin() so mid-batch supervisor
        # swaps can't mix a fresh worker into a half-run exchange.
        self._batch: Optional[Tuple[List[int], List[WorkerHandle]]] = None  # guarded-by: _state_lock
        self._load_spec: Optional[dict] = None
        self._launch_opts: Optional[dict] = None

    # -- construction -------------------------------------------------------
    @classmethod
    def launch(
        cls,
        n: int,
        *,
        host: str = "127.0.0.1",
        env: Optional[dict] = None,
        startup_timeout_s: float = 120.0,
        rpc_timeout_s: float = 120.0,
        degraded_policy: str = "serve_partial",
    ) -> "PartitionFleet":
        """Spawn ``n`` local worker processes (one per partition)."""
        opts = dict(
            host=host, env=env,
            startup_timeout_s=startup_timeout_s, rpc_timeout_s=rpc_timeout_s,
        )
        fleet = cls(launch_workers(n, **opts), degraded_policy=degraded_policy)
        fleet._launch_opts = opts  # respawn recipe for the supervisor
        return fleet

    @classmethod
    def connect(
        cls,
        addresses: Sequence[Tuple[str, int]],
        *,
        rpc_timeout_s: float = 120.0,
        degraded_policy: str = "serve_partial",
    ) -> "PartitionFleet":
        """Attach to already-running workers (the multi-host deployment)."""
        return cls([
            WorkerHandle(WorkerConnection(
                h, p, timeout_s=rpc_timeout_s, name=f"worker{i}@{h}:{p}"
            ))
            for i, (h, p) in enumerate(addresses)
        ], degraded_policy=degraded_policy)

    # -- BeamTransport ------------------------------------------------------
    @property
    def n_partitions(self) -> int:
        return len(self.handles)

    def _reset_connections(self) -> None:
        """Poison recovery: give every worker a fresh, in-sync stream.

        After an abandoned exchange, replies from the still-healthy workers
        may sit buffered on their sockets; the next call's recv would
        consume one as its own (identical ``[n, w]`` shapes — silently
        wrong results, not an error). Reconnecting drops those streams;
        workers keep their loaded partition across client connections. A
        dead worker's connection stays closed and surfaces as the typed
        ``WorkerUnavailable`` on next use.
        """
        for h in self.handles:
            try:
                h.conn.reconnect()
            except WorkerUnavailable:
                pass

    def _exchange(
        self, op: str, headers: Sequence[dict],
        arrays: Sequence[Sequence[np.ndarray]],
    ) -> List[Tuple[dict, List[np.ndarray]]]:
        """Locked fan-out: send to every worker first, then collect replies.

        Sends complete before any recv so the P workers overlap; replies
        are collected in partition order (the merge is order-independent,
        but determinism keeps debugging sane). Every connection's lock is
        held for the whole exchange so a concurrent health-check ping
        cannot interleave frames with the beam protocol. If any send/recv
        fails, the in-flight exchange is abandoned and every connection is
        reset before the error propagates — undrained replies must never
        be consumed by the next request.
        """
        for h in self.handles:
            h.conn.lock.acquire()
        try:
            try:
                for h, hd, arr in zip(self.handles, headers, arrays):
                    h.conn.send(op, hd, arr)
                return [h.conn.recv(op) for h in self.handles]
            except BaseException:  # noqa: BLE001 — reset desynced streams, re-raise
                self._reset_connections()
                raise
        finally:
            for h in self.handles:
                h.conn.lock.release()

    def _fanout(
        self, op: str, headers: Sequence[dict],
        arrays: Sequence[Sequence[np.ndarray]],
    ) -> List[Tuple[np.ndarray, np.ndarray]]:
        return [
            (reply[0], reply[1])
            for _, reply in self._exchange(op, headers, arrays)
        ]

    # -- degraded-mode state -------------------------------------------------
    def down_pids(self) -> List[int]:
        """Partitions currently out of rotation (sorted)."""
        with self._state_lock:
            return sorted(self._down)

    def mark_down(self, pid: int) -> None:
        """Take ``pid`` out of rotation (failed exchange / supervisor)."""
        with self._state_lock:
            self._down.add(pid)

    def mark_up(self, pid: int) -> None:
        """Return ``pid`` to rotation (after a successful respawn+reload)."""
        with self._state_lock:
            self._down.discard(pid)

    def down_partitions(self) -> List[int]:
        """Partitions the *current batch* ran without (planner contract).

        The complement of the begin-time snapshot, not the live down-set:
        a worker that died *after* this batch's ``begin`` did still
        contribute its beams, and one the supervisor revived mid-batch did
        not — the snapshot is what actually served the query.
        """
        with self._state_lock:
            if self._batch is None:
                return sorted(self._down)
            in_batch = set(self._batch[0])
            return [p for p in range(len(self.handles)) if p not in in_batch]

    def _batch_exchange(
        self, op: str, header: dict, arrays: Sequence[np.ndarray],
    ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """One beam-protocol fan-out over the batch snapshot.

        Same locking/poisoning discipline as :meth:`_exchange`, but scoped
        to the handles snapshotted at ``begin`` and failure-attributed: a
        transport-level loss of one worker under ``"serve_partial"`` marks
        that pid down and raises
        :class:`~repro.index.planner.TransportDegraded` so the planner
        replays the batch over the survivors. Application errors
        (``RemoteError``) and any failure under ``"reject"`` propagate
        unchanged — those are the pre-supervision semantics.
        """
        with self._state_lock:
            assert self._batch is not None, f"{op} before begin"
            pids, handles = self._batch
        if not pids:
            raise WorkerUnavailable("fleet", op, "no live partitions")
        failed_pid: Optional[int] = None
        for h in handles:
            h.conn.lock.acquire()
        try:
            try:
                for pid, h in zip(pids, handles):
                    try:
                        h.conn.send(op, header, arrays)
                    except BaseException:  # noqa: BLE001 — tag the failed pid, re-raise
                        failed_pid = pid
                        raise
                replies = []
                for pid, h in zip(pids, handles):
                    try:
                        replies.append(h.conn.recv(op))
                    except BaseException:  # noqa: BLE001 — tag the failed pid, re-raise
                        failed_pid = pid
                        raise
                return [(reply[0], reply[1]) for _, reply in replies]
            except BaseException as exc:  # noqa: BLE001 — degrade or re-raise below
                self._reset_connections()
                if (
                    self.degraded_policy == "serve_partial"
                    and failed_pid is not None
                    and isinstance(exc, WorkerUnavailable)
                    and len(pids) > 1
                ):
                    self.mark_down(failed_pid)
                    raise TransportDegraded(failed_pid, exc) from exc
                raise
        finally:
            for h in handles:
                h.conn.lock.release()

    def begin(self, x_idx, x_val, parent_ids, scores, *, beam=None, qt=None):
        with self._state_lock:
            n = len(self.handles)
            if self.degraded_policy == "serve_partial":
                pids = [p for p in range(n) if p not in self._down]
            else:
                # reject: always address the full fleet so a dead worker
                # fails the query typed instead of being silently skipped
                pids = list(range(n))
            self._batch = (pids, [self.handles[p] for p in pids])
        # Beam-tier overrides ride the begin header per batch; absent keys
        # mean the loaded full settings, so a no-SLO coordinator's frames
        # are byte-identical to the pre-tier wire format.
        header: dict = {}
        if beam is not None:
            header["beam"] = int(beam)
        if qt is not None:
            header["qt"] = int(qt)
        return self._batch_exchange(
            "begin", header, [x_idx, x_val, parent_ids, scores]
        )

    def step(self, level, winner_ids):
        return self._batch_exchange(
            "step", {"level": int(level)}, [winner_ids]
        )

    # -- loading / attaching ------------------------------------------------
    def load(
        self,
        index: PartitionedIndex,
        *,
        beam: int,
        topk: int,
        method: str,
        score_mode: str = "prod",
        qt: int = 8,
    ) -> None:
        """Ship each partition's sliced layers + metadata to its worker."""
        if index.n_partitions != self.n_partitions:
            raise ValueError(
                f"index has {index.n_partitions} partitions, fleet has "
                f"{self.n_partitions} workers"
            )
        self._load_spec = dict(
            index=index, beam=beam, topk=topk, method=method,
            score_mode=score_mode, qt=qt,
        )
        payloads = [
            partition_payload(
                index, pid, beam=beam, topk=topk, method=method,
                score_mode=score_mode, qt=qt,
            )
            for pid in range(index.n_partitions)
        ]
        self._exchange(
            "load", [h for h, _ in payloads], [a for _, a in payloads]
        )

    def load_worker(self, pid: int, handle: Optional[WorkerHandle] = None):
        """Re-ship partition ``pid`` to one worker (the supervisor's path).

        ``handle`` lets the supervisor load a freshly spawned worker before
        swapping it into rotation; default is the current ``handles[pid]``.
        """
        if self._load_spec is None:
            raise RuntimeError("load_worker before load/attach")
        header, arrays = partition_payload(
            self._load_spec["index"], pid,
            beam=self._load_spec["beam"], topk=self._load_spec["topk"],
            method=self._load_spec["method"],
            score_mode=self._load_spec["score_mode"],
            qt=self._load_spec["qt"],
        )
        if handle is None:
            with self._state_lock:
                handle = self.handles[pid]
        handle.conn.call("load", header, arrays)

    def attach(self, engine) -> "PartitionFleet":
        """Serve ``engine``'s partitions from this fleet's workers.

        The engine must be partitioned with ``partition_sync="pipelined"``
        (the only exchange the transport protocol covers) and no hot-beam
        cache. Ships the partitions, then routes the planner's per-level
        partition work through this fleet — the coordinator keeps only the
        router head and the tiny beam merges.
        """
        if engine.planner is None:
            raise ValueError("engine is unpartitioned; nothing to serve remotely")
        c = engine.config
        fleet_cfg = getattr(c, "fleet", None)
        if fleet_cfg is not None:
            # the config knob is authoritative once an engine is attached
            if fleet_cfg.degraded_policy not in DEGRADED_POLICIES:
                raise ValueError(
                    f"degraded_policy={fleet_cfg.degraded_policy!r}; choose "
                    f"from {DEGRADED_POLICIES}"
                )
            self.degraded_policy = fleet_cfg.degraded_policy
        engine.planner.set_transport(self)
        self.load(
            engine.index,
            beam=c.beam, topk=c.topk, method=engine.method,
            score_mode=c.score_mode, qt=c.qt,
        )
        engine.fleet = self
        return self

    # -- supervised recovery -------------------------------------------------
    def respawn_worker(self, pid: int) -> WorkerHandle:
        """Replace worker ``pid``: new process (or stream), re-shipped
        partition, then swap into rotation and clear the down mark.

        Locally-launched fleets spawn a fresh process from the stored
        launch recipe; ``connect()``-attached fleets reconnect to the
        externally managed address instead. The new worker is fully loaded
        *before* the swap, so an exchange can never observe a live but
        empty partition.
        """
        with self._state_lock:
            old = self.handles[pid]
            opts = self._launch_opts
        try:
            old.kill()
        except Exception as exc:  # noqa: BLE001 — reap best-effort, then respawn
            log.warning(
                "respawn(%d): kill of old worker failed (already dead / "
                "unreachable): %s", pid, exc,
            )
        if opts is not None and old.proc is not None:
            new = launch_workers(1, **opts)[0]
            new.name = f"worker{pid}"
            new.conn.name = new.name
        else:
            old.conn.reconnect()  # externally managed worker came back
            new = old
        try:
            if self._load_spec is not None:
                self.load_worker(pid, handle=new)
        except BaseException:  # noqa: BLE001 — reap the replacement, re-raise
            if new is not old:
                try:
                    new.kill()
                except Exception as exc:  # noqa: BLE001 — load failure re-raised below
                    log.warning(
                        "respawn(%d): cleanup kill of replacement failed: %s",
                        pid, exc,
                    )
            raise
        with self._state_lock:
            self.handles[pid] = new
            self._down.discard(pid)
        return new

    # -- health / lifecycle -------------------------------------------------
    def ping(self, timeout_s: float = 5.0) -> Dict[str, bool]:
        """Per-worker liveness, probed concurrently; the *whole* sweep is
        bounded by ``timeout_s`` (one hung worker used to serialize into
        a P×timeout health check).

        Each probe first tries the connection lock with the remaining
        budget: lock-busy means a beam exchange is in flight on that
        stream, which is proof of life — report process liveness rather
        than interleave frames. A failed probe closes the (now desynced)
        stream; a best-effort reconnect repairs it so one slow probe does
        not take a live worker out of rotation.
        """
        with self._state_lock:
            handles = list(self.handles)
        deadline = time.monotonic() + timeout_s
        out: Dict[str, bool] = {h.name: False for h in handles}

        def probe(h: WorkerHandle) -> None:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return
            if not h.conn.lock.acquire(timeout=remaining):
                out[h.name] = h.alive()  # stream busy mid-exchange
                return
            try:
                h.conn.call(
                    "ping",
                    timeout_s=min(timeout_s, h.conn.timeout_s),
                )
                out[h.name] = True
            except (WorkerUnavailable, RuntimeError):
                try:
                    h.conn.reconnect()
                except WorkerUnavailable:
                    pass
            finally:
                h.conn.lock.release()

        threads = [
            threading.Thread(target=probe, args=(h,), daemon=True)
            for h in handles
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(max(0.0, deadline - time.monotonic()) + 0.1)
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for h in self.handles:
            try:
                h.conn.call("shutdown")
            except (WorkerUnavailable, RuntimeError):
                pass
            h.kill()

    def __enter__(self) -> "PartitionFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
