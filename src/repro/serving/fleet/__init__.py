"""Cross-process partition fleet: workers, launcher, supervision, RPC.

``PartitionFleet.launch(P).attach(engine)`` moves a partitioned engine's
per-level scatter-gather work into P worker processes — each with its own
JAX runtime and device memory — while the coordinator keeps the router head
and the tiny per-level beam merges. Results stay bitwise-identical to
in-process serving (pinned by tests/test_fleet_gateway.py).

Robustness lives here too: :class:`FleetSupervisor` respawns dead workers
(state machine UP → SUSPECT → RESTARTING → UP, or FAILED on budget
exhaustion), the fleet's ``degraded_policy`` decides whether a partition
loss fails queries or serves survivor-exact partial rankings, and
:class:`FaultInjector` is the deterministic chaos seam the test suite and
``bench_gateway --chaos`` drive failures through.
"""

from repro.serving.fleet.launcher import (
    PartitionFleet,
    WorkerHandle,
    launch_workers,
    partition_payload,
)
from repro.serving.fleet.rpc import (
    FaultInjector,
    FaultRule,
    RemoteError,
    WorkerConnection,
)
from repro.serving.fleet.supervisor import (
    STATE_FAILED,
    STATE_RESTARTING,
    STATE_SUSPECT,
    STATE_UP,
    WORKER_STATES,
    FleetSupervisor,
)

__all__ = [
    "FaultInjector",
    "FaultRule",
    "FleetSupervisor",
    "PartitionFleet",
    "RemoteError",
    "STATE_FAILED",
    "STATE_RESTARTING",
    "STATE_SUSPECT",
    "STATE_UP",
    "WORKER_STATES",
    "WorkerConnection",
    "WorkerHandle",
    "launch_workers",
    "partition_payload",
]
