"""Cross-process partition fleet: workers, launcher, and socket RPC.

``PartitionFleet.launch(P).attach(engine)`` moves a partitioned engine's
per-level scatter-gather work into P worker processes — each with its own
JAX runtime and device memory — while the coordinator keeps the router head
and the tiny per-level beam merges. Results stay bitwise-identical to
in-process serving (pinned by tests/test_fleet_gateway.py).
"""

from repro.serving.fleet.launcher import (
    PartitionFleet,
    WorkerHandle,
    launch_workers,
)
from repro.serving.fleet.rpc import RemoteError, WorkerConnection

__all__ = [
    "PartitionFleet",
    "RemoteError",
    "WorkerConnection",
    "WorkerHandle",
    "launch_workers",
]
