"""Fleet partition worker: one process, one label partition.

Run as ``python -m repro.serving.fleet.worker --host 127.0.0.1 --port 0``.
The worker binds (port 0 = ephemeral), prints one JSON line with the bound
port + pid on stdout, then serves length-prefixed RPC frames
(:mod:`repro.serving.fleet.rpc`) until a ``shutdown`` op or EOF.

Ops:

``ping``
    liveness probe — replies immediately.
``load``
    receive one partition's sliced layer tensors + the global tree metadata
    and build the local :class:`~repro.core.tree.XMRTree`.
``begin`` / ``step``
    the partition half of the pipelined exchange protocol (see
    :class:`~repro.index.planner.BeamTransport`), executed by
    :class:`PartitionRunner` through the *same jitted programs* the
    in-process planner uses (``_owned_level_scores`` / ``_spec_select`` /
    ``_reconcile_select``) — which is what keeps fleet-served results
    bitwise-identical to in-process serving.
``shutdown``
    reply, then exit cleanly.

Scheduling inside ``begin``/``step`` mirrors the in-process pipelined
planner: the cheap local select is dispatched first, its tiny beam is
materialized and sent back, and the *speculative* next-level MSCM is
dispatched before the reply is written — JAX async dispatch keeps the heavy
matmul running on this worker's device while the coordinator merges beams.
"""

from __future__ import annotations

# xmrlint: single-threaded — one accept loop, one connection, no concurrent
# frame writers on this socket; the coordinator side carries the lock.
import argparse
import json
import os
import signal
import socket
import struct
import sys
import traceback
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.fleet.rpc import recv_frame, send_frame

_NEEDS_DENSE = (
    "mscm_dense", "mscm_pallas", "mscm_pallas_pregather",
    "mscm_pallas_grouped", "mscm_pallas_grouped_q",
)


class PartitionRunner:
    """One partition's half of the pipelined beam-exchange protocol."""

    def __init__(
        self,
        header: dict,
        arrays: List[np.ndarray],
    ) -> None:
        import jax.numpy as jnp

        from repro.core.tree import TreeLayerArrays, XMRTree

        self.pid = int(header["pid"])
        self.level = int(header["level"])          # split level li0
        self.n_cols = tuple(header["n_cols"])      # GLOBAL per-level counts
        self.branching = tuple(header["branching"])
        self.chunk_start = int(header["chunk_start"])
        self.beam = int(header["beam"])
        self.topk = int(header["topk"])
        self.method = str(header["method"])
        self.score_mode = str(header["score_mode"])
        self.qt = int(header["qt"])
        self.tier = str(header.get("tier", "exact"))
        d = int(header["d"])
        if self.tier != "exact":
            # Quantized payload: three tensors per layer (exact mask, int8
            # weights, f32 scale rows) — see ``partition_payload``. The
            # local sub-tree is a QuantizedTree; the shared jitted programs
            # dispatch on the quantized method string.
            from repro.quant import QuantLayerArrays, QuantizedTree

            n_layers = len(arrays) // 3
            qlayers = [
                QuantLayerArrays(
                    chunk_rows=jnp.asarray(arrays[3 * i]),
                    chunk_vals=jnp.asarray(arrays[3 * i + 1]),
                    chunk_scales=jnp.asarray(arrays[3 * i + 2]),
                )
                for i in range(n_layers)
            ]
            self.part = QuantizedTree(
                layers=qlayers,
                n_cols=tuple(header["part_n_cols"]),
                branching=self.branching[self.level:],
                d=d,
                tier=self.tier,
            )
        else:
            n_layers = len(arrays) // 4
            layers = [
                TreeLayerArrays(
                    chunk_rows=jnp.asarray(arrays[4 * i]),
                    chunk_vals=jnp.asarray(arrays[4 * i + 1]),
                    col_rows=jnp.asarray(arrays[4 * i + 2]),
                    col_vals=jnp.asarray(arrays[4 * i + 3]),
                )
                for i in range(n_layers)
            ]
            self.part = XMRTree(
                layers=layers,
                n_cols=tuple(header["part_n_cols"]),
                branching=self.branching[self.level:],
                d=d,
            )
        # per-batch state (the effective beam/qt default to the loaded
        # full settings; begin() may narrow them for one batch — adaptive
        # beam tiers are coordinator-chosen, the worker just obeys)
        self._beam = self.beam
        self._qt = self.qt
        self._xi = self._xv = self._xd = None
        self._spec_ids = self._spec_comb = None

    @property
    def depth(self) -> int:
        return len(self.n_cols)

    def _span(self, li: int) -> int:
        """Branching product between the split level and ``li``."""
        return int(
            np.prod(self.branching[self.level:li], dtype=np.int64)
        ) if li > self.level else 1

    def _next_b(self, li: int) -> int:
        is_last = li == self.depth - 1
        return min(self.topk if is_last else self._beam, self.n_cols[li])

    def _owned(self, li, parent_ids, parent_scores):
        """One level's owned combined scores through the shared jit."""
        import jax.numpy as jnp

        from repro.index.planner import _owned_level_scores

        lay = self.part.layers[li - self.level]
        c_real = lay.chunk_rows.shape[0] - 1  # minus phantom pad
        return _owned_level_scores(
            lay, self.branching[li], self.part.d,
            self._xi, self._xv, self._xd, parent_ids, parent_scores,
            jnp.int32(self.chunk_start * self._span(li)), jnp.int32(c_real),
            method=self.method, score_mode=self.score_mode, qt=self._qt,
        )

    def _speculate(self, li: int, beam_ids, beam_scores) -> None:
        """Dispatch the level-``li+1`` speculative expansion (async)."""
        if li + 1 < self.depth:
            self._spec_comb, _ = self._owned(li + 1, beam_ids, beam_scores)
            self._spec_ids = beam_ids
        else:
            self._spec_ids = self._spec_comb = None

    def begin(
        self, xi: np.ndarray, xv: np.ndarray,
        parent_ids: np.ndarray, scores: np.ndarray,
        *, beam: Optional[int] = None, qt: Optional[int] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        import jax.numpy as jnp

        from repro.index.planner import _scatter_dense, _spec_select

        # Per-batch tier override: the coordinator's begin header may
        # narrow beam/qt for this batch only; the loaded settings are the
        # default and are restored by the next begin without an override.
        self._beam = self.beam if beam is None else int(beam)
        self._qt = self.qt if qt is None else int(qt)
        li = self.level
        self._xi = jnp.asarray(xi)
        self._xv = jnp.asarray(xv)
        self._xd = (
            _scatter_dense(self._xi, self._xv, self.part.d)
            if self.method in _NEEDS_DENSE else None
        )
        ids = jnp.asarray(parent_ids)
        sc = jnp.asarray(scores)
        comb, own = self._owned(li, ids, sc)
        b_ids, b_sc = _spec_select(
            ids, comb, own,
            n_cols=self.n_cols[li], n_chunks=self.n_cols[li - 1],
            next_b=self._next_b(li),
        )
        self._speculate(li, b_ids, b_sc)
        return np.asarray(b_ids), np.asarray(b_sc)

    def step(
        self, li: int, winner_ids: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        import jax.numpy as jnp

        from repro.index.planner import _reconcile_select

        if self._spec_ids is None:
            raise RuntimeError(f"step(level={li}) before begin/speculation")
        lay = self.part.layers[li - self.level]
        b_ids, b_sc = _reconcile_select(
            jnp.asarray(winner_ids), self._spec_ids, self._spec_comb,
            jnp.int32(self.chunk_start * self._span(li)),
            jnp.int32(lay.chunk_rows.shape[0] - 1),
            n_cols=self.n_cols[li], n_chunks=self.n_cols[li - 1],
            next_b=self._next_b(li),
        )
        self._speculate(li, b_ids, b_sc)
        return np.asarray(b_ids), np.asarray(b_sc)


def _serve_connection(conn: socket.socket, state: dict) -> bool:
    """Serve one client connection. Returns True on a ``shutdown`` op."""
    while True:
        try:
            header, arrays = recv_frame(conn)
        except (EOFError, OSError):
            return False  # client gone; go back to accept()
        except (ValueError, KeyError, TypeError, struct.error):
            # Corrupt frame (oversized length prefix, malformed header):
            # the stream position is unknowable — drop this connection and
            # keep serving. The worker must survive garbage on the wire.
            traceback.print_exc(file=sys.stderr)
            return False
        op = header.get("op", "")
        try:
            if op == "ping":
                send_frame(conn, {"ok": True, "pid": os.getpid(),
                                  "loaded": state.get("runner") is not None})
            elif op == "load":
                state["runner"] = PartitionRunner(header, arrays)
                send_frame(conn, {"ok": True})
            elif op == "begin":
                ids, sc = state["runner"].begin(
                    *arrays,
                    beam=header.get("beam"), qt=header.get("qt"),
                )
                send_frame(conn, {"ok": True}, [ids, sc])
            elif op == "step":
                ids, sc = state["runner"].step(int(header["level"]), arrays[0])
                send_frame(conn, {"ok": True}, [ids, sc])
            elif op == "shutdown":
                send_frame(conn, {"ok": True})
                return True
            else:
                send_frame(conn, {"ok": False, "error": f"unknown op {op!r}"})
        except Exception as exc:  # noqa: BLE001 — report, keep serving
            traceback.print_exc(file=sys.stderr)
            try:
                send_frame(
                    conn,
                    {"ok": False, "error": f"{type(exc).__name__}: {exc}"},
                )
            except OSError:
                return False


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral (bound port printed on stdout)")
    args = ap.parse_args(argv)

    if hasattr(signal, "SIGTERM"):
        # Graceful stop (WorkerHandle.kill's grace window). Flush and exit
        # immediately: raising SystemExit from a handler mid-exchange would
        # unwind through library frames and spew tracebacks at teardown.
        def _on_sigterm(*_):
            sys.stdout.flush()
            sys.stderr.flush()
            os._exit(0)

        signal.signal(signal.SIGTERM, _on_sigterm)

    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((args.host, args.port))
    srv.listen(1)
    print(json.dumps({"port": srv.getsockname()[1], "pid": os.getpid()}),
          flush=True)

    state: dict = {"runner": None}
    try:
        while True:
            conn, _ = srv.accept()
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            try:
                if _serve_connection(conn, state):
                    return 0
            finally:
                conn.close()
    finally:
        srv.close()


if __name__ == "__main__":
    sys.exit(main())
