"""Length-prefixed socket RPC for the partition fleet.

One frame is::

    [8-byte big-endian frame length]
    [4-byte big-endian header length][JSON header]
    [raw array bytes, concatenated]

The header is a small JSON object (op name, metadata, and an ``"arrays"``
list of ``{"dtype", "shape"}`` descriptors); array payloads follow as raw
contiguous bytes in descriptor order. Pipelined beams are tiny ``[n, w]``
tensors, so JSON header + raw bytes is both simple and fast — no pickle on
the wire (workers never deserialize executable state).

:class:`WorkerConnection` is the client side: per-call timeouts, and every
transport-level failure (refused/reset connection, EOF from a dead process,
a timeout) raises the typed
:class:`~repro.serving.admission.WorkerUnavailable` so callers get a
bounded, classifiable failure instead of a hang. A worker that *replied*
with an application error raises :class:`RemoteError` instead — the worker
is alive, the request was bad.
"""

from __future__ import annotations

import json
import socket
import struct
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.admission import WorkerUnavailable

_LEN = struct.Struct(">Q")   # frame length
_HLEN = struct.Struct(">I")  # header length

#: Refuse frames beyond this (a corrupt length prefix must not OOM us).
MAX_FRAME_BYTES = 1 << 33


class RemoteError(RuntimeError):
    """The worker processed the call and replied with an error."""


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise EOFError(f"connection closed after {got}/{n} bytes")
        got += k
    return bytes(buf)


def send_frame(
    sock: socket.socket, header: dict, arrays: Sequence[np.ndarray] = ()
) -> None:
    arrays = [np.ascontiguousarray(a) for a in arrays]
    header = dict(header)
    header["arrays"] = [
        {"dtype": a.dtype.str, "shape": list(a.shape)} for a in arrays
    ]
    hbytes = json.dumps(header).encode()
    body = len(hbytes) + sum(a.nbytes for a in arrays)
    parts = [_LEN.pack(_HLEN.size + body), _HLEN.pack(len(hbytes)), hbytes]
    parts.extend(a.tobytes() for a in arrays)
    sock.sendall(b"".join(parts))


def recv_frame(sock: socket.socket) -> Tuple[dict, List[np.ndarray]]:
    (total,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if total > MAX_FRAME_BYTES:
        raise ValueError(f"frame length {total} exceeds {MAX_FRAME_BYTES}")
    payload = _recv_exact(sock, total)
    (hlen,) = _HLEN.unpack(payload[: _HLEN.size])
    off = _HLEN.size + hlen
    header = json.loads(payload[_HLEN.size : off])
    arrays = []
    for desc in header.pop("arrays", []):
        dt = np.dtype(desc["dtype"])
        shape = tuple(desc["shape"])
        n_elem = int(np.prod(shape, dtype=np.int64))
        arrays.append(
            np.frombuffer(payload, dt, count=n_elem, offset=off)
            .reshape(shape)
            .copy()
        )
        off += n_elem * dt.itemsize
    return header, arrays


class WorkerConnection:
    """Client handle to one fleet worker, with per-call timeouts.

    ``send``/``recv`` are split so a caller can fan a request out to every
    worker *before* collecting any reply — the workers compute in parallel
    while the client is still writing to the others.
    """

    def __init__(
        self, host: str, port: int, *, timeout_s: float = 60.0,
        name: Optional[str] = None,
    ) -> None:
        self.name = name or f"{host}:{port}"
        self.timeout_s = timeout_s
        try:
            self._sock = socket.create_connection((host, port), timeout=timeout_s)
            self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError as exc:
            raise WorkerUnavailable(self.name, "connect", str(exc)) from exc

    def send(
        self, op: str, header: Optional[dict] = None,
        arrays: Sequence[np.ndarray] = (),
    ) -> None:
        msg = dict(header or {})
        msg["op"] = op
        try:
            self._sock.settimeout(self.timeout_s)
            send_frame(self._sock, msg, arrays)
        except (OSError, EOFError) as exc:
            raise WorkerUnavailable(self.name, op, str(exc)) from exc

    def recv(self, op: str = "reply") -> Tuple[dict, List[np.ndarray]]:
        try:
            self._sock.settimeout(self.timeout_s)
            header, arrays = recv_frame(self._sock)
        except (OSError, EOFError, socket.timeout) as exc:
            raise WorkerUnavailable(self.name, op, str(exc)) from exc
        if not header.get("ok", False):
            raise RemoteError(
                f"worker {self.name} failed {op!r}: "
                f"{header.get('error', 'unknown error')}"
            )
        return header, arrays

    def call(
        self, op: str, header: Optional[dict] = None,
        arrays: Sequence[np.ndarray] = (),
    ) -> Tuple[dict, List[np.ndarray]]:
        self.send(op, header, arrays)
        return self.recv(op)

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass
