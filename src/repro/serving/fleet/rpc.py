"""Length-prefixed socket RPC for the partition fleet.

One frame is::

    [8-byte big-endian frame length]
    [4-byte big-endian header length][JSON header]
    [raw array bytes, concatenated]

The header is a small JSON object (op name, metadata, and an ``"arrays"``
list of ``{"dtype", "shape"}`` descriptors); array payloads follow as raw
contiguous bytes in descriptor order. Pipelined beams are tiny ``[n, w]``
tensors, so JSON header + raw bytes is both simple and fast — no pickle on
the wire (workers never deserialize executable state).

:class:`WorkerConnection` is the client side: per-call timeouts, and every
transport-level failure (refused/reset connection, EOF from a dead process,
a timeout, a corrupt frame) raises the typed
:class:`~repro.serving.admission.WorkerUnavailable` so callers get a
bounded, classifiable failure instead of a hang. Any such failure also
closes the socket — a failure mid-frame leaves the byte stream desynced,
so the connection must be re-established (:meth:`WorkerConnection.reconnect`)
before it can carry another call. A worker that *replied* with an
application error raises :class:`RemoteError` instead — the worker is
alive and the stream is intact, the request was bad.

The protocol is strict request→reply on one stream, so all socket use is
serialized through a per-connection :class:`threading.RLock`: ``call``
holds it across its send+recv pair, and fleet fan-outs hold it across a
whole exchange — a concurrent health-check ping can never interleave its
frames with an in-flight beam exchange.
"""

from __future__ import annotations

import json
import socket
import struct
import threading
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.admission import WorkerUnavailable

_LEN = struct.Struct(">Q")   # frame length
_HLEN = struct.Struct(">I")  # header length

#: Refuse frames beyond this (a corrupt length prefix must not OOM us).
#: Per-level beams are KiB; the largest legitimate frame is one partition's
#: sliced layers in ``load``, comfortably under 2 GiB at paper scale.
MAX_FRAME_BYTES = 1 << 31


class RemoteError(RuntimeError):
    """The worker processed the call and replied with an error."""


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise EOFError(f"connection closed after {got}/{n} bytes")
        got += k
    return bytes(buf)


def send_frame(
    sock: socket.socket, header: dict, arrays: Sequence[np.ndarray] = ()
) -> None:
    arrays = [np.ascontiguousarray(a) for a in arrays]
    header = dict(header)
    header["arrays"] = [
        {"dtype": a.dtype.str, "shape": list(a.shape)} for a in arrays
    ]
    hbytes = json.dumps(header).encode()
    body = len(hbytes) + sum(a.nbytes for a in arrays)
    parts = [_LEN.pack(_HLEN.size + body), _HLEN.pack(len(hbytes)), hbytes]
    parts.extend(a.tobytes() for a in arrays)
    sock.sendall(b"".join(parts))


def recv_frame(sock: socket.socket) -> Tuple[dict, List[np.ndarray]]:
    (total,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if total > MAX_FRAME_BYTES:
        raise ValueError(f"frame length {total} exceeds {MAX_FRAME_BYTES}")
    payload = _recv_exact(sock, total)
    (hlen,) = _HLEN.unpack(payload[: _HLEN.size])
    off = _HLEN.size + hlen
    header = json.loads(payload[_HLEN.size : off])
    arrays = []
    for desc in header.pop("arrays", []):
        dt = np.dtype(desc["dtype"])
        shape = tuple(desc["shape"])
        n_elem = int(np.prod(shape, dtype=np.int64))
        arrays.append(
            np.frombuffer(payload, dt, count=n_elem, offset=off)
            .reshape(shape)
            .copy()
        )
        off += n_elem * dt.itemsize
    return header, arrays


class WorkerConnection:
    """Client handle to one fleet worker, with per-call timeouts.

    ``send``/``recv`` are split so a caller can fan a request out to every
    worker *before* collecting any reply — the workers compute in parallel
    while the client is still writing to the others.
    """

    def __init__(
        self, host: str, port: int, *, timeout_s: float = 60.0,
        name: Optional[str] = None,
    ) -> None:
        self.host = host
        self.port = port
        self.name = name or f"{host}:{port}"
        self.timeout_s = timeout_s
        #: Serializes all socket use; held across each send+recv pair (see
        #: module docstring). Reentrant so ``call`` and fleet-level exchange
        #: locking compose.
        self.lock = threading.RLock()
        self._sock: Optional[socket.socket] = self._connect()

    def _connect(self) -> socket.socket:
        try:
            sock = socket.create_connection(
                (self.host, self.port), timeout=self.timeout_s
            )
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return sock
        except OSError as exc:
            raise WorkerUnavailable(self.name, "connect", str(exc)) from exc

    def reconnect(self) -> None:
        """Replace the stream with a fresh one (drops any buffered replies).

        Used after an abandoned or failed exchange: the old stream may be
        desynced mid-frame or carry a stale reply that the next call would
        consume as its own. Workers keep their loaded partition across
        client connections, so a reconnect is cheap and state-preserving.
        """
        with self.lock:
            self.close()
            self._sock = self._connect()

    def send(
        self, op: str, header: Optional[dict] = None,
        arrays: Sequence[np.ndarray] = (),
        timeout_s: Optional[float] = None,
    ) -> None:
        msg = dict(header or {})
        msg["op"] = op
        with self.lock:
            sock = self._sock
            if sock is None:
                raise WorkerUnavailable(self.name, op, "connection closed")
            try:
                sock.settimeout(self.timeout_s if timeout_s is None
                                else timeout_s)
                send_frame(sock, msg, arrays)
            except (OSError, EOFError) as exc:
                self.close()  # partial write: stream desynced
                raise WorkerUnavailable(self.name, op, str(exc)) from exc

    def recv(
        self, op: str = "reply", timeout_s: Optional[float] = None,
    ) -> Tuple[dict, List[np.ndarray]]:
        with self.lock:
            sock = self._sock
            if sock is None:
                raise WorkerUnavailable(self.name, op, "connection closed")
            try:
                sock.settimeout(self.timeout_s if timeout_s is None
                                else timeout_s)
                header, arrays = recv_frame(sock)
            except (OSError, EOFError, socket.timeout) as exc:
                self.close()  # mid-frame: stream desynced until reconnect
                raise WorkerUnavailable(self.name, op, str(exc)) from exc
            except (ValueError, KeyError, TypeError, struct.error) as exc:
                # Oversized/corrupt length prefix, malformed JSON header, or
                # a bad array descriptor: the stream position is unknowable.
                self.close()
                raise WorkerUnavailable(
                    self.name, op, f"corrupt frame: {exc}"
                ) from exc
        if not header.get("ok", False):
            raise RemoteError(
                f"worker {self.name} failed {op!r}: "
                f"{header.get('error', 'unknown error')}"
            )
        return header, arrays

    def call(
        self, op: str, header: Optional[dict] = None,
        arrays: Sequence[np.ndarray] = (),
        timeout_s: Optional[float] = None,
    ) -> Tuple[dict, List[np.ndarray]]:
        with self.lock:  # no foreign frame between our send and our recv
            self.send(op, header, arrays, timeout_s)
            return self.recv(op, timeout_s)

    def close(self) -> None:
        # Lockless on purpose: kill paths must be able to close the socket
        # out from under a blocked recv in another thread.
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass
