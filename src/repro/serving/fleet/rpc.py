"""Length-prefixed socket RPC for the partition fleet.

One frame is::

    [8-byte big-endian frame length]
    [4-byte big-endian header length][JSON header]
    [raw array bytes, concatenated]

The header is a small JSON object (op name, metadata, and an ``"arrays"``
list of ``{"dtype", "shape"}`` descriptors); array payloads follow as raw
contiguous bytes in descriptor order. Pipelined beams are tiny ``[n, w]``
tensors, so JSON header + raw bytes is both simple and fast — no pickle on
the wire (workers never deserialize executable state).

:class:`WorkerConnection` is the client side: per-call timeouts, and every
transport-level failure (refused/reset connection, EOF from a dead process,
a timeout, a corrupt frame) raises the typed
:class:`~repro.serving.admission.WorkerUnavailable` so callers get a
bounded, classifiable failure instead of a hang. Any such failure also
closes the socket — a failure mid-frame leaves the byte stream desynced,
so the connection must be re-established (:meth:`WorkerConnection.reconnect`)
before it can carry another call. A worker that *replied* with an
application error raises :class:`RemoteError` instead — the worker is
alive and the stream is intact, the request was bad.

The protocol is strict request→reply on one stream, so all socket use is
serialized through a per-connection :class:`threading.RLock`: ``call``
holds it across its send+recv pair, and fleet fan-outs hold it across a
whole exchange — a concurrent health-check ping can never interleave its
frames with an in-flight beam exchange.

:class:`FaultInjector` is the deterministic chaos seam: a connection built
with (or assigned) one routes every ``send``/``recv`` through its rules, so
tests and the chaos benchmark can drop, delay, truncate, or corrupt frames
— or kill a worker process on exactly the Nth exchange — without races or
wall-clock guesswork. Production connections carry no injector and pay a
single ``is None`` check.
"""

from __future__ import annotations

import dataclasses
import json
import socket
import struct
import threading
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.admission import WorkerUnavailable

_LEN = struct.Struct(">Q")   # frame length
_HLEN = struct.Struct(">I")  # header length

#: Refuse frames beyond this (a corrupt length prefix must not OOM us).
#: Per-level beams are KiB; the largest legitimate frame is one partition's
#: sliced layers in ``load``, comfortably under 2 GiB at paper scale.
MAX_FRAME_BYTES = 1 << 31


class RemoteError(RuntimeError):
    """The worker processed the call and replied with an error."""


# xmrlint: transport-primitive — bottom of the frame stack; callers hold the lock
def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise EOFError(f"connection closed after {got}/{n} bytes")
        got += k
    return bytes(buf)


def encode_frame(header: dict, arrays: Sequence[np.ndarray] = ()) -> bytes:
    """Serialize one frame to bytes (the exact wire image ``send_frame``
    writes — also the seam fault injection truncates/corrupts)."""
    arrays = [np.ascontiguousarray(a) for a in arrays]
    header = dict(header)
    header["arrays"] = [
        {"dtype": a.dtype.str, "shape": list(a.shape)} for a in arrays
    ]
    hbytes = json.dumps(header).encode()
    body = len(hbytes) + sum(a.nbytes for a in arrays)
    parts = [_LEN.pack(_HLEN.size + body), _HLEN.pack(len(hbytes)), hbytes]
    parts.extend(a.tobytes() for a in arrays)
    return b"".join(parts)


# xmrlint: transport-primitive — bottom of the frame stack; callers hold the lock
def send_frame(
    sock: socket.socket, header: dict, arrays: Sequence[np.ndarray] = ()
) -> None:
    sock.sendall(encode_frame(header, arrays))


# xmrlint: transport-primitive — bottom of the frame stack; callers hold the lock
def recv_frame(sock: socket.socket) -> Tuple[dict, List[np.ndarray]]:
    (total,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if total > MAX_FRAME_BYTES:
        raise ValueError(f"frame length {total} exceeds {MAX_FRAME_BYTES}")
    payload = _recv_exact(sock, total)
    (hlen,) = _HLEN.unpack(payload[: _HLEN.size])
    off = _HLEN.size + hlen
    header = json.loads(payload[_HLEN.size : off])
    arrays = []
    for desc in header.pop("arrays", []):
        dt = np.dtype(desc["dtype"])
        shape = tuple(desc["shape"])
        n_elem = int(np.prod(shape, dtype=np.int64))
        arrays.append(
            np.frombuffer(payload, dt, count=n_elem, offset=off)
            .reshape(shape)
            .copy()
        )
        off += n_elem * dt.itemsize
    return header, arrays


#: Byte-level actions a send-phase rule may return (applied to the frame).
_FRAME_ACTIONS = ("drop", "truncate", "corrupt")


@dataclasses.dataclass
class FaultRule:
    """One deterministic fault: *action* on the *nth* matching call.

    ``action``:
      ``"drop"``      — swallow the frame (the peer never sees it; the
                        caller's recv times out).
      ``"truncate"``  — send half the encoded frame, then close the stream
                        (the peer EOFs mid-frame).
      ``"corrupt"``   — send the frame with an oversized length prefix (the
                        peer must reject it without crashing or OOMing).
      ``"delay"``     — sleep ``seconds`` before the call proceeds.
      ``"kill"``      — run ``callback`` (e.g. ``handle.kill``) before the
                        call proceeds: kill-on-Nth-exchange.

    ``phase`` picks the hook point (``"send"`` or ``"recv"``); ``op``
    restricts to one RPC op (``None`` = any); the rule fires on matching
    calls ``nth`` through ``nth + count - 1`` (1-based), so "kill on the
    3rd step" is ``FaultRule("kill", op="step", nth=3, callback=...)``.
    """

    action: str
    phase: str = "send"
    op: Optional[str] = None
    nth: int = 1
    count: int = 1
    seconds: float = 0.0
    callback: Optional[Callable[[], None]] = None
    matched: int = 0  # internal: matching calls seen so far

    def __post_init__(self) -> None:
        if self.action not in _FRAME_ACTIONS + ("delay", "kill"):
            raise ValueError(f"unknown fault action {self.action!r}")
        if self.phase not in ("send", "recv"):
            raise ValueError(f"unknown fault phase {self.phase!r}")
        if self.action in _FRAME_ACTIONS and self.phase != "send":
            raise ValueError(f"{self.action!r} faults only apply on send")


class FaultInjector:
    """Deterministic fault plan for one or more :class:`WorkerConnection`.

    Thread-safe: rule counters advance under a lock, so a fleet fan-out
    hitting the injector from the dispatch thread while a health probe
    pings through it stays deterministic. Side-effect rules (``delay``,
    ``kill``) run their effect inside :meth:`fire`; frame-level rules
    return the action for the connection to apply to the outgoing bytes.
    """

    def __init__(self, *rules: FaultRule) -> None:
        self._rules: List[FaultRule] = list(rules)
        self._lock = threading.Lock()

    def rule(self, action: str, **kw) -> "FaultInjector":
        """Append a :class:`FaultRule` (chainable)."""
        with self._lock:
            self._rules.append(FaultRule(action, **kw))
        return self

    def fire(self, phase: str, op: str) -> Optional[str]:
        """Advance counters for one call; apply side effects; return the
        frame action (``drop``/``truncate``/``corrupt``) if one fired."""
        effects: List[FaultRule] = []
        frame_action: Optional[str] = None
        with self._lock:
            for r in self._rules:
                if r.phase != phase or (r.op is not None and r.op != op):
                    continue
                r.matched += 1
                if r.nth <= r.matched < r.nth + r.count:
                    if r.action in _FRAME_ACTIONS:
                        if frame_action is None:
                            frame_action = r.action
                    else:
                        effects.append(r)
        for r in effects:  # outside the lock: callbacks/sleeps may be slow
            if r.action == "delay":
                time.sleep(r.seconds)
            elif r.callback is not None:
                r.callback()
        return frame_action


class WorkerConnection:
    """Client handle to one fleet worker, with per-call timeouts.

    ``send``/``recv`` are split so a caller can fan a request out to every
    worker *before* collecting any reply — the workers compute in parallel
    while the client is still writing to the others.
    """

    def __init__(
        self, host: str, port: int, *, timeout_s: float = 60.0,
        name: Optional[str] = None, fault: Optional[FaultInjector] = None,
    ) -> None:
        self.host = host
        self.port = port
        self.name = name or f"{host}:{port}"
        self.timeout_s = timeout_s
        #: Optional chaos seam; assign a :class:`FaultInjector` any time.
        self.fault = fault
        #: Serializes all socket use; held across each send+recv pair (see
        #: module docstring). Reentrant so ``call`` and fleet-level exchange
        #: locking compose.
        self.lock = threading.RLock()
        self._sock: Optional[socket.socket] = self._connect()

    def _connect(self) -> socket.socket:
        try:
            sock = socket.create_connection(
                (self.host, self.port), timeout=self.timeout_s
            )
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return sock
        except OSError as exc:
            raise WorkerUnavailable(self.name, "connect", str(exc)) from exc

    def reconnect(self) -> None:
        """Replace the stream with a fresh one (drops any buffered replies).

        Used after an abandoned or failed exchange: the old stream may be
        desynced mid-frame or carry a stale reply that the next call would
        consume as its own. Workers keep their loaded partition across
        client connections, so a reconnect is cheap and state-preserving.
        """
        with self.lock:
            self.close()
            self._sock = self._connect()

    def send(
        self, op: str, header: Optional[dict] = None,
        arrays: Sequence[np.ndarray] = (),
        timeout_s: Optional[float] = None,
    ) -> None:
        msg = dict(header or {})
        msg["op"] = op
        action = None if self.fault is None else self.fault.fire("send", op)
        with self.lock:
            sock = self._sock
            if sock is None:
                raise WorkerUnavailable(self.name, op, "connection closed")
            try:
                sock.settimeout(self.timeout_s if timeout_s is None
                                else timeout_s)
                if action is None:
                    send_frame(sock, msg, arrays)
                elif action == "drop":
                    pass  # frame vanishes; the matching recv will time out
                else:
                    wire = encode_frame(msg, arrays)
                    if action == "truncate":
                        sock.sendall(wire[: max(1, len(wire) // 2)])
                        self.close()  # stream desynced beyond repair
                    else:  # corrupt: oversized length prefix
                        sock.sendall(
                            _LEN.pack(MAX_FRAME_BYTES + 1) + wire[_LEN.size:]
                        )
            except (OSError, EOFError) as exc:
                self.close()  # partial write: stream desynced
                raise WorkerUnavailable(self.name, op, str(exc)) from exc

    def recv(
        self, op: str = "reply", timeout_s: Optional[float] = None,
    ) -> Tuple[dict, List[np.ndarray]]:
        if self.fault is not None:
            self.fault.fire("recv", op)  # delay/kill rules only
        with self.lock:
            sock = self._sock
            if sock is None:
                raise WorkerUnavailable(self.name, op, "connection closed")
            try:
                sock.settimeout(self.timeout_s if timeout_s is None
                                else timeout_s)
                header, arrays = recv_frame(sock)
            except (OSError, EOFError, socket.timeout) as exc:
                self.close()  # mid-frame: stream desynced until reconnect
                raise WorkerUnavailable(self.name, op, str(exc)) from exc
            except (ValueError, KeyError, TypeError, struct.error) as exc:
                # Oversized/corrupt length prefix, malformed JSON header, or
                # a bad array descriptor: the stream position is unknowable.
                self.close()
                raise WorkerUnavailable(
                    self.name, op, f"corrupt frame: {exc}"
                ) from exc
        if not header.get("ok", False):
            raise RemoteError(
                f"worker {self.name} failed {op!r}: "
                f"{header.get('error', 'unknown error')}"
            )
        return header, arrays

    def call(
        self, op: str, header: Optional[dict] = None,
        arrays: Sequence[np.ndarray] = (),
        timeout_s: Optional[float] = None,
    ) -> Tuple[dict, List[np.ndarray]]:
        with self.lock:  # no foreign frame between our send and our recv
            self.send(op, header, arrays, timeout_s)
            return self.recv(op, timeout_s)

    def close(self) -> None:
        # Lockless on purpose: kill paths must be able to close the socket
        # out from under a blocked recv in another thread.
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass
