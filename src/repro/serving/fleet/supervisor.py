"""Self-healing for the partition fleet: watch, restart, re-ship.

:class:`FleetSupervisor` drives a per-worker health state machine::

    UP ──probe fails──▶ SUSPECT ──`suspect_after` fails──▶ RESTARTING
     ▲                     │                                   │
     │                     └──probe ok──▶ UP                   │
     └──respawn + reload ok────────────────────────────────────┤
                                                               │
            budget exhausted ──▶ FAILED (terminal)  ◀──────────┘

A worker whose process has exited, or that a failed beam exchange already
marked down (:meth:`PartitionFleet.mark_down`), skips SUSPECT and goes
straight to RESTARTING. Restart attempts run with exponential backoff
(``backoff_base_s`` doubling to ``backoff_max_s``) against a
``restart_budget``; each successful attempt respawns the process, re-ships
the partition arrays through the stored load spec
(:meth:`PartitionFleet.respawn_worker` → :meth:`PartitionFleet.load_worker`),
and only then returns the pid to rotation — queries can never land on a
live-but-empty worker.

The supervisor never blocks queries: while a pid is down, the fleet's
``serve_partial`` policy keeps answering from the survivors (explicitly
degraded, survivor-exact); the supervisor's only interaction with the
query path is the atomic handle swap under the fleet's state lock.

All transitions happen inside :meth:`poll_once`, which the background
thread calls every ``poll_interval_s`` — tests drive it directly for
deterministic, wall-clock-free state machine coverage. Backoff waits are
non-blocking (a per-worker next-attempt timestamp), so one worker in a
long backoff never delays probing the others.
"""

from __future__ import annotations

import dataclasses
import logging
import threading
import time
from typing import Dict, Optional

from repro.serving.admission import WorkerUnavailable
from repro.serving.config import FleetConfig
from repro.serving.fleet.rpc import RemoteError

log = logging.getLogger(__name__)

#: Worker health states (the values appear verbatim in /healthz).
STATE_UP = "up"
STATE_SUSPECT = "suspect"
STATE_RESTARTING = "restarting"
STATE_FAILED = "failed"

WORKER_STATES = (STATE_UP, STATE_SUSPECT, STATE_RESTARTING, STATE_FAILED)


@dataclasses.dataclass
class _WorkerWatch:
    """Supervisor-side bookkeeping for one worker pid."""

    pid: int
    state: str = STATE_UP
    probe_failures: int = 0   # consecutive failed probes while SUSPECT
    restarts: int = 0         # respawn attempts consumed from the budget
    backoff_s: float = 0.0    # current inter-attempt delay
    next_attempt: float = 0.0  # monotonic time gating the next respawn
    detail: str = ""          # human-readable cause for /healthz


class FleetSupervisor:
    """Watches a :class:`PartitionFleet`; respawns and re-ships dead workers.

    Usage::

        fleet = PartitionFleet.launch(P)
        fleet.attach(engine)
        with FleetSupervisor(fleet, config.fleet) as sup:
            ...  # serve; workers now self-heal

    ``config`` is a :class:`~repro.serving.config.FleetConfig` (defaults
    apply when omitted). :meth:`states` is the gateway's ``/healthz``
    payload; :meth:`metrics` feeds ``/metrics``.
    """

    def __init__(self, fleet, config: Optional[FleetConfig] = None) -> None:
        self.fleet = fleet
        self.config = config if config is not None else FleetConfig()
        self._watch = [  # guarded-by: _lock
            _WorkerWatch(pid) for pid in range(len(fleet.handles))
        ]
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # Serializes poll_once against introspection: states()/metrics()
        # must never see a half-applied transition.
        self._lock = threading.RLock()

    # -- state machine -------------------------------------------------------
    def poll_once(self) -> None:
        """One supervision sweep over every worker (the thread's body)."""
        with self._lock:
            for w in self._watch:
                self._check(w)

    def _check(self, w: _WorkerWatch) -> None:
        if w.state == STATE_FAILED:
            return  # terminal: a human (or a redeploy) takes over
        if w.state == STATE_RESTARTING:
            if time.monotonic() >= w.next_attempt:
                self._attempt_restart(w)
            return
        # UP / SUSPECT: detect death three ways — the process exited, a
        # beam exchange already marked the pid down, or the probe fails.
        with self.fleet._state_lock:
            handle = self.fleet.handles[w.pid]
            marked_down = w.pid in self.fleet._down
        if not handle.alive():
            self._to_restarting(w, "process exited")
            return
        if marked_down:
            self._to_restarting(w, "marked down by a failed exchange")
            return
        if self._probe(handle):
            if w.state != STATE_UP:
                w.state = STATE_UP
                w.detail = ""
            w.probe_failures = 0
        else:
            w.probe_failures += 1
            w.state = STATE_SUSPECT
            w.detail = f"{w.probe_failures} consecutive failed probe(s)"
            if w.probe_failures >= self.config.suspect_after:
                self._to_restarting(w, w.detail)

    def _probe(self, handle) -> bool:
        """One bounded liveness probe; lock-busy counts as proof of life."""
        timeout = self.config.ping_timeout_s
        if not handle.conn.lock.acquire(timeout=timeout):
            return handle.alive()  # an exchange is in flight on the stream
        try:
            handle.conn.call("ping", timeout_s=timeout)
            return True
        except (WorkerUnavailable, RemoteError, RuntimeError):
            return False
        finally:
            handle.conn.lock.release()

    def _to_restarting(self, w: _WorkerWatch, why: str) -> None:
        self.fleet.mark_down(w.pid)  # degraded serving takes over now
        w.state = STATE_RESTARTING
        w.detail = why
        w.probe_failures = 0
        w.backoff_s = 0.0
        w.next_attempt = time.monotonic()  # first attempt is immediate

    def _attempt_restart(self, w: _WorkerWatch) -> None:
        cfg = self.config
        if w.restarts >= cfg.restart_budget:
            w.state = STATE_FAILED
            w.detail = f"restart budget ({cfg.restart_budget}) exhausted"
            return
        w.restarts += 1
        try:
            self.fleet.respawn_worker(w.pid)
        except Exception as exc:  # noqa: BLE001 — recorded on the watch, drives backoff
            w.backoff_s = (
                cfg.backoff_base_s if w.backoff_s == 0.0
                else min(w.backoff_s * 2.0, cfg.backoff_max_s)
            )
            w.next_attempt = time.monotonic() + w.backoff_s
            w.detail = (
                f"respawn failed ({exc}); retry in {w.backoff_s:.2f}s"
            )
            return
        w.state = STATE_UP
        w.detail = ""
        w.probe_failures = 0
        w.backoff_s = 0.0

    # -- introspection -------------------------------------------------------
    def states(self) -> Dict[str, dict]:
        """Per-worker machine state for ``/healthz``."""
        with self._lock:
            return {
                f"worker{w.pid}": {
                    "state": w.state,
                    "restarts": w.restarts,
                    "detail": w.detail,
                }
                for w in self._watch
            }

    def metrics(self) -> dict:
        """Fleet health roll-up for ``/metrics``."""
        with self._lock:
            states = [w.state for w in self._watch]
            return {
                "workers": len(states),
                "up": states.count(STATE_UP),
                "suspect": states.count(STATE_SUSPECT),
                "restarting": states.count(STATE_RESTARTING),
                "failed": states.count(STATE_FAILED),
                "restarts_total": sum(w.restarts for w in self._watch),
                "degraded_policy": self.fleet.degraded_policy,
            }

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "FleetSupervisor":
        if self._thread is not None:
            raise RuntimeError("FleetSupervisor already started")
        self.fleet.supervisor = self
        self._thread = threading.Thread(
            target=self._run, name="xmr-fleet-supervisor", daemon=True
        )
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.config.poll_interval_s):
            try:
                self.poll_once()
            except Exception:  # noqa: BLE001 — supervision must survive any sweep
                # A sweep must never kill supervision (e.g. a handle racing
                # close()); the next sweep re-observes from scratch.
                log.exception("supervision sweep failed; retrying next poll")

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        if getattr(self.fleet, "supervisor", None) is self:
            self.fleet.supervisor = None

    def __enter__(self) -> "FleetSupervisor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
