from repro.serving.batcher import BatchPolicy, MicroBatcher, RequestQueue
from repro.serving.engine import ServeConfig, XMRServingEngine, resolve_method
from repro.serving.metrics import LatencyStats, ServerMetrics

__all__ = [
    "BatchPolicy",
    "LatencyStats",
    "MicroBatcher",
    "RequestQueue",
    "ServeConfig",
    "ServerMetrics",
    "XMRServingEngine",
    "resolve_method",
]
