"""Serving tier: engine, micro-batcher, admission, metrics, fleet, gateway.

``__all__`` is the **Public API v1** surface (documented in the README
table); everything else in the submodules is internal and may change
without notice. The cross-process fleet lives in :mod:`repro.serving.fleet`
(imported lazily — spawning workers is opt-in).
"""

from repro.serving.admission import (
    AdmissionController,
    AdmissionPolicy,
    DeadlineExceeded,
    Overloaded,
    ServingError,
    WorkerUnavailable,
)
from repro.serving.api import (
    HTTP_STATUS,
    WIRE_VERSION,
    Query,
    QueryResult,
    WireError,
    status_for_exception,
)
from repro.serving.batcher import (
    BatchPolicy,
    MicroBatcher,
    RequestQueue,
    StreamResult,
)
from repro.serving.config import (
    AdmissionConfig,
    FleetConfig,
    PartitionConfig,
    QuantConfig,
    ServeConfig,
    SLOConfig,
)
from repro.serving.engine import XMRServingEngine, resolve_method
from repro.serving.gateway import ServingGateway
from repro.serving.metrics import LatencyStats, ServerMetrics
from repro.serving.slo import BeamTier, BeamTierPolicy, resolve_tiers

__all__ = [
    # configuration
    "AdmissionConfig",
    "FleetConfig",
    "PartitionConfig",
    "QuantConfig",
    "ServeConfig",
    "SLOConfig",
    # adaptive beam tiers
    "BeamTier",
    "BeamTierPolicy",
    "resolve_tiers",
    # engine + front end
    "BatchPolicy",
    "MicroBatcher",
    "XMRServingEngine",
    "resolve_method",
    # request/response currency + wire schema
    "HTTP_STATUS",
    "Query",
    "QueryResult",
    "WIRE_VERSION",
    "WireError",
    "status_for_exception",
    # typed errors
    "DeadlineExceeded",
    "Overloaded",
    "ServingError",
    "WorkerUnavailable",
    # admission + metrics
    "AdmissionController",
    "AdmissionPolicy",
    "LatencyStats",
    "ServerMetrics",
    # network edge
    "ServingGateway",
    # legacy aliases
    "RequestQueue",
    "StreamResult",
]
