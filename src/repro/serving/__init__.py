from repro.serving.admission import (
    AdmissionController,
    AdmissionPolicy,
    DeadlineExceeded,
    Overloaded,
    ServingError,
)
from repro.serving.batcher import (
    BatchPolicy,
    MicroBatcher,
    RequestQueue,
    StreamResult,
)
from repro.serving.engine import ServeConfig, XMRServingEngine, resolve_method
from repro.serving.metrics import LatencyStats, ServerMetrics

__all__ = [
    "AdmissionController",
    "AdmissionPolicy",
    "BatchPolicy",
    "DeadlineExceeded",
    "LatencyStats",
    "MicroBatcher",
    "Overloaded",
    "RequestQueue",
    "ServeConfig",
    "ServerMetrics",
    "ServingError",
    "StreamResult",
    "XMRServingEngine",
    "resolve_method",
]
