from repro.serving.engine import LatencyStats, ServeConfig, XMRServingEngine
