"""Admission control for the micro-batching server.

Under sustained overload an unbounded request queue converts every incoming
query into latency: the queue grows without bound, every request eventually
completes, and P99 is whatever backlog happened to accumulate — the classic
open-loop failure mode. Production XMR serving (the traffic regime of the
paper's §6 enterprise deployment) instead *sheds* load at a bounded queue
depth so the requests it does serve stay within their latency budget.

This module provides the pieces the batcher wires in:

* :class:`Overloaded` / :class:`DeadlineExceeded` — typed errors a shed or
  expired request's future resolves with (clients can distinguish "retry
  elsewhere" from a real failure).
* :class:`AdmissionPolicy` — queue-depth bound, shed policy, and the default
  per-request deadline.
* :class:`AdmissionController` — applies the policy at enqueue time (under
  the queue lock, so depth checks are race-free) and expires requests at
  dispatch time so a query past its deadline never burns device time.

Shed policies:

``reject``
    The *new* request is refused: its future resolves with
    :class:`Overloaded` and the queue is untouched. Favors requests already
    waiting (FIFO fairness under overload).
``shed-oldest``
    The oldest *queued* request is dropped and the new one admitted. Favors
    freshness: under overload the oldest request is the most likely to blow
    its deadline anyway, so shedding it wastes the least useful work.

    With **priority classes** (``MicroBatcher.submit(priority=...)``, higher
    = more important) the victim is the oldest request of the *lowest*
    priority present — weighted shedding: background traffic is sacrificed
    first, and a low-priority arrival at a queue full of higher-priority
    work is itself refused rather than displacing it.

``max_queue_depth="auto"``
    Resolved by ``MicroBatcher.start()`` from the measured drain rate times
    the deadline budget (see :meth:`MicroBatcher._auto_queue_depth`): the
    queue holds no more work than the device can clear within a request's
    latency budget. Until resolved (a batcher that never started), the
    bound is inactive.
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Deque, List, Optional, Union

if TYPE_CHECKING:  # circular at runtime: batcher/metrics import this module
    from repro.serving.batcher import _Request
    from repro.serving.metrics import ServerMetrics

SHED_REJECT = "reject"
SHED_OLDEST = "shed-oldest"
SHED_POLICIES = (SHED_REJECT, SHED_OLDEST)


class ServingError(RuntimeError):
    """Base class for typed serving-tier request failures."""


class Overloaded(ServingError):
    """Request shed by admission control (bounded queue was full)."""

    def __init__(self, queue_depth: int, policy: str):
        super().__init__(
            f"request shed: queue depth bound {queue_depth} reached "
            f"(policy={policy!r})"
        )
        self.queue_depth = queue_depth
        self.policy = policy


class DeadlineExceeded(ServingError):
    """Request expired before dispatch; no device time was spent on it."""

    def __init__(self, waited_ms: float, deadline_ms: float):
        super().__init__(
            f"request deadline exceeded before dispatch: waited "
            f"{waited_ms:.2f} ms > {deadline_ms:.2f} ms budget"
        )
        self.waited_ms = waited_ms
        self.deadline_ms = deadline_ms


class WorkerUnavailable(ServingError):
    """A fleet partition worker died or timed out mid-request.

    Raised by the fleet RPC layer (:mod:`repro.serving.fleet`) when a
    partition process is unreachable — connection refused/reset, EOF, or a
    per-call timeout. The batcher fails the in-flight batch's futures with
    it (never hangs), and the gateway maps it to HTTP 503: the request *may*
    be retried once the fleet is repaired, unlike a 4xx.
    """

    def __init__(self, worker: str, op: str, cause: str):
        super().__init__(
            f"fleet worker {worker} unavailable during {op!r}: {cause}"
        )
        self.worker = worker
        self.op = op
        self.cause = cause


@dataclasses.dataclass
class AdmissionPolicy:
    """Overload policy for a :class:`~repro.serving.batcher.MicroBatcher`.

    ``max_queue_depth=None`` disables the bound (the pre-admission-control
    behavior); ``"auto"`` defers it to the batcher's capacity probe at
    ``start()``; ``deadline_ms=None`` disables per-request deadlines.
    """

    max_queue_depth: Union[int, str, None] = None
    shed_policy: str = SHED_REJECT
    deadline_ms: Optional[float] = None

    def __post_init__(self) -> None:
        if self.shed_policy not in SHED_POLICIES:
            raise ValueError(
                f"shed_policy={self.shed_policy!r}; choose from {SHED_POLICIES}"
            )
        if isinstance(self.max_queue_depth, str):
            if self.max_queue_depth != "auto":
                raise ValueError(
                    f"max_queue_depth={self.max_queue_depth!r}; the only "
                    'string value is "auto"'
                )
        elif self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError('max_queue_depth must be >= 1, None, or "auto"')


class AdmissionController:
    """Applies an :class:`AdmissionPolicy` at the queue boundary.

    ``admit`` runs under the request-queue lock (depth check and shed are
    atomic with the append); ``expire`` runs on the worker thread at batch
    dispatch. Both resolve futures with typed errors and record into
    ``metrics`` — neither ever raises into the caller.
    """

    def __init__(self, policy: AdmissionPolicy, metrics: "ServerMetrics") -> None:
        self.policy = policy
        self.metrics = metrics

    def stamp_deadline(self, req: "_Request") -> None:
        """Attach the policy's default deadline to a request lacking one."""
        if req.t_deadline is None and self.policy.deadline_ms is not None:
            req.t_deadline = req.t_enqueue + 1e-3 * self.policy.deadline_ms

    def admit(self, queue: "Deque[_Request]", req: "_Request") -> bool:
        """Decide admission for ``req`` against the live deque ``queue``.

        Returns True if ``req`` should be appended. On shed, the victim's
        future (the new request under ``reject``, the oldest lowest-priority
        queued request under ``shed-oldest``) resolves with
        :class:`Overloaded`. ``"auto"`` depth is inactive until the batcher
        resolves it at ``start()``.
        """
        depth = self.policy.max_queue_depth
        if depth is None or depth == "auto" or len(queue) < depth:
            return True
        prio = getattr(req, "priority", 0)
        if self.policy.shed_policy == SHED_OLDEST:
            # Weighted shed-oldest: victim = oldest request of the lowest
            # priority present — unless everything queued outranks the new
            # arrival, in which case the arrival itself is refused.
            floor = min(getattr(r, "priority", 0) for r in queue)
            if floor <= prio:
                vi = next(
                    i for i, r in enumerate(queue)
                    if getattr(r, "priority", 0) == floor
                )
                victim = queue[vi]
                del queue[vi]  # not .remove(): dataclass eq on array fields
                victim.future.set_exception(Overloaded(depth, SHED_OLDEST))
                self.metrics.record_shed(getattr(victim, "priority", 0))
                return True
        req.future.set_exception(Overloaded(depth, self.policy.shed_policy))
        self.metrics.record_shed(prio)
        return False

    def expire(
        self, reqs: "List[_Request]", now: Optional[float] = None
    ) -> "List[_Request]":
        """Split a formed batch into live requests, failing expired ones.

        Called at dispatch time so an expired request never reaches the
        device. Returns the surviving (still-live) requests in order.
        """
        if now is None:
            now = time.perf_counter()
        live: "List[_Request]" = []
        for r in reqs:
            if r.t_deadline is not None and now >= r.t_deadline:
                waited = 1e3 * (now - r.t_enqueue)
                budget = 1e3 * (r.t_deadline - r.t_enqueue)
                r.future.set_exception(DeadlineExceeded(waited, budget))
                self.metrics.record_deadline_miss()
            else:
                live.append(r)
        return live
