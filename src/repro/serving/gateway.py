"""HTTP front door for the micro-batching server (stdlib only).

``ServingGateway`` puts a :class:`~repro.serving.batcher.MicroBatcher`
behind three endpoints:

* ``POST /v1/query`` — body is a :class:`~repro.serving.api.Query` wire
  document (``{"v": 1, "idx": [...], "val": [...]}``); the response is the
  :class:`~repro.serving.api.QueryResult` wire document with the HTTP code
  derived from its status: 200 ok, 429 overloaded, 504 deadline exceeded,
  503 worker unavailable, 400 invalid, 500 internal.
* ``GET /healthz`` — 200 when serving; with a fleet attached, pings every
  worker (one concurrent bounded sweep) and reports per-worker liveness
  plus, when a :class:`~repro.serving.fleet.FleetSupervisor` is running,
  each worker's health-machine state. Dead workers degrade the status:
  503 under ``degraded_policy="reject"`` (queries are failing) but 200
  ``"degraded"`` under ``"serve_partial"`` while at least one worker
  lives — the tier is still answering, partially and flagged, so an LB
  must not eject it. Pings are serialized with in-flight beam exchanges
  by the per-connection RPC lock, so an LB probe landing mid-query can
  never interleave frames with the dispatch thread on a worker socket.
* ``GET /metrics`` — :meth:`ServerMetrics.summary` as JSON, plus a
  ``"fleet"`` roll-up (up/suspect/restarting/failed worker counts and
  total restarts) when a supervisor is attached.

The float32 scores survive the JSON round trip bit-for-bit (see
:mod:`repro.serving.api`), so gateway-served results are bitwise-identical
to in-process ``XMRServingEngine`` output — the house exactness contract
holds across the network edge.
"""

from __future__ import annotations

import json
import threading
from concurrent.futures import TimeoutError as FutureTimeout
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

import numpy as np

from repro.serving.api import (
    HTTP_STATUS,
    STATUS_DEADLINE_EXCEEDED,
    STATUS_INVALID,
    STATUS_WORKER_UNAVAILABLE,
    WIRE_VERSION,
    Query,
    WireError,
)
from repro.serving.batcher import MicroBatcher


def _json_safe(obj):
    """Recursively convert numpy scalars/arrays for json.dumps."""
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


class ServingGateway:
    """HTTP edge over a started :class:`MicroBatcher`.

    Usage::

        with MicroBatcher(engine) as mb, ServingGateway(mb, port=8080) as gw:
            ...  # POST http://127.0.0.1:8080/v1/query

    ``fleet`` (a :class:`~repro.serving.fleet.PartitionFleet`) opts
    ``/healthz`` into per-worker liveness. ``request_timeout_s`` bounds how
    long one HTTP request may wait on its future — a backstop behind the
    per-request deadlines; hitting it answers 504.
    """

    def __init__(
        self,
        batcher: MicroBatcher,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        fleet=None,
        request_timeout_s: float = 120.0,
    ) -> None:
        self.batcher = batcher
        self.fleet = fleet
        self.request_timeout_s = request_timeout_s
        gateway = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def log_message(self, *args) -> None:  # quiet by default
                pass

            def _reply(self, code: int, doc: dict) -> None:
                body = json.dumps(_json_safe(doc)).encode()
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self) -> None:
                if self.path == "/healthz":
                    code, doc = gateway._healthz()
                    self._reply(code, doc)
                elif self.path == "/metrics":
                    code, doc = gateway._metrics()
                    self._reply(code, doc)
                else:
                    self._reply(404, {"v": WIRE_VERSION, "detail": "not found"})

            def do_POST(self) -> None:
                if self.path != "/v1/query":
                    self._reply(404, {"v": WIRE_VERSION, "detail": "not found"})
                    return
                length = int(self.headers.get("Content-Length", 0))
                code, doc = gateway._query(self.rfile.read(length))
                self._reply(code, doc)

        self._server = ThreadingHTTPServer((host, port), Handler)
        self._server.daemon_threads = True
        self.host, self.port = self._server.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    # -- endpoint bodies ----------------------------------------------------
    def _error_doc(self, status: str, detail: str) -> tuple:
        return HTTP_STATUS[status], {
            "v": WIRE_VERSION, "status": status, "detail": detail,
        }

    def _query(self, body: bytes) -> tuple:
        try:
            query = Query.from_wire(json.loads(body))
        except (WireError, ValueError, TypeError) as exc:
            return self._error_doc(STATUS_INVALID, str(exc))
        try:
            fut = self.batcher.submit(query)
        except RuntimeError as exc:  # queue closed: server shutting down
            return self._error_doc(STATUS_WORKER_UNAVAILABLE, str(exc))
        try:
            res = fut.result(timeout=self.request_timeout_s)
        except FutureTimeout:
            return self._error_doc(
                STATUS_DEADLINE_EXCEEDED,
                f"no result within {self.request_timeout_s:.0f}s",
            )
        return res.http_status, res.to_wire()

    def _healthz(self) -> tuple:
        doc = {"v": WIRE_VERSION, "status": "ok"}
        if self.batcher.queue.closed:
            doc["status"] = "closed"
            return 503, doc
        if self.fleet is not None:
            workers = self.fleet.ping()
            doc["workers"] = workers
            supervisor = getattr(self.fleet, "supervisor", None)
            if supervisor is not None:
                doc["supervision"] = supervisor.states()
            if not all(workers.values()):
                doc["status"] = "degraded"
                policy = getattr(self.fleet, "degraded_policy", "reject")
                doc["degraded_policy"] = policy
                if policy == "serve_partial" and any(workers.values()):
                    # Still answering (partial, flagged on the wire): 200
                    # so load balancers keep routing; operators read the
                    # "degraded" status + supervision states instead.
                    return 200, doc
                return 503, doc
        return 200, doc

    def _metrics(self) -> tuple:
        doc = {"v": WIRE_VERSION, **self.batcher.metrics.summary()}
        supervisor = (
            getattr(self.fleet, "supervisor", None)
            if self.fleet is not None else None
        )
        if supervisor is not None:
            doc["fleet"] = supervisor.metrics()
        return 200, doc

    # -- lifecycle ----------------------------------------------------------
    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ServingGateway":
        if self._thread is not None:
            raise RuntimeError("ServingGateway already started")
        self._thread = threading.Thread(
            target=self._server.serve_forever, kwargs={"poll_interval": 0.05},
            name="xmr-gateway", daemon=True,
        )
        self._thread.start()
        return self

    def close(self) -> None:
        if self._thread is not None:
            self._server.shutdown()
            self._thread.join()
            self._thread = None
        self._server.server_close()

    def __enter__(self) -> "ServingGateway":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()
