"""Latency-SLO adaptive inference: degrade beam width instead of shedding.

The overload tier (:mod:`repro.serving.admission`) protects latency by
dropping whole queries. Baharav et al. (arXiv 2106.00730) formalize the
smoother knob label trees already have: beam width trades recall for
latency continuously, so a backlogged server can serve *every* query at a
narrower beam instead of serving most at full beam and shedding the rest.

This module is the policy half of that trade:

* :class:`BeamTier` — one rung of the ladder: a ``(beam, qt)`` pair. Tier 0
  is always the engine's configured full beam; deeper tiers are narrower.
  :func:`resolve_tiers` derives the ladder from :class:`~repro.serving
  .config.SLOConfig` (explicit pairs, or beam-halving down to ``min_beam``).
* :class:`BeamTierPolicy` — the dispatch-time selector the
  :class:`~repro.serving.batcher.MicroBatcher` consults per formed batch.
  It is calibrated once at startup with the same drain-rate probe that
  backs ``queue_depth="auto"`` (``XMRServingEngine.measure_batch_seconds``,
  run once per tier — which also warms each tier's jit bucket), then picks
  the *fullest* tier whose measured batch cost, multiplied by the batches
  already queued ahead, fits the batch's remaining deadline budget.

The tier set is a bounded static ladder fixed at engine build (XMR003:
every ``(bucket, tier)`` pair is one jit cache entry, warmed up front), and
tier choice is coordinator-side only — partitioned and fleet dispatch
receive the chosen ``(beam, qt)`` per batch, so partition-local selects
stay bitwise-exact *at that tier*, and tier 0 stays bitwise-identical to a
server without an SLO configured.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

__all__ = ["BeamTier", "BeamTierPolicy", "resolve_tiers"]


@dataclasses.dataclass(frozen=True)
class BeamTier:
    """One rung of the adaptive ladder: the static args it dispatches with."""

    beam: int
    qt: int


def resolve_tiers(config) -> Tuple[BeamTier, ...]:
    """The engine's tier ladder for a :class:`~repro.serving.config
    .ServeConfig` — ``(full, degraded...)``, full first.

    With the SLO disabled (``slo.target_p99_ms is None``) the ladder is just
    the full tier: nothing anywhere in the serving path can pick a degraded
    beam, so behavior is identical to a config without the group. Explicit
    ``slo.tiers`` pairs are validated against the full beam; the auto ladder
    halves the beam down to ``slo.min_beam``.
    """
    full = BeamTier(int(config.beam), int(config.qt))
    slo = config.slo
    if slo.target_p99_ms is None:
        return (full,)
    if slo.tiers:
        ladder = [BeamTier(int(b), int(q)) for b, q in slo.tiers]
        if ladder and ladder[0].beam >= full.beam:
            raise ValueError(
                f"degraded tier beam {ladder[0].beam} must be narrower "
                f"than the configured full beam {full.beam}"
            )
    else:
        ladder, b = [], full.beam // 2
        while b >= max(slo.min_beam, 1):
            ladder.append(BeamTier(b, full.qt))
            b //= 2
    return (full, *ladder)


class BeamTierPolicy:
    """Dispatch-time beam-tier selection from queue depth + deadline budget.

    The cost model is measured, not assumed: :meth:`calibrate` probes one
    full-bucket dispatch per tier (median of a few warmed runs — the same
    probe ``queue_depth="auto"`` uses to bound admission) so the selector
    works in the same units as the SLO. :meth:`select` then answers, per
    formed batch: *given how many batches are queued ahead of this one,
    what is the fullest beam the device can afford and still clear the
    backlog inside this batch's remaining budget?*
    """

    def __init__(
        self,
        tiers: Sequence[BeamTier],
        *,
        target_ms: float,
        bucket: int,
    ) -> None:
        if not tiers:
            raise ValueError("a BeamTierPolicy needs at least one tier")
        if target_ms <= 0:
            raise ValueError(f"target_ms must be positive; got {target_ms}")
        if bucket < 1:
            raise ValueError(f"bucket must be >= 1; got {bucket}")
        self.tiers: Tuple[BeamTier, ...] = tuple(tiers)
        self.target_ms = float(target_ms)
        self.bucket = int(bucket)
        #: Measured full-bucket dispatch cost per tier (ms), monotone
        #: non-increasing in tier index after calibration.
        self.cost_ms: Optional[List[float]] = None

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    @property
    def calibrated(self) -> bool:
        return self.cost_ms is not None

    def calibrate(self, probe_cost_ms) -> "BeamTierPolicy":
        """Measure per-tier batch cost via ``probe_cost_ms(tier) -> ms``.

        The probe is the engine's warmed drain-rate measurement; running it
        per tier also warms each tier's coalescing bucket, so the first
        degraded dispatch under live overload never pays an XLA compile.
        A narrower beam can't honestly cost more than a wider one — probe
        jitter on shared hardware can still measure it that way, so costs
        are clamped monotone; the policy must never prefer a *narrower*
        beam while claiming the same latency.
        """
        costs: List[float] = []
        for k in range(len(self.tiers)):
            c = float(probe_cost_ms(k))
            if costs:
                c = min(c, costs[-1])
            costs.append(c)
        self.cost_ms = costs
        return self

    def select(self, *, queue_depth: int, budget_ms: Optional[float]) -> int:
        """Tier index for a batch dispatched now.

        ``queue_depth`` is the number of requests still queued *behind*
        this batch; ``budget_ms`` the batch's remaining deadline budget
        (``None`` = only the SLO target applies). The chosen tier is the
        fullest whose cost times the backlog's batch count fits the
        budget; if none fits, the deepest tier — degrade, don't shed.
        """
        if self.cost_ms is None:
            return 0
        budget = self.target_ms if budget_ms is None else min(
            self.target_ms, float(budget_ms)
        )
        backlog_batches = 1 + math.ceil(max(queue_depth, 0) / self.bucket)
        for k, cost in enumerate(self.cost_ms):
            if cost * backlog_batches <= budget:
                return k
        return len(self.tiers) - 1
