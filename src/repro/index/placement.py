"""Partition → device placement over a ``("data", "model")`` mesh.

Each partition is pinned to one **model column** of the mesh; a column's
``n_data`` devices are data-parallel replicas of everything placed there
(batch dims split over ``"data"``, exactly PR 3's replica dispatch — the two
axes compose: ``ServeConfig(partitions=P, shards=N)`` is model-parallel ×
data-parallel through the same micro-batching front end).

More partitions than columns is normal (one big host serving a tree sliced
P ways): partitions are packed onto columns with longest-processing-time
greedy bin packing over the manifest's per-partition ``memory_bytes``, the
classic 4/3-approximation for balanced bins.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import partition_mesh
from repro.index.partition import PartitionedIndex, PartitionManifest


def assign_partitions(
    memory_bytes: Sequence[int], n_bins: int
) -> List[int]:
    """LPT greedy: heaviest partition first onto the lightest bin.

    Returns the bin (mesh model-column) index per partition.
    """
    if n_bins < 1:
        raise ValueError(f"n_bins must be >= 1; got {n_bins}")
    order = np.argsort(-np.asarray(memory_bytes, dtype=np.int64), kind="stable")
    load = np.zeros(n_bins, dtype=np.int64)
    out = [0] * len(memory_bytes)
    for pid in order:
        bin_ = int(np.argmin(load))
        out[int(pid)] = bin_
        load[bin_] += int(memory_bytes[pid])
    return out


@dataclasses.dataclass
class Placement:
    """Resolved device plan for a partitioned index."""

    mesh: Mesh                       # ("data", "model"), shape (n_data, n_model)
    assignments: List[int]           # partition -> model column
    array_shardings: List[Any]       # per partition: replicate over its column
    batch_shardings: List[Any]       # per partition: batch split over "data"
    coordinator: Any                 # device for route/gather/select steps

    @property
    def n_data(self) -> int:
        return int(self.mesh.shape["data"])

    @property
    def n_model(self) -> int:
        return int(self.mesh.shape["model"])

    def column_loads(self, manifest: PartitionManifest) -> List[int]:
        """Resident model bytes per mesh column (balance diagnostics)."""
        load = [0] * self.n_model
        for info, col in zip(manifest.partitions, self.assignments):
            load[col] += info.memory_bytes
        return load


def place(
    index: PartitionedIndex,
    *,
    shards: int = 1,
    devices: Optional[Sequence[Any]] = None,
    occupancy: Optional[Sequence[float]] = None,
) -> Placement:
    """Map ``index``'s partitions onto local devices.

    ``shards`` is the data-parallel width (PR 3's replica count); the model
    width is ``min(P, n_devices // shards)`` — as many columns as the device
    budget affords, never more than there are partitions.

    By default columns are balanced by resident ``memory_bytes`` (capacity).
    Pass observed per-partition ``occupancy`` shares (``ServerMetrics.
    partition_occupancy`` or ``HotBeamCache.occupancy()``) to balance by
    expected *load* instead — under the skewed traffic the hot-beam cache
    exploits, memory-balanced columns can be compute-imbalanced.
    """
    devices = list(devices if devices is not None else jax.devices())
    if shards < 1:
        raise ValueError(f"shards must be >= 1; got {shards}")
    if shards > len(devices):
        raise ValueError(
            f"shards={shards}: only {len(devices)} local devices "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count on CPU)"
        )
    n_model = max(1, min(index.n_partitions, len(devices) // shards))
    mesh = partition_mesh(shards, n_model, devices=devices)
    if occupancy is not None:
        occ = np.asarray(occupancy, dtype=np.float64)
        if occ.shape != (index.n_partitions,) or np.any(occ < 0):
            raise ValueError(
                f"occupancy must hold {index.n_partitions} non-negative "
                f"shares; got {occupancy!r}"
            )
        # Integerize for the LPT packer; resolution of 1e-6 of total load.
        load = [int(round(o * 1_000_000)) for o in occ]
    else:
        load = [p.memory_bytes for p in index.manifest.partitions]
    assignments = assign_partitions(load, n_model)
    array_shardings, batch_shardings = [], []
    for col in assignments:
        col_devices = np.asarray(mesh.devices)[:, col]
        sub = Mesh(col_devices, ("data",))
        array_shardings.append(NamedSharding(sub, P()))
        batch_shardings.append(NamedSharding(sub, P("data")))
    # Coordinator (route/merge/select steps): prefer a device OUTSIDE the
    # mesh when the budget leaves one idle — a coordinator sharing a
    # partition's device queues its per-level select behind that
    # partition's matmul, serializing exactly the exchange the pipelined
    # sync mode overlaps.
    n_used = shards * n_model
    coordinator = devices[n_used] if n_used < len(devices) else devices[0]
    return Placement(
        mesh=mesh,
        assignments=assignments,
        array_shardings=array_shardings,
        batch_shardings=batch_shardings,
        coordinator=coordinator,
    )
