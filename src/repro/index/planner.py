"""Scatter–gather query planner over a :class:`PartitionedIndex`.

Query path (``sync="level"``, the default, **bitwise-exact**):

1. **route** — the replicated router head runs the ordinary jitted beam
   search over the levels above the split, producing the global beam.
2. **scatter** — the beam is broadcast to every partition; each partition
   scores *only the beam rows it owns* (out-of-range rows park on its
   phantom chunk) through :func:`repro.core.tree.level_combined` — the same
   arithmetic the unpartitioned traversal uses, on sliced layers with
   identical ELL pad widths, so owned rows are bit-identical.
3. **gather + select** — the planner reassembles the global ``[n, b, B]``
   candidate tensor from the owners and applies the canonical
   (score desc, id asc) :func:`~repro.core.beam.beam_select`. Steps 2–3
   repeat per partitioned level; the final level's select *is* the global
   top-k — results are **bitwise-identical** to the unpartitioned tree for
   every MSCM method (pinned by tests and a structural benchmark flag).

Why per-level gathers: beam search prunes globally at every level. A
partition-local beam keeps candidates global pruning discarded, and their
descendants can out-rank reference results at the leaves — a single final
merge is a (weakly better, recall ≥) *different* ranking. That mode exists
too (``sync="final"``): each partition runs the whole jitted sub-tree
traversal from the router handoff (one merge, no per-level sync — the
low-communication production topology); its top-k scores dominate the exact
result's but are not bitwise-reproducible, so serving defaults to
``"level"``.

Communication is activations only — ``[n, b]`` beams out, ``[n, b, B]``
candidates back, per level — while the weights stay put: with a
:class:`~repro.index.placement.Placement` each partition lives on its own
device (column of the ``("data", "model")`` mesh), batches split over the
data axis, and partitions score concurrently (JAX dispatch is async; the
gather only synchronizes at the select).
"""

from __future__ import annotations

import functools
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mscm as mscm_lib
from repro.core.beam import NEG_INF, beam_select
from repro.core.tree import level_combined
from repro.index.partition import PartitionedIndex
from repro.index.placement import Placement


def reference_topk_width(
    n_cols: Sequence[int], branching: Sequence[int], beam: int, topk: int
) -> int:
    """Output width of the unpartitioned ``infer`` for these settings.

    Mirrors the traversal's clamps: ``next_b = min(beam-or-topk, n_cols)``
    further clamped by the candidate count ``b · B`` (jnp slicing clamps).
    """
    b = 1
    for li, ncol in enumerate(n_cols):
        want = topk if li == len(n_cols) - 1 else beam
        b = min(want, int(ncol), b * int(branching[li]))
    return b


@functools.partial(
    jax.jit,
    static_argnames=("branching", "d", "method", "score_mode", "qt"),
)
def _owned_level_scores(
    layer,
    x_idx: jax.Array,
    x_val: jax.Array,
    x_dense: Optional[jax.Array],
    parent_ids: jax.Array,     # int32 [n, b] GLOBAL chunk ids at this level
    parent_scores: jax.Array,  # f32 [n, b]
    chunk_start: jax.Array,    # scalar: partition's first global chunk
    chunk_count: jax.Array,    # scalar: partition's real chunk count
    *,
    branching: int,
    d: int,
    method: str,
    score_mode: str,
    qt: int,
) -> Tuple[jax.Array, jax.Array]:
    """One partition's owned slice of a level: ([n, b, B] combined, owned).

    Unowned rows park on the phantom chunk (index ``chunk_count`` — the
    all-sentinel pad :meth:`XMRTree.extract` appends) and return exactly
    ``NEG_INF``; owned rows are bitwise what the full tree computes for the
    same (query, parent) pair. ``chunk_start``/``chunk_count`` are traced so
    equal-shape partitions share one compilation.
    """
    owned = (parent_ids >= chunk_start) & (parent_ids < chunk_start + chunk_count)
    local_ids = jnp.where(owned, parent_ids - chunk_start, chunk_count)
    local_scores = jnp.where(owned, parent_scores, NEG_INF)
    combined = level_combined(
        layer, branching, d, x_idx, x_val, x_dense,
        local_ids.astype(jnp.int32), local_scores,
        method=method, score_mode=score_mode, qt=qt,
    )
    return jnp.where(owned[..., None], combined, NEG_INF), owned


@functools.partial(jax.jit, static_argnames=("n_cols", "next_b"))
def _gather_select(
    parent_ids: jax.Array,
    parts_combined: Tuple[jax.Array, ...],
    parts_owned: Tuple[jax.Array, ...],
    *,
    n_cols: int,
    next_b: int,
) -> Tuple[jax.Array, jax.Array]:
    """Compose the owners' slices into the global candidate tensor + select.

    Every beam row is owned by at most one partition; rows owned by none
    (global phantoms) stay ``NEG_INF``, exactly what the canonical mask
    pins them to in the unpartitioned traversal.
    """
    acc = jnp.full_like(parts_combined[0], NEG_INF)
    for combined, owned in zip(parts_combined, parts_owned):
        acc = jnp.where(owned[..., None], combined, acc)
    return beam_select(parent_ids, acc, n_cols, next_b)


@functools.partial(jax.jit, static_argnames=("width",))
def merge_topk(
    scores: jax.Array, labels: jax.Array, *, width: int
) -> Tuple[jax.Array, jax.Array]:
    """Canonical (score desc, id asc) top-``width`` of concatenated
    per-partition candidates — the ``sync="final"`` merge."""
    neg_sorted, id_sorted = jax.lax.sort(
        (-scores, labels), dimension=1, num_keys=2
    )
    return -neg_sorted[:, :width], id_sorted[:, :width].astype(jnp.int32)


_scatter_dense = jax.jit(mscm_lib.scatter_dense, static_argnums=2)

SYNC_MODES = ("level", "final")


class ScatterGatherPlanner:
    """Executes partitioned queries; see the module docstring for the path.

    With ``placement`` the partitions' layer tensors are copied onto their
    assigned mesh columns at construction and every scatter/gather hop is an
    explicit ``device_put`` (batch dim split over the column's data axis);
    without one, everything runs on the default device — same arithmetic,
    same results.
    """

    def __init__(
        self,
        index: PartitionedIndex,
        *,
        beam: int = 10,
        topk: int = 10,
        method: str = "mscm_dense",
        score_mode: str = "prod",
        qt: int = 8,
        sync: str = "level",
        placement: Optional[Placement] = None,
    ) -> None:
        if sync not in SYNC_MODES:
            raise ValueError(f"sync={sync!r}; choose from {SYNC_MODES}")
        self.index = index
        self.beam = beam
        self.topk = topk
        self.method = method
        self.score_mode = score_mode
        self.qt = qt
        self.sync = sync
        self.placement = placement
        self.parts = index.parts
        if placement is not None:
            if len(placement.array_shardings) != index.n_partitions:
                raise ValueError(
                    f"placement covers {len(placement.array_shardings)} "
                    f"partitions, index has {index.n_partitions}"
                )
            self.parts = [
                p.device_put(sh)
                for p, sh in zip(index.parts, placement.array_shardings)
            ]
        self._needs_dense = method in (
            "mscm_dense", "mscm_pallas", "mscm_pallas_pregather",
            "mscm_pallas_grouped",
        )

    # -- device hops --------------------------------------------------------
    def _to_partition(self, pid: int, *arrays):
        if self.placement is None:
            return arrays
        sh = self.placement.batch_shardings[pid]
        return tuple(jax.device_put(a, sh) for a in arrays)

    def _to_coordinator(self, *arrays):
        if self.placement is None:
            return arrays
        dev = self.placement.coordinator
        return tuple(jax.device_put(a, dev) for a in arrays)

    # -- query path ---------------------------------------------------------
    def _route(self, x_idx: jax.Array, x_val: jax.Array):
        """Router head: the global beam after the levels above the split."""
        return self.index.head.infer(
            x_idx, x_val, beam=self.beam, topk=self.beam,
            method=self.method, score_mode=self.score_mode, qt=self.qt,
        )

    def _partition_inputs(self, x_idx, x_val):
        """Per-partition (xi, xv, x_dense) resident on the partition's devices.

        The dense [n, d+1] query table is the expensive piece (d can be
        millions); partitions sharing a batch sharding — all of them when no
        placement is set, column-mates under LPT packing — share one copy.
        """
        out, by_sharding = [], {}
        for pid in range(self.index.n_partitions):
            key = (
                self.placement.batch_shardings[pid]
                if self.placement is not None else None
            )
            if key not in by_sharding:
                xi_p, xv_p = self._to_partition(pid, x_idx, x_val)
                xd_p = (
                    _scatter_dense(xi_p, xv_p, self.index.d)
                    if self._needs_dense else None
                )
                by_sharding[key] = (xi_p, xv_p, xd_p)
            out.append(by_sharding[key])
        return out

    def infer(
        self, x_idx: jax.Array, x_val: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        """Global (scores [n, k], labels [n, k]) for a query batch."""
        scores, parent_ids = self._route(x_idx, x_val)
        if self.sync == "final":
            return self._infer_final(x_idx, x_val, parent_ids, scores)
        return self._infer_level(x_idx, x_val, parent_ids, scores)

    def _infer_level(self, x_idx, x_val, parent_ids, scores):
        idx = self.index
        inputs = self._partition_inputs(x_idx, x_val)
        infos = idx.manifest.partitions
        depth = len(idx.n_cols)
        for li in range(idx.level, depth):
            is_last = li == depth - 1
            next_b = min(
                self.topk if is_last else self.beam, idx.n_cols[li]
            )
            combined, owned = [], []
            # Chunk ranges at this level: the split ranges scaled by the
            # branching products of the levels in between (tree order).
            span = int(np.prod(idx.branching[idx.level:li], dtype=np.int64)) \
                if li > idx.level else 1
            for pid, (part, info) in enumerate(zip(self.parts, infos)):
                lay = part.layers[li - idx.level]
                c_real = lay.chunk_rows.shape[0] - 1  # minus phantom pad
                ids_p, sc_p = self._to_partition(pid, parent_ids, scores)
                xi_p, xv_p, xd_p = inputs[pid]
                comb_p, own_p = _owned_level_scores(
                    lay, xi_p, xv_p, xd_p, ids_p, sc_p,
                    jnp.int32(info.chunk_start * span), jnp.int32(c_real),
                    branching=idx.branching[li], d=idx.d,
                    method=self.method, score_mode=self.score_mode,
                    qt=self.qt,
                )
                comb_p, own_p = self._to_coordinator(comb_p, own_p)
                combined.append(comb_p)
                owned.append(own_p)
            parent_ids, scores = _gather_select(
                parent_ids, tuple(combined), tuple(owned),
                n_cols=idx.n_cols[li], next_b=next_b,
            )
        return scores, parent_ids

    def _run_partition(self, part, info, ids_p, sc_p, xi_p, xv_p):
        """One partition's whole-sub-tree traversal from the router beam.

        Localizes the global beam (out-of-range rows -> phantom chunk,
        score ``NEG_INF``) and runs the jitted continuation — shared by the
        ``"final"`` merge path and :meth:`profile` so the measured traversal
        can never drift from the served one.
        """
        c_real = info.chunk_end - info.chunk_start
        owned = (ids_p >= info.chunk_start) & (ids_p < info.chunk_end)
        local_ids = jnp.where(owned, ids_p - info.chunk_start, c_real)
        local_sc = jnp.where(owned, sc_p, NEG_INF)
        return part.infer(
            xi_p, xv_p, beam=self.beam, topk=self.topk,
            method=self.method, score_mode=self.score_mode, qt=self.qt,
            init_parent_ids=local_ids.astype(jnp.int32),
            init_scores=local_sc, clamp_chunks=True,
        )

    def _infer_final(self, x_idx, x_val, parent_ids, scores):
        """Single-merge mode: whole sub-tree traversals, one canonical merge.

        Not bitwise-reproducible against the unpartitioned tree — each
        partition prunes locally, so the merged top-k *dominates* the exact
        result (every merged score >= its exact counterpart, recall >=).
        """
        idx = self.index
        inputs = self._partition_inputs(x_idx, x_val)
        width = reference_topk_width(
            idx.n_cols, idx.branching, self.beam, self.topk
        )
        out_s, out_l = [], []
        for pid, (part, info) in enumerate(
            zip(self.parts, idx.manifest.partitions)
        ):
            ids_p, sc_p = self._to_partition(pid, parent_ids, scores)
            xi_p, xv_p, _ = inputs[pid]
            s, l = self._run_partition(part, info, ids_p, sc_p, xi_p, xv_p)
            # Globalize: real leaves get the partition's label offset; local
            # phantoms (id >= the partition's label count) are pushed past
            # every real global id so they can never tie-break into the merge.
            gl = jnp.where(
                l < part.n_labels,
                l + info.label_start,
                idx.n_labels + info.label_start + l,
            )
            s, gl = self._to_coordinator(s, gl)
            out_s.append(s)
            out_l.append(gl)
        s_cat = jnp.concatenate(out_s, axis=1)
        l_cat = jnp.concatenate(out_l, axis=1)
        if s_cat.shape[1] < width:  # degenerate config; cannot fill the panel
            raise ValueError(
                f"merged candidate width {s_cat.shape[1]} < reference width "
                f"{width}; raise beam/topk or lower partitions"
            )
        return merge_topk(s_cat, l_cat, width=width)

    # -- diagnostics --------------------------------------------------------
    def profile(
        self, x_idx: jax.Array, x_val: jax.Array
    ) -> List[float]:
        """Blocking per-partition sub-tree latency (ms) for one batch.

        Runs each partition's whole-sub-tree traversal (the ``"final"``
        path) serially with a blocking gather — the per-partition latency
        panel for benchmarks and capacity planning.
        """
        scores, parent_ids = jax.block_until_ready(
            self._route(x_idx, x_val)
        )
        out = []
        for pid, (part, info) in enumerate(
            zip(self.parts, self.index.manifest.partitions)
        ):
            ids_p, sc_p = self._to_partition(pid, parent_ids, scores)
            xi_p, xv_p = self._to_partition(pid, x_idx, x_val)
            t0 = time.perf_counter()
            jax.block_until_ready(
                self._run_partition(part, info, ids_p, sc_p, xi_p, xv_p)
            )
            out.append(1e3 * (time.perf_counter() - t0))
        return out

    def hit_counts(self, labels: np.ndarray) -> np.ndarray:
        """Per-partition share of a result set (occupancy accounting)."""
        return self.index.hit_counts(labels)
