"""Scatter–gather query planner over a :class:`PartitionedIndex`.

Query path (``sync="level"``, **bitwise-exact**):

1. **route** — the replicated router head runs the ordinary jitted beam
   search over the levels above the split, producing the global beam.
2. **scatter** — the beam is broadcast to every partition; each partition
   scores *only the beam rows it owns* (out-of-range rows park on its
   phantom chunk) through :func:`repro.core.tree.owned_level_combined` — the
   same arithmetic the unpartitioned traversal uses, on sliced layers with
   identical ELL pad widths, so owned rows are bit-identical.
3. **gather + select** — the planner reassembles the global ``[n, b, B]``
   candidate tensor from the owners and applies the canonical
   (score desc, id asc) :func:`~repro.core.beam.beam_select`. Steps 2–3
   repeat per partitioned level; the final level's select *is* the global
   top-k — results are **bitwise-identical** to the unpartitioned tree for
   every MSCM method (pinned by tests and a structural benchmark flag).

``sync="pipelined"`` keeps the same bitwise contract while taking the
per-level exchange off the partitions' critical path. In ``"level"`` mode a
partition's level-(l+1) matmul cannot start until the coordinator has
gathered every partition's level-l candidates, selected, and scattered the
winning beam back — P devices idle behind one host-coordinated exchange
every level. The pipelined mode **double-buffers the exchange with
speculation**:

* each partition runs a *local* canonical select over the candidates it
  owns (:func:`_local_select` — same ``(score desc, id asc)`` order as the
  global select, via an id-presorted ``top_k``) and speculatively expands
  those survivors through the level-(l+1) MSCM **now**, through the same
  ``owned_level_combined`` continuation;
* canonical-order dominance guarantees every *globally* surviving
  candidate is present in its owner's local beam (the owner's competitor
  set is a subset of the global one, and unowned rows are junk-id-shifted
  past every real candidate so they lose all ties) — so the coordinator
  never needs the ``[n, b, B]`` candidate tensor at all: it **canonically
  merges the P local beams** (:func:`_merge_beams`, ``[n, w]`` ids +
  scores each) and that *is* the global select, bit for bit. Per-level
  communication drops ~B× and the coordinator's sort shrinks from ``b·B``
  wide to ``P·w``;
* reconciliation (:func:`_reconcile_select`, fused with the next local
  select) aligns the canonical winners with the speculative expansion — a
  cheap per-row gather that drops speculative losers and re-pins
  everything else to ``NEG_INF`` via the existing phantom machinery. No
  recompute, no second matmul: a partition's heavy matmul for level l+1
  depends on the merge of level **l−1**, not level l, so the exchange and
  the next level's compute genuinely overlap (JAX async dispatch realizes
  it as concurrent device streams). Results stay **bitwise-identical** to
  ``sync="level"`` (pinned by ``tests/test_pipelined.py`` across
  method × beam × qt × score_mode and the ``pipelined_parity`` flag).

Why per-level gathers at all: beam search prunes globally at every level. A
partition-local beam keeps candidates global pruning discarded, and their
descendants can out-rank reference results at the leaves — a single final
merge is a (weakly better, recall ≥) *different* ranking. That mode exists
too (``sync="final"``): each partition runs the whole jitted sub-tree
traversal from the router handoff (one merge, no per-level sync — the
low-communication production topology); its top-k scores dominate the exact
result's but are not bitwise-reproducible, so serving defaults to
``"level"``.

Communication is activations only — ``[n, b]`` beams out, ``[n, b, B]``
candidates back, per level — while the weights stay put: with a
:class:`~repro.index.placement.Placement` each partition lives on its own
device (column of the ``("data", "model")`` mesh), batches split over the
data axis, and partitions score concurrently (JAX dispatch is async; the
gather only synchronizes at the select).

With ``cache_entries > 0`` a :class:`~repro.index.cache.HotBeamCache` maps
router-beam signatures to the set of partitions that own any surviving row;
partitions owning nothing are skipped for the whole batch (bitwise-safe —
ownership is nested, so they could only ever contribute ``NEG_INF``). The
lookup materializes the router beam on the host (one small sync per batch),
which is why it is opt-in.
"""

from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mscm as mscm_lib
from repro.core.beam import NEG_INF, beam_select, topk_canonical
from repro.core.tree import owned_level_combined
from repro.index.cache import HotBeamCache
from repro.index.partition import PartitionedIndex
from repro.index.placement import Placement


def reference_topk_width(
    n_cols: Sequence[int], branching: Sequence[int], beam: int, topk: int
) -> int:
    """Output width of the unpartitioned ``infer`` for these settings.

    Mirrors the traversal's clamps: ``next_b = min(beam-or-topk, n_cols)``
    further clamped by the candidate count ``b · B`` (jnp slicing clamps).
    """
    b = 1
    for li, ncol in enumerate(n_cols):
        want = topk if li == len(n_cols) - 1 else beam
        b = min(want, int(ncol), b * int(branching[li]))
    return b


_owned_level_scores = functools.partial(
    jax.jit,
    static_argnames=("branching", "d", "method", "score_mode", "qt"),
)(owned_level_combined)
"""Jitted :func:`repro.core.tree.owned_level_combined` — one partition's
owned slice of a level: ``([n, b, B] combined, owned)``. ``chunk_start`` /
``chunk_count`` are traced so equal-shape partitions share one
compilation."""


def _local_select(
    parent_ids: jax.Array,  # int32 [n, b] GLOBAL chunk ids at this level
    combined: jax.Array,    # f32 [n, b, B] this partition's owned candidates
    owned: jax.Array,       # bool [n, b]
    *,
    n_cols: int,            # valid columns at this level
    n_chunks: int,          # GLOBAL chunk count at this level (junk shift)
    next_b: int,
) -> Tuple[jax.Array, jax.Array]:
    """Partition-local canonical select — the speculation step.

    Identical ``(score desc, id asc)`` ordering to the coordinator's global
    select, over the partition's own candidate slice. Unowned beam rows are
    **id-shifted** onto the junk parent ``n_chunks`` (one past the last real
    chunk anywhere in the tree) so their ``NEG_INF`` children carry ids
    strictly greater than every real or padding candidate: they lose every
    tie, which is what makes the speculative set a guaranteed superset of
    the partition's globally-surviving candidates — even ones whose score
    is exactly ``NEG_INF``.

    Runs once per partition per level (vs the coordinator's one global
    select), so it uses a cheaper kernel than ``beam_select``'s full
    two-key sort: the beam is first ordered by parent id (an ``O(b)``-wide
    argsort), which makes the flattened candidate ids ascending in index —
    ``lax.top_k``'s lowest-index tie-break then *is* the canonical lowest-id
    tie-break, at a fraction of the sort's cost. Returns the same bits as
    ``beam_select`` in the same canonical order.
    """
    n, b = parent_ids.shape
    B = combined.shape[-1]
    shifted = jnp.where(owned, parent_ids, jnp.int32(n_chunks))
    order = jnp.argsort(shifted, axis=1)
    p_sorted = jnp.take_along_axis(shifted, order, axis=1)
    c_sorted = jnp.take_along_axis(combined, order[..., None], axis=1)
    child_ids = p_sorted[:, :, None] * B + jnp.arange(B)[None, None, :]
    valid = child_ids < n_cols
    scores = jnp.where(valid, c_sorted, NEG_INF).reshape(n, b * B)
    k = min(next_b, b * B)  # the reference width clamp (slicing semantics)
    top_scores, top_idx = jax.lax.top_k(scores, k)
    top_ids = jnp.take_along_axis(
        child_ids.reshape(n, b * B), top_idx, axis=1
    )
    return top_ids.astype(jnp.int32), top_scores


_spec_select = functools.partial(
    jax.jit, static_argnames=("n_cols", "n_chunks", "next_b")
)(_local_select)


def _reconcile(
    winner_ids: jax.Array,   # int32 [n, w] canonical global beam (level l-1)
    spec_ids: jax.Array,     # int32 [n, w] speculative local beam (level l-1)
    spec_combined: jax.Array,  # f32 [n, w, B] speculative level-l candidates
    chunk_start: jax.Array,  # scalar: partition's first chunk at level l
    chunk_count: jax.Array,  # scalar: partition's real chunks at level l
) -> Tuple[jax.Array, jax.Array]:
    """Align the speculative expansion with the canonical global beam.

    For each globally-selected parent, find it in the speculative beam (a
    per-row ``searchsorted`` through the id-sorted speculative ids) and
    gather its precomputed level-l candidate row. Winners owned by this
    partition are guaranteed present (see :func:`_local_select`); everything
    else — losers, rows owned elsewhere — re-pins to exactly ``NEG_INF``,
    the same bits :func:`~repro.core.tree.owned_level_combined` would have
    produced. Returns ``(combined [n, w, B], owned [n, w])`` in canonical
    beam order, indistinguishable from the non-speculative path.
    """
    owned = (winner_ids >= chunk_start) & (winner_ids < chunk_start + chunk_count)
    order = jnp.argsort(spec_ids, axis=1)
    sorted_ids = jnp.take_along_axis(spec_ids, order, axis=1)
    pos = jax.vmap(jnp.searchsorted)(sorted_ids, winner_ids)
    pos = jnp.clip(pos, 0, spec_ids.shape[1] - 1)
    hit = jnp.take_along_axis(sorted_ids, pos, axis=1) == winner_ids
    src = jnp.take_along_axis(order, pos, axis=1)
    combined = jnp.take_along_axis(spec_combined, src[..., None], axis=1)
    mask = owned & hit
    return jnp.where(mask[..., None], combined, NEG_INF), mask


@functools.partial(jax.jit, static_argnames=("n_cols", "n_chunks", "next_b"))
def _reconcile_select(
    winner_ids: jax.Array,     # int32 [n, w] canonical beam from the merge
    spec_ids: jax.Array,       # int32 [n, w] previous speculative beam
    spec_combined: jax.Array,  # f32 [n, w, B] speculative this-level scores
    chunk_start: jax.Array,
    chunk_count: jax.Array,
    *,
    n_cols: int,
    n_chunks: int,
    next_b: int,
) -> Tuple[jax.Array, jax.Array]:
    """Fused reconcile + local select: one cheap dispatch per level.

    Both steps are gathers/sorts over ``[n, w(, B)]`` tensors with the same
    operands, so fusing them keeps the partition's per-level exchange to a
    single small XLA program between the heavy speculative matmuls.
    """
    combined, owned = _reconcile(
        winner_ids, spec_ids, spec_combined, chunk_start, chunk_count
    )
    return _local_select(
        winner_ids, combined, owned,
        n_cols=n_cols, n_chunks=n_chunks, next_b=next_b,
    )


@functools.partial(jax.jit, static_argnames=("width",))
def _merge_beams(
    ids: Tuple[jax.Array, ...],     # per partition: int32 [n, w]
    scores: Tuple[jax.Array, ...],  # per partition: f32 [n, w]
    *,
    width: int,
) -> Tuple[jax.Array, jax.Array]:
    """Canonical merge of the partitions' speculative beams == global select.

    Every candidate that survives the *global* canonical select is present
    in its owner's speculative beam (:func:`_local_select` dominance), and
    canonical ``(score desc, id asc)`` order is a total order — so the
    top-``width`` of the concatenated local beams is exactly the
    top-``width`` of the full candidate set, at P·w merge cost instead of a
    b·B-wide sort, with only ``[n, w]`` beams ever crossing devices
    (``width`` carries the unpartitioned traversal's ``min(next_b, b·B)``
    clamp so degenerate narrow levels keep the reference output shape).
    Delegates the tie-break-critical sort to :func:`merge_topk` so the
    canonical ordering lives in exactly one place.
    """
    merged_scores, merged_ids = merge_topk(
        jnp.concatenate(scores, axis=1),
        jnp.concatenate(ids, axis=1),
        width=width,
    )
    return merged_ids, merged_scores


@functools.partial(jax.jit, static_argnames=("n_cols", "next_b"))
def _gather_select(
    parent_ids: jax.Array,
    parts_combined: Tuple[jax.Array, ...],
    parts_owned: Tuple[jax.Array, ...],
    *,
    n_cols: int,
    next_b: int,
) -> Tuple[jax.Array, jax.Array]:
    """Compose the owners' slices into the global candidate tensor + select.

    Every beam row is owned by at most one partition; rows owned by none
    (global phantoms) stay ``NEG_INF``, exactly what the canonical mask
    pins them to in the unpartitioned traversal.
    """
    acc = jnp.full_like(parts_combined[0], NEG_INF)
    for combined, owned in zip(parts_combined, parts_owned):
        acc = jnp.where(owned[..., None], combined, acc)
    return beam_select(parent_ids, acc, n_cols, next_b)


@functools.partial(jax.jit, static_argnames=("width",))
def merge_topk(
    scores: jax.Array, labels: jax.Array, *, width: int
) -> Tuple[jax.Array, jax.Array]:
    """Canonical (score desc, id asc) top-``width`` of concatenated
    per-partition candidates — the ``sync="final"`` merge, delegated to
    the one shared two-key sort in :func:`repro.core.beam.topk_canonical`."""
    ids, top_scores = topk_canonical(scores, labels, width)
    return top_scores, ids


_scatter_dense = jax.jit(mscm_lib.scatter_dense, static_argnums=2)

SYNC_MODES = ("level", "pipelined", "final")


class TransportDegraded(RuntimeError):
    """A partition was lost mid-exchange but the batch is retryable.

    Raised by a transport whose degraded policy is ``"serve_partial"``
    after it has removed the lost partition from its live set; the
    coordinator replays the batch from ``begin`` over the survivors (the
    workers' per-batch speculation state restarts cleanly at ``begin``).
    """

    def __init__(self, pid: int, cause: BaseException) -> None:
        super().__init__(f"partition {pid} lost mid-exchange: {cause}")
        self.pid = pid
        self.cause = cause


class BeamTransport:
    """Where the pipelined exchange's partition halves run.

    The per-level pipelined protocol has two sides: P partitions computing
    local canonical beams (score + speculate, the heavy half) and a
    coordinator merging P tiny ``[n, w]`` beams (:func:`_merge_beams`). A
    ``BeamTransport`` abstracts the partition side so the same coordinator
    loop (:meth:`ScatterGatherPlanner._infer_transport`) drives in-process
    partitions or remote worker processes — the fleet RPC implementation is
    :class:`repro.serving.fleet.PartitionFleet`.

    Protocol, per query batch:

    * :meth:`begin` — ship the batch (ELL ``idx``/``val``) and the router
      handoff beam; every partition computes its level-``li0`` local beam
      and speculatively expands level ``li0+1``. Returns the P local beams
      ``[(ids [n, w], scores [n, w]), ...]`` in partition order.
    * :meth:`step` — ship the canonical winners of level ``level - 1``;
      every partition reconciles its speculation, locally selects level
      ``level``, and speculates ``level + 1``. Returns the P local beams.

    All arrays cross the transport as host ``numpy`` — the tiny ``[n, w]``
    beams are the only per-level traffic, which is what makes the exchange
    bandwidth-trivial over a socket.
    """

    @property
    def n_partitions(self) -> int:
        raise NotImplementedError

    def begin(
        self,
        x_idx: np.ndarray,
        x_val: np.ndarray,
        parent_ids: np.ndarray,
        scores: np.ndarray,
        *,
        beam: Optional[int] = None,
        qt: Optional[int] = None,
    ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """``beam``/``qt`` override the partitions' configured settings for
        this batch only (adaptive beam tiers; ``None`` = the configured
        full values — the coordinator omits them unless degraded, so tier-0
        traffic is byte-identical to a transport without tiers)."""
        raise NotImplementedError

    def step(
        self, level: int, winner_ids: np.ndarray
    ) -> List[Tuple[np.ndarray, np.ndarray]]:
        raise NotImplementedError

    def down_partitions(self) -> List[int]:
        """Partitions excluded from the current batch (degraded mode).

        Default: none. A degraded-capable transport returns the pids whose
        beams were missing from the batch it just served, so the
        coordinator can stamp the result with the unsearched label ranges.
        """
        return []


class ScatterGatherPlanner:
    """Executes partitioned queries; see the module docstring for the path.

    With ``placement`` the partitions' layer tensors are copied onto their
    assigned mesh columns at construction and every scatter/gather hop is an
    explicit ``device_put`` (batch dim split over the column's data axis);
    without one, everything runs on the default device — same arithmetic,
    same results.
    """

    def __init__(
        self,
        index: PartitionedIndex,
        *,
        beam: int = 10,
        topk: int = 10,
        method: str = "mscm_dense",
        score_mode: str = "prod",
        qt: int = 8,
        sync: str = "level",
        placement: Optional[Placement] = None,
        cache_entries: int = 0,
        transport: Optional[BeamTransport] = None,
    ) -> None:
        if sync not in SYNC_MODES:
            raise ValueError(f"sync={sync!r}; choose from {SYNC_MODES}")
        self.transport = None
        if transport is not None:
            self._check_transport(sync, cache_entries, transport)
            self.transport = transport
        #: Degraded-batch info from the most recent :meth:`infer` over a
        #: transport: ``None`` when every partition participated, else
        #: ``{"partitions": [pid, ...], "label_ranges": [(lo, hi), ...]}``.
        self.last_degraded: Optional[dict] = None
        self.index = index
        self.beam = beam
        self.topk = topk
        self.method = method
        self.score_mode = score_mode
        self.qt = qt
        self.sync = sync
        self.placement = placement
        self.parts = index.parts
        if placement is not None:
            if len(placement.array_shardings) != index.n_partitions:
                raise ValueError(
                    f"placement covers {len(placement.array_shardings)} "
                    f"partitions, index has {index.n_partitions}"
                )
            self.parts = [
                p.device_put(sh)
                for p, sh in zip(index.parts, placement.array_shardings)
            ]
        self._needs_dense = method in (
            "mscm_dense", "mscm_pallas", "mscm_pallas_pregather",
            "mscm_pallas_grouped", "mscm_pallas_grouped_q",
        )
        # The router head is always exact f32 (only the partitions are
        # quantized — repro.quant.quantize_index), so a quantized method
        # routes through its exact grouped twin: same grouping, same
        # epilogue, f32 tiles.
        self._router_method = (
            "mscm_pallas_grouped"
            if method == "mscm_pallas_grouped_q" else method
        )
        self.cache: Optional[HotBeamCache] = None
        if cache_entries:
            if sync == "final":
                # The final-merge path always traverses every partition
                # (dropping one changes the merged candidate panel), so a
                # cache would be built but never consulted — refuse rather
                # than silently no-op.
                raise ValueError(
                    'cache_entries is only meaningful for the exact sync '
                    'modes ("level"/"pipelined"), not sync="final"'
                )
            bounds = [p.chunk_start for p in index.manifest.partitions]
            bounds.append(index.manifest.partitions[-1].chunk_end)
            self.cache = HotBeamCache(cache_entries, bounds)

    # -- transport (cross-process partitions) -------------------------------
    def _check_transport(
        self, sync: str, cache_entries: int, transport: BeamTransport
    ) -> None:
        if sync != "pipelined":
            raise ValueError(
                'a BeamTransport requires sync="pipelined" (the only mode '
                "whose per-level exchange is the tiny local-beam protocol); "
                f"got sync={sync!r}"
            )
        if cache_entries:
            raise ValueError(
                "beam_cache is incompatible with a BeamTransport: the "
                "hot-beam owner-set skip is a host-side optimization of the "
                "in-process scatter, and remote workers always participate"
            )

    def set_transport(self, transport: Optional[BeamTransport]) -> None:
        """Route the partition halves through ``transport`` (None = local).

        The coordinator keeps the router head and the per-level merge; the
        partitions' score/speculate halves run wherever the transport says
        (e.g. the fleet's worker processes). Results stay bitwise-identical
        to in-process serving: both sides run the same jitted programs on
        the same partition slices, and :func:`_merge_beams` is
        concatenation-order independent.
        """
        if transport is not None:
            self._check_transport(
                self.sync, 0 if self.cache is None else 1, transport
            )
            if transport.n_partitions != self.index.n_partitions:
                raise ValueError(
                    f"transport serves {transport.n_partitions} partitions, "
                    f"index has {self.index.n_partitions}"
                )
        self.transport = transport

    def _infer_transport(self, x_idx, x_val, parent_ids, scores, *,
                         beam: int, qt: int):
        """Coordinator half of the pipelined exchange over a transport.

        If the transport loses a partition mid-exchange and its policy
        allows partial service, it raises :class:`TransportDegraded` after
        shrinking its live set; the whole batch is replayed over the
        survivors. The loop is bounded: every replay follows the permanent
        loss of at least one partition. Degraded merges stay bitwise-exact
        for surviving-partition labels: each survivor's local beam is
        already merge-width wide (``k = min(next_b, b·B)`` equals the
        coordinator's width recurrence), and a path's score is a
        deterministic chain independent of which other candidates shared
        the beam — dropping a partition only frees panel slots, it cannot
        perturb any survivor's bits.
        """
        while True:
            try:
                w_scores, w_ids = self._transport_exchange(
                    x_idx, x_val, parent_ids, scores, beam=beam, qt=qt
                )
                break
            except TransportDegraded:
                continue  # replay over the survivors
        down = sorted(self.transport.down_partitions())
        if down:
            infos = self.index.manifest.partitions
            self.last_degraded = {
                "partitions": down,
                "label_ranges": [
                    (int(infos[p].label_start), int(infos[p].label_end))
                    for p in down
                ],
            }
        return w_scores, w_ids

    def _transport_exchange(self, x_idx, x_val, parent_ids, scores, *,
                            beam: int, qt: int):
        """One full begin/step/merge pass over the transport.

        Same width/level recurrence as :meth:`_infer_pipelined`; the
        partitions' reconcile/select/speculate halves run behind
        ``self.transport`` (each worker mirrors the in-process device-stream
        schedule, so the speculative matmuls still overlap this merge loop).
        """
        idx = self.index
        depth = len(idx.n_cols)
        width = parent_ids.shape[1]  # router handoff beam width
        # Tier overrides ride the begin header only when they actually
        # differ from the workers' loaded settings — full-beam batches stay
        # byte-identical on the wire to a fleet that predates tiers.
        overrides = {}
        if beam != self.beam:
            overrides["beam"] = beam
        if qt != self.qt:
            overrides["qt"] = qt
        beams = self.transport.begin(
            np.asarray(x_idx), np.asarray(x_val),
            np.asarray(parent_ids), np.asarray(scores),
            **overrides,
        )
        w_ids = w_scores = None
        for li in range(idx.level, depth):
            is_last = li == depth - 1
            next_b = min(self.topk if is_last else beam, idx.n_cols[li])
            width = min(next_b, width * idx.branching[li])
            if li > idx.level:
                beams = self.transport.step(li, np.asarray(w_ids))
            w_ids, w_scores = _merge_beams(
                tuple(jnp.asarray(i) for i, _ in beams),
                tuple(jnp.asarray(s) for _, s in beams),
                width=width,
            )
        return w_scores, w_ids

    # -- device hops --------------------------------------------------------
    def _to_partition(self, pid: int, *arrays):
        if self.placement is None:
            return arrays
        sh = self.placement.batch_shardings[pid]
        return tuple(jax.device_put(a, sh) for a in arrays)

    def _to_coordinator(self, *arrays):
        if self.placement is None:
            return arrays
        dev = self.placement.coordinator
        return tuple(jax.device_put(a, dev) for a in arrays)

    # -- query path ---------------------------------------------------------
    def _route(self, x_idx: jax.Array, x_val: jax.Array, *,
               beam: int, qt: int):
        """Router head: the global beam after the levels above the split."""
        return self.index.head.infer(
            x_idx, x_val, beam=beam, topk=beam,
            method=self._router_method, score_mode=self.score_mode,
            qt=qt,
        )

    def _active_partitions(self, parent_ids: jax.Array) -> List[int]:
        """Partitions participating in this batch.

        Without a cache: all of them, no host sync. With one: the cached
        owner set of each row's router-beam signature — partitions owning
        no surviving row are skipped for every level (ownership is nested),
        which cannot change any bit of the gather (their slices are all
        ``NEG_INF`` by construction).
        """
        if self.cache is None:
            return list(range(self.index.n_partitions))
        return self.cache.active_partitions(np.asarray(parent_ids))

    def _partition_inputs(self, x_idx, x_val, active: Sequence[int]):
        """Per-partition (xi, xv, x_dense) resident on the partition's devices.

        The dense [n, d+1] query table is the expensive piece (d can be
        millions); partitions sharing a batch sharding — all of them when no
        placement is set, column-mates under LPT packing — share one copy.
        """
        out: Dict[int, tuple] = {}
        by_sharding: Dict = {}
        for pid in active:
            key = (
                self.placement.batch_shardings[pid]
                if self.placement is not None else None
            )
            if key not in by_sharding:
                xi_p, xv_p = self._to_partition(pid, x_idx, x_val)
                xd_p = (
                    _scatter_dense(xi_p, xv_p, self.index.d)
                    if self._needs_dense else None
                )
                by_sharding[key] = (xi_p, xv_p, xd_p)
            out[pid] = by_sharding[key]
        return out

    def infer(
        self, x_idx: jax.Array, x_val: jax.Array, *,
        beam: Optional[int] = None, qt: Optional[int] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        """Global (scores [n, k], labels [n, k]) for a query batch.

        ``beam``/``qt`` override the configured settings for this call only
        (the adaptive tier path — the coordinator picks a tier per batch);
        ``None`` keeps the constructor values, and that default path is
        unchanged down to the wire. Every sync mode clamps widths from the
        effective beam, so partition-local selects stay bitwise-exact *at
        that tier*.
        """
        beam = self.beam if beam is None else int(beam)
        qt = self.qt if qt is None else int(qt)
        self.last_degraded = None
        scores, parent_ids = self._route(x_idx, x_val, beam=beam, qt=qt)
        if self.transport is not None:
            return self._infer_transport(
                x_idx, x_val, parent_ids, scores, beam=beam, qt=qt
            )
        if self.sync == "final":
            return self._infer_final(
                x_idx, x_val, parent_ids, scores, beam=beam, qt=qt
            )
        active = self._active_partitions(parent_ids)
        run = (
            self._infer_pipelined if self.sync == "pipelined"
            else self._infer_level
        )
        return run(x_idx, x_val, parent_ids, scores, active, beam=beam, qt=qt)

    def _level_owned(self, li, pid, inputs, parent_ids, scores, span,
                     qt: Optional[int] = None):
        """One partition's owned candidate slice of level ``li`` (jitted)."""
        idx = self.index
        part, info = self.parts[pid], idx.manifest.partitions[pid]
        lay = part.layers[li - idx.level]
        c_real = lay.chunk_rows.shape[0] - 1  # minus phantom pad
        xi_p, xv_p, xd_p = inputs[pid]
        return _owned_level_scores(
            lay, idx.branching[li], idx.d, xi_p, xv_p, xd_p,
            parent_ids, scores,
            jnp.int32(info.chunk_start * span), jnp.int32(c_real),
            method=self.method, score_mode=self.score_mode,
            qt=self.qt if qt is None else qt,
        )

    def _infer_level(self, x_idx, x_val, parent_ids, scores, active, *,
                     beam: int, qt: int):
        idx = self.index
        inputs = self._partition_inputs(x_idx, x_val, active)
        depth = len(idx.n_cols)
        for li in range(idx.level, depth):
            is_last = li == depth - 1
            next_b = min(
                self.topk if is_last else beam, idx.n_cols[li]
            )
            combined, owned = [], []
            # Chunk ranges at this level: the split ranges scaled by the
            # branching products of the levels in between (tree order).
            span = int(np.prod(idx.branching[idx.level:li], dtype=np.int64)) \
                if li > idx.level else 1
            for pid in active:
                ids_p, sc_p = self._to_partition(pid, parent_ids, scores)
                comb_p, own_p = self._level_owned(
                    li, pid, inputs, ids_p, sc_p, span, qt=qt
                )
                comb_p, own_p = self._to_coordinator(comb_p, own_p)
                combined.append(comb_p)
                owned.append(own_p)
            parent_ids, scores = _gather_select(
                parent_ids, tuple(combined), tuple(owned),
                n_cols=idx.n_cols[li], next_b=next_b,
            )
        return scores, parent_ids

    def _infer_pipelined(self, x_idx, x_val, parent_ids, scores, active, *,
                         beam: int, qt: int):
        """Double-buffered exchange: level-l select ∥ level-(l+1) matmul.

        Each iteration, per partition and in device-stream order:

        1. reconcile the previous level's winners against the speculative
           expansion and run the *local* canonical select (one fused cheap
           dispatch, :func:`_reconcile_select`) — at the first partitioned
           level, score the scattered router handoff instead;
        2. ship the tiny ``[n, w]`` speculative beam to the coordinator —
           *before* any heavy work, so the merge is never queued behind the
           matmul it is meant to overlap;
        3. speculatively expand the local survivors through the next
           level's MSCM (the heavy matmul — depends only on partition-local
           data, so it runs concurrently with the coordinator's merge);

        then on the coordinator: 4. canonically merge the local beams
        (:func:`_merge_beams` — bitwise the global select, because every
        global winner is in its owner's local beam) and scatter the winner
        ids (ids only — ``[n, w]`` int32) back to the partitions for the
        next iteration's reconcile. All dispatch is async — the host never
        blocks, and a partition's level-(l+1) matmul transitively depends
        on the *level-(l-1)* merge, not the level-l one: one full level of
        slack for the exchange to hide in.

        Versus ``sync="level"``, per-level communication drops from the
        full ``[n, b, B]`` candidate tensor + ownership mask per partition
        to two ``[n, w]`` beams, and the coordinator's sort shrinks from
        ``b·B`` wide to ``P·w``.
        """
        idx = self.index
        infos = idx.manifest.partitions
        inputs = self._partition_inputs(x_idx, x_val, active)
        depth = len(idx.n_cols)
        li0 = idx.level
        beam_p: Dict[int, Tuple[jax.Array, jax.Array]] = {}
        spec_comb: Dict[int, jax.Array] = {}
        spec_ids: Dict[int, jax.Array] = {}
        w_ids = parent_ids
        width = parent_ids.shape[1]  # router handoff beam width
        span = span_next = 1
        for li in range(li0, depth):
            is_last = li == depth - 1
            next_b = min(self.topk if is_last else beam, idx.n_cols[li])
            width = min(next_b, width * idx.branching[li])
            # (1) local canonical beams for level li.
            if li == li0:
                for pid in active:  # scored from the router handoff
                    ids, sc = self._to_partition(pid, parent_ids, scores)
                    comb, own = self._level_owned(
                        li0, pid, inputs, ids, sc, 1, qt=qt
                    )
                    beam_p[pid] = _spec_select(
                        ids, comb, own,
                        n_cols=idx.n_cols[li], n_chunks=idx.n_cols[li - 1],
                        next_b=next_b,
                    )
            else:
                for pid in active:
                    info = infos[pid]
                    lay = self.parts[pid].layers[li - li0]
                    (ids,) = self._to_partition(pid, w_ids)
                    beam_p[pid] = _reconcile_select(
                        ids, spec_ids[pid], spec_comb[pid],
                        jnp.int32(info.chunk_start * span),
                        jnp.int32(lay.chunk_rows.shape[0] - 1),
                        n_cols=idx.n_cols[li], n_chunks=idx.n_cols[li - 1],
                        next_b=next_b,
                    )
            # (2) beam transfers to the coordinator go ahead of the matmul.
            gathered = [
                self._to_coordinator(*beam_p[pid]) for pid in active
            ]
            # (3) canonical merge == the global select for level li —
            # dispatched BEFORE the expansions so that when the coordinator
            # shares a device with a partition, the merge is not queued
            # behind that partition's matmul (it depends only on the tiny
            # beams transferred above).
            w_ids, w_scores = _merge_beams(
                tuple(i for i, _ in gathered),
                tuple(s for _, s in gathered),
                width=width,
            )
            # (4) speculative expansion of level li+1 — the double buffer.
            if not is_last:
                span_next = span * idx.branching[li]
                for pid in active:
                    s_ids, s_sc = beam_p[pid]
                    spec_comb[pid], _ = self._level_owned(
                        li + 1, pid, inputs, s_ids, s_sc, span_next, qt=qt
                    )
                    spec_ids[pid] = s_ids
            span = span_next
        return w_scores, w_ids

    def _run_partition(self, part, info, ids_p, sc_p, xi_p, xv_p,
                       beam: Optional[int] = None, qt: Optional[int] = None):
        """One partition's whole-sub-tree traversal from the router beam.

        Localizes the global beam (out-of-range rows -> phantom chunk,
        score ``NEG_INF``) and runs the jitted continuation — shared by the
        ``"final"`` merge path and :meth:`profile` so the measured traversal
        can never drift from the served one.
        """
        c_real = info.chunk_end - info.chunk_start
        owned = (ids_p >= info.chunk_start) & (ids_p < info.chunk_end)
        local_ids = jnp.where(owned, ids_p - info.chunk_start, c_real)
        local_sc = jnp.where(owned, sc_p, NEG_INF)
        return part.infer(
            xi_p, xv_p,
            beam=self.beam if beam is None else beam, topk=self.topk,
            method=self.method, score_mode=self.score_mode,
            qt=self.qt if qt is None else qt,
            init_parent_ids=local_ids.astype(jnp.int32),
            init_scores=local_sc, clamp_chunks=True,
        )

    def _infer_final(self, x_idx, x_val, parent_ids, scores, *,
                     beam: int, qt: int):
        """Single-merge mode: whole sub-tree traversals, one canonical merge.

        Not bitwise-reproducible against the unpartitioned tree — each
        partition prunes locally, so the merged top-k *dominates* the exact
        result (every merged score >= its exact counterpart, recall >=).
        """
        idx = self.index
        inputs = self._partition_inputs(
            x_idx, x_val, range(idx.n_partitions)
        )
        width = reference_topk_width(
            idx.n_cols, idx.branching, beam, self.topk
        )
        out_s, out_l = [], []
        for pid, (part, info) in enumerate(
            zip(self.parts, idx.manifest.partitions)
        ):
            ids_p, sc_p = self._to_partition(pid, parent_ids, scores)
            xi_p, xv_p, _ = inputs[pid]
            s, l = self._run_partition(
                part, info, ids_p, sc_p, xi_p, xv_p, beam=beam, qt=qt
            )
            # Globalize: real leaves get the partition's label offset; local
            # phantoms (id >= the partition's label count) are pushed past
            # every real global id so they can never tie-break into the merge.
            gl = jnp.where(
                l < part.n_labels,
                l + info.label_start,
                idx.n_labels + info.label_start + l,
            )
            s, gl = self._to_coordinator(s, gl)
            out_s.append(s)
            out_l.append(gl)
        s_cat = jnp.concatenate(out_s, axis=1)
        l_cat = jnp.concatenate(out_l, axis=1)
        if s_cat.shape[1] < width:  # degenerate config; cannot fill the panel
            raise ValueError(
                f"merged candidate width {s_cat.shape[1]} < reference width "
                f"{width}; raise beam/topk or lower partitions"
            )
        return merge_topk(s_cat, l_cat, width=width)

    # -- diagnostics --------------------------------------------------------
    def cache_stats(self) -> Optional[dict]:
        """Hot-beam cache accounting, or None when the cache is off."""
        return self.cache.stats() if self.cache is not None else None

    def profile(
        self, x_idx: jax.Array, x_val: jax.Array
    ) -> List[float]:
        """Blocking per-partition sub-tree latency (ms) for one batch.

        Runs each partition's whole-sub-tree traversal (the ``"final"``
        path) serially with a blocking gather — the per-partition latency
        panel for benchmarks and capacity planning.
        """
        scores, parent_ids = jax.block_until_ready(
            self._route(x_idx, x_val, beam=self.beam, qt=self.qt)
        )
        out = []
        for pid, (part, info) in enumerate(
            zip(self.parts, self.index.manifest.partitions)
        ):
            ids_p, sc_p = self._to_partition(pid, parent_ids, scores)
            xi_p, xv_p = self._to_partition(pid, x_idx, x_val)
            t0 = time.perf_counter()
            jax.block_until_ready(
                self._run_partition(part, info, ids_p, sc_p, xi_p, xv_p)
            )
            out.append(1e3 * (time.perf_counter() - t0))
        return out

    def hit_counts(self, labels: np.ndarray) -> np.ndarray:
        """Per-partition share of a result set (occupancy accounting)."""
        return self.index.hit_counts(labels)
