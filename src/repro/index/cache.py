"""Partition-local hot-beam cache for the scatter–gather planner.

Production query distributions over tree-based semantic search are heavily
skewed (Chang et al., semantic product search; Etter et al., MSCM): a small
set of router-head beams covers a large share of traffic. After the router
runs, the only thing the partitioned levels need from the beam in order to
*plan* the exchange is **which partitions own any surviving row** — a pure
function of the beam's chunk-id set, because label ownership is nested: a
partition owning zero rows of the router handoff can never own a row at any
deeper level (children of an owned chunk stay inside the owner's contiguous
range), so it contributes an all-``NEG_INF`` slice to every gather and can
be skipped outright without changing a single bit of the result.

:class:`HotBeamCache` memoizes that signature → owner-set mapping with an
LRU over **beam signatures** (the sorted chunk-id multiset of one query's
router beam — order-insensitive, so canonically-reordered beams share an
entry). Alongside the hit/miss accounting it accumulates ``owner_counts`` —
how many routed beam rows each partition owned — which is the live
occupancy feed :func:`repro.index.partition.rebalance` consumes (the same
signal ``ServerMetrics.partition_occupancy`` reports from served top-k
results, one level earlier).

The cache is consulted on the host (it must materialize the router beam,
one small ``[n, beam]`` device→host copy per batch), so it is opt-in:
``ScatterGatherPlanner(..., cache_entries=0)`` (the default) never syncs.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Sequence

import numpy as np


class HotBeamCache:
    """LRU of router-beam signatures → the partitions that own any row.

    ``chunk_bounds`` are the split-level chunk boundaries from the manifest
    (``[p.chunk_start for p] + [last.chunk_end]``); a beam id ``c`` is owned
    by partition ``searchsorted(bounds, c, "right") - 1``.
    """

    def __init__(self, capacity: int, chunk_bounds: Sequence[int]) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        self.capacity = capacity
        self._bounds = np.asarray(chunk_bounds, dtype=np.int64)
        if self._bounds.ndim != 1 or len(self._bounds) < 2:
            raise ValueError("chunk_bounds must hold >= 2 boundaries")
        # Each entry maps a beam signature to {pid: owned-row count} — the
        # counts (not just the owner set) are what keep the occupancy feed
        # faithful to per-partition *load*, not mere participation.
        self._lru: "OrderedDict[bytes, Dict[int, int]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # Router-level occupancy: beam rows owned per partition — the feed
        # for offline rebalancing (repro.index.partition.rebalance).
        self.owner_counts = np.zeros(len(self._bounds) - 1, dtype=np.int64)

    @property
    def n_partitions(self) -> int:
        return len(self._bounds) - 1

    def __len__(self) -> int:
        return len(self._lru)

    # ------------------------------------------------------------------
    def _owners(self, row: np.ndarray) -> Dict[int, int]:
        """{pid: number of this row's beam entries the partition owns}."""
        valid = row[(row >= self._bounds[0]) & (row < self._bounds[-1])]
        pids = np.searchsorted(self._bounds, valid, side="right") - 1
        uniq, counts = np.unique(pids, return_counts=True)
        return {int(p): int(c) for p, c in zip(uniq, counts)}

    def active_partitions(self, beam_ids: np.ndarray) -> List[int]:
        """Partitions owning ≥ 1 row of any query's router beam.

        ``beam_ids`` is the routed ``[n, b]`` handoff. Per-row signatures
        hit the LRU; the batch's active set is the union. Falls back to
        *every* partition when no row is owned (a degenerate all-phantom
        beam) so the planner's gather always has at least one operand.
        """
        beam_ids = np.asarray(beam_ids, dtype=np.int64)
        if beam_ids.ndim == 1:
            beam_ids = beam_ids[None, :]
        active: set = set()
        for row in beam_ids:
            key = np.sort(row).tobytes()
            owners = self._lru.get(key)
            if owners is None:
                self.misses += 1
                owners = self._owners(row)
                self._lru[key] = owners
                if len(self._lru) > self.capacity:
                    self._lru.popitem(last=False)
                    self.evictions += 1
            else:
                self.hits += 1
                self._lru.move_to_end(key)
            for p, count in owners.items():
                self.owner_counts[p] += count
            active |= owners.keys()
        if not active:
            return list(range(self.n_partitions))
        return sorted(active)

    # ------------------------------------------------------------------
    def occupancy(self) -> np.ndarray:
        """Per-partition share of routed beam rows (sums to 1; uniform when
        nothing has been routed yet) — rebalance's input format."""
        total = self.owner_counts.sum()
        if total == 0:
            return np.full(self.n_partitions, 1.0 / self.n_partitions)
        return self.owner_counts / total

    def stats(self) -> dict:
        lookups = self.hits + self.misses
        return {
            "hits": int(self.hits),
            "misses": int(self.misses),
            "hit_rate": float(self.hits / lookups) if lookups else 0.0,
            "evictions": int(self.evictions),
            "entries": len(self._lru),
            "capacity": self.capacity,
            "owner_counts": [int(c) for c in self.owner_counts],
        }
