"""Label-partitioned scatter–gather index: serve trees bigger than one device.

``partition`` splits an :class:`~repro.core.tree.XMRTree` into a replicated
router head plus P label-contiguous sub-trees (with a serializable
manifest); ``placement`` packs the partitions onto a ``("data", "model")``
device mesh balanced by ``memory_bytes``; ``planner`` runs the
scatter–gather query path — bitwise-identical to the unpartitioned tree in
its default per-level sync mode. See ``src/repro/index/README.md``.
"""

from repro.index.cache import HotBeamCache
from repro.index.partition import (
    PartitionedIndex,
    PartitionInfo,
    PartitionManifest,
    default_split_level,
    partition_tree,
    rebalance,
    rebalance_bounds,
)
from repro.index.placement import Placement, assign_partitions, place
from repro.index.planner import (
    SYNC_MODES,
    BeamTransport,
    ScatterGatherPlanner,
    TransportDegraded,
    merge_topk,
    reference_topk_width,
)

# Public API v1 (see the README table). ``HotBeamCache``, ``merge_topk``,
# ``assign_partitions``, ``rebalance_bounds`` and ``reference_topk_width``
# stay importable for tests/benches but are internal plumbing.
__all__ = [
    "BeamTransport",
    "PartitionInfo",
    "PartitionManifest",
    "PartitionedIndex",
    "Placement",
    "SYNC_MODES",
    "ScatterGatherPlanner",
    "TransportDegraded",
    "default_split_level",
    "partition_tree",
    "place",
    "rebalance",
]
