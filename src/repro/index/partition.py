"""Label-space partitioning of an :class:`~repro.core.tree.XMRTree`.

The enterprise regime (paper §6: 100M labels, d = 4M) does not fit one
device: the leaf ranker layer dominates model memory and grows linearly in
L. :func:`partition_tree` splits the tree at a chosen level into P disjoint
sub-trees — each owning a **contiguous label range** (labels are laid out in
tree order, so a contiguous chunk range at any level induces a contiguous
leaf range) — plus a small **router head** (the levels above the split,
replicated everywhere; they hold ~L/(B-1) of the L leaf columns, a few
percent of the weights).

Every sub-tree layer is a *slice* of the parent tree's device arrays: the
ELL pad widths R/Rc are preserved, so scoring a column through a partition
is bitwise-identical to scoring it through the full tree. Each level also
gains one all-sentinel **phantom chunk** where out-of-partition beam entries
are parked (logits exactly 0, children past the local label count, re-masked
to ``NEG_INF`` every level — see :meth:`XMRTree.extract`).

A :class:`PartitionManifest` records, per partition, the chunk range at the
split level, the owned label range, resident ``memory_bytes``, and a content
hash of the sliced weights — the unit a placement policy balances and an
operator audits (format documented in ``src/repro/index/README.md``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.tree import XMRTree

# v2 adds the compressed-storage columns ``tier``/``dtype`` (repro.quant);
# v1 manifests are still readable — the new columns default to the exact
# tier. See src/repro/index/README.md for the schema history.
MANIFEST_VERSION = 2


@dataclasses.dataclass(frozen=True)
class PartitionInfo:
    """One partition's row in the manifest."""

    pid: int
    chunk_start: int      # chunk range at the split level (disjoint, sorted)
    chunk_end: int
    label_start: int      # owned leaf-label range [label_start, label_end)
    label_end: int
    memory_bytes: int     # resident chunked-weight bytes (incl. phantom pad)
    content_hash: str     # sha256 over the sliced layer tensors
    tier: str = "exact"   # storage tier (repro.quant QUANT-prefixed or exact)
    dtype: str = "float32"  # chunk_vals storage dtype actually resident

    @property
    def n_labels(self) -> int:
        return self.label_end - self.label_start


@dataclasses.dataclass
class PartitionManifest:
    """Serializable description of a label-partitioned index."""

    level: int                      # split level (index into stored layers)
    n_partitions: int
    n_labels: int                   # global leaf count
    d: int
    branching: Tuple[int, ...]
    router_memory_bytes: int        # replicated head layers
    total_memory_bytes: int         # unpartitioned tree, for shrink ratios
    partitions: List[PartitionInfo]
    version: int = MANIFEST_VERSION

    def max_partition_bytes(self) -> int:
        return max(p.memory_bytes for p in self.partitions)

    def shrink_ratio(self) -> float:
        """Unpartitioned bytes over the largest per-device resident slice."""
        resident = self.max_partition_bytes() + self.router_memory_bytes
        return self.total_memory_bytes / max(resident, 1)

    def to_json(self) -> str:
        doc = dataclasses.asdict(self)
        doc["branching"] = list(self.branching)
        return json.dumps(doc, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "PartitionManifest":
        doc = json.loads(text)
        version = doc.get("version")
        if version not in (1, MANIFEST_VERSION):
            raise ValueError(
                f"manifest version {version} not in (1, {MANIFEST_VERSION})"
            )
        # v1 rows predate the storage-tier columns; the dataclass defaults
        # (exact f32) describe every v1 partition correctly. Re-serialized
        # manifests are written at the current version.
        parts = [PartitionInfo(**p) for p in doc.pop("partitions")]
        doc["branching"] = tuple(doc["branching"])
        doc["version"] = MANIFEST_VERSION
        return cls(partitions=parts, **doc)


def _content_hash(tree: XMRTree) -> str:
    h = hashlib.sha256()
    for lay in tree.layers:
        tensors = [lay.chunk_rows, lay.chunk_vals]
        scales = getattr(lay, "chunk_scales", None)  # quantized layers
        if scales is not None:
            tensors.append(scales)
        for t in tensors:
            a = np.asarray(t)
            # dtype is part of the hashed header, so an int8 cut of the same
            # weights can never collide with its f32 original.
            h.update(str((a.shape, str(a.dtype))).encode())
            h.update(a.tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class PartitionedIndex:
    """A router head + P label-partitioned sub-trees, ready to serve."""

    head: XMRTree                 # levels [0, level): replicated router
    parts: List[XMRTree]          # P disjoint sub-trees, label-contiguous
    manifest: PartitionManifest
    n_cols: Tuple[int, ...]       # global per-level column counts
    branching: Tuple[int, ...]

    @property
    def n_partitions(self) -> int:
        return len(self.parts)

    @property
    def level(self) -> int:
        return self.manifest.level

    @property
    def n_labels(self) -> int:
        return self.manifest.n_labels

    @property
    def d(self) -> int:
        return self.manifest.d

    def label_ranges(self) -> List[Tuple[int, int]]:
        return [(p.label_start, p.label_end) for p in self.manifest.partitions]

    def hit_counts(self, labels: np.ndarray) -> np.ndarray:
        """Per-partition count of result labels (occupancy accounting)."""
        labels = np.asarray(labels).reshape(-1)
        edges = [p.label_start for p in self.manifest.partitions]
        edges.append(self.manifest.partitions[-1].label_end)
        valid = labels[(labels >= 0) & (labels < self.n_labels)]
        hist, _ = np.histogram(valid, bins=np.asarray(edges))
        return hist.astype(np.int64)


def default_split_level(tree: XMRTree, n_partitions: int) -> int:
    """Smallest level whose chunk count can host P contiguous partitions.

    Splitting as high as possible partitions the *most* layers (every layer
    at or below the split is sliced 1/P), so the replicated router head stays
    minimal.
    """
    for level in range(1, tree.depth):
        if tree.n_cols[level - 1] >= n_partitions:
            return level
    raise ValueError(
        f"tree has no level with >= {n_partitions} chunks "
        f"(n_cols={tree.n_cols}); reduce partitions"
    )


def partition_tree(
    tree: XMRTree,
    n_partitions: int,
    *,
    level: int | None = None,
    bounds: Sequence[int] | None = None,
) -> PartitionedIndex:
    """Split ``tree`` into a router head + ``n_partitions`` sub-trees.

    Chunks of layer ``level`` (== nodes of level ``level - 1``) are divided
    into contiguous, near-equal ranges — with a B-ary layout equal chunk
    counts are equal label counts, up to the global ragged tail which lands
    in the last partition (deliberately: the uneven-range edge case stays
    exercised). Pass explicit ``bounds`` (``n_partitions + 1`` strictly
    increasing chunk boundaries covering ``[0, n_chunks]``) to cut uneven
    ranges on purpose — :func:`rebalance` uses this to re-cut from observed
    occupancy skew.
    """
    if n_partitions < 1:
        raise ValueError(f"n_partitions must be >= 1; got {n_partitions}")
    if level is None:
        level = default_split_level(tree, n_partitions)
    n_chunks = tree.n_cols[level - 1]
    if n_partitions > n_chunks:
        raise ValueError(
            f"partitions={n_partitions} exceeds the {n_chunks} chunks of "
            f"level {level}"
        )
    if bounds is None:
        bounds = np.linspace(0, n_chunks, n_partitions + 1).round().astype(int)
    else:
        bounds = np.asarray(list(bounds), dtype=int)
        if (
            len(bounds) != n_partitions + 1
            or bounds[0] != 0
            or bounds[-1] != n_chunks
            or np.any(np.diff(bounds) < 1)
        ):
            raise ValueError(
                f"bounds must be {n_partitions + 1} strictly increasing "
                f"chunk boundaries covering [0, {n_chunks}]; got "
                f"{bounds.tolist()}"
            )
    leaf_span = int(np.prod(tree.branching[level:]))

    head = tree.head(level)
    parts, infos = [], []
    for pid in range(n_partitions):
        c0, c1 = int(bounds[pid]), int(bounds[pid + 1])
        sub = tree.extract(level, c0, c1)
        parts.append(sub)
        label_start = c0 * leaf_span
        infos.append(
            PartitionInfo(
                pid=pid,
                chunk_start=c0,
                chunk_end=c1,
                label_start=label_start,
                label_end=label_start + sub.n_labels,
                memory_bytes=sub.memory_bytes(),
                content_hash=_content_hash(sub),
            )
        )
    assert infos[-1].label_end == tree.n_labels
    manifest = PartitionManifest(
        level=level,
        n_partitions=n_partitions,
        n_labels=tree.n_labels,
        d=tree.d,
        branching=tree.branching,
        router_memory_bytes=head.memory_bytes(),
        total_memory_bytes=tree.memory_bytes(),
        partitions=infos,
    )
    return PartitionedIndex(
        head=head,
        parts=parts,
        manifest=manifest,
        n_cols=tree.n_cols,
        branching=tree.branching,
    )


def rebalance_bounds(
    manifest: PartitionManifest, occupancy: Sequence[float]
) -> List[int]:
    """Re-cut split-level chunk boundaries from observed occupancy skew.

    ``occupancy`` is the per-partition share of observed traffic under the
    *current* cut — ``ServerMetrics.partition_occupancy`` (top-k result
    share) or :meth:`~repro.index.cache.HotBeamCache.occupancy` (router-beam
    share); both sum to ~1. Each chunk is assigned the uniform slice of its
    current partition's observed weight (the finest granularity the signal
    resolves), and the boundary ``k`` moves to the chunk whose weight prefix
    is closest to ``k/P`` of the total — so a partition that served 2× its
    share gives up chunks to its neighbours. Boundaries stay strictly
    increasing (every partition keeps >= 1 chunk); the result feeds
    ``partition_tree(tree, P, level=manifest.level, bounds=...)``.
    """
    P = manifest.n_partitions
    occ = np.asarray(occupancy, dtype=np.float64)
    if occ.shape != (P,):
        raise ValueError(
            f"occupancy must hold {P} shares; got shape {occ.shape}"
        )
    if np.any(occ < 0) or occ.sum() <= 0:
        raise ValueError(f"occupancy shares must be >= 0 and sum > 0; got {occ}")
    n_chunks = manifest.partitions[-1].chunk_end
    weight = np.empty(n_chunks, dtype=np.float64)
    for p, info in zip(occ, manifest.partitions):
        width = info.chunk_end - info.chunk_start
        weight[info.chunk_start:info.chunk_end] = p / width
    prefix = np.concatenate([[0.0], np.cumsum(weight)])  # [n_chunks + 1]
    bounds = [0]
    for k in range(1, P):
        target = prefix[-1] * k / P
        cut = int(np.argmin(np.abs(prefix - target)))
        # Strictly increasing, and leave room for the partitions after us.
        cut = min(max(cut, bounds[-1] + 1), n_chunks - (P - k))
        bounds.append(cut)
    bounds.append(n_chunks)
    return bounds


def rebalance(
    tree: XMRTree,
    manifest: PartitionManifest,
    occupancy: Sequence[float],
) -> PartitionedIndex:
    """Offline re-partition of ``tree`` from observed ``occupancy`` skew.

    Returns a fresh :class:`PartitionedIndex` cut at the manifest's split
    level with :func:`rebalance_bounds`' ranges. The new manifest keeps the
    same schema ``version`` — rebalancing changes *content*, not format —
    so per-partition ``content_hash`` values are the way a deployment tells
    the cuts apart (see ``src/repro/index/README.md``).
    """
    return partition_tree(
        tree,
        manifest.n_partitions,
        level=manifest.level,
        bounds=rebalance_bounds(manifest, occupancy),
    )
