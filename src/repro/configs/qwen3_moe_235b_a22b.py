"""Qwen3-MoE-235B-A22B: 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

Adafactor optimizer (factored 2nd moment) so optimizer state fits v5e HBM
at 256 chips — see DESIGN.md §6.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936,
    n_experts=128, experts_per_token=8, moe_d_ff=1536,
    optimizer="adafactor",
)
