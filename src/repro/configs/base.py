"""Config registry: ``get_config(arch_id)`` + reduced configs for smoke tests."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.common import ArchConfig

ARCH_IDS: List[str] = [
    "yi-9b",
    "minicpm3-4b",
    "phi3-medium-14b",
    "yi-6b",
    "qwen3-moe-235b-a22b",
    "grok-1-314b",
    "seamless-m4t-large-v2",
    "llava-next-mistral-7b",
    "hymba-1.5b",
    "rwkv6-7b",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.CONFIG


def reduced_config(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    kw = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab=256,
        remat=False,
    )
    if cfg.attn_type == "mla":
        kw.update(q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8, qk_nope_dim=16,
                  v_head_dim=16)
    if cfg.n_experts:
        # ample capacity: no token drops at smoke scale (keeps the
        # prefill/decode equivalence exact; production keeps 1.25)
        kw.update(n_experts=4, experts_per_token=2, moe_d_ff=32,
                  capacity_factor=4.0)
    if cfg.ssm_heads:
        kw.update(ssm_heads=4, ssm_head_dim=16, ssm_state=8)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2)
    if cfg.family == "vlm":
        kw.update(frontend_tokens=8)
    if cfg.sliding_window:
        kw.update(sliding_window=8)
    return dataclasses.replace(cfg, **kw)


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
