"""LLaVA-NeXT (mistral-7b backbone): anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Vision frontend is a stub: input_specs() provides precomputed anyres patch
embeddings [B, frontend_tokens, d] (up to 2880 tokens = 5 tiles x 576).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000, rope_theta=1e6,
    frontend="vision", frontend_tokens=2880,
)
