"""Assigned input shapes (all 10 LM archs share this 4-shape grid).

train_4k / prefill_32k lower the full-sequence step; decode_32k / long_500k
lower serve_step: ONE new token against a KV cache of seq_len.
long_500k requires a sub-quadratic prefill path => only ssm/hybrid run it.
"""
import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def runnable(arch_family: str, shape: ShapeSpec) -> bool:
    """long_500k is skipped for pure full-attention archs (see DESIGN.md)."""
    if shape.name == "long_500k":
        return arch_family in ("ssm", "hybrid")
    return True
