"""Hymba-1.5B: parallel attn+mamba heads [arXiv:2411.13676; hf].

Per layer: GQA attention heads and Mamba2/SSD heads run in PARALLEL on the
same input, outputs averaged (the paper's hybrid-head module). Sliding-window
attention everywhere except 3 global layers (first/middle/last), which is
what makes long_500k feasible: SWA KV + O(1) SSD state.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001, sliding_window=1024,
    ssm_heads=25, ssm_head_dim=64, ssm_state=16,
)
