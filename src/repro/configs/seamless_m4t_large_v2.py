"""SeamlessM4T-large-v2: enc-dec, multimodal [arXiv:2308.11596; hf].

Transformer BACKBONE only — the audio frontend is a stub: input_specs()
provides precomputed frame embeddings [B, S_src, d].
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab=256206, n_enc_layers=24, frontend="audio",
)
