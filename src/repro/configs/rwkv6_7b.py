"""RWKV6-7B (Finch): attn-free, data-dependent decay [arXiv:2404.05892; hf]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=14336, vocab=65536, attn_type="none",
    ssm_heads=64, ssm_head_dim=64, ssm_state=64,
)
