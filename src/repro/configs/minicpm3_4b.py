"""MiniCPM3-4B: MLA attention [hf:openbmb/MiniCPM3-4B; hf].

40 heads over d_model=2560; MLA ranks follow the HF config
(q_lora 768, kv_lora 256, rope 32 + nope 64 per head, v 64).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=96,
    d_ff=6400, vocab=73448, attn_type="mla",
    q_lora_rank=768, kv_lora_rank=256, qk_rope_dim=32, qk_nope_dim=64,
    v_head_dim=64,
)
