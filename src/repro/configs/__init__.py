from repro.configs.base import ARCH_IDS, all_configs, get_config, reduced_config
from repro.configs.shapes import SHAPES, ShapeSpec, runnable
