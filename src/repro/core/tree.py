"""XMR tree model + beam-search inference (paper §3, Algorithm 1).

An :class:`XMRTree` holds one :class:`~repro.core.chunked.ChunkedLayer` per
tree level (plus the vanilla per-column layout for the baseline method) as
device arrays. ``infer`` runs the full beam search; the per-level masked
matmul dispatches to any of the MSCM variants or the Pallas kernels, and all
of them return *identical* rankings — the paper's "free of charge" property,
pinned by tests.

Label layout convention: nodes at level l are numbered so that the children
of node p are [p*B, (p+1)*B) at level l+1 — chunk id == parent id, which is
what makes the beam's active-block list trivially static-shaped.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mscm as mscm_lib
from repro.core.beam import beam_step
from repro.core.chunked import ChunkedLayer, ColumnELLLayer
from repro.sparse.csr import CSC

METHODS = (
    "vanilla",            # paper Alg. 4 baseline: per-column sparse dots
    "mscm_dense",         # dense-lookup MSCM (paper item 4)
    "mscm_searchsorted",  # binary-search MSCM (paper item 2)
    "mscm_pallas",        # Pallas kernel (fused or pregather by d)
    "mscm_pallas_pregather",
)


@dataclasses.dataclass
class TreeLayerArrays:
    """Device-resident tensors for one level (a pytree)."""

    chunk_rows: jax.Array  # int32 [C, R]
    chunk_vals: jax.Array  # f32 [C, R, B]
    col_rows: jax.Array    # int32 [L, Rc] (vanilla baseline layout)
    col_vals: jax.Array    # f32 [L, Rc]


jax.tree_util.register_dataclass(
    TreeLayerArrays,
    data_fields=["chunk_rows", "chunk_vals", "col_rows", "col_vals"],
    meta_fields=[],
)


@dataclasses.dataclass
class XMRTree:
    layers: List[TreeLayerArrays]
    n_cols: Tuple[int, ...]     # true (unpadded) label count per level
    branching: Tuple[int, ...]  # B per level
    d: int

    @property
    def depth(self) -> int:
        return len(self.layers)

    @property
    def n_labels(self) -> int:
        return self.n_cols[-1]

    # ------------------------------------------------------------------
    @classmethod
    def from_weight_matrices(
        cls, weights: Sequence[CSC], branching: int | Sequence[int]
    ) -> "XMRTree":
        """Build from per-level CSC weight matrices W^(l), l = 2..depth.

        ``weights[i]`` scores the nodes of level i+2; level sizes must follow
        the chunk layout: L_{l+1} chunks == L_l columns (ragged trees are
        padded by the converters)."""
        bs = (
            [int(branching)] * len(weights)
            if np.isscalar(branching)
            else [int(b) for b in branching]
        )
        layers, ncols = [], []
        for w, b in zip(weights, bs):
            ch = ChunkedLayer.from_csc(w, b)
            col = ColumnELLLayer.from_csc(w, b)
            layers.append(
                TreeLayerArrays(
                    chunk_rows=jnp.asarray(ch.rows),
                    chunk_vals=jnp.asarray(ch.vals),
                    col_rows=jnp.asarray(col.rows),
                    col_vals=jnp.asarray(col.vals),
                )
            )
            ncols.append(w.shape[1])
        return cls(layers=layers, n_cols=tuple(ncols), branching=tuple(bs), d=weights[0].shape[0])

    def memory_bytes(self) -> int:
        tot = 0
        for l in self.layers:
            tot += sum(np.asarray(t).nbytes for t in (l.chunk_rows, l.chunk_vals))
        return tot

    # ------------------------------------------------------------------
    def infer(
        self,
        x_idx: jax.Array,  # int32 [n, Q] sorted, sentinel-padded
        x_val: jax.Array,  # f32 [n, Q]
        *,
        beam: int = 10,
        topk: int = 10,
        method: str = "mscm_dense",
        score_mode: str = "prod",
    ) -> Tuple[jax.Array, jax.Array]:
        """Beam-search inference. Returns (scores [n, k], labels [n, k])."""
        return _tree_infer(
            tuple(self.layers),
            self.n_cols,
            self.branching,
            self.d,
            x_idx,
            x_val,
            beam=beam,
            topk=topk,
            method=method,
            score_mode=score_mode,
        )


def _masked_matmul(
    layer: TreeLayerArrays,
    x_idx: jax.Array,
    x_val: jax.Array,
    x_dense: jax.Array | None,
    block_q: jax.Array,
    block_c: jax.Array,
    branching: int,
    d: int,
    method: str,
) -> jax.Array:
    """Dispatch one level's masked product A = M ⊙ (X W) (paper eq. 6)."""
    if method == "vanilla":
        return mscm_lib.vanilla_columns(
            x_idx, x_val, layer.col_rows, layer.col_vals, block_q, block_c, branching, d
        )
    if method == "mscm_dense":
        return mscm_lib.mscm_dense_lookup(
            x_dense, layer.chunk_rows, layer.chunk_vals, block_q, block_c
        )
    if method == "mscm_searchsorted":
        return mscm_lib.mscm_searchsorted(
            x_idx, x_val, layer.chunk_rows, layer.chunk_vals, block_q, block_c, d
        )
    if method in ("mscm_pallas", "mscm_pallas_pregather"):
        from repro.kernels import ops  # local import: kernels are optional

        variant = "pregather" if method.endswith("pregather") else "auto"
        return ops.mscm_pallas(
            x_dense, layer.chunk_rows, layer.chunk_vals, block_q, block_c, variant=variant
        )
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")


@functools.partial(
    jax.jit,
    static_argnames=("n_cols", "branching", "d", "beam", "topk", "method", "score_mode"),
)
def _tree_infer(
    layers: Tuple[TreeLayerArrays, ...],
    n_cols: Tuple[int, ...],
    branching: Tuple[int, ...],
    d: int,
    x_idx: jax.Array,
    x_val: jax.Array,
    *,
    beam: int,
    topk: int,
    method: str,
    score_mode: str,
) -> Tuple[jax.Array, jax.Array]:
    n = x_idx.shape[0]
    needs_dense = method in ("mscm_dense", "mscm_pallas", "mscm_pallas_pregather")
    x_dense = mscm_lib.scatter_dense(x_idx, x_val, d) if needs_dense else None

    # Layer 1 is the root: prediction 1 (Alg. 1 line 3); its children form
    # chunk 0 of the first stored level.
    parent_ids = jnp.zeros((n, 1), jnp.int32)
    scores = (
        jnp.ones((n, 1), jnp.float32)
        if score_mode == "prod"
        else jnp.zeros((n, 1), jnp.float32)
    )
    for li, layer in enumerate(layers):
        b_cur = parent_ids.shape[1]
        block_q = jnp.repeat(jnp.arange(n, dtype=jnp.int32), b_cur)
        block_c = parent_ids.reshape(-1)
        logits = _masked_matmul(
            layer, x_idx, x_val, x_dense, block_q, block_c, branching[li], d, method
        ).reshape(n, b_cur, branching[li])
        is_last = li == len(layers) - 1
        next_b = min(topk if is_last else beam, n_cols[li])
        parent_ids, scores = beam_step(
            parent_ids, scores, logits, n_cols[li], next_b, mode=score_mode
        )
    return scores, parent_ids
