"""XMR tree model + beam-search inference (paper §3, Algorithm 1).

An :class:`XMRTree` holds one :class:`~repro.core.chunked.ChunkedLayer` per
tree level (plus the vanilla per-column layout for the baseline method) as
device arrays. ``infer`` runs the full beam search; the per-level masked
matmul dispatches to any of the MSCM variants or the Pallas kernels, and all
of them return *identical* rankings — the paper's "free of charge" property,
pinned by tests.

Label layout convention: nodes at level l are numbered so that the children
of node p are [p*B, (p+1)*B) at level l+1 — chunk id == parent id, which is
what makes the beam's active-block list trivially static-shaped.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mscm as mscm_lib
from repro.core.beam import NEG_INF, beam_select, combine_scores
from repro.core.chunked import ChunkedLayer, ColumnELLLayer
from repro.sparse.csr import CSC

# Masked-matmul method selection — every exact entry returns *identical*
# rankings (the paper's "free of charge" property, pinned by tests); they
# differ only in how the traversal maps to hardware. The one exception is
# the quantized tier's method (suffix ``_q``), which is exact *given its
# compressed weights* but approximate against the f32 tree:
#
#   vanilla               per-column sparse dots (paper Alg. 4 baseline).
#                         Correctness oracle; B× the traversal work.
#   mscm_dense            dense-lookup MSCM (paper §4 item 4): queries
#                         scattered into a dense [n, d+1] table, XLA gather +
#                         einsum. Best non-Pallas batch method; needs the
#                         dense table to fit (d ≲ a few M).
#   mscm_searchsorted     binary-search MSCM (paper §4 item 2): no dense
#                         table, log₂(Q)-depth intersections. Best when d is
#                         huge or memory-tight; slower than dense per block.
#   mscm_pallas           Pallas fused kernel: one [1,R]×[R,B] contraction
#                         per block, in-kernel VMEM gather, chunk-sorted grid
#                         so each chunk tile is DMA'd once (paper Alg. 3).
#                         Best online/small-batch TPU path for d ≤ ~1M.
#   mscm_pallas_pregather Pallas pregather kernel: XLA gathers query rows in
#                         HBM, kernel streams [1,R]×[R,B]. The huge-d TPU
#                         path (enterprise d = 4M).
#   mscm_pallas_grouped   MXU-tiled grouped kernel: blocks packed per chunk
#                         into QT-row tiles *on device*, one [QT,R]×[R,B]
#                         matmul per tile with the σ⊗parent beam epilogue
#                         fused in-kernel. The high-throughput batch TPU
#                         path — amortizes each chunk tile over up to QT
#                         queries and keeps the whole traversal in one XLA
#                         program.
#   mscm_pallas_grouped_q the grouped kernel over *quantized* chunk tiles
#                         (int8/fp8 + per-column scales, repro.quant):
#                         dequantize-in-register before the tile matmul.
#                         The one approximate member — bitwise-identical to
#                         mscm_pallas_grouped on the *dequantized* weights,
#                         but the weights themselves carry quantization
#                         error (measured contract, benchmarks/bench_quant).
METHODS = (
    "vanilla",
    "mscm_dense",
    "mscm_searchsorted",
    "mscm_pallas",
    "mscm_pallas_pregather",
    "mscm_pallas_grouped",
    "mscm_pallas_grouped_q",
)


@dataclasses.dataclass
class TreeLayerArrays:
    """Device-resident tensors for one level (a pytree)."""

    chunk_rows: jax.Array  # int32 [C, R]
    chunk_vals: jax.Array  # f32 [C, R, B]
    col_rows: jax.Array    # int32 [L, Rc] (vanilla baseline layout)
    col_vals: jax.Array    # f32 [L, Rc]


jax.tree_util.register_dataclass(
    TreeLayerArrays,
    data_fields=["chunk_rows", "chunk_vals", "col_rows", "col_vals"],
    meta_fields=[],
)


@dataclasses.dataclass
class XMRTree:
    layers: List[TreeLayerArrays]
    n_cols: Tuple[int, ...]     # true (unpadded) label count per level
    branching: Tuple[int, ...]  # B per level
    d: int

    @property
    def depth(self) -> int:
        return len(self.layers)

    @property
    def n_labels(self) -> int:
        return self.n_cols[-1]

    # ------------------------------------------------------------------
    @classmethod
    def from_weight_matrices(
        cls, weights: Sequence[CSC], branching: int | Sequence[int]
    ) -> "XMRTree":
        """Build from per-level CSC weight matrices W^(l), l = 2..depth.

        ``weights[i]`` scores the nodes of level i+2; level sizes must follow
        the chunk layout: L_{l+1} chunks == L_l columns (ragged trees are
        padded by the converters)."""
        bs = (
            [int(branching)] * len(weights)
            if np.isscalar(branching)
            else [int(b) for b in branching]
        )
        layers, ncols = [], []
        for w, b in zip(weights, bs):
            ch = ChunkedLayer.from_csc(w, b)
            col = ColumnELLLayer.from_csc(w, b)
            layers.append(
                TreeLayerArrays(
                    chunk_rows=jnp.asarray(ch.rows),
                    chunk_vals=jnp.asarray(ch.vals),
                    col_rows=jnp.asarray(col.rows),
                    col_vals=jnp.asarray(col.vals),
                )
            )
            ncols.append(w.shape[1])
        return cls(layers=layers, n_cols=tuple(ncols), branching=tuple(bs), d=weights[0].shape[0])

    def device_put(self, sharding) -> "XMRTree":
        """Copy of the tree with every layer tensor placed per ``sharding``.

        With a replicated ``NamedSharding(mesh, P())`` this is the serving
        tier's multi-device path: one physical copy per device, after which
        data-sharded query batches fan out over the mesh for free.
        """
        layers = [
            jax.tree.map(lambda a: jax.device_put(a, sharding), l)
            for l in self.layers
        ]
        return dataclasses.replace(self, layers=layers)

    def memory_bytes(self) -> int:
        tot = 0
        for l in self.layers:
            tensors = [l.chunk_rows, l.chunk_vals]
            scales = getattr(l, "chunk_scales", None)  # quantized layers
            if scales is not None:
                tensors.append(scales)
            tot += sum(np.asarray(t).nbytes for t in tensors)
        return tot

    # -- split / extract (label-space partitioning, repro.index) -----------
    def head(self, level: int) -> "XMRTree":
        """Top ``level`` stored layers as a standalone tree (the router).

        The head's leaves are the nodes of level ``level - 1`` — exactly the
        chunk ids of layer ``level`` — so ``head(level).infer(...,
        beam=b, topk=b)`` reproduces the unpartitioned traversal's beam state
        after ``level`` levels bit-for-bit (its internal "last level" uses
        ``next_b = min(b, n_cols[level-1])``, the same clamp the full
        traversal applies at a non-last level).
        """
        if not 1 <= level < self.depth:
            raise ValueError(f"head level must be in [1, {self.depth}); got {level}")
        return XMRTree(
            layers=list(self.layers[:level]),
            n_cols=self.n_cols[:level],
            branching=self.branching[:level],
            d=self.d,
        )

    def extract(self, level: int, chunk_start: int, chunk_end: int) -> "XMRTree":
        """Sub-tree owning chunks ``[chunk_start, chunk_end)`` of layer
        ``level`` down to the leaves, as a standalone :class:`XMRTree`.

        Layer tensors are *slices* of this tree's arrays — the ELL pad widths
        R/Rc are preserved, so every per-column dot product in the sub-tree is
        bitwise-identical to the same column scored through the full tree.
        Each level additionally gains one **phantom chunk** (all-sentinel
        rows, zero values, logits exactly 0): out-of-partition beam entries
        are parked there, their children ids land at/after the local label
        count, and the standard phantom-column mask re-pins their scores to
        ``NEG_INF`` at every level — they can never collide with a real
        label or surface in a merge.
        """
        if not 1 <= level < self.depth:
            raise ValueError(f"extract level must be in [1, {self.depth}); got {level}")
        if not 0 <= chunk_start < chunk_end:
            raise ValueError(f"bad chunk range [{chunk_start}, {chunk_end})")
        layers, ncols = [], []
        c0, c1 = chunk_start, chunk_end
        for li in range(level, self.depth):
            lay = self.layers[li]
            b = self.branching[li]
            c_global = lay.chunk_rows.shape[0]
            # The last partition's range can overrun the ragged global tail
            # at deeper levels (fewer real chunks than chunk_end * B): clamp.
            c1 = min(c1, c_global)
            if c0 >= c1:
                raise ValueError(
                    f"chunk range start {c0} has no real chunks at layer {li} "
                    f"({c_global} total)"
                )
            n_local = min(c1 * b, self.n_cols[li]) - c0 * b
            if n_local <= 0:
                raise ValueError(
                    f"chunk range [{c0}, {c1}) holds no real columns at "
                    f"layer {li}"
                )
            cr = lay.chunk_rows[c0:c1]
            cv = lay.chunk_vals[c0:c1]
            phantom_rows = jnp.full((1,) + cr.shape[1:], self.d, cr.dtype)
            phantom_vals = jnp.zeros((1,) + cv.shape[1:], cv.dtype)
            col_r = lay.col_rows[c0 * b : c1 * b]
            col_v = lay.col_vals[c0 * b : c1 * b]
            pcol_r = jnp.full((b,) + col_r.shape[1:], self.d, col_r.dtype)
            pcol_v = jnp.zeros((b,) + col_v.shape[1:], col_v.dtype)
            layers.append(
                TreeLayerArrays(
                    chunk_rows=jnp.concatenate([cr, phantom_rows]),
                    chunk_vals=jnp.concatenate([cv, phantom_vals]),
                    col_rows=jnp.concatenate([col_r, pcol_r]),
                    col_vals=jnp.concatenate([col_v, pcol_v]),
                )
            )
            ncols.append(n_local)
            c0, c1 = c0 * b, c1 * b
        return XMRTree(
            layers=layers,
            n_cols=tuple(ncols),
            branching=self.branching[level:],
            d=self.d,
        )

    # ------------------------------------------------------------------
    def infer(
        self,
        x_idx: jax.Array,  # int32 [n, Q] sorted, sentinel-padded
        x_val: jax.Array,  # f32 [n, Q]
        *,
        beam: int = 10,
        topk: int = 10,
        method: str = "mscm_dense",
        score_mode: str = "prod",
        qt: int = 8,
        init_parent_ids: jax.Array | None = None,
        init_scores: jax.Array | None = None,
        clamp_chunks: bool = False,
    ) -> Tuple[jax.Array, jax.Array]:
        """Beam-search inference. Returns (scores [n, k], labels [n, k]).

        ``method`` picks the masked-matmul backend (see the table above the
        ``METHODS`` tuple); ``qt`` is the query-tile height of the grouped
        Pallas kernel (ignored by other methods). All methods return
        identical rankings.

        ``init_parent_ids``/``init_scores`` (int32/f32 ``[n, b]``) start the
        search from an externally-computed beam instead of the root — the
        scatter–gather continuation path (``repro.index``): a router hands
        each label partition its surviving beam entries. ``clamp_chunks``
        parks out-of-range parents (id ≥ chunk count) on the last chunk —
        the phantom chunk :meth:`extract` appends — instead of relying on
        gather clamping, so masked beam entries score exactly ``NEG_INF``
        children and never alias a real chunk.
        """
        return _tree_infer(
            tuple(self.layers),
            self.n_cols,
            self.branching,
            self.d,
            x_idx,
            x_val,
            init_parent_ids,
            init_scores,
            beam=beam,
            topk=topk,
            method=method,
            score_mode=score_mode,
            qt=qt,
            clamp_chunks=clamp_chunks,
        )


def _masked_matmul(
    layer: TreeLayerArrays,
    x_idx: jax.Array,
    x_val: jax.Array,
    x_dense: jax.Array | None,
    block_q: jax.Array,
    block_c: jax.Array,
    branching: int,
    d: int,
    method: str,
) -> jax.Array:
    """Dispatch one level's masked product A = M ⊙ (X W) (paper eq. 6)."""
    if method == "vanilla":
        return mscm_lib.vanilla_columns(
            x_idx, x_val, layer.col_rows, layer.col_vals, block_q, block_c, branching, d
        )
    if method == "mscm_dense":
        return mscm_lib.mscm_dense_lookup(
            x_dense, layer.chunk_rows, layer.chunk_vals, block_q, block_c
        )
    if method == "mscm_searchsorted":
        return mscm_lib.mscm_searchsorted(
            x_idx, x_val, layer.chunk_rows, layer.chunk_vals, block_q, block_c, d
        )
    if method in ("mscm_pallas", "mscm_pallas_pregather"):
        from repro.kernels import ops  # local import: kernels are optional

        variant = "pregather" if method.endswith("pregather") else "auto"
        return ops.mscm_pallas(
            x_dense, layer.chunk_rows, layer.chunk_vals, block_q, block_c, variant=variant
        )
    if method in ("mscm_pallas_grouped", "mscm_pallas_grouped_q"):
        # Dispatched directly in _tree_infer: the grouped kernels fuse the
        # σ⊗parent epilogue with the beam step, which needs the parent
        # scores this function never sees. Raw logits are available via
        # ops.mscm_grouped_level / repro.quant.kernels.mscm_grouped_q_level
        # with mode="none".
        raise ValueError(
            f"{method} is dispatched inside _tree_infer; use the "
            "mscm_grouped(_q)_level wrappers for a bare matmul"
        )
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")


def level_combined(
    layer: TreeLayerArrays,
    branching: int,
    d: int,
    x_idx: jax.Array,
    x_val: jax.Array,
    x_dense: jax.Array | None,
    parent_ids: jax.Array,     # int32 [n, b] chunk ids (already clamped)
    parent_scores: jax.Array,  # f32 [n, b]
    *,
    method: str,
    score_mode: str,
    qt: int = 8,
) -> jax.Array:
    """One level's *combined* child scores σ(logit) ⊗ parent — f32 [n, b, B].

    The single source of truth for per-level arithmetic: the in-tree beam
    search and the scatter–gather planner (:mod:`repro.index.planner`) both
    go through here, which is what makes a partition's owned rows
    bitwise-identical to the same rows scored through the full tree.
    """
    n, b_cur = parent_ids.shape
    block_q = jnp.repeat(jnp.arange(n, dtype=jnp.int32), b_cur)
    block_c = parent_ids.reshape(-1)
    if method == "mscm_pallas_grouped":
        from repro.kernels import ops  # local import: kernels are optional

        # Grouped path: chunk grouping, MXU-tiled matmul, and the σ⊗parent
        # epilogue all happen inside the kernel dispatch — the combined beam
        # scores are the only HBM round-trip per level.
        return ops.mscm_grouped_level(
            x_dense,
            layer.chunk_rows,
            layer.chunk_vals,
            block_q,
            block_c,
            parent_scores.reshape(-1),
            qt=qt,
            mode=score_mode,
        ).reshape(n, b_cur, branching)
    if method == "mscm_pallas_grouped_q":
        from repro.quant import kernels as qkernels  # local: tier is optional

        # Quantized grouped path: same device grouping and fused epilogue,
        # with the int8/fp8 chunk tile dequantized in-register against its
        # per-column scale row (layer is a QuantLayerArrays).
        return qkernels.mscm_grouped_q_level(
            x_dense,
            layer.chunk_rows,
            layer.chunk_vals,
            layer.chunk_scales,
            block_q,
            block_c,
            parent_scores.reshape(-1),
            qt=qt,
            mode=score_mode,
        ).reshape(n, b_cur, branching)
    logits = _masked_matmul(
        layer, x_idx, x_val, x_dense, block_q, block_c, branching, d, method
    ).reshape(n, b_cur, branching)
    return combine_scores(parent_scores, logits, score_mode)


def owned_level_combined(
    layer: TreeLayerArrays,
    branching: int,
    d: int,
    x_idx: jax.Array,
    x_val: jax.Array,
    x_dense: jax.Array | None,
    parent_ids: jax.Array,     # int32 [n, b] GLOBAL chunk ids at this level
    parent_scores: jax.Array,  # f32 [n, b]
    chunk_start: jax.Array,    # scalar: partition's first global chunk
    chunk_count: jax.Array,    # scalar: partition's real chunk count
    *,
    method: str,
    score_mode: str,
    qt: int = 8,
) -> Tuple[jax.Array, jax.Array]:
    """The :func:`level_combined` continuation API for partitioned slices.

    Localizes a *global* beam onto a partition's sliced layer — rows whose
    chunk falls in ``[chunk_start, chunk_start + chunk_count)`` are owned;
    everything else parks on the phantom chunk (index ``chunk_count``, the
    all-sentinel pad :meth:`XMRTree.extract` appends) and returns exactly
    ``NEG_INF`` — then scores one level through the same arithmetic as the
    in-tree traversal. Returns ``(combined [n, b, B], owned [n, b])``.

    This is the single continuation point both scatter–gather sync modes go
    through (``repro.index.planner``): the per-level exchange scores the
    canonical global beam here, and the pipelined mode scores its
    *speculative* local beam here — which is why a speculative row that
    survives the global select is bitwise what the full tree computes.
    ``chunk_start``/``chunk_count`` are meant to be traced so equal-shape
    partitions share one compilation.
    """
    owned = (parent_ids >= chunk_start) & (parent_ids < chunk_start + chunk_count)
    local_ids = jnp.where(owned, parent_ids - chunk_start, chunk_count)
    local_scores = jnp.where(owned, parent_scores, NEG_INF)
    combined = level_combined(
        layer, branching, d, x_idx, x_val, x_dense,
        local_ids.astype(jnp.int32), local_scores,
        method=method, score_mode=score_mode, qt=qt,
    )
    return jnp.where(owned[..., None], combined, NEG_INF), owned


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_cols", "branching", "d", "beam", "topk", "method", "score_mode",
        "qt", "clamp_chunks",
    ),
)
def _tree_infer(
    layers: Tuple[TreeLayerArrays, ...],
    n_cols: Tuple[int, ...],
    branching: Tuple[int, ...],
    d: int,
    x_idx: jax.Array,
    x_val: jax.Array,
    init_parent_ids: jax.Array | None = None,
    init_scores: jax.Array | None = None,
    *,
    beam: int,
    topk: int,
    method: str,
    score_mode: str,
    qt: int = 8,
    clamp_chunks: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    n = x_idx.shape[0]
    needs_dense = method in (
        "mscm_dense", "mscm_pallas", "mscm_pallas_pregather",
        "mscm_pallas_grouped", "mscm_pallas_grouped_q",
    )
    x_dense = mscm_lib.scatter_dense(x_idx, x_val, d) if needs_dense else None

    if init_parent_ids is not None:
        # Continuation from an external beam (scatter–gather partitions).
        parent_ids = init_parent_ids.astype(jnp.int32)
        scores = init_scores.astype(jnp.float32)
    else:
        # Layer 1 is the root: prediction 1 (Alg. 1 line 3); its children
        # form chunk 0 of the first stored level.
        parent_ids = jnp.zeros((n, 1), jnp.int32)
        scores = (
            jnp.ones((n, 1), jnp.float32)
            if score_mode == "prod"
            else jnp.zeros((n, 1), jnp.float32)
        )
    for li, layer in enumerate(layers):
        chunk_ids = parent_ids
        if clamp_chunks:
            # Phantom beam entries (id ≥ real chunk count) park on the last
            # chunk — the all-sentinel phantom extract() appends, whose
            # logits are exactly 0 and whose children ids fall at/after the
            # local label count, so beam_select re-pins them to NEG_INF.
            chunk_ids = jnp.minimum(
                parent_ids, layer.chunk_rows.shape[0] - 1
            )
        is_last = li == len(layers) - 1
        next_b = min(topk if is_last else beam, n_cols[li])
        combined = level_combined(
            layer, branching[li], d, x_idx, x_val, x_dense, chunk_ids,
            scores, method=method, score_mode=score_mode, qt=qt,
        )
        parent_ids, scores = beam_select(
            chunk_ids, combined, n_cols[li], next_b
        )
        if method in ("mscm_pallas_grouped", "mscm_pallas_grouped_q") and not is_last:
            # Keep the beam id-ascending: children of a sorted beam are a
            # concatenation of sorted runs, so level l+1's block list
            # inherits level l's chunk-major discipline and the global
            # grouping argsort only merges across queries. Selection is
            # canonical (beam_select), so reordering cannot change results.
            perm = jnp.argsort(parent_ids, axis=1)
            parent_ids = jnp.take_along_axis(parent_ids, perm, axis=1)
            scores = jnp.take_along_axis(scores, perm, axis=1)
    return scores, parent_ids
