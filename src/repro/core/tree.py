"""XMR tree model + beam-search inference (paper §3, Algorithm 1).

An :class:`XMRTree` holds one :class:`~repro.core.chunked.ChunkedLayer` per
tree level (plus the vanilla per-column layout for the baseline method) as
device arrays. ``infer`` runs the full beam search; the per-level masked
matmul dispatches to any of the MSCM variants or the Pallas kernels, and all
of them return *identical* rankings — the paper's "free of charge" property,
pinned by tests.

Label layout convention: nodes at level l are numbered so that the children
of node p are [p*B, (p+1)*B) at level l+1 — chunk id == parent id, which is
what makes the beam's active-block list trivially static-shaped.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mscm as mscm_lib
from repro.core.beam import beam_select, beam_step
from repro.core.chunked import ChunkedLayer, ColumnELLLayer
from repro.sparse.csr import CSC

# Masked-matmul method selection — every entry returns *identical* rankings
# (the paper's "free of charge" property, pinned by tests); they differ only
# in how the traversal maps to hardware:
#
#   vanilla               per-column sparse dots (paper Alg. 4 baseline).
#                         Correctness oracle; B× the traversal work.
#   mscm_dense            dense-lookup MSCM (paper §4 item 4): queries
#                         scattered into a dense [n, d+1] table, XLA gather +
#                         einsum. Best non-Pallas batch method; needs the
#                         dense table to fit (d ≲ a few M).
#   mscm_searchsorted     binary-search MSCM (paper §4 item 2): no dense
#                         table, log₂(Q)-depth intersections. Best when d is
#                         huge or memory-tight; slower than dense per block.
#   mscm_pallas           Pallas fused kernel: one [1,R]×[R,B] contraction
#                         per block, in-kernel VMEM gather, chunk-sorted grid
#                         so each chunk tile is DMA'd once (paper Alg. 3).
#                         Best online/small-batch TPU path for d ≤ ~1M.
#   mscm_pallas_pregather Pallas pregather kernel: XLA gathers query rows in
#                         HBM, kernel streams [1,R]×[R,B]. The huge-d TPU
#                         path (enterprise d = 4M).
#   mscm_pallas_grouped   MXU-tiled grouped kernel: blocks packed per chunk
#                         into QT-row tiles *on device*, one [QT,R]×[R,B]
#                         matmul per tile with the σ⊗parent beam epilogue
#                         fused in-kernel. The high-throughput batch TPU
#                         path — amortizes each chunk tile over up to QT
#                         queries and keeps the whole traversal in one XLA
#                         program.
METHODS = (
    "vanilla",
    "mscm_dense",
    "mscm_searchsorted",
    "mscm_pallas",
    "mscm_pallas_pregather",
    "mscm_pallas_grouped",
)


@dataclasses.dataclass
class TreeLayerArrays:
    """Device-resident tensors for one level (a pytree)."""

    chunk_rows: jax.Array  # int32 [C, R]
    chunk_vals: jax.Array  # f32 [C, R, B]
    col_rows: jax.Array    # int32 [L, Rc] (vanilla baseline layout)
    col_vals: jax.Array    # f32 [L, Rc]


jax.tree_util.register_dataclass(
    TreeLayerArrays,
    data_fields=["chunk_rows", "chunk_vals", "col_rows", "col_vals"],
    meta_fields=[],
)


@dataclasses.dataclass
class XMRTree:
    layers: List[TreeLayerArrays]
    n_cols: Tuple[int, ...]     # true (unpadded) label count per level
    branching: Tuple[int, ...]  # B per level
    d: int

    @property
    def depth(self) -> int:
        return len(self.layers)

    @property
    def n_labels(self) -> int:
        return self.n_cols[-1]

    # ------------------------------------------------------------------
    @classmethod
    def from_weight_matrices(
        cls, weights: Sequence[CSC], branching: int | Sequence[int]
    ) -> "XMRTree":
        """Build from per-level CSC weight matrices W^(l), l = 2..depth.

        ``weights[i]`` scores the nodes of level i+2; level sizes must follow
        the chunk layout: L_{l+1} chunks == L_l columns (ragged trees are
        padded by the converters)."""
        bs = (
            [int(branching)] * len(weights)
            if np.isscalar(branching)
            else [int(b) for b in branching]
        )
        layers, ncols = [], []
        for w, b in zip(weights, bs):
            ch = ChunkedLayer.from_csc(w, b)
            col = ColumnELLLayer.from_csc(w, b)
            layers.append(
                TreeLayerArrays(
                    chunk_rows=jnp.asarray(ch.rows),
                    chunk_vals=jnp.asarray(ch.vals),
                    col_rows=jnp.asarray(col.rows),
                    col_vals=jnp.asarray(col.vals),
                )
            )
            ncols.append(w.shape[1])
        return cls(layers=layers, n_cols=tuple(ncols), branching=tuple(bs), d=weights[0].shape[0])

    def device_put(self, sharding) -> "XMRTree":
        """Copy of the tree with every layer tensor placed per ``sharding``.

        With a replicated ``NamedSharding(mesh, P())`` this is the serving
        tier's multi-device path: one physical copy per device, after which
        data-sharded query batches fan out over the mesh for free.
        """
        layers = [
            jax.tree.map(lambda a: jax.device_put(a, sharding), l)
            for l in self.layers
        ]
        return dataclasses.replace(self, layers=layers)

    def memory_bytes(self) -> int:
        tot = 0
        for l in self.layers:
            tot += sum(np.asarray(t).nbytes for t in (l.chunk_rows, l.chunk_vals))
        return tot

    # ------------------------------------------------------------------
    def infer(
        self,
        x_idx: jax.Array,  # int32 [n, Q] sorted, sentinel-padded
        x_val: jax.Array,  # f32 [n, Q]
        *,
        beam: int = 10,
        topk: int = 10,
        method: str = "mscm_dense",
        score_mode: str = "prod",
        qt: int = 8,
    ) -> Tuple[jax.Array, jax.Array]:
        """Beam-search inference. Returns (scores [n, k], labels [n, k]).

        ``method`` picks the masked-matmul backend (see the table above the
        ``METHODS`` tuple); ``qt`` is the query-tile height of the grouped
        Pallas kernel (ignored by other methods). All methods return
        identical rankings.
        """
        return _tree_infer(
            tuple(self.layers),
            self.n_cols,
            self.branching,
            self.d,
            x_idx,
            x_val,
            beam=beam,
            topk=topk,
            method=method,
            score_mode=score_mode,
            qt=qt,
        )


def _masked_matmul(
    layer: TreeLayerArrays,
    x_idx: jax.Array,
    x_val: jax.Array,
    x_dense: jax.Array | None,
    block_q: jax.Array,
    block_c: jax.Array,
    branching: int,
    d: int,
    method: str,
) -> jax.Array:
    """Dispatch one level's masked product A = M ⊙ (X W) (paper eq. 6)."""
    if method == "vanilla":
        return mscm_lib.vanilla_columns(
            x_idx, x_val, layer.col_rows, layer.col_vals, block_q, block_c, branching, d
        )
    if method == "mscm_dense":
        return mscm_lib.mscm_dense_lookup(
            x_dense, layer.chunk_rows, layer.chunk_vals, block_q, block_c
        )
    if method == "mscm_searchsorted":
        return mscm_lib.mscm_searchsorted(
            x_idx, x_val, layer.chunk_rows, layer.chunk_vals, block_q, block_c, d
        )
    if method in ("mscm_pallas", "mscm_pallas_pregather"):
        from repro.kernels import ops  # local import: kernels are optional

        variant = "pregather" if method.endswith("pregather") else "auto"
        return ops.mscm_pallas(
            x_dense, layer.chunk_rows, layer.chunk_vals, block_q, block_c, variant=variant
        )
    if method == "mscm_pallas_grouped":
        # Dispatched directly in _tree_infer: the grouped kernel fuses the
        # σ⊗parent epilogue with the beam step, which needs the parent
        # scores this function never sees. Raw logits are available via
        # ops.mscm_grouped_level(..., mode="none").
        raise ValueError(
            "mscm_pallas_grouped is dispatched inside _tree_infer; "
            "use repro.kernels.ops.mscm_grouped_level for a bare matmul"
        )
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_cols", "branching", "d", "beam", "topk", "method", "score_mode", "qt"
    ),
)
def _tree_infer(
    layers: Tuple[TreeLayerArrays, ...],
    n_cols: Tuple[int, ...],
    branching: Tuple[int, ...],
    d: int,
    x_idx: jax.Array,
    x_val: jax.Array,
    *,
    beam: int,
    topk: int,
    method: str,
    score_mode: str,
    qt: int = 8,
) -> Tuple[jax.Array, jax.Array]:
    n = x_idx.shape[0]
    needs_dense = method in (
        "mscm_dense", "mscm_pallas", "mscm_pallas_pregather", "mscm_pallas_grouped"
    )
    x_dense = mscm_lib.scatter_dense(x_idx, x_val, d) if needs_dense else None

    # Layer 1 is the root: prediction 1 (Alg. 1 line 3); its children form
    # chunk 0 of the first stored level.
    parent_ids = jnp.zeros((n, 1), jnp.int32)
    scores = (
        jnp.ones((n, 1), jnp.float32)
        if score_mode == "prod"
        else jnp.zeros((n, 1), jnp.float32)
    )
    for li, layer in enumerate(layers):
        b_cur = parent_ids.shape[1]
        block_q = jnp.repeat(jnp.arange(n, dtype=jnp.int32), b_cur)
        block_c = parent_ids.reshape(-1)
        is_last = li == len(layers) - 1
        next_b = min(topk if is_last else beam, n_cols[li])
        if method == "mscm_pallas_grouped":
            from repro.kernels import ops  # local import: kernels are optional

            # Grouped path: chunk grouping, MXU-tiled matmul, and the
            # σ⊗parent epilogue all happen inside the kernel dispatch — the
            # combined beam scores are the only HBM round-trip per level.
            combined = ops.mscm_grouped_level(
                x_dense,
                layer.chunk_rows,
                layer.chunk_vals,
                block_q,
                block_c,
                scores.reshape(-1),
                qt=qt,
                mode=score_mode,
            ).reshape(n, b_cur, branching[li])
            parent_ids, scores = beam_select(
                parent_ids, combined, n_cols[li], next_b
            )
            if not is_last:
                # Keep the beam id-ascending: children of a sorted beam are
                # a concatenation of sorted runs, so level l+1's block list
                # inherits level l's chunk-major discipline and the global
                # grouping argsort only merges across queries. Selection is
                # canonical (beam_select), so reordering cannot change
                # results.
                perm = jnp.argsort(parent_ids, axis=1)
                parent_ids = jnp.take_along_axis(parent_ids, perm, axis=1)
                scores = jnp.take_along_axis(scores, perm, axis=1)
        else:
            logits = _masked_matmul(
                layer, x_idx, x_val, x_dense, block_q, block_c,
                branching[li], d, method,
            ).reshape(n, b_cur, branching[li])
            parent_ids, scores = beam_step(
                parent_ids, scores, logits, n_cols[li], next_b, mode=score_mode
            )
    return scores, parent_ids
