"""Distributed XMR inference: queries × label-space sharding (shard_map).

Maps the paper's §6.1 parallelism onto the production mesh:

* ``data`` axis  — queries shard embarrassingly (the paper's OpenMP claim);
* ``model`` axis — the LEAF level's chunks shard by label range (at 100M
  labels the leaf weight tensor is the model; upper levels are ≤ 1/B the
  size and replicate).

Each (query, surviving-parent) block is owned by exactly one model shard
(chunk ranges are contiguous), so every shard scores its local blocks with
the same MSCM kernels, takes a local top-k, and a candidate all-gather +
global top-k completes the beam — the standard distributed-retrieval
reduction, with traffic k·shards candidates per query instead of the full
score row.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import mscm as mscm_lib
from repro.core.beam import NEG_INF, beam_step, topk_canonical
from repro.core.tree import TreeLayerArrays, XMRTree


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Version-portable ``jax.shard_map``.

    Public API from jax 0.6; older versions expose it as
    ``jax.experimental.shard_map.shard_map`` with the replication check named
    ``check_rep`` instead of ``check_vma``.
    """
    sm = getattr(jax, "shard_map", None)
    kw = {}
    if sm is not None:
        if check_vma is not None:
            kw["check_vma"] = check_vma
    else:
        from jax.experimental.shard_map import shard_map as sm
        if check_vma is not None:
            kw["check_rep"] = check_vma
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def shard_leaf_level(tree: XMRTree, mesh: Mesh):
    """Device-put the leaf level sharded over 'model', upper levels replicated."""
    leaf = tree.layers[-1]
    rep = NamedSharding(mesh, P())
    sharded = TreeLayerArrays(
        chunk_rows=jax.device_put(leaf.chunk_rows, NamedSharding(mesh, P("model", None))),
        chunk_vals=jax.device_put(leaf.chunk_vals, NamedSharding(mesh, P("model", None, None))),
        col_rows=jax.device_put(leaf.col_rows, rep),
        col_vals=jax.device_put(leaf.col_vals, rep),
    )
    upper = [
        jax.tree.map(lambda a: jax.device_put(a, rep), l) for l in tree.layers[:-1]
    ]
    return upper, sharded


def sharded_infer(
    tree: XMRTree,
    upper_layers,
    leaf_sharded: TreeLayerArrays,
    x_idx: jax.Array,
    x_val: jax.Array,
    mesh: Mesh,
    *,
    beam: int = 10,
    topk: int = 10,
) -> Tuple[jax.Array, jax.Array]:
    """Distributed Algorithm 1. Queries sharded over 'data', leaf chunks over
    'model'. Returns (scores [n, k], leaf ids [n, k]) fully replicated."""
    d = tree.d
    n_cols = tree.n_cols
    branching = tree.branching
    n_total = x_idx.shape[0]

    upper_flat, upper_tree = jax.tree_util.tree_flatten(
        [(l.chunk_rows, l.chunk_vals) for l in upper_layers]
    )

    @functools.partial(
        shard_map_compat,
        mesh=mesh,
        in_specs=(
            P("data", None), P("data", None),
            P("model", None), P("model", None, None),
            tuple(P() for _ in upper_flat),
        ),
        out_specs=(P("data", None), P("data", None)),
        check_vma=False,
    )
    def run(xi, xv, leaf_rows, leaf_vals, upper_arrays):
        upper_local = jax.tree_util.tree_unflatten(upper_tree, list(upper_arrays))
        n = xi.shape[0]
        xd = mscm_lib.scatter_dense(xi, xv, d)
        parent = jnp.zeros((n, 1), jnp.int32)
        scores = jnp.ones((n, 1), jnp.float32)
        # upper levels: replicated weights, local queries
        for li, (rows_l, vals_l) in enumerate(upper_local):
            bc = parent.shape[1]
            bq = jnp.repeat(jnp.arange(n, dtype=jnp.int32), bc)
            logits = mscm_lib.mscm_dense_lookup(
                xd, rows_l, vals_l, bq, parent.reshape(-1)
            ).reshape(n, bc, branching[li])
            nb = min(beam, n_cols[li])
            parent, scores = beam_step(parent, scores, logits, n_cols[li], nb)

        # leaf level: chunk-range ownership on the model axis
        li = len(upper_local)
        my = jax.lax.axis_index("model")
        c_local = leaf_vals.shape[0]  # per-shard chunk count
        bc = parent.shape[1]
        bq = jnp.repeat(jnp.arange(n, dtype=jnp.int32), bc)
        flat_parent = parent.reshape(-1)
        owner = flat_parent // c_local
        local_c = jnp.clip(flat_parent - my * c_local, 0, c_local - 1)
        logits = mscm_lib.mscm_dense_lookup(
            xd, leaf_rows, leaf_vals, bq, local_c
        ).reshape(n, bc, branching[li])
        mine = (owner == my).reshape(n, bc, 1)
        child = flat_parent.reshape(n, bc, 1) * branching[li] + jnp.arange(branching[li])
        comb = jnp.where(
            mine & (child < n_cols[li]),
            jax.nn.sigmoid(logits) * scores[..., None],
            NEG_INF,
        )
        k = min(topk, n_cols[li])
        # canonical (score desc, id asc) local top-k — same tie-break as
        # beam_select, so the shard boundary can never reorder ties
        loc_i, loc_s = topk_canonical(
            comb.reshape(n, -1), child.reshape(n, -1), k
        )
        # candidate all-gather over the label shards + canonical global top-k
        all_s = jax.lax.all_gather(loc_s, "model", axis=1).reshape(n, -1)
        all_i = jax.lax.all_gather(loc_i, "model", axis=1).reshape(n, -1)
        g_i, g_s = topk_canonical(all_s, all_i, k)
        return g_s, g_i

    return run(x_idx, x_val, leaf_sharded.chunk_rows, leaf_sharded.chunk_vals,
               tuple(upper_flat))
