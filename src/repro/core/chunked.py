"""Column-chunked weight matrices — the MSCM data structure (paper §4, eq. 7-8).

A *chunk* is the group of ``B`` sibling columns of the level-``l`` weight
matrix ``W ∈ R^{d×L}`` that share a parent node at level ``l-1``. The paper
stores a chunk as a vertical sparse array of horizontal row vectors; the
TPU-native translation here is a per-chunk **ELL tile**:

    rows : int32 [C, R]      union of the sibling row supports, sorted and
                             padded with the sentinel ``d``
    vals : f32   [C, R, B]   dense (R × B) value tile per chunk; positions
                             where a sibling lacks an entry hold explicit 0

The sibling-support-similarity observation (paper Item 2) is what makes the
``[R, B]`` tile dense enough to be profitable: R ≈ max-union-support per
chunk rather than B × per-column-support.

Shapes are static once a model is loaded, which is what makes the whole beam
search jit-able with no dynamic sparsity in the control path.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.csr import CSC


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass
class ChunkedLayer:
    """One tree level's weight matrix in chunked (MSCM) format."""

    rows: np.ndarray  # int32 [C, R], sentinel-padded (sentinel == d)
    vals: np.ndarray  # f32   [C, R, B]
    d: int            # feature dimension
    B: int            # branching factor == columns per chunk

    @property
    def C(self) -> int:
        return self.rows.shape[0]

    @property
    def R(self) -> int:
        return self.rows.shape[1]

    @property
    def n_cols(self) -> int:
        return self.C * self.B

    @property
    def nnz_dense_tile(self) -> int:
        """Elements actually stored (incl. explicit zeros) — memory model."""
        return int(self.vals.size)

    # ------------------------------------------------------------------
    @classmethod
    def from_csc(
        cls,
        w: CSC,
        branching: int,
        *,
        row_align: int = 8,
        min_width: int = 8,
    ) -> "ChunkedLayer":
        """Convert a CSC weight matrix to chunked format.

        Columns [i*B, (i+1)*B) form chunk i (labels are laid out in tree
        order, so siblings are contiguous — paper eq. 7). The last chunk is
        zero-padded if L % B != 0. R is the max over chunks of the union
        support size, rounded up to ``row_align`` (f32 sublane alignment).
        """
        d, L = w.shape
        B = int(branching)
        C = (L + B - 1) // B
        # vectorized per chunk: union of sibling supports via np.unique, then
        # scatter values at searchsorted positions (no per-entry Python loop)
        unions = []
        width = min_width
        col_start = w.indptr
        for c in range(C):
            lo = col_start[c * B]
            hi = col_start[min((c + 1) * B, L)]
            idx = np.unique(w.indices[lo:hi])
            unions.append(idx.astype(np.int32))
            width = max(width, len(idx))
        R = _round_up(width, row_align)
        rows = np.full((C, R), d, dtype=np.int32)
        vals = np.zeros((C, R, B), dtype=np.float32)
        for c, idx in enumerate(unions):
            rows[c, : len(idx)] = idx
            lo = col_start[c * B]
            hi = col_start[min((c + 1) * B, L)]
            ent_rows = w.indices[lo:hi]
            ent_vals = w.data[lo:hi]
            # column offset of every entry within the chunk
            n_cols = min((c + 1) * B, L) - c * B
            reps = np.diff(col_start[c * B : c * B + n_cols + 1]).astype(np.int64)
            ent_cols = np.repeat(np.arange(n_cols), reps)
            pos = np.searchsorted(idx, ent_rows)
            vals[c, pos, ent_cols] = ent_vals
        return cls(rows=rows, vals=vals, d=d, B=B)

    def to_dense(self) -> np.ndarray:
        """Reconstruct the dense [d, C*B] weight matrix (tests only)."""
        out = np.zeros((self.d + 1, self.n_cols), dtype=np.float32)
        for c in range(self.C):
            cols = slice(c * self.B, (c + 1) * self.B)
            np.add.at(out, (self.rows[c], cols), self.vals[c])
        return out[: self.d]

    def memory_bytes(self) -> int:
        return self.rows.nbytes + self.vals.nbytes

    def occupancy(self) -> float:
        """Fraction of the [C,R,B] tile holding true nonzeros (Item 2 metric)."""
        return float((self.vals != 0).mean())


@dataclasses.dataclass
class ColumnELLLayer:
    """Vanilla per-column layout (the paper's non-MSCM baseline, Alg. 4).

    Each column keeps its own sorted row list — the baseline traverses the
    query/column intersection once *per column* instead of once per chunk.
    """

    rows: np.ndarray  # int32 [L, Rc], sentinel-padded
    vals: np.ndarray  # f32   [L, Rc]
    d: int
    B: int            # branching factor (for block -> column expansion)

    @property
    def L(self) -> int:
        return self.rows.shape[0]

    @property
    def Rc(self) -> int:
        return self.rows.shape[1]

    @classmethod
    def from_csc(cls, w: CSC, branching: int, *, row_align: int = 8) -> "ColumnELLLayer":
        d, L = w.shape
        width = _round_up(max(1, int(w.col_nnz().max(initial=1))), row_align)
        rows, vals = w.to_col_ell(width)
        B = int(branching)
        Lp = _round_up(L, B)
        if Lp != L:  # pad phantom columns so chunk c covers cols [cB, cB+B)
            rows = np.concatenate([rows, np.full((Lp - L, width), d, np.int32)])
            vals = np.concatenate([vals, np.zeros((Lp - L, width), np.float32)])
        return cls(rows=rows, vals=vals, d=d, B=B)

    def memory_bytes(self) -> int:
        return self.rows.nbytes + self.vals.nbytes
