"""Beam search over the label tree (paper Alg. 1, lines 5-9).

Static shapes throughout: beam width, branching factor, and layer sizes are
compile-time constants, so the whole search jits cleanly and the active-block
lists handed to MSCM are fixed-size `[n·b]` vectors.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-1e30)


def combine_scores(
    parent_scores: jax.Array,  # [n, b]  (prob or log-prob, see mode)
    logits: jax.Array,         # [n, b, B] ranker activations (pre-sigmoid)
    mode: str = "prod",
) -> jax.Array:
    """Conditional combine (paper eq. 5): child = σ(logit) ⊗ parent.

    ``prod``  — probability space, exactly the paper's formulation.
    ``logsum`` — log space (numerically safer for deep trees); rankings are
    identical because log is monotone.
    """
    if mode == "prod":
        return jax.nn.sigmoid(logits) * parent_scores[..., None]
    if mode == "logsum":
        return jax.nn.log_sigmoid(logits) + parent_scores[..., None]
    raise ValueError(f"unknown score mode {mode}")


def beam_select(
    parent_ids: jax.Array,  # int32 [n, b]
    scores: jax.Array,      # f32 [n, b, B] pre-combined child scores
    n_cols: int,            # valid columns at this level (masks padding)
    next_b: int,
) -> Tuple[jax.Array, jax.Array]:
    """SelectTop_b over pre-combined child scores (paper Alg. 1 line 9).

    Children ids are parent*B + within-chunk offset; phantom columns from
    chunk padding (id >= n_cols) are masked to -inf so they never survive.

    Selection is *canonical*: candidates are ordered by (score desc, child
    id asc) via a two-key sort, so the surviving set — and the order it is
    returned in — is a pure function of the candidate (id, score) multiset,
    independent of the beam's layout. That is what lets the grouped MSCM
    path keep its beam chunk-sorted between levels and still produce
    bitwise-identical results to every other method, ties included.
    """
    n, b, B = scores.shape
    child_ids = parent_ids[:, :, None] * B + jnp.arange(B)[None, None, :]
    valid = child_ids < n_cols
    scores = jnp.where(valid, scores, NEG_INF)
    neg_sorted, id_sorted = jax.lax.sort(
        (-scores.reshape(n, b * B), child_ids.reshape(n, b * B)),
        dimension=1,
        num_keys=2,
    )
    top_scores = -neg_sorted[:, :next_b]
    top_ids = id_sorted[:, :next_b]
    return top_ids.astype(jnp.int32), top_scores


def topk_canonical(
    scores: jax.Array,  # f32 [n, m] candidate scores (NEG_INF = masked)
    ids: jax.Array,     # int32 [n, m] candidate ids, aligned with scores
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Canonical top-k over flat candidate lists: (score desc, id asc).

    The same two-key sort as :func:`beam_select`, exposed for paths that
    already hold flat ``(ids, scores)`` candidates — sharded local selects,
    cross-partition merges — so every selection in the stack breaks ties
    identically and stays bitwise-reproducible regardless of candidate
    layout. Returns ``(ids[:, :k], scores[:, :k])``.
    """
    neg_sorted, id_sorted = jax.lax.sort(
        (-scores, ids), dimension=1, num_keys=2
    )
    return id_sorted[:, :k].astype(jnp.int32), -neg_sorted[:, :k]


def beam_step(
    parent_ids: jax.Array,     # int32 [n, b]
    parent_scores: jax.Array,  # f32 [n, b]
    logits: jax.Array,         # f32 [n, b, B]
    n_cols: int,               # valid columns at this level (masks padding)
    next_b: int,
    *,
    mode: str = "prod",
) -> Tuple[jax.Array, jax.Array]:
    """Combine (eq. 5) + canonical SelectTop_b (paper Alg. 1 lines 8-9)."""
    scores = combine_scores(parent_scores, logits, mode)              # [n,b,B]
    return beam_select(parent_ids, scores, n_cols, next_b)
