"""Beam search over the label tree (paper Alg. 1, lines 5-9).

Static shapes throughout: beam width, branching factor, and layer sizes are
compile-time constants, so the whole search jits cleanly and the active-block
lists handed to MSCM are fixed-size `[n·b]` vectors.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-1e30)


def combine_scores(
    parent_scores: jax.Array,  # [n, b]  (prob or log-prob, see mode)
    logits: jax.Array,         # [n, b, B] ranker activations (pre-sigmoid)
    mode: str = "prod",
) -> jax.Array:
    """Conditional combine (paper eq. 5): child = σ(logit) ⊗ parent.

    ``prod``  — probability space, exactly the paper's formulation.
    ``logsum`` — log space (numerically safer for deep trees); rankings are
    identical because log is monotone.
    """
    if mode == "prod":
        return jax.nn.sigmoid(logits) * parent_scores[..., None]
    if mode == "logsum":
        return jax.nn.log_sigmoid(logits) + parent_scores[..., None]
    raise ValueError(f"unknown score mode {mode}")


def beam_step(
    parent_ids: jax.Array,     # int32 [n, b]
    parent_scores: jax.Array,  # f32 [n, b]
    logits: jax.Array,         # f32 [n, b, B]
    n_cols: int,               # valid columns at this level (masks padding)
    next_b: int,
    *,
    mode: str = "prod",
) -> Tuple[jax.Array, jax.Array]:
    """SelectTop_b over the expanded beam (paper Alg. 1 line 9).

    Children ids are parent*B + within-chunk offset; phantom columns from
    chunk padding (id >= n_cols) are masked to -inf so they never survive.
    """
    n, b, B = logits.shape
    scores = combine_scores(parent_scores, logits, mode)              # [n,b,B]
    child_ids = parent_ids[:, :, None] * B + jnp.arange(B)[None, None, :]
    valid = child_ids < n_cols
    scores = jnp.where(valid, scores, NEG_INF)
    flat_scores = scores.reshape(n, b * B)
    flat_ids = child_ids.reshape(n, b * B)
    top_scores, top_pos = jax.lax.top_k(flat_scores, next_b)          # [n, nb]
    top_ids = jnp.take_along_axis(flat_ids, top_pos, axis=1)
    return top_ids.astype(jnp.int32), top_scores
