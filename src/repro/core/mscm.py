"""Masked Sparse Chunk Multiplication — JAX implementations (paper §4).

Evaluates the masked product  A = M ⊙ (X · W)  where the mask nonzeros come
in contiguous width-B blocks, one per (query, surviving-parent) beam pair.
The active blocks are given as parallel index vectors

    block_q : int32 [A]   query row of each block
    block_c : int32 [A]   chunk (parent) id of each block

and the result is the dense [A, B] stack of block values — static shapes,
no dynamic sparsity anywhere.

Iterator variants (paper §4 items 1-4, TPU-adapted — see DESIGN.md §2):

* ``mscm_dense_lookup``  — dense-lookup analogue: queries pre-scattered into a
  dense [n, d+1] table; per-block gather at the chunk's ELL rows + one
  [R]×[R,B] contraction. One traversal *per chunk*.
* ``mscm_searchsorted``  — binary-search analogue: vectorized searchsorted of
  the chunk's row list into the query's sorted nnz list (fixed log₂ depth).
  No dense table required.
* ``vanilla_columns``    — the non-MSCM baseline (paper Alg. 4): each of the
  B columns of the block intersects with the query *independently* (per-column
  ELL layout). Same result, B× the traversal.
* hash-map / marching pointers do not transfer to TPU (no pointer-chasing);
  ``repro.kernels.ref`` keeps a marching-pointer oracle for tests.

All functions are jit-friendly and differentiable in ``vals``/``x_val``.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def scatter_dense(x_idx: jax.Array, x_val: jax.Array, d: int) -> jax.Array:
    """Scatter ELL queries into a dense [n, d+1] lookup table.

    The trailing slot (index d) is the sentinel target and always holds 0,
    so gathers at padded chunk rows contribute nothing. This is the TPU
    analogue of the paper's *dense lookup* iterator: the scatter cost is paid
    once per query and amortized over every chunk it meets (paper §4 item 4).
    """
    n = x_idx.shape[0]
    out = jnp.zeros((n, d + 1), dtype=x_val.dtype)
    return out.at[jnp.arange(n)[:, None], x_idx].add(x_val, mode="drop")


def mscm_dense_lookup(
    x_dense: jax.Array,   # f32 [n, d+1]
    rows: jax.Array,      # int32 [C, R]
    vals: jax.Array,      # f32 [C, R, B]
    block_q: jax.Array,   # int32 [A]
    block_c: jax.Array,   # int32 [A]
) -> jax.Array:
    """Dense-lookup MSCM: gather query values at chunk rows, contract."""
    r = rows[block_c]                                   # [A, R]
    xg = x_dense[block_q[:, None], r]                   # [A, R]  (gather)
    return jnp.einsum("ar,arb->ab", xg, vals[block_c])  # [A, B]


def gather_query_rows(
    x_dense: jax.Array, rows: jax.Array, block_q: jax.Array, block_c: jax.Array
) -> jax.Array:
    """The gather half of dense-lookup MSCM, exposed for the pre-gathered
    Pallas kernel (huge-d path where the query row exceeds VMEM)."""
    return x_dense[block_q[:, None], rows[block_c]]     # [A, R]


def _searchsorted_rows(xi: jax.Array, r: jax.Array) -> jax.Array:
    """Row-wise searchsorted: for each a, positions of r[a,:] in xi[a,:]."""
    return jax.vmap(lambda a, v: jnp.searchsorted(a, v, side="left"))(xi, r)


def mscm_searchsorted(
    x_idx: jax.Array,     # int32 [n, Q] sorted, sentinel-padded (== d)
    x_val: jax.Array,     # f32 [n, Q]
    rows: jax.Array,      # int32 [C, R]
    vals: jax.Array,      # f32 [C, R, B]
    block_q: jax.Array,   # int32 [A]
    block_c: jax.Array,   # int32 [A]
    d: int,
) -> jax.Array:
    """Binary-search MSCM: intersect chunk rows with query nnz (paper item 2).

    One log₂(Q)-depth vectorized binary search per chunk row — the traversal
    happens once per *chunk*, not once per column, which is the entire MSCM
    point.
    """
    xi = x_idx[block_q]                    # [A, Q]
    xv = x_val[block_q]                    # [A, Q]
    r = rows[block_c]                      # [A, R]
    q = xi.shape[1]
    pos = _searchsorted_rows(xi, r)        # [A, R] in [0, Q]
    pos_c = jnp.minimum(pos, q - 1)
    hit = (jnp.take_along_axis(xi, pos_c, axis=1) == r) & (r < d)
    xg = jnp.where(hit, jnp.take_along_axis(xv, pos_c, axis=1), 0.0)
    return jnp.einsum("ar,arb->ab", xg, vals[block_c])


def vanilla_columns(
    x_idx: jax.Array,     # int32 [n, Q]
    x_val: jax.Array,     # f32 [n, Q]
    col_rows: jax.Array,  # int32 [L, Rc] per-column ELL
    col_vals: jax.Array,  # f32 [L, Rc]
    block_q: jax.Array,   # int32 [A]
    block_c: jax.Array,   # int32 [A]
    branching: int,
    d: int,
) -> jax.Array:
    """Non-MSCM baseline (paper Alg. 4): per-column sparse dot products.

    Expands each block into its B columns and intersects each column's row
    list with the query separately — B independent traversals per block.
    Bitwise-identical results to the MSCM variants up to summation order.
    """
    a = block_q.shape[0]
    cols = block_c[:, None] * branching + jnp.arange(branching)[None, :]  # [A, B]
    xi = x_idx[block_q]                                  # [A, Q]
    xv = x_val[block_q]
    cr = col_rows[cols]                                  # [A, B, Rc]
    cv = col_vals[cols]                                  # [A, B, Rc]
    q = xi.shape[1]

    def one_col(xi_a, xv_a, cr_ab, cv_ab):
        pos = jnp.searchsorted(xi_a, cr_ab, side="left")
        pos_c = jnp.minimum(pos, q - 1)
        hit = (xi_a[pos_c] == cr_ab) & (cr_ab < d)
        return jnp.sum(jnp.where(hit, xv_a[pos_c] * cv_ab, 0.0))

    per_block = jax.vmap(
        lambda xi_a, xv_a, cr_a, cv_a: jax.vmap(lambda r, v: one_col(xi_a, xv_a, r, v))(cr_a, cv_a)
    )
    return per_block(xi, xv, cr, cv)                     # [A, B]


# ---------------------------------------------------------------------------
# Cost model counters (paper Table 6) — host-side, used by tests/benchmarks.
# ---------------------------------------------------------------------------

def iterator_cost(
    method: str,
    nnz_x: int,
    nnz_k: int,
    *,
    n_queries: int = 1,
    d: int = 0,
    hash_cost: float = 1.5,
) -> float:
    """Per-query traversal cost of one (query, chunk) intersection.

    Mirrors paper Table 6:
      marching    O(nnz_x + nnz_K)
      binsearch   O(min · log max)
      hash        O(h · nnz_x)
      dense       O(nnz_x + nnz_K / n)   (scatter amortized over the batch)
    """
    if method == "marching":
        return nnz_x + nnz_k
    if method in ("binsearch", "searchsorted"):
        lo, hi = sorted((max(nnz_x, 1), max(nnz_k, 1)))
        return lo * float(np.log2(max(hi, 2)))
    if method == "hash":
        return hash_cost * nnz_x
    if method in ("dense", "dense_lookup"):
        return nnz_k + nnz_x / max(n_queries, 1)
    raise ValueError(f"unknown iterator {method}")


def chunk_vs_column_traversals(
    chunk_R: int, col_nnz: np.ndarray, branching: int
) -> Tuple[int, int]:
    """(MSCM traversal length, vanilla traversal length) for one block —
    quantifies paper Item 1/2: once-per-chunk vs once-per-column."""
    return int(chunk_R), int(col_nnz[:branching].sum())
