from repro.core.chunked import ChunkedLayer, ColumnELLLayer
from repro.core.tree import METHODS, TreeLayerArrays, XMRTree

__all__ = [
    "ChunkedLayer",
    "ColumnELLLayer",
    "XMRTree",
    "TreeLayerArrays",
    "METHODS",
]
