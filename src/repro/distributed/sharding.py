"""Rule-based parameter/activation sharding with divisibility fallback.

The mesh is ("data", "model") single-pod or ("pod", "data", "model")
multi-pod. Policy (DESIGN.md §6):

* batch/token dims           -> all data-parallel axes ("pod","data")
* output-feature dims (heads, ffn-out-of-d, vocab, experts) -> "model" (TP)
* the complementary feature dim -> "data" (FSDP within pod)
* stacked-layer leading dim  -> never sharded (lax.scan axis)
* 1-D tensors (norm scales)  -> replicated
* every assignment checks divisibility and falls back down the preference
  list; undivisible dims end up replicated rather than erroring.
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def replica_mesh(n: int, *, devices: Sequence[Any] | None = None) -> Mesh:
    """1-D ``("data",)`` mesh over the first ``n`` local devices.

    The serving tier's data-parallel dispatch mesh: model tensors replicate
    (``P()``), each micro-batch's batch dim splits over ``"data"`` so one
    formed bucket occupies all ``n`` replicas. On CPU, multiple host devices
    come from ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) < n:
        raise ValueError(
            f"replica_mesh(n={n}): only {len(devices)} local devices "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count on CPU)"
        )
    return Mesh(np.asarray(devices[:n]), ("data",))


def partition_mesh(
    n_data: int, n_model: int, *, devices: Sequence[Any] | None = None
) -> Mesh:
    """2-D ``("data", "model")`` serving mesh over the first
    ``n_data * n_model`` local devices.

    The label-partitioned serving tier's topology (``repro.index``): each
    **model column** hosts one or more label partitions (placed by
    :mod:`repro.index.placement`), replicated down the column's ``n_data``
    rows; batch dims split over ``"data"`` exactly as in
    :func:`replica_mesh`, so model- and data-parallel dispatch compose.
    """
    need = n_data * n_model
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) < need:
        raise ValueError(
            f"partition_mesh({n_data}x{n_model}): needs {need} devices, "
            f"only {len(devices)} local "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count on CPU)"
        )
    return Mesh(
        np.asarray(devices[:need]).reshape(n_data, n_model),
        ("data", "model"),
    )


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _assign(shape: Sequence[int], prefs: List[Tuple[int, Any]], mesh: Mesh) -> P:
    """prefs: [(dim, axis-or-tuple)] in priority order; skip non-divisible."""
    spec: List[Any] = [None] * len(shape)
    used = set()
    for dim, ax in prefs:
        d = dim if dim >= 0 else len(shape) + dim
        if d < 0 or d >= len(shape) or spec[d] is not None:
            continue
        key = tuple(ax) if isinstance(ax, tuple) else (ax,)
        if any(k in used for k in key):
            continue
        if shape[d] % axis_size(mesh, ax) == 0 and shape[d] >= axis_size(mesh, ax):
            spec[d] = ax
            used.update(key)
    return P(*spec)


# name-pattern rules: (regex, fn(shape, mesh, n_leading) -> P)
def _param_rules(mesh: Mesh):
    da = data_axes(mesh)
    fsdp = "data" if "data" in mesh.axis_names else None

    def embed(shape, lead):
        return _assign(shape, [(0 + lead, "model"), (1 + lead, fsdp)], mesh)

    def head_out(shape, lead):  # [d, H*dh] / [d, ff]-style: out dim -> model
        return _assign(shape, [(-1, "model"), (-2, fsdp)], mesh)

    def head_in(shape, lead):   # [H*dh, d] / [ff, d]-style: in dim -> model
        return _assign(shape, [(-2, "model"), (-1, fsdp)], mesh)

    def experts(shape, lead):   # [E, d, ff] or [E, ff, d]
        return _assign(shape, [(0 + lead, "model"), (1 + lead, fsdp), (2 + lead, None)], mesh)

    return [
        (re.compile(r"embed$"), embed),
        (re.compile(r"lm_head$"), head_out),
        (re.compile(r"(wq|wk|wv|w1|w3|wuq|wukv|wdq|wdkv|wx|wB|wC|wdt|router|ddw1|ww1|wkr)$"), head_out),
        (re.compile(r"(wo|w2|wr|wg|ww2|ddw2)$"), head_in),
        (re.compile(r"ffn/(w1|w3)$"), head_out),
    ]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_spec(path: str, shape: Sequence[int], mesh: Mesh, *,
               stacked: bool = True) -> P:
    """PartitionSpec for one parameter tensor."""
    if len(shape) <= 1:
        return P()
    lead = 1 if (stacked and "layers" in path and len(shape) >= 2) else 0
    name = path.split("/")[-1]
    # MoE expert tensors: [L, E, d, ff]. Prefer experts over 'model'
    # (qwen3: 128/16); when E does not divide the TP axis (grok: 8 < 16)
    # shard BOTH feature dims instead so the 1.2TB weight still spreads
    # over all 256 chips (d -> data, ff -> model for w1/w3; mirrored for w2).
    if re.search(r"ffn/(w1|w2|w3)$", path) and len(shape) - lead == 3:
        fsdp = "data" if "data" in mesh.axis_names else None
        e_dim = shape[lead]
        if e_dim % axis_size(mesh, "model") == 0 and e_dim >= axis_size(mesh, "model"):
            return _assign(
                shape,
                [(0 + lead, "model"), (1 + lead, fsdp), (2 + lead, None)],
                mesh,
            )
        return _assign(
            shape,
            [(2 + lead, "model"), (1 + lead, fsdp)],
            mesh,
        )
    for rx, fn in _param_rules(mesh):
        if rx.search(path):
            return fn(shape, lead)
    # generic fallback: shard the largest trailing dim on model, next on data
    fsdp = "data" if "data" in mesh.axis_names else None
    dims = sorted(range(lead, len(shape)), key=lambda i: -shape[i])
    prefs = []
    if dims:
        prefs.append((dims[0], "model"))
    if len(dims) > 1:
        prefs.append((dims[1], fsdp))
    return _assign(shape, prefs, mesh)


def shard_params(params_shapes: Params, mesh: Mesh) -> Params:
    """NamedSharding pytree matching a params (or ShapeDtypeStruct) pytree."""
    def per_leaf(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(per_leaf, params_shapes)


def shard_opt_state(opt_shapes: Params, params_shapes: Params, mesh: Mesh) -> Params:
    """Optimizer state mirrors its parameter's sharding (m/v same shape);
    factored adafactor rows/cols and scalars replicate on the missing dim."""
    param_leaves = {tuple(p.shape): param_spec("", p.shape, mesh)
                    for p in jax.tree.leaves(params_shapes)}

    def per_leaf(path, leaf):
        ps = _path_str(path)
        # match m/v by shape against some param; else generic rule
        spec = param_spec(ps, leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(per_leaf, opt_shapes)


def batch_specs(cfg, batch_shapes: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    """Input shardings: batch dim over all DP axes; embeds also over model=none."""
    da = data_axes(mesh)
    dp = da if len(da) > 1 else (da[0] if da else None)

    def per_leaf(leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        if shape[0] % axis_size(mesh, dp) == 0:
            return NamedSharding(mesh, P(dp, *([None] * (len(shape) - 1))))
        return NamedSharding(mesh, P())
    return jax.tree.map(per_leaf, batch_shapes)


def cache_specs(cfg, cache_shapes: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    """KV-cache shardings for decode: [L, B, S, ...] -> B over DP axes,
    S over model (flash-decode style); SSM states shard heads over model."""
    da = data_axes(mesh)
    dp = da if len(da) > 1 else (da[0] if da else None)
    dp_size = axis_size(mesh, dp)
    model_size = axis_size(mesh, "model") if "model" in mesh.axis_names else 1

    def per_leaf(path, leaf):
        shape = leaf.shape
        name = _path_str(path)
        spec: List[Any] = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % dp_size == 0 and shape[1] >= dp_size:
            spec[1] = dp  # batch
        if len(shape) >= 3 and "model" in (mesh.axis_names or ()):
            # seq dim for kv caches; head dim for ssm states
            if name in ("k", "v", "ckv", "kr", "cross_k", "cross_v"):
                if shape[2] % model_size == 0 and shape[2] >= model_size:
                    spec[2] = "model"
            elif name in ("tm_s", "ssd_s") and shape[2] % model_size == 0:
                spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(per_leaf, cache_shapes)
