from repro.distributed.sharding import (
    batch_specs,
    cache_specs,
    param_spec,
    shard_params,
)
from repro.distributed.fault import StepWatchdog, TransientError, run_with_retries
