"""Fault tolerance: step watchdog, straggler stats, retrying runner, elastic.

At 1000+ nodes the relevant failure modes are (a) hard node loss — handled by
checkpoint/auto-resume, possibly on a different device count (the checkpoint
format is mesh-independent), (b) transient step failures — handled by the
retrying runner, and (c) stragglers — detected by the watchdog from the step
time distribution; persistent stragglers trigger a logged re-mesh
recommendation (on real fleets: drain + elastic restart).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, List, Optional

import numpy as np

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class StepWatchdog:
    """Tracks step durations; flags stragglers above k× the running median."""

    straggler_factor: float = 2.0
    window: int = 64
    durations: List[float] = dataclasses.field(default_factory=list)
    stragglers: List[int] = dataclasses.field(default_factory=list)
    _t0: Optional[float] = None
    _step: int = 0

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        self.durations.append(dt)
        hist = self.durations[-self.window :]
        med = float(np.median(hist))
        if len(hist) >= 8 and dt > self.straggler_factor * med:
            self.stragglers.append(self._step)
            log.warning(
                "straggler step %d: %.3fs vs median %.3fs (x%.1f)",
                self._step, dt, med, dt / med,
            )
        self._step += 1
        return dt

    def should_remesh(self, patience: int = 5) -> bool:
        """Persistent straggling in the recent window => recommend re-mesh."""
        recent = [s for s in self.stragglers if s >= self._step - self.window]
        return len(recent) >= patience

    def summary(self) -> dict:
        if not self.durations:
            return {}
        arr = np.asarray(self.durations)
        return {
            "steps": len(arr),
            "mean_s": float(arr.mean()),
            "p50_s": float(np.percentile(arr, 50)),
            "p99_s": float(np.percentile(arr, 99)),
            "stragglers": len(self.stragglers),
        }


class TransientError(RuntimeError):
    """Raised by tests / injected failures to exercise the retry path."""


def run_with_retries(
    step_fn: Callable[[], None],
    *,
    max_retries: int = 3,
    on_retry: Optional[Callable[[int, Exception], None]] = None,
) -> None:
    """Run one training step with bounded retries (transient-failure path).

    ``on_retry(attempt, err)`` is the hook where the caller restores from the
    last checkpoint / rebuilds device state before retrying.
    """
    for attempt in range(max_retries + 1):
        try:
            step_fn()
            return
        except TransientError as e:  # pragma: no cover - exercised in tests
            if attempt == max_retries:
                raise
            log.warning("transient failure (attempt %d): %s — retrying", attempt, e)
            if on_retry is not None:
                on_retry(attempt, e)


def elastic_device_counts(n_total: int, model_parallel: int) -> List[int]:
    """Valid shrunk device counts when nodes are lost: multiples of the TP
    group size, largest first. The mesh-independent checkpoint restores onto
    any of these (data-parallel dimension shrinks)."""
    out = []
    n = (n_total // model_parallel) * model_parallel
    while n >= model_parallel:
        out.append(n)
        n -= model_parallel
    return out
