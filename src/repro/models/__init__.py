from repro.models.common import ArchConfig
from repro.models import lm
