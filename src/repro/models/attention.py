"""Attention variants: GQA (llama-family), MLA (MiniCPM3), sliding window.

Two execution modes per variant:
* full  — training / prefill over [B, S] with causal (+ optional window) mask
* decode — one query token against a KV cache of length S_max

MLA keeps the *compressed* cache (c_kv + rotary key), as the architecture
intends; decode supports both the naive expand-per-step form and the
"absorbed" form (projection matrices folded into the query / output) — the
absorbed form is the §Perf optimization for decode_32k.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (ArchConfig, apply_rope, dense_init,
                                 get_abstract_mesh, rms_norm, rope_angles)


# ---------------------------------------------------------------------------
# masking
# ---------------------------------------------------------------------------

def causal_window_mask(s_q: int, s_k: int, q_offset, window) -> jax.Array:
    """[s_q, s_k] bool; window (traced int32) 0 => plain causal."""
    qpos = jnp.arange(s_q)[:, None] + q_offset
    kpos = jnp.arange(s_k)[None, :]
    mask = kpos <= qpos
    win = jnp.asarray(window, jnp.int32)
    windowed = mask & (qpos - kpos < jnp.maximum(win, 1))
    return jnp.where(win > 0, windowed, mask)


def _sdpa(q, k, v, mask, *, scores_bf16: bool = False) -> jax.Array:
    """q [B,Sq,H,dh], k [B,Sk,Hkv,dh], v [B,Sk,Hkv,dv]; GQA head grouping."""
    b, sq, h, dh = q.shape
    hkv, dv = k.shape[2], v.shape[3]
    g = h // hkv
    q = q.reshape(b, sq, hkv, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k)
    if not scores_bf16:
        scores = scores.astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(dh, scores.dtype))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, dv)


def _attn_act_specs(cfg: ArchConfig, b, s, h, hkv):
    """(q_spec, kv_spec, out_spec) under attn_act_shard="auto", else Nones.

    Heads shard over 'model' when they divide it; otherwise the query SEQ
    dim shards over 'model' (sequence-parallel attention: k/v replicate —
    they are Hkv·dh wide, tiny — and each shard computes its q-rows against
    all keys). Fixes full-head replication for 25-head/5-kv archs on TP=16.
    """
    if cfg.attn_act_shard != "auto":
        return None, None, None
    am = get_abstract_mesh()
    if am is None or am.empty or "model" not in am.axis_names:
        return None, None, None
    from jax.sharding import PartitionSpec as _P

    msz = am.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in am.axis_names)
    dsz = 1
    for a in dp:
        dsz *= am.shape[a]
    b_ax = (dp if len(dp) > 1 else dp[0]) if (dp and b % dsz == 0 and b >= dsz) else None
    if h % msz == 0 and h >= msz:
        q_spec = _P(b_ax, None, "model", None)
        kv_spec = _P(b_ax, None, "model", None) if (hkv % msz == 0 and hkv >= msz) else _P(b_ax, None, None, None)
        return q_spec, kv_spec, q_spec
    if s % msz == 0 and s >= msz and s > 1:
        return (_P(b_ax, "model", None, None), _P(b_ax, None, None, None),
                _P(b_ax, "model", None, None))
    return None, None, None


def _maybe_constrain(x, spec):
    return x if spec is None else jax.lax.with_sharding_constraint(x, spec)


def _chunked_sdpa(q, k, v, *, q_offset, window, kblock: int, qblock: int,
                  causal: bool = True, full_unroll: bool = False) -> jax.Array:
    """Flash-style attention: online softmax over key blocks.

    Never materializes the [Sq, Sk] score matrix — peak intermediate is one
    [qblock, kblock] tile per head group. Key blocks are taken with
    ``dynamic_slice`` from the ORIGINAL k/v layout (an earlier scan-xs
    formulation copy-transposed the whole cache per call — refuted §Perf
    iteration C-it1, kept as a lesson in EXPERIMENTS.md). Same FLOPs as
    naive; bit-compatible up to fp reassociation. q [B,Sq,H,dh].

    ``full_unroll`` unrolls the key-block scan (dry-run cost probes only —
    HloCostAnalysis counts rolled loop bodies once).
    """
    b, sq, h, dh = q.shape
    sk, hkv, dv = k.shape[1], k.shape[2], v.shape[3]
    g = h // hkv
    kblock = min(kblock, sk)
    qblock = min(qblock, sq)
    n_k = (sk + kblock - 1) // kblock
    pad_k = n_k * kblock - sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    win = jnp.asarray(window, jnp.int32)
    scale = jax.lax.rsqrt(jnp.float32(dh))

    outs = []
    for q0 in range(0, sq, qblock):
        qb = q.reshape(b, sq, hkv, g, dh)[:, q0 : q0 + qblock]
        qbs = qb.shape[1]
        qpos = (jnp.arange(qbs) + q0 + q_offset)[:, None]

        def kstep(carry, k0):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(k, k0, kblock, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, k0, kblock, axis=1)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb).astype(jnp.float32) * scale
            kpos = (k0 + jnp.arange(kblock))[None, :]
            if causal:
                mask = (kpos <= qpos) & (kpos < sk)
                mask = jnp.where(
                    win > 0, mask & (qpos - kpos < jnp.maximum(win, 1)), mask
                )
            else:
                mask = jnp.broadcast_to(kpos < sk, (qpos.shape[0], kblock))
            s = jnp.where(mask[None, None, None], s, -1e30)
            m2 = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + p.sum(-1)
            acc2 = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb
            ).astype(jnp.float32)
            return (m2, l2, acc2), None

        m0 = jnp.full((b, hkv, g, qbs), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qbs), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qbs, dv), jnp.float32)
        k0s = jnp.arange(n_k) * kblock
        (m, l, acc), _ = jax.lax.scan(
            kstep, (m0, l0, a0), k0s, unroll=n_k if full_unroll else 1
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        outs.append(out.astype(v.dtype))
    out = jnp.concatenate(outs, axis=3) if len(outs) > 1 else outs[0]
    # [B, hkv, g, Sq, dv] -> [B, Sq, H, dv]
    return jnp.moveaxis(out, 3, 1).reshape(b, sq, h, dv)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ArchConfig) -> Dict[str, jax.Array]:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    return {
        "wq": dense_init(ks[0], (d, h * dh), d, dt),
        "wk": dense_init(ks[1], (d, hkv * dh), d, dt),
        "wv": dense_init(ks[2], (d, hkv * dh), d, dt),
        "wo": dense_init(ks[3], (h * dh, d), h * dh, dt),
    }


def gqa_full(p, x: jax.Array, cfg: ArchConfig, *, window=0, q_offset=0) -> jax.Array:
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, hkv, dh)
    v = (x @ p["wv"]).reshape(b, s, hkv, dh)
    cos, sin = rope_angles(jnp.arange(s) + q_offset, dh, cfg.rope_theta)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    q_spec, kv_spec, out_spec = _attn_act_specs(cfg, b, s, h, hkv)
    q = _maybe_constrain(q, q_spec)
    k = _maybe_constrain(k, kv_spec)
    v = _maybe_constrain(v, kv_spec)
    if cfg.attn_impl == "chunked":
        out = _chunked_sdpa(q, k, v, q_offset=q_offset, window=window,
                            kblock=cfg.attn_kblock, qblock=cfg.attn_qblock,
                            full_unroll=cfg.unroll_layers)
    else:
        mask = causal_window_mask(s, s, q_offset, window)
        out = _sdpa(q, k, v, mask, scores_bf16=cfg.attn_scores_bf16)
    out = _maybe_constrain(out, out_spec)
    return out.reshape(b, s, h * dh) @ p["wo"], (k, v)


def gqa_decode(p, x: jax.Array, cache_k, cache_v, pos, cfg: ArchConfig,
               *, window=0) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [B,1,d]; cache_k/v [B,S,Hkv,dh]; pos int32 [] write position."""
    b, _, d = x.shape
    s_max = cache_k.shape[1]
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, 1, h, dh)
    k = (x @ p["wk"]).reshape(b, 1, hkv, dh)
    v = (x @ p["wv"]).reshape(b, 1, hkv, dh)
    cos, sin = rope_angles(pos[None], dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    if cfg.attn_impl == "chunked":
        # flash-decode: online softmax over cache blocks — one pass over the
        # cache, no [B,H,1,S] f32 score buffer round-trips (§Perf cell C)
        out = _chunked_sdpa(q, cache_k, cache_v, q_offset=pos, window=window,
                            kblock=cfg.attn_kblock, qblock=1,
                            full_unroll=cfg.unroll_layers)
    else:
        kpos = jnp.arange(s_max)
        win = jnp.asarray(window, jnp.int32)
        mask = kpos <= pos
        mask = jnp.where(win > 0, mask & (pos - kpos < jnp.maximum(win, 1)), mask)
        out = _sdpa(q, cache_k, cache_v, mask[None, :],
                    scores_bf16=cfg.attn_scores_bf16)
    return out.reshape(b, 1, h * dh) @ p["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (MiniCPM3 / DeepSeek-style multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ArchConfig) -> Dict[str, jax.Array]:
    d = cfg.d_model
    h = cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    dt = cfg.param_dtype
    return {
        "wdq": dense_init(ks[0], (d, qr), d, dt),
        "q_norm": jnp.ones((qr,), dt),
        "wuq": dense_init(ks[1], (qr, h * (nope + rope_d)), qr, dt),
        "wdkv": dense_init(ks[2], (d, kvr), d, dt),
        "kv_norm": jnp.ones((kvr,), dt),
        "wkr": dense_init(ks[3], (d, rope_d), d, dt),
        "wukv": dense_init(ks[4], (kvr, h * (nope + vd)), kvr, dt),
        "wo": dense_init(ks[5], (h * vd, d), h * vd, dt),
    }


def _mla_q(p, x, cfg):
    b, s, _ = x.shape
    h, nope, rope_d = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rms_norm(x @ p["wdq"], p["q_norm"])
    q = (cq @ p["wuq"]).reshape(b, s, h, nope + rope_d)
    return q[..., :nope], q[..., nope:]


def mla_full(p, x: jax.Array, cfg: ArchConfig, *, q_offset=0):
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, x, cfg)
    ckv = rms_norm(x @ p["wdkv"], p["kv_norm"])                  # [B,S,kvr]
    kr = (x @ p["wkr"])[:, :, None, :]                           # [B,S,1,rope]
    cos, sin = rope_angles(jnp.arange(s) + q_offset, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    kr = apply_rope(kr, cos, sin)
    kv = (ckv @ p["wukv"]).reshape(b, s, h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr, (b, s, h, rope_d))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q_spec, kv_spec, out_spec = _attn_act_specs(cfg, b, s, h, h)
    q = _maybe_constrain(q, q_spec)
    k = _maybe_constrain(k, kv_spec)
    v = _maybe_constrain(v, kv_spec)
    if cfg.attn_impl == "chunked":
        out = _chunked_sdpa(q, k, v, q_offset=q_offset, window=0,
                            kblock=cfg.attn_kblock, qblock=cfg.attn_qblock,
                            full_unroll=cfg.unroll_layers)
    else:
        mask = causal_window_mask(s, s, q_offset, 0)
        out = _sdpa(q, k, v, mask, scores_bf16=cfg.attn_scores_bf16)
    out = _maybe_constrain(out, out_spec)
    return out.reshape(b, s, h * vd) @ p["wo"], (ckv, kr[:, :, 0, :])


def mla_decode(p, x, cache_ckv, cache_kr, pos, cfg: ArchConfig, *, absorb: bool = True):
    """Compressed-cache decode. absorb=True folds W_ukv into q/out (the
    inference-optimal form); absorb=False expands keys/values per step
    (naive baseline kept for §Perf before/after)."""
    b, _, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    s_max = cache_ckv.shape[1]
    q_nope, q_rope = _mla_q(p, x, cfg)                    # [B,1,H,*]
    cos, sin = rope_angles(pos[None], rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    ckv_t = rms_norm(x @ p["wdkv"], p["kv_norm"])         # [B,1,kvr]
    kr_t = apply_rope((x @ p["wkr"])[:, :, None, :], cos, sin)[:, :, 0, :]
    cache_ckv = jax.lax.dynamic_update_slice(cache_ckv, ckv_t.astype(cache_ckv.dtype), (0, pos, 0))
    cache_kr = jax.lax.dynamic_update_slice(cache_kr, kr_t.astype(cache_kr.dtype), (0, pos, 0))
    kpos = jnp.arange(s_max)
    mask = kpos <= pos                                    # [S]
    wukv = p["wukv"].reshape(kvr, h, nope + vd)
    wk = wukv[..., :nope]                                 # [kvr,H,nope]
    wv = wukv[..., nope:]                                 # [kvr,H,vd]
    scale = jnp.sqrt(jnp.float32(nope + rope_d))
    if absorb:
        # score_h(s) = <q_nope_h W_k_h, ckv_s> + <q_rope_h, kr_s>
        q_eff = jnp.einsum("bqhn,chn->bqhc", q_nope, wk)  # [B,1,H,kvr]
        s_c = jnp.einsum("bqhc,bsc->bhqs", q_eff, cache_ckv)
        s_r = jnp.einsum("bqhr,bsr->bhqs", q_rope, cache_kr)
        scores = (s_c + s_r).astype(jnp.float32) / scale
        scores = jnp.where(mask[None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(cache_ckv.dtype)
        ctx = jnp.einsum("bhqs,bsc->bqhc", probs, cache_ckv)     # [B,1,H,kvr]
        out = jnp.einsum("bqhc,chv->bqhv", ctx, wv)              # [B,1,H,vd]
    else:
        kv = jnp.einsum("bsc,chn->bshn", cache_ckv, wukv.reshape(kvr, h, nope + vd))
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(cache_kr[:, :, None, :], k_nope.shape[:3] + (rope_d,))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) / scale
        scores = jnp.where(mask[None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqs,bshv->bqhv", probs, v)
    return out.reshape(b, 1, h * vd) @ p["wo"], cache_ckv, cache_kr
