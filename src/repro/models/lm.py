"""Unified LM: parameter init, train forward, prefill, decode — all families.

Layers are stacked on a leading L axis and driven by ``lax.scan`` (small HLO,
per-layer FSDP all-gathers under GSPMD). Families:

  dense | moe | vlm   decoder-only attention (GQA or MLA) + SwiGLU/MoE FFN
  ssm                 RWKV6 blocks (time-mix + channel-mix)
  hybrid              Hymba: parallel GQA + SSD heads per layer, SwiGLU FFN,
                      sliding-window attention except a few global layers
  encdec              Seamless: bidirectional encoder over frame embeddings +
                      causal decoder with cross-attention

Modality frontends are STUBS per the assignment: VLM/audio inputs arrive as
precomputed patch/frame embeddings (see ``launch.specs.input_specs``).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.common import (ArchConfig, cross_entropy_loss, dense_init,
                                 get_abstract_mesh, rms_norm)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _ffn_init(key, cfg: ArchConfig) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.param_dtype
    return {
        "w1": dense_init(ks[0], (d, ff), d, dt),
        "w3": dense_init(ks[1], (d, ff), d, dt),
        "w2": dense_init(ks[2], (ff, d), ff, dt),
    }


def _ffn(p, x):
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]


def _layer_init(key, cfg: ArchConfig) -> Params:
    dt = cfg.param_dtype
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    layer: Params = {"ln1": jnp.ones((d,), dt), "ln2": jnp.ones((d,), dt)}
    if cfg.family == "ssm":
        layer["tm"] = ssm_lib.rwkv_time_mix_init(ks[0], cfg)
        layer["cm"] = ssm_lib.rwkv_channel_mix_init(ks[1], cfg)
        return layer
    if cfg.attn_type == "mla":
        layer["attn"] = attn_lib.mla_init(ks[0], cfg)
    else:
        layer["attn"] = attn_lib.gqa_init(ks[0], cfg)
    if cfg.family == "hybrid":
        layer["ssd"] = ssm_lib.ssd_init(ks[1], cfg)
    if cfg.n_experts:
        layer["ffn"] = moe_lib.moe_init(ks[2], cfg)
    else:
        layer["ffn"] = _ffn_init(ks[2], cfg)
    return layer


def _enc_layer_init(key, cfg: ArchConfig) -> Params:
    dt = cfg.param_dtype
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.ones((d,), dt),
        "ln2": jnp.ones((d,), dt),
        "attn": attn_lib.gqa_init(ks[0], cfg),
        "ffn": _ffn_init(ks[1], cfg),
    }


def _stack_layers(key, cfg: ArchConfig, n: int, init_fn) -> Params:
    keys = jax.random.split(key, n)
    layers = [init_fn(k, cfg) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def layer_windows(cfg: ArchConfig) -> jnp.ndarray:
    """Per-layer attention window (0 = full/global). Hymba keeps 3 global."""
    if cfg.sliding_window is None:
        return jnp.zeros((cfg.n_layers,), jnp.int32)
    w = jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
    for g in (0, cfg.n_layers // 2, cfg.n_layers - 1):
        w = w.at[g].set(0)
    return w


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, 6)
    dt = cfg.param_dtype
    params: Params = {
        "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), cfg.d_model, dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "layers": _stack_layers(ks[1], cfg, cfg.n_layers, _layer_init),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab), cfg.d_model, dt)
    if cfg.family == "encdec":
        params["enc_layers"] = _stack_layers(ks[3], cfg, cfg.n_enc_layers, _enc_layer_init)
        params["enc_norm"] = jnp.ones((cfg.d_model,), dt)
        params["cross_layers"] = _stack_layers(ks[4], cfg, cfg.n_layers, _cross_init)
    return params


def _cross_init(key, cfg: ArchConfig) -> Params:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    dt = cfg.param_dtype
    return {
        "ln": jnp.ones((d,), dt),
        "wq": dense_init(ks[0], (d, h * dh), d, dt),
        "wk": dense_init(ks[1], (d, hkv * dh), d, dt),
        "wv": dense_init(ks[2], (d, hkv * dh), d, dt),
        "wo": dense_init(ks[3], (h * dh, d), h * dh, dt),
    }


def param_shapes(cfg: ArchConfig, key=None) -> Params:
    """ShapeDtypeStruct pytree — no allocation (dry-run input)."""
    k = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda kk: init_params(cfg, kk), k)


# ---------------------------------------------------------------------------
# layer bodies (full-sequence mode: train / prefill)
# ---------------------------------------------------------------------------



def _remat(cfg: ArchConfig, body):
    """Layer-scan remat policy: full (save only inputs), dots (save matmul
    outputs — avoids recomputing scatter/dispatch chains in backward, trades
    memory for bytes), none."""
    if not cfg.remat or cfg.remat_policy == "none":
        return body
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    if cfg.remat_policy == "moe":
        # save the named MoE dispatch buffers (forward scatter chain is not
        # recomputed in backward); everything else rematerializes
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.save_only_these_names(
                "moe_xin", "moe_out"
            ),
        )
    return jax.checkpoint(body)


def _shard_act(x: jax.Array) -> jax.Array:
    """Constrain activation batch dim to the data-parallel mesh axes.

    GSPMD propagation can drop to full replication through the SSM chunk
    scans (observed on hymba prefill: every device computed the whole global
    batch). Explicit per-layer constraints pin the batch dim — standard
    production practice (cf. MaxText). No-op outside a mesh context or when
    the batch dim does not divide."""
    am = get_abstract_mesh()
    if am is None or am.empty:
        return x
    dp = tuple(a for a in ("pod", "data") if a in am.axis_names)
    if not dp:
        return x
    size = 1
    for a in dp:
        size *= am.shape[a]
    if x.ndim == 0 or x.shape[0] % size != 0 or x.shape[0] < size:
        return x
    from jax.sharding import PartitionSpec as _P

    return jax.lax.with_sharding_constraint(
        x, _P(dp if len(dp) > 1 else dp[0], *([None] * (x.ndim - 1)))
    )


def _cast_layer(cfg: ArchConfig, lp):
    """Mixed precision: use bf16 copies of the layer weights in compute
    (f32 master params stay in the optimizer) when activations_bf16."""
    if not cfg.activations_bf16:
        return lp
    return jax.tree.map(
        lambda a: a.astype(jnp.bfloat16) if a.dtype == jnp.float32 else a, lp
    )


def _attn_block_full(cfg, lp, x, window, q_offset):
    h = rms_norm(x, lp["ln1"])
    if cfg.attn_type == "mla":
        out, kv = attn_lib.mla_full(lp["attn"], h, cfg, q_offset=q_offset)
    else:
        out, kv = attn_lib.gqa_full(lp["attn"], h, cfg, window=window, q_offset=q_offset)
    if cfg.family == "hybrid":
        sstate = jnp.zeros(
            (x.shape[0], cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32
        )
        ssd_out, sstate = ssm_lib.ssd_mix(lp["ssd"], h, sstate, cfg, mode="chunked")
        out = 0.5 * (out + ssd_out)
        kv = kv + (sstate,)
    return x + out, kv


def _ffn_block(cfg, lp, x):
    h = rms_norm(x, lp["ln2"])
    if cfg.n_experts:
        out, aux = moe_lib.moe_ffn(lp["ffn"], h, cfg)
    else:
        out, aux = _ffn(lp["ffn"], h), jnp.float32(0)
    return x + out, aux


def _rwkv_block_full(cfg, lp, x, mode="chunked"):
    b = x.shape[0]
    h = rms_norm(x, lp["ln1"])
    tm_x0 = jnp.zeros((b, cfg.d_model), x.dtype)
    tm_s0 = jnp.zeros((b, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_head_dim), jnp.float32)
    out, tm_x, tm_s = ssm_lib.rwkv_time_mix(lp["tm"], h, tm_x0, tm_s0, cfg, mode=mode)
    x = x + out
    h2 = rms_norm(x, lp["ln2"])
    cm_x0 = jnp.zeros((b, cfg.d_model), x.dtype)
    out2, cm_x = ssm_lib.rwkv_channel_mix(lp["cm"], h2, cm_x0)
    return x + out2, (tm_x, tm_s, cm_x)


# ---------------------------------------------------------------------------
# full forward (train / prefill)
# ---------------------------------------------------------------------------

def _decoder_stack(cfg: ArchConfig, params: Params, x: jax.Array, *,
                   q_offset: int = 0, collect_cache: bool = False,
                   enc_out: Optional[jax.Array] = None):
    """Scan the decoder layers over a full sequence.

    Returns (hidden [B,S,d], per-layer cache pytree or None, aux loss)."""
    windows = layer_windows(cfg)
    use_cross = cfg.family == "encdec"

    def body(carry, xs):
        x, aux = carry
        x = _shard_act(x)
        if use_cross:
            lp, w, cp = xs
            cp = _cast_layer(cfg, cp)
        else:
            (lp, w), cp = xs, None
        lp = _cast_layer(cfg, lp)
        if cfg.family == "ssm":
            x, cache = _rwkv_block_full(cfg, lp, x)
            a = jnp.float32(0)  # channel-mix IS the ffn for rwkv
        else:
            x, cache = _attn_block_full(cfg, lp, x, w, q_offset)
            if use_cross:
                x, ck, cv = _cross_attn(cfg, cp, x, enc_out)
                cache = cache + (ck, cv)
            x, a = _ffn_block(cfg, lp, x)
        out_cache = cache if collect_cache else None
        return (x, aux + a), out_cache

    body_fn = _remat(cfg, body)
    xs = (params["layers"], windows)
    if use_cross:
        xs = (params["layers"], windows, params["cross_layers"])
    unroll = cfg.n_layers if cfg.unroll_layers else 1
    (x, aux), caches = jax.lax.scan(body_fn, (x, jnp.float32(0)), xs, unroll=unroll)
    return x, caches, aux


def _cross_attn(cfg, cp, x, enc_out, cached_kv=None):
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hq = rms_norm(x, cp["ln"])
    q = (hq @ cp["wq"]).reshape(b, s, h, dh)
    if cached_kv is None:
        se = enc_out.shape[1]
        k = (enc_out @ cp["wk"]).reshape(b, se, hkv, dh)
        v = (enc_out @ cp["wv"]).reshape(b, se, hkv, dh)
    else:
        k, v = cached_kv
    if cfg.attn_impl == "chunked":
        out = attn_lib._chunked_sdpa(q, k, v, q_offset=0, window=0,
                                     kblock=cfg.attn_kblock,
                                     qblock=cfg.attn_qblock, causal=False,
                                     full_unroll=cfg.unroll_layers)
    else:
        mask = jnp.ones((s, k.shape[1]), bool)
        out = attn_lib._sdpa(q, k, v, mask)
    return x + out.reshape(b, s, h * dh) @ cp["wo"], k, v


def _encoder_stack(cfg: ArchConfig, params: Params, src: jax.Array) -> jax.Array:
    """Bidirectional encoder over frame embeddings (stub frontend)."""

    def body(x, lp):
        x = _shard_act(x)
        lp = _cast_layer(cfg, lp)
        h = rms_norm(x, lp["ln1"])
        b, s, d = h.shape
        hh, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = (h @ lp["attn"]["wq"]).reshape(b, s, hh, dh)
        k = (h @ lp["attn"]["wk"]).reshape(b, s, hkv, dh)
        v = (h @ lp["attn"]["wv"]).reshape(b, s, hkv, dh)
        from repro.models.common import apply_rope, rope_angles

        cos, sin = rope_angles(jnp.arange(s), dh, cfg.rope_theta)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        out = attn_lib._sdpa(q, k, v, jnp.ones((s, s), bool))
        x = x + out.reshape(b, s, hh * dh) @ lp["attn"]["wo"]
        h2 = rms_norm(x, lp["ln2"])
        return x + _ffn(lp["ffn"], h2), None

    body_fn = _remat(cfg, body)
    unroll = cfg.n_enc_layers if cfg.unroll_layers else 1
    x, _ = jax.lax.scan(body_fn, src, params["enc_layers"], unroll=unroll)
    return rms_norm(x, params["enc_norm"])


def _logits(cfg: ArchConfig, params: Params, x: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head).astype(jnp.float32)


def _maybe_bf16(cfg: ArchConfig, x: jax.Array) -> jax.Array:
    return x.astype(cfg.activ_dtype) if cfg.activations_bf16 else x


def forward_train(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array]):
    """Full training forward. Returns (logits [B,S,V], aux_loss)."""
    emb = params["embed"]
    if cfg.family == "encdec":
        enc_out = _encoder_stack(cfg, params, _maybe_bf16(cfg, batch["src_embeds"].astype(emb.dtype)))
        x = _maybe_bf16(cfg, emb[batch["tokens"]])
        x, _, aux = _decoder_stack(cfg, params, x, enc_out=enc_out)
    elif cfg.family == "vlm":
        tok = emb[batch["tokens"]]
        x = jnp.concatenate([batch["patch_embeds"].astype(emb.dtype), tok], axis=1)
        x = _maybe_bf16(cfg, x)
        x, _, aux = _decoder_stack(cfg, params, x)
        x = x[:, batch["patch_embeds"].shape[1] :]  # only text positions score
    else:
        x = _maybe_bf16(cfg, emb[batch["tokens"]])
        x, _, aux = _decoder_stack(cfg, params, x)
    x = rms_norm(x, params["final_norm"])
    return _logits(cfg, params, x), aux


def loss_fn(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array]):
    logits, aux = forward_train(cfg, params, batch)
    mask = batch.get("loss_mask")
    ce = cross_entropy_loss(logits, batch["targets"], mask)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# prefill / decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               src_len: int = 0) -> Dict[str, jax.Array]:
    """Allocate an empty cache pytree for ``decode_step``."""
    L, b = cfg.n_layers, batch
    dt = cfg.activ_dtype
    cache: Dict[str, jax.Array] = {}
    if cfg.family == "ssm":
        cache["tm_x"] = jnp.zeros((L, b, cfg.d_model), dt)
        cache["tm_s"] = jnp.zeros(
            (L, b, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_head_dim), jnp.float32
        )
        cache["cm_x"] = jnp.zeros((L, b, cfg.d_model), dt)
        return cache
    if cfg.attn_type == "mla":
        cache["ckv"] = jnp.zeros((L, b, max_len, cfg.kv_lora_rank), dt)
        cache["kr"] = jnp.zeros((L, b, max_len, cfg.qk_rope_dim), dt)
    else:
        cache["k"] = jnp.zeros((L, b, max_len, cfg.n_kv_heads, cfg.head_dim), dt)
        cache["v"] = jnp.zeros((L, b, max_len, cfg.n_kv_heads, cfg.head_dim), dt)
    if cfg.family == "hybrid":
        cache["ssd_s"] = jnp.zeros(
            (L, b, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32
        )
    if cfg.family == "encdec":
        cache["cross_k"] = jnp.zeros((L, b, src_len, cfg.n_kv_heads, cfg.head_dim), dt)
        cache["cross_v"] = jnp.zeros((L, b, src_len, cfg.n_kv_heads, cfg.head_dim), dt)
    return cache


def prefill(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array],
            max_len: int):
    """Process the prompt; returns (last-position logits, filled cache)."""
    emb = params["embed"]
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encoder_stack(cfg, params, batch["src_embeds"].astype(emb.dtype))
        x = emb[batch["tokens"]]
    elif cfg.family == "vlm":
        tok = emb[batch["tokens"]]
        x = jnp.concatenate([batch["patch_embeds"].astype(emb.dtype), tok], axis=1)
    else:
        x = emb[batch["tokens"]]
    x = _maybe_bf16(cfg, x)
    b, s, _ = x.shape
    x, caches, _ = _decoder_stack(cfg, params, x, collect_cache=True, enc_out=enc_out)
    x = rms_norm(x, params["final_norm"])
    logits = _logits(cfg, params, x[:, -1:])
    cache = init_cache(cfg, b, max_len, src_len=0 if enc_out is None else enc_out.shape[1])
    if cfg.family == "ssm":
        tm_x, tm_s, cm_x = caches
        cache.update(tm_x=tm_x.astype(cache["tm_x"].dtype), tm_s=tm_s,
                     cm_x=cm_x.astype(cache["cm_x"].dtype))
    else:
        k, v = caches[0], caches[1]
        if cfg.attn_type == "mla":
            cache["ckv"] = _place(cache["ckv"], k)
            cache["kr"] = _place(cache["kr"], v)
        else:
            cache["k"] = _place(cache["k"], k)
            cache["v"] = _place(cache["v"], v)
        extra = 2
        if cfg.family == "hybrid":
            cache["ssd_s"] = caches[extra]
            extra += 1
        if cfg.family == "encdec":
            cache["cross_k"] = caches[extra].astype(cache["cross_k"].dtype)
            cache["cross_v"] = caches[extra + 1].astype(cache["cross_v"].dtype)
    return logits, cache


def _place(buf, val):
    """Write [L,B,S,...] prefill values into the [L,B,max,...] cache."""
    return jax.lax.dynamic_update_slice(
        buf, val.astype(buf.dtype), (0,) * buf.ndim
    )


def decode_step(cfg: ArchConfig, params: Params, cache: Dict[str, jax.Array],
                tokens: jax.Array, pos: jax.Array):
    """One token for every sequence. tokens [B] int32; pos scalar int32.

    Returns (logits [B, V], updated cache)."""
    emb = params["embed"]
    x = _maybe_bf16(cfg, emb[tokens][:, None, :])          # [B,1,d]
    windows = layer_windows(cfg)
    use_cross = cfg.family == "encdec"

    def body(x, xs):
        x = _shard_act(x)
        if cfg.family == "ssm":
            lp, w, tm_x, tm_s, cm_x = xs
            lp = _cast_layer(cfg, lp)
            h = rms_norm(x, lp["ln1"])
            out, tm_x, tm_s = ssm_lib.rwkv_time_mix(
                lp["tm"], h, tm_x.astype(h.dtype), tm_s, cfg, mode="recurrent"
            )
            x = x + out
            h2 = rms_norm(x, lp["ln2"])
            out2, cm_x = ssm_lib.rwkv_channel_mix(lp["cm"], h2, cm_x.astype(h2.dtype))
            x = x + out2
            x, _ = _ffn_block_noop(cfg, lp, x)
            return x, (tm_x, tm_s, cm_x)
        if use_cross:
            lp, w, cp, ck, cv, xk, xv = xs
            cp = _cast_layer(cfg, cp)
        elif cfg.family == "hybrid":
            lp, w, ck, cv, ss = xs
        else:
            lp, w, ck, cv = xs
        lp = _cast_layer(cfg, lp)
        h = rms_norm(x, lp["ln1"])
        if cfg.attn_type == "mla":
            out, ck, cv = attn_lib.mla_decode(
                lp["attn"], h, ck, cv, pos, cfg, absorb=cfg.mla_absorb
            )
        else:
            out, ck, cv = attn_lib.gqa_decode(lp["attn"], h, ck, cv, pos, cfg, window=w)
        if cfg.family == "hybrid":
            sout, ss = ssm_lib.ssd_mix(lp["ssd"], h, ss, cfg, mode="recurrent")
            out = 0.5 * (out + sout)
        x = x + out
        if use_cross:
            x, _, _ = _cross_attn(cfg, cp, x, None, cached_kv=(xk, xv))
        x, _ = _ffn_block(cfg, lp, x)
        new_cache = (ck, cv)
        if cfg.family == "hybrid":
            new_cache = (ck, cv, ss)
        return x, new_cache

    unroll = cfg.n_layers if cfg.unroll_layers else 1
    if cfg.family == "ssm":
        xs = (params["layers"], windows, cache["tm_x"], cache["tm_s"], cache["cm_x"])
        x, (tm_x, tm_s, cm_x) = jax.lax.scan(body, x, xs, unroll=unroll)
        cache = dict(cache, tm_x=tm_x, tm_s=tm_s, cm_x=cm_x)
    elif cfg.attn_type == "mla":
        xs = (params["layers"], windows, cache["ckv"], cache["kr"])
        x, (ckv, kr) = jax.lax.scan(body, x, xs, unroll=unroll)
        cache = dict(cache, ckv=ckv, kr=kr)
    elif use_cross:
        xs = (params["layers"], windows, params["cross_layers"],
              cache["k"], cache["v"], cache["cross_k"], cache["cross_v"])
        x, (k, v) = jax.lax.scan(body, x, xs, unroll=unroll)
        cache = dict(cache, k=k, v=v)
    elif cfg.family == "hybrid":
        xs = (params["layers"], windows, cache["k"], cache["v"], cache["ssd_s"])
        x, (k, v, ss) = jax.lax.scan(body, x, xs, unroll=unroll)
        cache = dict(cache, k=k, v=v, ssd_s=ss)
    else:
        xs = (params["layers"], windows, cache["k"], cache["v"])
        x, (k, v) = jax.lax.scan(body, x, xs, unroll=unroll)
        cache = dict(cache, k=k, v=v)
    x = rms_norm(x, params["final_norm"])
    return _logits(cfg, params, x)[:, 0], cache


def _ffn_block_noop(cfg, lp, x):
    """RWKV has no separate FFN block (channel-mix plays that role)."""
    return x, jnp.float32(0)
