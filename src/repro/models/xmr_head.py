"""MSCM tree head over the vocabulary — the paper's technique inside an LM.

A 2-level XMR tree over the vocab (C = ceil(V/B) cluster rankers + the token
rankers grouped in chunks of B) replaces the dense lm_head at decode time:

    cluster scores   h · Wc            [B, C]        (small dense matmul)
    beam             top-b clusters
    token scores     MSCM blocks       [B, b, B]     (chunked kernels)

Decode cost drops from O(d·V) to O(d·C + b·d·B) per token — sub-linear in V,
exactly the paper's beam-search economics, with the *dense-query* variant of
the chunk product (LM hidden states are dense; see DESIGN.md §5: chunking
still removes all masked-out compute and keeps sibling locality; the sparse
iterators don't apply).

Construction is weight-exact: ``from_lm_head`` partitions the existing dense
head, so beam=C reproduces the full softmax argmax exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class VocabTreeHead:
    wc: jax.Array       # [d, C] cluster rankers (PIFA-style centroids or learned)
    chunks: jax.Array   # [C, d, B] token rankers, chunked by cluster
    n_vocab: int

    @property
    def branching(self) -> int:
        return self.chunks.shape[2]

    @property
    def n_clusters(self) -> int:
        return self.chunks.shape[0]

    @classmethod
    def from_lm_head(cls, head: jax.Array, branching: int = 128,
                     order: np.ndarray | None = None) -> "VocabTreeHead":
        """Partition a dense [d, V] head into a 2-level chunked tree.

        ``order`` optionally permutes the vocab (e.g. by embedding clustering)
        so chunk-mates are semantically similar; identity keeps exactness
        trivially testable.
        """
        d, v = head.shape
        b = int(branching)
        c = (v + b - 1) // b
        if order is not None:
            head = head[:, order]
        pad = c * b - v
        if pad:
            head = jnp.pad(head, ((0, 0), (0, pad)))
        chunks = head.reshape(d, c, b).transpose(1, 0, 2)       # [C, d, B]
        wc = chunks.mean(axis=2)                                # [C, d] centroid
        return cls(wc=wc.T, chunks=chunks, n_vocab=v)

    def decode_logits(self, h: jax.Array, *, beam: int) -> Tuple[jax.Array, jax.Array]:
        """h [N, d] -> (scores [N, beam*B], token ids [N, beam*B]).

        Only beam·B of the V logits are computed (MSCM masked blocks)."""
        n, d = h.shape
        b = self.branching
        cscore = h @ self.wc                                    # [N, C]
        top_c, top_i = jax.lax.top_k(cscore, beam)              # [N, beam]
        # MSCM block evaluation: gather the beam's chunks, batched matmul.
        sel = self.chunks[top_i]                                # [N, beam, d, B]
        logits = jnp.einsum("nd,nkdb->nkb", h, sel)             # [N, beam, B]
        ids = top_i[:, :, None] * b + jnp.arange(b)[None, None]
        logits = jnp.where(ids < self.n_vocab, logits, -jnp.inf)
        return logits.reshape(n, -1), ids.reshape(n, -1)

    def full_logits(self, h: jax.Array) -> jax.Array:
        """Dense oracle (tests): all V logits."""
        w = self.chunks.transpose(1, 0, 2).reshape(h.shape[1], -1)
        return (h @ w)[:, : self.n_vocab]


def greedy_token(head: VocabTreeHead, h: jax.Array, beam: int = 8) -> jax.Array:
    scores, ids = head.decode_logits(h, beam=beam)
    best = jnp.argmax(scores, axis=1)
    return jnp.take_along_axis(ids, best[:, None], axis=1)[:, 0]
