"""Shared model components: config schema, norms, RoPE, initializers.

One :class:`ArchConfig` covers all ten assigned architecture families; the
family field selects the code path in ``models.lm``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | encdec | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # attention
    attn_type: str = "gqa"          # gqa | mla | none
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None   # hymba SWA width
    global_every: int = 0           # every k-th layer is full attention (0=all)

    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM (rwkv6 / hymba-mamba)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0

    # enc-dec (seamless)
    n_enc_layers: int = 0

    # MLA decode: absorbed (inference-optimal) vs naive expand — §Perf knob
    mla_absorb: bool = False

    # Fully unroll the layer scan (dry-run cost probes only: HloCostAnalysis
    # counts while-loop bodies once, so probes unroll to get true totals)
    unroll_layers: bool = False

    # ---- §Perf hillclimb knobs (baseline = paper-faithful naive values) ----
    # chunked flash-style attention: online softmax over key blocks, never
    # materializes the [S,S] score matrix (memory-term optimization)
    attn_impl: str = "naive"        # naive | chunked
    attn_kblock: int = 1024
    attn_qblock: int = 2048
    # mixed precision: bf16 activations + bf16 weight use (f32 master params)
    activations_bf16: bool = False
    # explicit sharding constraints inside the MoE dispatch (keeps expert
    # weights stationary; tokens move via all-to-all instead of weight
    # all-gathers — collective-term optimization)
    moe_shard_constraints: bool = False
    # attention activation sharding: "none" (GSPMD decides) or "auto"
    # (shard heads over model when divisible, else sequence-parallel q —
    # fixes full-head replication for archs whose head counts don't divide TP)
    attn_act_shard: str = "none"
    # keep attention scores in bf16 end-to-end (decode memory-term knob)
    attn_scores_bf16: bool = False
    # remat policy for the layer scan: full | dots | none
    remat_policy: str = "full"
    # MoE dispatch: "global" (single token stream; paper-faithful baseline —
    # global cumsum serializes and GSPMD replicates the chain) or "grouped"
    # (per-batch-row queues, fully shardable — see moe.moe_ffn_grouped)
    moe_dispatch: str = "global"

    # modality frontend stub (audio frames / vision patches)
    frontend: Optional[str] = None  # audio | vision
    frontend_tokens: int = 0        # image tokens per example (vlm)

    # training knobs
    optimizer: str = "adamw"        # adamw | adafactor (large MoE)
    remat: bool = True
    param_dtype: Any = jnp.float32
    activ_dtype: Any = jnp.bfloat16
    tie_embeddings: bool = False

    # long-context capability: sub-quadratic path exists for this arch
    @property
    def supports_long_context(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (enc-dec included)

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for rooflines."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.attn_type == "mla":
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                + d * (self.kv_lora_rank + self.qk_rope_dim)
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        elif self.attn_type == "gqa":
            attn = d * self.head_dim * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * self.head_dim * d
        else:
            attn = 0
        if self.family == "ssm":  # rwkv6: time-mix + channel-mix
            h = self.ssm_heads * self.ssm_head_dim
            attn = 4 * d * h + h * d  # r,k,v,g,out (w is low-rank, small)
            ffn = 2 * d * ff  # channel mix has 2 mats + small r
        elif self.n_experts:
            ffn = 3 * d * self.moe_d_ff * self.n_experts + d * self.n_experts
        else:
            ffn = 3 * d * ff
        if self.family == "hybrid":
            h = self.ssm_heads * self.ssm_head_dim
            attn += 3 * d * h  # mamba in/out/gate projections (approx)
        blocks = L * (attn + ffn)
        if self.family == "encdec":
            enc_attn = d * self.head_dim * (self.n_heads + 2 * self.n_kv_heads) + d * d
            blocks += self.n_enc_layers * (enc_attn + 3 * d * ff) + L * (2 * d * d)
        return emb + blocks

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.n_experts:
            return self.n_params()
        full = self.n_params()
        expert_p = self.n_layers * 3 * self.d_model * self.moe_d_ff * self.n_experts
        active_e = expert_p * self.experts_per_token / self.n_experts
        return int(full - expert_p + active_e)


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def get_abstract_mesh():
    """Version-portable ``jax.sharding.get_abstract_mesh``.

    Public API from jax 0.5; on older versions fall back to the private
    equivalent. Returns ``None`` when no usable abstract mesh is active
    (including old versions where the fallback yields a bare tuple), so
    callers can uniformly skip sharding constraints.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        try:
            from jax._src.mesh import get_abstract_mesh as fn
        except ImportError:
            return None
    am = fn()
    if am is None or not hasattr(am, "axis_names") or getattr(am, "empty", False):
        return None
    return am


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale).astype(dt)


def rope_angles(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions [...]; returns (cos, sin) of shape [..., dim/2]."""
    freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., S, H, dh]; cos/sin [S, dh/2] (broadcast over batch/heads)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def dense_init(key: jax.Array, shape: Tuple[int, ...], in_dim: int,
               dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(in_dim)).astype(dtype)


def split_keys(key: jax.Array, n: int):
    return list(jax.random.split(key, n))


def cross_entropy_loss(logits: jax.Array, targets: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE; logits [..., V] f32, targets int32 [...]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
