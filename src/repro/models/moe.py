"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is the sort/scatter formulation (MegaBlocks/MaxText-style "dropping"
MoE): tokens are scattered into per-expert buffers of size
``capacity = ceil(T·K/E · capacity_factor)``; overflow tokens lose that
expert's contribution (standard at scale). Expert compute is a batched
einsum over the [E, cap, d] buffer, so compiled FLOPs scale with *active*
experts (what the roofline wants), and under pjit the scatter/gather is where
GSPMD inserts the expert-parallel all-to-alls.

A dense all-experts reference (``moe_dense_ref``) is kept for smoke tests:
with ample capacity the two must agree exactly.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from repro.models.common import ArchConfig, dense_init, get_abstract_mesh


def moe_init(key, cfg: ArchConfig) -> Dict[str, jax.Array]:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    return {
        "router": dense_init(ks[0], (d, e), d, dt),
        "w1": dense_init(ks[1], (e, d, ff), d, dt),   # gate proj
        "w3": dense_init(ks[2], (e, d, ff), d, dt),   # up proj
        "w2": dense_init(ks[3], (e, ff, d), ff, dt),  # down proj
    }


def _route(p, x2d: jax.Array, cfg: ArchConfig):
    """x2d [T, d] -> (weights [T, K], experts [T, K])."""
    logits = (x2d @ p["router"]).astype(jnp.float32)       # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.experts_per_token)   # [T, K]
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)    # renormalize
    return w, idx, probs


def moe_capacity(n_tokens: int, cfg: ArchConfig) -> int:
    cap = math.ceil(
        n_tokens * cfg.experts_per_token * cfg.capacity_factor / cfg.n_experts
    )
    return max(8, cap)


def _moe_spec(cfg: ArchConfig):
    """Dispatch-buffer spec for [E, cap, *]: experts over 'model' when E
    divides it (qwen: 128/16); otherwise shard the token-capacity dim over
    'data' so expert weights stay put and tokens move (grok: E=8 < 16)."""
    from jax.sharding import PartitionSpec as _P

    am = get_abstract_mesh()
    if am is not None and not am.empty and "model" in am.axis_names:
        if cfg.n_experts % am.shape["model"] == 0:
            return _P("model", None, None)
    if am is not None and not am.empty and "data" in am.axis_names:
        return _P(None, "data", None)
    return _P(None, None, None)


def moe_ffn_grouped(p, x: jax.Array, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """Grouped dispatch: per-batch-row expert queues (MaxText-style).

    The global-cumsum dispatch (``moe_ffn``) has a sequential dependency
    across the whole token stream, which defeats GSPMD: the scatter chain —
    and with it the expert einsums — replicates on every device (measured:
    qwen3 train compute 180× MODEL_FLOPS). Grouped dispatch computes queue
    positions *within each batch row* (cumsum over an unsharded axis), so
    the whole pipeline stays batch-sharded and expert compute parallelizes.
    Capacity is enforced per (row, expert) — the standard locality
    trade-off; with capacity_factor≈1.25 drop rates are comparable.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    capg = max(1, math.ceil(s * k * cfg.capacity_factor / e))
    w, idx, probs = _route(p, x.reshape(-1, d), cfg)
    w = w.reshape(b, s, k)
    idx = idx.reshape(b, s, k)

    flat_e = idx.reshape(b, s * k)                          # [B, S*K]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)     # [B, S*K, E]
    pos = jnp.cumsum(onehot, axis=1) - onehot               # queue slot per row
    slot = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = slot < capg
    dest = jnp.where(keep, flat_e * capg + slot, e * capg)  # [B, S*K]

    tok_of = jnp.repeat(jnp.arange(s), k)                   # [S*K] within row
    x_rep = x[:, tok_of, :]                                 # [B, S*K, d]
    buf = jnp.zeros((b, e * capg + 1, d), x.dtype)
    buf = jax.vmap(lambda bf, ds, xr: bf.at[ds].set(xr))(buf, dest, x_rep)
    xin = buf[:, : e * capg].reshape(b, e, capg, d)
    xin = checkpoint_name(xin, "moe_xin")
    if cfg.moe_shard_constraints:
        xin = jax.lax.with_sharding_constraint(xin, _moe_spec_grouped(cfg))

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, p["w1"])) * jnp.einsum(
        "becd,edf->becf", xin, p["w3"]
    )
    out_e = jnp.einsum("becf,efd->becd", h, p["w2"])        # [B, E, capg, d]
    out_e = checkpoint_name(out_e, "moe_out")

    flat_out = jnp.concatenate(
        [out_e.reshape(b, e * capg, d), jnp.zeros((b, 1, d), x.dtype)], axis=1
    )
    gathered = jax.vmap(lambda fo, ds: fo[ds])(flat_out, dest)  # [B, S*K, d]
    y_tok = gathered * (w.reshape(b, s * k)[..., None] * keep[..., None]).astype(x.dtype)
    y = jax.ops.segment_sum(
        y_tok.reshape(b * s * k, d),
        (jnp.arange(b)[:, None] * s + tok_of[None, :]).reshape(-1),
        num_segments=b * s,
    ).reshape(b, s, d)

    frac_tokens = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(2),
                           axis=(0, 1)) / k
    aux = e * jnp.sum(frac_tokens * probs.mean(0))
    return y, aux


def _moe_spec_grouped(cfg: ArchConfig):
    """[B, E, capg, d] dispatch spec: rows over data, experts over model."""
    from jax.sharding import PartitionSpec as _P

    am = get_abstract_mesh()
    if am is None or am.empty:
        return _P(None, None, None, None)
    dp = tuple(a for a in ("pod", "data") if a in am.axis_names)
    b_ax = (dp if len(dp) > 1 else dp[0]) if dp else None
    e_ax = ("model" if "model" in am.axis_names
            and cfg.n_experts % am.shape["model"] == 0 else None)
    return _P(b_ax, e_ax, None, None)


def moe_ffn(p, x: jax.Array, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """x [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    if cfg.moe_dispatch == "grouped":
        return moe_ffn_grouped(p, x, cfg)
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = moe_capacity(t, cfg)
    x2 = x.reshape(t, d)
    w, idx, probs = _route(p, x2, cfg)

    # position of each (token, k) in its expert's queue
    flat_e = idx.reshape(-1)                               # [T*K]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)    # [T*K, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot              # queue slot
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = slot < cap
    dest = jnp.where(keep, flat_e * cap + slot, e * cap)   # overflow -> scratch

    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    tok_of = jnp.repeat(jnp.arange(t), k)                  # [T*K]
    buf = buf.at[dest].set(x2[tok_of])
    xin = buf[: e * cap].reshape(e, cap, d)
    # name the dispatch buffers so remat_policy="moe" can SAVE them instead
    # of recomputing the whole scatter chain in the backward pass
    xin = checkpoint_name(xin, "moe_xin")

    if cfg.moe_shard_constraints:
        xin = jax.lax.with_sharding_constraint(xin, _moe_spec(cfg))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w1"])) * jnp.einsum(
        "ecd,edf->ecf", xin, p["w3"]
    )
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w2"])         # [E, cap, d]
    out_e = checkpoint_name(out_e, "moe_out")
    if cfg.moe_shard_constraints:
        h = jax.lax.with_sharding_constraint(h, _moe_spec(cfg))
        out_e = jax.lax.with_sharding_constraint(out_e, _moe_spec(cfg))

    flat_out = jnp.concatenate(
        [out_e.reshape(e * cap, d), jnp.zeros((1, d), x.dtype)]
    )
    y_tok = flat_out[dest] * (w.reshape(-1)[:, None] * keep[:, None]).astype(x.dtype)
    y = jax.ops.segment_sum(y_tok, tok_of, num_segments=t)

    # load-balancing auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(idx, e, dtype=jnp.float32)).sum(1), axis=0
    ) / k
    frac_probs = probs.mean(0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return y.reshape(b, s, d), aux


def moe_dense_ref(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """All-experts dense reference (smoke-test oracle; O(E) compute)."""
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    w, idx, _ = _route(p, x2, cfg)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x2, p["w1"])) * jnp.einsum(
        "td,edf->tef", x2, p["w3"]
    )
    y_all = jnp.einsum("tef,efd->ted", h, p["w2"])         # [T, E, d]
    gates = jnp.zeros((x2.shape[0], cfg.n_experts), x.dtype)
    gates = jax.vmap(lambda g, i, v: g.at[i].set(v))(gates, idx, w.astype(x.dtype))
    return jnp.einsum("ted,te->td", y_all, gates).reshape(b, s, d)
