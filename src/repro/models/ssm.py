"""Attention-free sequence mixers: RWKV6 (Finch) and Mamba2-style SSD.

Both mixers come in two algebraically-equivalent forms:
* ``*_recurrent`` — lax.scan over time; the decode step uses one iteration.
* ``*_chunked``   — chunk-parallel form (intra-chunk matmuls + inter-chunk
  state carry); this is the sub-quadratic **prefill** path that makes the
  long_500k shape feasible for the ssm/hybrid architectures.

Equivalence of the two forms is property-tested (tests/test_ssm.py).

RWKV6 notes: data-dependent per-channel decay w_t = exp(-exp(·)) (the Finch
signature), data-dependent token-shift (ddlerp), per-head bonus u, grouped
rms-norm on the output. The chunked form rescales k by the within-chunk
inverse decay product; with chunk=16 and the decay parameterization used
here this stays comfortably inside f32 (see DESIGN.md §2 numerics note).

Mamba2/SSD notes (hymba's mamba heads): scalar per-head decay, shared B/C
projections of state size N; the chunked form is unconditionally stable
(decay ratios are ≤ 1).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, dense_init, rms_norm


# ===========================================================================
# RWKV6 time-mix
# ===========================================================================

DDLERP_RANK = 16
DECAY_RANK = 32


def rwkv_time_mix_init(key, cfg: ArchConfig) -> Dict[str, jax.Array]:
    d = cfg.d_model
    h, dh = cfg.ssm_heads, cfg.ssm_head_dim
    hd = h * dh
    ks = jax.random.split(key, 10)
    dt = cfg.param_dtype
    return {
        "mu_base": jnp.full((d,), 0.5, dt),
        "mu": jnp.full((5, d), 0.5, dt),                    # r,k,v,w,g
        "ddw1": dense_init(ks[0], (d, 5 * DDLERP_RANK), d, dt),
        "ddw2": dense_init(ks[1], (5, DDLERP_RANK, d), DDLERP_RANK, dt),
        "wr": dense_init(ks[2], (d, hd), d, dt),
        "wk": dense_init(ks[3], (d, hd), d, dt),
        "wv": dense_init(ks[4], (d, hd), d, dt),
        "wg": dense_init(ks[5], (d, hd), d, dt),
        "w0": (0.3 * jax.random.normal(ks[6], (hd,), jnp.float32)).astype(dt),
        "ww1": dense_init(ks[7], (d, DECAY_RANK), d, dt),
        "ww2": dense_init(ks[8], (DECAY_RANK, hd), DECAY_RANK, dt),
        "u": (0.3 * jax.random.normal(ks[9], (h, dh), jnp.float32)).astype(dt),
        "ln_x": jnp.ones((hd,), dt),
        "wo": dense_init(jax.random.fold_in(key, 99), (hd, d), hd, dt),
    }


def _rwkv_projections(p, x: jax.Array, x_prev: jax.Array, cfg: ArchConfig):
    """Token-shifted projections. x [B,T,d]; x_prev [B,d] = token before x[:,0]."""
    b, t, d = x.shape
    h, dh = cfg.ssm_heads, cfg.ssm_head_dim
    xs = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)  # shift(x)
    sx = xs - x
    # data-dependent lerp (ddlerp)
    base = x + sx * p["mu_base"]
    lora = jnp.tanh(base @ p["ddw1"]).reshape(b, t, 5, DDLERP_RANK)
    delta = jnp.einsum("btfa,fad->btfd", lora, p["ddw2"])             # [B,T,5,d]
    mix = x[:, :, None, :] + sx[:, :, None, :] * (p["mu"][None, None] + delta)
    mr, mk, mv, mw, mg = [mix[:, :, i, :] for i in range(5)]
    r = (mr @ p["wr"]).reshape(b, t, h, dh)
    k = (mk @ p["wk"]).reshape(b, t, h, dh)
    v = (mv @ p["wv"]).reshape(b, t, h, dh)
    g = jax.nn.silu(mg @ p["wg"]).reshape(b, t, h, dh)
    # data-dependent decay in (0,1): w = exp(-exp(w0 + lora(mw)))
    z = p["w0"] + jnp.tanh(mw @ p["ww1"]) @ p["ww2"]
    logw = -jnp.exp(jnp.clip(z.astype(jnp.float32), -8.0, 2.0))      # log w <= 0
    logw = logw.reshape(b, t, h, dh)
    return r, k, v, g, logw, x[:, -1, :]


def _rwkv_out(p, o: jax.Array, g: jax.Array, cfg: ArchConfig) -> jax.Array:
    b, t, h, dh = o.shape
    o = o.reshape(b, t, h * dh)
    # grouped rms-norm per head
    on = rms_norm(o.reshape(b, t, h, dh), jnp.ones((dh,), o.dtype)).reshape(b, t, h * dh)
    on = on * p["ln_x"]
    return (on * g.reshape(b, t, h * dh)) @ p["wo"]


def wkv6_recurrent(r, k, v, logw, u, state):
    """Exact recurrence. r,k,v,logw [B,T,H,dh]; u [H,dh]; state [B,H,dh,dh].

    o_t = r_t · (S + (u ∘ k_t) ⊗ v_t);  S ← diag(w_t) S + k_t ⊗ v_t
    """
    def step(s, inp):
        rt, kt, vt, lwt = inp                              # [B,H,dh]
        att = s + (u[None] * kt)[..., :, None] * vt[..., None, :]
        ot = jnp.einsum("bhk,bhkv->bhv", rt, att)
        s = jnp.exp(lwt)[..., :, None] * s + kt[..., :, None] * vt[..., None, :]
        return s, ot

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, logw))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1), state                  # [B,T,H,dh], state


def wkv6_chunked(r, k, v, logw, u, state, chunk: int = 16):
    """Chunk-parallel WKV (intra matmuls + state carry), == recurrent."""
    b, t, h, dh = r.shape
    pad = (-t) % chunk
    if pad:
        zeros = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nt = (t + pad) // chunk
    rs = r.reshape(b, nt, chunk, h, dh)
    ks = k.reshape(b, nt, chunk, h, dh)
    vs = v.reshape(b, nt, chunk, h, dh)
    lw = logw.reshape(b, nt, chunk, h, dh).astype(jnp.float32)
    cum = jnp.cumsum(lw, axis=2)                           # L_i (inclusive)
    cum_prev = cum - lw                                    # L_{i-1} (exclusive)
    total = cum[:, :, -1]                                  # [B,nt,H,dh]

    r_dec = rs * jnp.exp(cum_prev).astype(rs.dtype)        # r_t ∘ P_{t-1}
    k_inc = ks * jnp.exp(-cum).astype(ks.dtype)            # k_i / P_i
    k_rem = ks * jnp.exp(total[:, :, None] - cum).astype(ks.dtype)  # P_n/P_i k_i

    # intra-chunk pairwise term A[t,i] = Σ_c r_dec[t,c] k_inc[i,c], i < t
    A = jnp.einsum("bncht,bnmht->bnhcm", r_dec, k_inc)     # [B,nt,H,chunk,chunk]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    A = jnp.where(tri[None, None, None], A, 0.0)
    diag = jnp.einsum("bncht,bncht->bnch", rs, u[None, None, None] * ks)
    intra = jnp.einsum("bnhcm,bnmht->bncht", A, vs)
    intra = intra + diag[..., None] * vs

    def carry(s, inp):
        rd, krem, vv, tot = inp
        inter = jnp.einsum("bchk,bhkv->bchv", rd, s)       # [B,chunk,H,dh]
        s = jnp.exp(tot)[..., :, None] * s + jnp.einsum(
            "bchk,bchv->bhkv", krem, vv
        )
        return s, inter

    xs = (
        jnp.moveaxis(r_dec, 1, 0),
        jnp.moveaxis(k_rem, 1, 0),
        jnp.moveaxis(vs, 1, 0),
        jnp.moveaxis(total, 1, 0),
    )
    state, inter = jax.lax.scan(carry, state, xs)
    out = intra + jnp.moveaxis(inter, 0, 1)
    out = out.reshape(b, nt * chunk, h, dh)[:, :t]
    return out, state


def rwkv_time_mix(p, x, x_prev, state, cfg: ArchConfig, *, mode: str = "chunked"):
    """Full time-mix block. Returns (y [B,T,d], new_x_prev, new_state)."""
    r, k, v, g, logw, last = _rwkv_projections(p, x, x_prev, cfg)
    fn = wkv6_chunked if mode == "chunked" else wkv6_recurrent
    o, state = fn(r, k, v, logw, p["u"].astype(jnp.float32), state)
    return _rwkv_out(p, o.astype(x.dtype), g, cfg), last, state


def rwkv_channel_mix_init(key, cfg: ArchConfig) -> Dict[str, jax.Array]:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.param_dtype
    return {
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_r": jnp.full((d,), 0.5, dt),
        "wk": dense_init(ks[0], (d, ff), d, dt),
        "wv": dense_init(ks[1], (ff, d), ff, dt),
        "wr": dense_init(ks[2], (d, d), d, dt),
    }


def rwkv_channel_mix(p, x, x_prev):
    """y = σ(r) ∘ ((relu(k)²) Wv). Returns (y, new_x_prev)."""
    xs = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    mk = x + (xs - x) * p["mu_k"]
    mr = x + (xs - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(mk @ p["wk"]))
    return jax.nn.sigmoid(mr @ p["wr"]) * (k @ p["wv"]), x[:, -1, :]


# ===========================================================================
# Mamba2-style SSD (hymba's parallel mamba heads)
# ===========================================================================

def ssd_init(key, cfg: ArchConfig) -> Dict[str, jax.Array]:
    d = cfg.d_model
    h, dh, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    ks = jax.random.split(key, 6)
    dt = cfg.param_dtype
    return {
        "wx": dense_init(ks[0], (d, h * dh), d, dt),
        "wB": dense_init(ks[1], (d, n), d, dt),
        "wC": dense_init(ks[2], (d, n), d, dt),
        "wdt": dense_init(ks[3], (d, h), d, dt),
        "dt_bias": jnp.zeros((h,), dt),
        "a_log": (0.5 * jax.random.normal(ks[4], (h,), jnp.float32)).astype(dt),
        "D": jnp.ones((h, dh), dt),
        "wo": dense_init(ks[5], (h * dh, d), h * dh, dt),
    }


def _ssd_projections(p, x, cfg: ArchConfig):
    b, t, d = x.shape
    h, dh = cfg.ssm_heads, cfg.ssm_head_dim
    xv = (x @ p["wx"]).reshape(b, t, h, dh)
    B = x @ p["wB"]                                        # [B,T,N]
    C = x @ p["wC"]
    dt = jax.nn.softplus((x @ p["wdt"]) + p["dt_bias"])    # [B,T,H] > 0
    loga = -jax.nn.softplus(p["a_log"].astype(jnp.float32))  # per head, < 0
    logdecay = dt.astype(jnp.float32) * loga[None, None]   # [B,T,H] <= 0
    return xv, B, C, dt, logdecay


def ssd_recurrent(xv, B, C, dt, logdecay, D, state):
    """h_t = a_t h + dt_t B_t ⊗ x_t; y_t = C_t·h_t + D∘x_t. state [B,H,N,dh]."""
    def step(s, inp):
        xt, bt, ct, dtt, ldt = inp
        s = jnp.exp(ldt)[..., None, None] * s + (
            dtt[..., None, None] * bt[:, None, :, None] * xt[..., None, :]
        )
        yt = jnp.einsum("bn,bhnv->bhv", ct, s) + D[None] * xt
        return s, yt

    xs = (
        jnp.moveaxis(xv, 1, 0),
        jnp.moveaxis(B, 1, 0),
        jnp.moveaxis(C, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(logdecay, 1, 0),
    )
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1), state


def ssd_chunked(xv, B, C, dt, logdecay, D, state, chunk: int = 32):
    """Chunk-parallel SSD; decay ratios exp(L_t-L_i) ≤ 1 => stable."""
    b, t, h, dh = xv.shape
    n = B.shape[-1]
    pad = (-t) % chunk
    if pad:
        xv = jnp.pad(xv, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        logdecay = jnp.pad(logdecay, ((0, 0), (0, pad), (0, 0)))
    nt = (t + pad) // chunk
    xs = xv.reshape(b, nt, chunk, h, dh)
    Bs = B.reshape(b, nt, chunk, n)
    Cs = C.reshape(b, nt, chunk, n)
    dts = dt.reshape(b, nt, chunk, h)
    ld = logdecay.reshape(b, nt, chunk, h).astype(jnp.float32)
    L = jnp.cumsum(ld, axis=2)                             # inclusive
    total = L[:, :, -1]                                    # [B,nt,H]

    # intra: M[t,i] = exp(L_t - L_i) (C_t·B_i) dt_i   for i <= t
    cb = jnp.einsum("bnca,bnma->bncm", Cs, Bs)             # [B,nt,chunk,chunk]
    gap = L[:, :, :, None, :] - L[:, :, None, :, :]        # [B,nt,c,m,H]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = jnp.exp(jnp.where(tri[None, None, :, :, None], gap, -jnp.inf))
    M = M * cb[..., None] * dts[:, :, None, :, :]          # [B,nt,c,m,H]
    intra = jnp.einsum("bncmh,bnmhv->bnchv", M, xs)

    def carry(s, inp):
        cs, bs_, xx, dd, ll, tot = inp
        inter = jnp.exp(ll)[..., None] * jnp.einsum("bca,bhav->bchv", cs, s)
        upd = jnp.einsum(
            "bch,bca,bchv->bhav", dd * jnp.exp(tot[:, None] - ll), bs_, xx
        )
        s = jnp.exp(tot)[..., None, None] * s + upd
        return s, inter

    xs_scan = (
        jnp.moveaxis(Cs, 1, 0),
        jnp.moveaxis(Bs, 1, 0),
        jnp.moveaxis(xs, 1, 0),
        jnp.moveaxis(dts, 1, 0),
        jnp.moveaxis(L, 1, 0),
        jnp.moveaxis(total, 1, 0),
    )
    state, inter = jax.lax.scan(carry, state, xs_scan)
    out = intra + jnp.moveaxis(inter, 0, 1)
    out = out + D[None, None, None] * xs
    out = out.reshape(b, nt * chunk, h, dh)[:, :t]
    return out, state


def ssd_mix(p, x, state, cfg: ArchConfig, *, mode: str = "chunked"):
    """Full SSD head block. Returns (y [B,T,d], new_state)."""
    b, t, d = x.shape
    xv, B, C, dt, logdecay = _ssd_projections(p, x, cfg)
    fn = ssd_chunked if mode == "chunked" else ssd_recurrent
    o, state = fn(
        xv.astype(jnp.float32), B.astype(jnp.float32), C.astype(jnp.float32),
        dt.astype(jnp.float32), logdecay, p["D"].astype(jnp.float32), state
    )
    h, dh = cfg.ssm_heads, cfg.ssm_head_dim
    return (o.astype(x.dtype).reshape(b, t, h * dh)) @ p["wo"], state
