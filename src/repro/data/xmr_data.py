"""XMR datasets: synthetic generators + SVMlight-style loader.

Two distinct uses:

1. **Benchmark models** (paper Tables 1-4): inference latency depends only on
   the *sparsity structure* (d, L, nnz, branching, sibling overlap), not the
   learned values, so the benchmark harness instantiates random models at the
   TRUE paper dimensions (Table 5) with sibling-correlated supports.
2. **Training-path datasets**: small generative hierarchical datasets with
   real label structure, used by tests/examples to exercise the full
   cluster -> train -> serve pipeline and report P@k.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.sparse.csr import CSR, random_sparse_csr


# ---------------------------------------------------------------------------
# Paper dataset shapes (Table 5) + typical sparsity statistics. Query/column
# nnz are approximations from the public XMC repository statistics; latency
# behaviour is governed by these orders of magnitude, not exact values.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class XMRShape:
    name: str
    d: int           # feature dimension
    L: int           # labels
    n_test: int      # queries used for benchmarking
    query_nnz: int   # avg nonzeros per query
    col_nnz: int     # avg nonzeros per ranker column after pruning


PAPER_SHAPES: Dict[str, XMRShape] = {
    "eurlex-4k":     XMRShape("eurlex-4k",     5_000,     3_956,   3_865, 236, 64),
    "amazoncat-13k": XMRShape("amazoncat-13k", 203_882,   13_330,  306_782, 71, 64),
    "wiki10-31k":    XMRShape("wiki10-31k",    101_938,   30_938,  6_616, 673, 64),
    "wiki-500k":     XMRShape("wiki-500k",     2_381_304, 501_070, 783_743, 200, 64),
    "amazon-670k":   XMRShape("amazon-670k",   135_909,   670_091, 153_025, 75, 64),
    "amazon-3m":     XMRShape("amazon-3m",     337_067,   2_812_281, 742_507, 100, 64),
}

ENTERPRISE_SHAPE = XMRShape(
    # Paper §6: semantic product search, 100M products, d = 4M.
    "enterprise-100m", 4_000_000, 100_000_000, 10_000, 150, 64
)


def scaled_shape(shape: XMRShape, scale: float) -> XMRShape:
    """Shrink L and n_test (d and nnz preserved) for CPU-budget benchmarks."""
    return XMRShape(
        name=f"{shape.name}@{scale:g}",
        d=max(64, int(shape.d * min(1.0, scale * 4))),
        L=max(64, int(shape.L * scale)),
        n_test=max(16, int(min(shape.n_test, 2000) * scale)),
        query_nnz=shape.query_nnz,
        col_nnz=shape.col_nnz,
    )


# ---------------------------------------------------------------------------
# Labeled generative dataset (training path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class XMRDataset:
    name: str
    x_train: CSR
    y_train: List[np.ndarray]
    x_test: CSR
    y_test: List[np.ndarray]
    n_labels: int

    @property
    def d(self) -> int:
        return self.x_train.shape[1]


def synthetic_labeled_dataset(
    rng: np.random.Generator,
    *,
    name: str = "synth",
    n_labels: int = 256,
    d: int = 512,
    n_train: int = 1024,
    n_test: int = 256,
    proto_nnz: int = 24,
    query_nnz: int = 16,
    n_groups: int | None = None,
    noise: float = 0.25,
) -> XMRDataset:
    """Hierarchical generative model.

    Labels live in groups; each group has a sparse center, each label a
    sparse prototype = center + private features. A query picks a label and
    samples features from its prototype support (plus noise features), so
    sibling labels have correlated discriminative features — the structure
    both the clustering and MSCM's Item 2 rely on.
    """
    g = n_groups or max(1, int(np.sqrt(n_labels)))
    group_of = rng.integers(0, g, size=n_labels)
    group_centers = [
        rng.choice(d, size=min(d, proto_nnz), replace=False) for _ in range(g)
    ]
    protos: List[np.ndarray] = []
    for lbl in range(n_labels):
        c = group_centers[group_of[lbl]]
        keep = rng.random(len(c)) < 0.7
        priv = rng.choice(d, size=max(1, proto_nnz // 3), replace=False)
        protos.append(np.unique(np.concatenate([c[keep], priv])))

    def make_split(n: int) -> Tuple[CSR, List[np.ndarray]]:
        rows_i, rows_v, ys = [], [], []
        for _ in range(n):
            lbl = int(rng.integers(0, n_labels))
            support = protos[lbl]
            k = min(query_nnz, len(support))
            feat = rng.choice(support, size=k, replace=False)
            n_noise = max(0, int(query_nnz * noise))
            if n_noise:
                feat = np.concatenate([feat, rng.choice(d, size=n_noise)])
            feat = np.unique(feat).astype(np.int32)
            val = (np.abs(rng.standard_normal(len(feat))) + 0.1).astype(np.float32)
            rows_i.append(feat)
            rows_v.append(val)
            pos = [lbl]
            if rng.random() < 0.3:  # multi-label: add a sibling from the group
                sibs = np.nonzero(group_of == group_of[lbl])[0]
                pos.append(int(rng.choice(sibs)))
            ys.append(np.unique(pos))
        return CSR.from_rows(rows_i, rows_v, (n, d)), ys

    x_tr, y_tr = make_split(n_train)
    x_te, y_te = make_split(n_test)
    return XMRDataset(name, x_tr, y_tr, x_te, y_te, n_labels)


def benchmark_queries(shape: XMRShape, n: int, rng: np.random.Generator) -> CSR:
    """Random queries matching a paper dataset's sparsity statistics."""
    return random_sparse_csr(n, shape.d, shape.query_nnz, rng)


# ---------------------------------------------------------------------------
# SVMlight-style loader (the public XMC repository format):
#   <label>,<label>,... <feat>:<val> <feat>:<val> ...
# ---------------------------------------------------------------------------

def load_svmlight_xmr(path: str, d: int, n_labels: int) -> Tuple[CSR, List[np.ndarray]]:
    rows_i, rows_v, ys = [], [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            parts = line.split()
            if ":" in parts[0]:
                labels = np.zeros(0, np.int64)
                feats = parts
            else:
                labels = np.array(
                    [int(t) for t in parts[0].split(",") if t], np.int64
                )
                feats = parts[1:]
            idx, val = [], []
            for tok in feats:
                k, v = tok.split(":")
                idx.append(int(k))
                val.append(float(v))
            order = np.argsort(idx)
            rows_i.append(np.asarray(idx, np.int32)[order])
            rows_v.append(np.asarray(val, np.float32)[order])
            ys.append(labels[labels < n_labels])
    x = CSR.from_rows(rows_i, rows_v, (len(rows_i), d))
    return x, ys
