"""Deterministic synthetic token pipeline, host-sharded, with prefetch.

Sequences have learnable structure (a noisy affine-bigram process) so the
end-to-end training example shows a falling loss; generation is a pure
function of (seed, host, step), which makes the pipeline trivially
resumable after restart — the data layer's contribution to fault tolerance.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np

from repro.models.common import ArchConfig


def batch_at_step(
    cfg: ArchConfig, *, seed: int, step: int, host: int, n_hosts: int,
    batch: int, seq: int,
) -> Dict[str, np.ndarray]:
    """Pure function (seed, step, host) -> batch dict (numpy)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, host]))
    per_host = batch // n_hosts
    v = cfg.vocab
    a = 31 % v or 1
    x = np.empty((per_host, seq + 1), np.int64)
    x[:, 0] = rng.integers(0, v, size=per_host)
    noise = rng.integers(0, 7, size=(per_host, seq))
    for t in range(seq):
        x[:, t + 1] = (a * x[:, t] + 17 + noise[:, t]) % v
    out: Dict[str, np.ndarray] = {
        "tokens": x[:, :-1].astype(np.int32),
        "targets": x[:, 1:].astype(np.int32),
    }
    if cfg.family == "encdec":
        out["src_embeds"] = rng.standard_normal(
            (per_host, seq, cfg.d_model)
        ).astype(np.float32)
    if cfg.family == "vlm":
        n_img = min(cfg.frontend_tokens, max(seq // 2, 8))
        out["patch_embeds"] = rng.standard_normal(
            (per_host, n_img, cfg.d_model)
        ).astype(np.float32)
    return out


class PrefetchingLoader:
    """Background-thread prefetch of the deterministic pipeline."""

    def __init__(self, cfg: ArchConfig, *, seed: int, batch: int, seq: int,
                 host: int = 0, n_hosts: int = 1, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg, self.seed = cfg, seed
        self.batch, self.seq = batch, seq
        self.host, self.n_hosts = host, n_hosts
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self.step
        while not self._stop.is_set():
            b = batch_at_step(
                self.cfg, seed=self.seed, step=step, host=self.host,
                n_hosts=self.n_hosts, batch=self.batch, seq=self.seq,
            )
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
