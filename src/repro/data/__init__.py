from repro.data.xmr_data import (
    ENTERPRISE_SHAPE,
    PAPER_SHAPES,
    XMRDataset,
    XMRShape,
    benchmark_queries,
    load_svmlight_xmr,
    scaled_shape,
    synthetic_labeled_dataset,
)
