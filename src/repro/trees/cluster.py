"""Label-tree construction: PIFA embeddings + recursive balanced bisection.

Following the PECOS/Parabel family the paper builds on:

* **PIFA** (positive instance feature aggregation): each label's embedding is
  the L2-normalized sum of its positive training queries.
* **Hierarchical clustering**: recursive *balanced* 2-means orders the labels
  so that similar labels are adjacent; the ordered list is then cut into a
  perfect B-ary tree. Balance is by construction (equal splits), which is
  exactly what the chunk layout wants: every chunk holds B real siblings, and
  sibling rankers see near-identical positive sets — the origin of the
  correlated column supports that MSCM exploits (paper Item 2).

Everything here is offline model-construction code (numpy); the inference
path never calls it.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.sparse.csr import CSR


def pifa_embeddings(x: CSR, y: Sequence[np.ndarray], n_labels: int) -> np.ndarray:
    """Dense [L, d] PIFA label embeddings (L2-normalized).

    ``y[i]`` lists the positive label ids of query i.
    """
    n, d = x.shape
    out = np.zeros((n_labels, d), dtype=np.float32)
    for i in range(n):
        idx, val = x.row(i)
        for lbl in y[i]:
            out[lbl, idx] += val
    norms = np.linalg.norm(out, axis=1, keepdims=True)
    norms[norms == 0] = 1.0
    return out / norms


def _balanced_bisect(emb: np.ndarray, ids: np.ndarray, rng: np.random.Generator,
                     iters: int = 12) -> Tuple[np.ndarray, np.ndarray]:
    """Balanced 2-means: split ids into two equal halves by cluster affinity."""
    m = len(ids)
    if m <= 2:
        return ids[: m // 2], ids[m // 2 :]
    sub = emb[ids]
    c = sub[rng.choice(m, size=2, replace=False)].copy()  # [2, d]
    for _ in range(iters):
        score = sub @ c.T                     # [m, 2] cosine affinity
        margin = score[:, 0] - score[:, 1]
        order = np.argsort(-margin, kind="stable")
        half = m // 2
        left, right = order[:half], order[half:]
        new_c = np.stack([sub[left].mean(0), sub[right].mean(0)])
        nrm = np.linalg.norm(new_c, axis=1, keepdims=True)
        nrm[nrm == 0] = 1.0
        new_c = new_c / nrm
        if np.allclose(new_c, c, atol=1e-6):
            c = new_c
            break
        c = new_c
    score = sub @ c.T
    margin = score[:, 0] - score[:, 1]
    order = np.argsort(-margin, kind="stable")
    half = m // 2
    return ids[order[:half]], ids[order[half:]]


def cluster_label_order(
    emb: np.ndarray, rng: np.random.Generator, *, min_leaf: int = 2
) -> np.ndarray:
    """Similarity-preserving label ordering via recursive balanced bisection."""
    out: List[np.ndarray] = []

    def rec(ids: np.ndarray):
        if len(ids) <= min_leaf:
            out.append(ids)
            return
        l, r = _balanced_bisect(emb, ids, rng)
        rec(l)
        rec(r)

    rec(np.arange(emb.shape[0]))
    return np.concatenate(out)


@dataclasses.dataclass
class TreeStructure:
    """A perfect B-ary tree over a label permutation.

    ``level_sizes[l]`` = number of nodes at stored level l (level 0 here is
    the paper's level 2 — children of the root). ``label_perm[j]`` maps tree
    leaf position j -> original label id; positions >= n_labels are padding.
    """

    label_perm: np.ndarray        # [n_leaf_slots] int32, padded with -1
    level_sizes: Tuple[int, ...]  # e.g. (B, B^2, ..., B^depth)
    branching: int
    n_labels: int

    @property
    def depth(self) -> int:
        return len(self.level_sizes)

    def leaf_to_label(self, leaf_pos: np.ndarray) -> np.ndarray:
        return self.label_perm[leaf_pos]

    def label_to_leaf(self) -> np.ndarray:
        inv = np.full(self.n_labels, -1, np.int64)
        for pos, lbl in enumerate(self.label_perm):
            if lbl >= 0:
                inv[lbl] = pos
        return inv

    def ancestor_at_level(self, leaf_pos: np.ndarray, level: int) -> np.ndarray:
        """Node id at stored level ``level`` containing each leaf position."""
        span = 1
        for l in range(level + 1, self.depth):
            span *= self.branching
        return leaf_pos // span


def build_tree_structure(
    n_labels: int, branching: int, *, max_depth: int | None = None
) -> TreeStructure:
    """Perfect B-ary tree: depth = ceil(log_B n_labels), padded leaf slots."""
    b = int(branching)
    depth = 1
    while b**depth < n_labels:
        depth += 1
    if max_depth is not None:
        depth = min(depth, max_depth)
    sizes = tuple(b**l for l in range(1, depth + 1))
    slots = sizes[-1]
    perm = np.full(slots, -1, np.int64)
    perm[:n_labels] = np.arange(n_labels)
    return TreeStructure(
        label_perm=perm, level_sizes=sizes, branching=b, n_labels=n_labels
    )


def build_clustered_tree(
    x: CSR,
    y: Sequence[np.ndarray],
    n_labels: int,
    branching: int,
    rng: np.random.Generator,
) -> TreeStructure:
    """PIFA + balanced bisection ordering + perfect B-ary tree."""
    emb = pifa_embeddings(x, y, n_labels)
    order = cluster_label_order(emb, rng)
    tree = build_tree_structure(n_labels, branching)
    tree.label_perm[: n_labels] = order
    return tree
