"""Per-level one-vs-rest ranker training (PECOS-style, pure JAX).

For each stored tree level l the targets are the level-l ancestors of each
query's positive labels; rankers are logistic (paper eq. 1) and are trained
with *teacher-forced matched negatives*: node j's ranker only sees queries
positive for j's parent (the standard PECOS/Parabel recipe — it matches the
conditional factorization of eq. 2 and keeps training sets small).

Training is full-batch Adam on dense tensors (laptop-scale substrate; the
paper treats training as out of scope). The trained weights are magnitude-
pruned per column to the requested sparsity and handed to the chunked
converters, closing the loop: cluster -> train -> sparsify -> MSCM serve.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import XMRTree
from repro.sparse.csr import CSC, CSR
from repro.trees.cluster import TreeStructure, build_clustered_tree


@functools.partial(jax.jit, static_argnames=("steps",))
def _train_level(
    xd: jax.Array,      # f32 [n, d] dense queries
    y: jax.Array,       # f32 [n, L] binary node targets
    p: jax.Array,       # f32 [n, L] parent-positive mask (training set)
    *,
    steps: int = 150,
    lr: float = 0.5,
    l2: float = 1e-4,
) -> jax.Array:
    """Masked logistic regression for all L node rankers at once."""
    n, d = xd.shape
    L = y.shape[1]
    w0 = jnp.zeros((d, L), jnp.float32)

    def loss_fn(w):
        logits = xd @ w
        bce = jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
        denom = jnp.maximum(p.sum(), 1.0)
        return (bce * p).sum() / denom + l2 * jnp.sum(w * w)

    grad_fn = jax.grad(loss_fn)

    def step(carry, _):
        w, m, v, t = carry
        g = grad_fn(w)
        t = t + 1
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9**t)
        vh = v / (1 - 0.999**t)
        w = w - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return (w, m, v, t), None

    init = (w0, jnp.zeros_like(w0), jnp.zeros_like(w0), jnp.float32(0))
    (w, _, _, _), _ = jax.lax.scan(step, init, None, length=steps)
    return w


def sparsify_columns(w: np.ndarray, nnz_per_col: int, *, min_abs: float = 1e-6) -> CSC:
    """Keep the top-|w| entries of each column (PECOS-style pruning)."""
    d, L = w.shape
    cols_i, cols_v = [], []
    k = min(nnz_per_col, d)
    for j in range(L):
        col = w[:, j]
        idx = np.argpartition(-np.abs(col), k - 1)[:k] if k < d else np.arange(d)
        idx = idx[np.abs(col[idx]) > min_abs]
        idx = np.sort(idx).astype(np.int32)
        cols_i.append(idx)
        cols_v.append(col[idx].astype(np.float32))
    return CSC.from_cols(cols_i, cols_v, (d, L))


@dataclasses.dataclass
class TrainedXMRModel:
    """Tree structure + trained chunked model + label mapping."""

    tree: XMRTree
    structure: TreeStructure

    def predict(
        self, x_idx, x_val, *, beam: int = 10, topk: int = 10, method: str = "mscm_dense"
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (scores [n,k], original-label ids [n,k]; -1 = padding)."""
        s, leaf_pos = self.tree.infer(
            x_idx, x_val, beam=beam, topk=topk, method=method
        )
        labels = self.structure.label_perm[np.asarray(leaf_pos)]
        return np.asarray(s), labels


def leaf_targets(
    y: Sequence[np.ndarray], structure: TreeStructure
) -> List[np.ndarray]:
    """Map positive label ids -> leaf positions under the tree permutation."""
    inv = structure.label_to_leaf()
    return [inv[np.asarray(lbls, np.int64)] for lbls in y]


def train_xmr_model(
    x: CSR,
    y: Sequence[np.ndarray],
    n_labels: int,
    branching: int,
    rng: np.random.Generator,
    *,
    nnz_per_col: int = 32,
    steps: int = 150,
    structure: TreeStructure | None = None,
) -> TrainedXMRModel:
    """Full pipeline: cluster -> per-level ranker training -> sparsify."""
    n, d = x.shape
    if structure is None:
        structure = build_clustered_tree(x, y, n_labels, branching, rng)
    leaves = leaf_targets(y, structure)
    xd = jnp.asarray(x.to_dense())

    weights: List[CSC] = []
    prev_pos: np.ndarray | None = None  # [n, L_{l-1}] bool
    for level, size in enumerate(structure.level_sizes):
        yl = np.zeros((n, size), np.float32)
        for i, lp in enumerate(leaves):
            nodes = structure.ancestor_at_level(lp, level)
            yl[i, nodes] = 1.0
        if prev_pos is None:
            pl = np.ones((n, size), np.float32)
        else:
            pl = prev_pos[:, np.arange(size) // structure.branching]
        w = np.asarray(_train_level(xd, jnp.asarray(yl), jnp.asarray(pl), steps=steps))
        weights.append(sparsify_columns(w, nnz_per_col))
        prev_pos = yl
    tree = XMRTree.from_weight_matrices(weights, branching)
    return TrainedXMRModel(tree=tree, structure=structure)
