from repro.trees.cluster import (
    TreeStructure,
    build_clustered_tree,
    build_tree_structure,
    pifa_embeddings,
)
from repro.trees.train import TrainedXMRModel, sparsify_columns, train_xmr_model
