"""The quantized tier's measured accuracy contract.

Exactness is the house style — every exact method returns *identical*
rankings, pinned by bitwise tests. A compressed tier cannot make that claim,
so it ships with a **measured contract** instead: recall@k against the f32
reference ranking stays above a floor, and the top-k score MAE stays below a
bound, both swept by ``benchmarks/bench_quant.py`` and gated as numeric
tolerance rows in ``benchmarks/check_regression.py``. These helpers are the
single definition of those two metrics, shared by the benchmark and the
tests so the gate can never drift from what the suite verifies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# Contract measurement orders score *values* outside the serving path;
# canonical (score desc, id asc) tie-breaking is irrelevant to a mean.
# xmrlint: tolerance-tier
def topk_scores(scores: jax.Array, k: int) -> jax.Array:
    """Descending top-``k`` score values per row (order-only, no ids).

    Not a serving-path selection: quantized and exact tiers may rank
    near-tied labels differently, so the contract compares the score
    *multisets*, which this makes positional.
    """
    vals, _ = jax.lax.top_k(jnp.asarray(scores, jnp.float32), k)
    return vals


def recall_at_k(ref_labels: np.ndarray, got_labels: np.ndarray) -> float:
    """Mean per-query overlap |ref ∩ got| / k between two top-k label sets.

    ``ref_labels`` is the exact tier's [n, k] panel, ``got`` the compressed
    tier's [n, k']; recall is measured at the reference width k.
    """
    ref = np.asarray(ref_labels)
    got = np.asarray(got_labels)
    n, k = ref.shape
    hits = 0
    for i in range(n):
        hits += np.intersect1d(ref[i], got[i]).size
    return hits / float(n * k)


def score_mae(ref_scores: np.ndarray, got_scores: np.ndarray,
              k: int | None = None) -> float:
    """Mean |Δ| between the two tiers' descending top-k score values."""
    ref = np.asarray(ref_scores)
    got = np.asarray(got_scores)
    k = min(ref.shape[1], got.shape[1]) if k is None else k
    a = np.asarray(topk_scores(ref, k))
    b = np.asarray(topk_scores(got, k))
    return float(np.mean(np.abs(a - b)))
