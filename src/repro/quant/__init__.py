"""Compressed-weight serving tier (ROADMAP: quantized storage).

The leaf ranker layer dominates per-partition model memory (paper §5/§6);
Lin et al. (arXiv 2410.09554) show tree-linear XMC weights tolerate
aggressive low-precision storage and magnitude pruning with tiny precision
loss. This package turns that into a serving *tier*:

* :mod:`repro.quant.storage` — per-(chunk, column) symmetric quantization of
  the ELL chunk weights (int8 everywhere, fp8-e4m3 where the backend has the
  dtype) plus an optional magnitude-pruned ELL re-pack that shrinks the pad
  width R, producing a :class:`QuantizedTree` that round-trips through
  ``repro.checkpoint`` and the :class:`~repro.index.partition
  .PartitionManifest` (tier/dtype recorded per partition, folded into
  ``content_hash``).
* :mod:`repro.quant.kernels` — the ``method="mscm_pallas_grouped_q"`` Pallas
  path: dequantize-in-register inside the grouped tile matmul, reusing the
  fused σ⊗parent epilogue and the canonical ``beam_select`` unchanged.
* :mod:`repro.quant.contract` — the *measured* accuracy contract (recall@k
  floor, score MAE bound) the tier ships with instead of a bitwise claim;
  gated by ``benchmarks/bench_quant.py`` + ``check_regression``.

Selected via ``ServeConfig(quant=QuantConfig(tier="int8"))`` — see
:mod:`repro.serving.config`.
"""

from repro.quant.contract import recall_at_k, score_mae, topk_scores
from repro.quant.kernels import mscm_grouped_q, mscm_grouped_q_level
from repro.quant.storage import (
    QUANT_DTYPES,
    QuantLayerArrays,
    QuantizedTree,
    dequantize_layer,
    dequantize_tree,
    prune_chunks,
    quantize_index,
    quantize_layer,
    quantize_tree,
)

__all__ = [
    "QUANT_DTYPES",
    "QuantLayerArrays",
    "QuantizedTree",
    "dequantize_layer",
    "dequantize_tree",
    "mscm_grouped_q",
    "mscm_grouped_q_level",
    "prune_chunks",
    "quantize_index",
    "quantize_layer",
    "quantize_tree",
    "recall_at_k",
    "score_mae",
    "topk_scores",
]
