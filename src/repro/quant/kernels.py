"""Quantized grouped MSCM: dequantize-in-register inside the tile matmul.

``method="mscm_pallas_grouped_q"`` is the grouped kernel
(:func:`repro.kernels.mscm_kernel.mscm_grouped`) with one extra input — the
per-(chunk, column) scale row — and one extra in-kernel op: the chunk tile
is widened ``int8 → f32`` and multiplied by its scale row **in VMEM**, right
before the [QT, R] × [R, B] contraction. Everything else is shared with the
exact path: the chunk-major device grouping (``ops.group_blocks_device``),
the fused σ⊗parent epilogue, the gather-based unsort, and the canonical
``beam_select`` downstream — so the quantized tier changes *weight bits*,
never selection semantics.

HBM traffic per tile drops ~4× on the dominant operand (the [R, B] chunk
tile ships as int8; the [B] scale row is noise), which is the whole point:
the tier trades a bounded score perturbation (|err| ≤ scale/2 per weight,
measured contract in ``benchmarks/bench_quant.py``) for ~4× memory and
bandwidth.

Parity contract (pinned by tests + the ``quant_kernel_parity`` flag): the
in-register dequant computes exactly ``q.astype(f32) * scale`` — the same
elementwise reconstruction :func:`repro.quant.storage.dequantize_layer`
materializes — so running this kernel on a :class:`QuantizedTree` is
bitwise-identical (in interpret mode) to running the exact grouped kernel
on the dequantized f32 tree. Interpret-mode fallback mirrors the exact
kernels: ``MSCM_FORCE_INTERPRET`` / non-TPU backends run the kernel body in
Python (``ops._auto_interpret``).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import (
    DEFAULT_QT,
    _auto_interpret,
    group_blocks_device,
)


def _grouped_q_body(
    tc_ref, xg_ref, ps_ref, vals_ref, scales_ref, out_ref, *, mode
):
    del tc_ref
    # In-register dequant: widen the resident int8/fp8 chunk tile to f32 and
    # apply the per-column scale row while both live in VMEM — the f32 tile
    # never exists in HBM.
    v = vals_ref[0].astype(jnp.float32) * scales_ref[0][None, :]  # [R, B]
    acc = jax.lax.dot_general(
        xg_ref[0], v,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                    # [QT, B]
    if mode == "prod":
        acc = jax.nn.sigmoid(acc) * ps_ref[0][:, None]
    elif mode == "logsum":
        acc = jax.nn.log_sigmoid(acc) + ps_ref[0][:, None]
    out_ref[0] = acc


def mscm_grouped_q(
    xg_tiles: jax.Array,    # f32 [T, QT, R] gathered query rows per tile
    vals: jax.Array,        # int8/fp8 [C, R, B] quantized chunk tiles
    scales: jax.Array,      # f32 [C, B] per-(chunk, column) scales
    tile_chunk: jax.Array,  # int32 [T]
    parent_scores: Optional[jax.Array] = None,  # f32 [T, QT] beam scores
    *,
    mode: str = "none",
    interpret: bool = False,
) -> jax.Array:
    """Quantized chunk-major tile matmul with the fused beam epilogue.

    Identical contract to :func:`~repro.kernels.mscm_kernel.mscm_grouped`
    (``mode`` ∈ none/prod/logsum, [T, QT, B] f32 out); the chunk tile and
    its scale row are both indexed by ``tile_chunk``, so a chunk-sorted grid
    keeps them VMEM-resident across every query tile that hits the chunk.
    """
    t, qt, r = xg_tiles.shape
    c, _, b = vals.shape
    if mode not in ("none", "prod", "logsum"):
        raise ValueError(f"unknown epilogue mode {mode!r}")
    if parent_scores is None:
        if mode != "none":
            raise ValueError(
                f"mode={mode!r} combines with the parent beam scores; pass "
                "parent_scores (zeros would silently flatten every score)"
            )
        parent_scores = jnp.zeros((t, qt), jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, qt, r), lambda i, tc: (i, 0, 0)),
            pl.BlockSpec((1, qt), lambda i, tc: (i, 0)),
            pl.BlockSpec((1, r, b), lambda i, tc: (tc[i], 0, 0)),
            pl.BlockSpec((1, b), lambda i, tc: (tc[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, qt, b), lambda i, tc: (i, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_grouped_q_body, mode=mode),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, qt, b), jnp.float32),
        interpret=interpret,
    )(tile_chunk, xg_tiles, parent_scores, vals, scales)


def mscm_grouped_q_level(
    x_dense: jax.Array,        # f32 [n, Dp]
    rows: jax.Array,           # int32 [C, R]
    vals: jax.Array,           # int8/fp8 [C, R, B]
    scales: jax.Array,         # f32 [C, B]
    block_q: jax.Array,        # int32 [A]
    block_c: jax.Array,        # int32 [A]
    parent_scores: Optional[jax.Array] = None,  # f32 [A] (beam scores)
    *,
    qt: int = DEFAULT_QT,
    mode: str = "none",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One tree level through the quantized grouped kernel, fully in-jit.

    Mirrors :func:`repro.kernels.ops.mscm_grouped_level` exactly — same
    device grouping, same gather/mask staging, same unsort — with the
    quantized kernel in the middle. Traceable inside an enclosing jit.
    """
    interp = _auto_interpret(interpret)
    c, _, b = vals.shape
    tile_chunk, tile_src, order, flat_pos = group_blocks_device(
        block_c, qt, c
    )
    safe_src = jnp.maximum(tile_src, 0)                  # [T, QT]
    bq = block_q[safe_src]                               # [T, QT]
    r = rows[tile_chunk]                                 # [T, R]
    xg = x_dense[bq[..., None], r[:, None, :]]           # [T, QT, R]
    xg = jnp.where((tile_src >= 0)[..., None], xg, 0.0)
    ps = None
    if parent_scores is not None:
        ps = jnp.where(tile_src >= 0, parent_scores[safe_src], 0.0)
    tiles = mscm_grouped_q(
        xg, vals, scales, tile_chunk, ps, mode=mode, interpret=interp
    )                                                    # [T, QT, B]
    flat = tiles.reshape(-1, b)
    return flat[flat_pos[jnp.argsort(order)]]            # [A, B]


@functools.partial(
    jax.jit, static_argnames=("qt", "mode", "interpret")
)
def mscm_pallas_grouped_q(
    x_dense: jax.Array,
    rows: jax.Array,
    vals: jax.Array,
    scales: jax.Array,
    block_q: jax.Array,
    block_c: jax.Array,
    parent_scores: Optional[jax.Array] = None,
    *,
    qt: int = DEFAULT_QT,
    mode: str = "none",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Jitted entry point mirroring ``ops.mscm_pallas_grouped`` (tests)."""
    return mscm_grouped_q_level(
        x_dense, rows, vals, scales, block_q, block_c, parent_scores,
        qt=qt, mode=mode, interpret=interpret,
    )
