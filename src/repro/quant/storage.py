"""Quantized ELL chunk storage: int8/fp8 weights + pruned re-pack.

Layout. A :class:`~repro.core.tree.TreeLayerArrays` stores one level's chunk
tiles as ``chunk_vals`` f32 [C, R, B]. The quantized layer replaces that with
``chunk_vals`` int8 (or fp8-e4m3) [C, R, B] plus ``chunk_scales`` f32 [C, B]
— one symmetric scale per (chunk, column), i.e. per tree node, so a dominant
column cannot flatten its siblings' resolution. ``chunk_rows`` (the ELL row
indices, the masked-multiplication *mask*) stays exact int32: quantization
perturbs scores, never the sparsity pattern.

Scales: ``scale[c, b] = max_r |vals[c, r, b]| / Q`` with ``Q = 127`` (int8)
or ``448`` (fp8-e4m3 finite max); all-zero columns get scale 1 so dequant is
exactly 0 (and never divides by zero). For int8, ``q = rint(v / scale)``
clipped to ±127 — the worst-case dequant error is ``scale / 2`` per weight,
the bound the hypothesis property pins.

Pruned re-pack (:func:`prune_chunks`): per chunk, keep the top
``ceil(keep_frac · nnz_c)`` ELL rows by magnitude ``max_b |vals[c, r, :]|``
(ties break to the lower row index) and re-pack into a narrower pad width
``R' = round_up(max kept, 8)`` (min 8 — the same f32 sublane alignment
``ChunkedLayer.from_csc`` applies). Kept weights are **bitwise** the
original f32 values when dequantized at the same scale grid; dropped rows
simply vanish from the mask.

Only the chunked layout is quantized: the per-column vanilla arrays exist
for the exact baseline method, which a compressed tier never dispatches.
:func:`dequantize_layer` therefore returns sentinel-only stubs for
``col_rows``/``col_vals`` — the dequantized tree serves every chunked MSCM
method, not ``method="vanilla"``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import TreeLayerArrays, XMRTree

#: Storage dtypes by name -> (numpy target dtype factory, symmetric qmax).
#: fp8-e4m3 is present only when the backend's jax build ships the dtype.
QUANT_DTYPES = {"int8": (np.int8, 127.0)}
if hasattr(jnp, "float8_e4m3fn"):
    QUANT_DTYPES["fp8"] = (np.dtype(jnp.float8_e4m3fn).type, 448.0)


@dataclasses.dataclass
class QuantLayerArrays:
    """Quantized device tensors for one level (a pytree).

    Mirrors :class:`~repro.core.tree.TreeLayerArrays` for the chunked layout
    (same field names where shared, so shape-only consumers — phantom
    clamping, chunk counts — work on either)."""

    chunk_rows: jax.Array    # int32 [C, R]  exact ELL mask (sentinel = d)
    chunk_vals: jax.Array    # int8/fp8 [C, R, B] quantized weights
    chunk_scales: jax.Array  # f32 [C, B] per-(chunk, column) symmetric scale


jax.tree_util.register_dataclass(
    QuantLayerArrays,
    data_fields=["chunk_rows", "chunk_vals", "chunk_scales"],
    meta_fields=[],
)


@dataclasses.dataclass
class QuantizedTree(XMRTree):
    """An :class:`XMRTree` whose layers are :class:`QuantLayerArrays`.

    Inherits the traversal machinery (``infer`` dispatches the quantized
    grouped method through the same ``_tree_infer``/``level_combined`` path,
    ``device_put``/``memory_bytes`` walk the layer pytrees) — only the
    per-level matmul changes. ``tier`` names the compression recipe so the
    manifest and fleet payloads can record it.
    """

    tier: str = "int8"

    def head(self, level: int) -> "XMRTree":
        raise TypeError(
            "QuantizedTree cannot be re-split: quantize per partition "
            "(repro.quant.quantize_index) after partition_tree()"
        )

    def extract(self, level: int, chunk_start: int, chunk_end: int) -> "XMRTree":
        raise TypeError(
            "QuantizedTree cannot be re-split: quantize per partition "
            "(repro.quant.quantize_index) after partition_tree()"
        )


def _dtype_for(tier: str) -> str:
    if tier in ("int8", "int8_pruned"):
        return "int8"
    if tier == "fp8":
        if "fp8" not in QUANT_DTYPES:
            raise ValueError(
                "tier='fp8' needs jax.numpy.float8_e4m3fn, which this jax "
                "build does not provide; use tier='int8'"
            )
        return "fp8"
    raise ValueError(f"no storage dtype for tier {tier!r}")


def quantize_layer(layer: TreeLayerArrays, dtype: str = "int8",
                   *, rows: np.ndarray | None = None,
                   vals: np.ndarray | None = None) -> QuantLayerArrays:
    """Symmetric per-(chunk, column) quantization of one level's chunk tiles.

    ``rows``/``vals`` override the layer's chunk arrays (the pruned re-pack
    path quantizes its narrower tiles through the same scale math).
    """
    np_dtype, qmax = QUANT_DTYPES[_dtype_for(dtype)]
    rows = np.asarray(layer.chunk_rows if rows is None else rows)
    vals = np.asarray(layer.chunk_vals if vals is None else vals,
                      dtype=np.float32)
    amax = np.abs(vals).max(axis=1)                      # [C, B]
    scale = (amax / qmax).astype(np.float32)
    scale = np.where(scale > 0, scale, np.float32(1.0))
    scaled = vals / scale[:, None, :]
    if np_dtype is np.int8:
        q = np.clip(np.rint(scaled), -qmax, qmax).astype(np.int8)
    else:
        # fp8 rounds to nearest representable; the clip is implicit (the
        # scale maps amax onto the finite max 448).
        q = np.asarray(jnp.asarray(scaled).astype(jnp.float8_e4m3fn))
    return QuantLayerArrays(
        chunk_rows=jnp.asarray(rows),
        chunk_vals=jnp.asarray(q),
        chunk_scales=jnp.asarray(scale),
    )


def dequantize_layer(qlayer: QuantLayerArrays, *, d: int) -> TreeLayerArrays:
    """f32 reconstruction ``q · scale`` of a quantized layer.

    The per-column vanilla arrays are sentinel-only stubs (see the module
    docstring): the reconstruction serves every *chunked* MSCM method.
    """
    vals = (
        np.asarray(qlayer.chunk_vals).astype(np.float32)
        * np.asarray(qlayer.chunk_scales)[:, None, :]
    )
    return TreeLayerArrays(
        chunk_rows=qlayer.chunk_rows,
        chunk_vals=jnp.asarray(vals),
        col_rows=jnp.full((1, 1), d, jnp.int32),
        col_vals=jnp.zeros((1, 1), jnp.float32),
    )


def _round_up(x: int, align: int) -> int:
    return -(-x // align) * align


def prune_chunks(
    rows: np.ndarray,          # int32 [C, R] (sentinel = d)
    vals: np.ndarray,          # f32 [C, R, B]
    keep_frac: float,
    *,
    sentinel: int,
    row_align: int = 8,
    min_width: int = 8,
) -> Tuple[np.ndarray, np.ndarray]:
    """Magnitude-pruned ELL re-pack: keep the heavy rows, shrink R.

    Per chunk, the top ``ceil(keep_frac · nnz_c)`` rows by
    ``max_b |vals[c, r, :]|`` survive (stable: ties keep the lower row
    index); survivors are re-packed in ascending row order into a fresh pad
    width ``R' = round_up(max kept, row_align)`` (min ``min_width``). Kept
    values are copied bitwise; everything else becomes sentinel/0 padding.
    """
    if not 0.0 < keep_frac <= 1.0:
        raise ValueError(f"keep_frac must be in (0, 1]; got {keep_frac}")
    rows = np.asarray(rows)
    vals = np.asarray(vals)
    c, r = rows.shape
    valid = rows != sentinel                              # [C, R]
    mag = np.abs(vals).max(axis=2)                        # [C, R]
    mag = np.where(valid, mag, -1.0)                      # padding never kept
    nnz = valid.sum(axis=1)                               # [C]
    keep = np.ceil(keep_frac * nnz).astype(np.int64)      # [C], 0 when empty
    # Stable argsort on -mag: equal magnitudes stay in ascending row order.
    order = np.argsort(-mag, axis=1, kind="stable")       # [C, R]
    keep_mask = np.zeros_like(valid)
    np.put_along_axis(
        keep_mask, order,
        np.arange(r)[None, :] < keep[:, None], axis=1,
    )
    r_new = max(min_width, _round_up(max(1, int(keep.max(initial=1))),
                                     row_align))
    out_rows = np.full((c, r_new), sentinel, dtype=rows.dtype)
    out_vals = np.zeros((c, r_new) + vals.shape[2:], dtype=vals.dtype)
    for ci in range(c):
        src = np.flatnonzero(keep_mask[ci])               # ascending row order
        out_rows[ci, : len(src)] = rows[ci, src]
        out_vals[ci, : len(src)] = vals[ci, src]
    return out_rows, out_vals


def quantize_tree(
    tree: XMRTree, *, tier: str = "int8", prune_keep: float = 0.5
) -> QuantizedTree:
    """Compress every layer of ``tree`` into a :class:`QuantizedTree`.

    ``tier``: ``"int8"`` / ``"fp8"`` quantize in place; ``"int8_pruned"``
    first re-packs each chunk to its top ``prune_keep`` fraction of rows by
    magnitude (:func:`prune_chunks`), then quantizes the narrower tiles.
    """
    dtype = _dtype_for(tier)
    qlayers: List[QuantLayerArrays] = []
    for lay in tree.layers:
        rows = vals = None
        if tier == "int8_pruned":
            rows, vals = prune_chunks(
                np.asarray(lay.chunk_rows), np.asarray(lay.chunk_vals),
                prune_keep, sentinel=tree.d,
            )
        qlayers.append(quantize_layer(lay, dtype, rows=rows, vals=vals))
    return QuantizedTree(
        layers=qlayers, n_cols=tree.n_cols, branching=tree.branching,
        d=tree.d, tier=tier,
    )


def dequantize_tree(qtree: QuantizedTree) -> XMRTree:
    """f32 reconstruction of ``qtree`` (chunked methods only — see
    :func:`dequantize_layer`)."""
    return XMRTree(
        layers=[dequantize_layer(l, d=qtree.d) for l in qtree.layers],
        n_cols=qtree.n_cols,
        branching=qtree.branching,
        d=qtree.d,
    )


def quantize_index(index, *, tier: str = "int8", prune_keep: float = 0.5):
    """Compress a :class:`~repro.index.partition.PartitionedIndex` in place
    of its parts — the serving-tier entry point.

    The router head stays exact f32 (it is a few percent of the weights and
    its beam feeds *every* partition — quantizing it would perturb the
    handoff all tiers share). Each partition sub-tree is quantized after
    extraction, and the manifest is rebuilt so ``memory_bytes`` /
    ``content_hash`` describe the *compressed* bytes actually resident, with
    ``tier``/``dtype`` recorded per partition (manifest schema v2 — see
    ``src/repro/index/README.md``).
    """
    from repro.index.partition import _content_hash  # cycle-free at runtime

    dtype = _dtype_for(tier)
    np_dtype, _ = QUANT_DTYPES[dtype]
    qparts = [
        quantize_tree(p, tier=tier, prune_keep=prune_keep)
        for p in index.parts
    ]
    infos = [
        dataclasses.replace(
            info,
            memory_bytes=qp.memory_bytes(),
            content_hash=_content_hash(qp),
            tier=tier,
            dtype=np.dtype(np_dtype).name,
        )
        for info, qp in zip(index.manifest.partitions, qparts)
    ]
    manifest = dataclasses.replace(index.manifest, partitions=infos)
    return dataclasses.replace(index, parts=qparts, manifest=manifest)
