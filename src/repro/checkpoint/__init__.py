from repro.checkpoint.ckpt import Checkpointer
