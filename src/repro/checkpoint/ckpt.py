"""Mesh-independent checkpointing: logical arrays + manifest, async, atomic.

Format: a directory per step containing one ``.npy`` per pytree leaf (keyed
by its flattened path) plus ``manifest.json`` (treedef, step, metadata).
Because leaves are stored as full *logical* arrays, a checkpoint written on a
16×16 mesh restores onto any other device count — the elastic-restart path.

Writes are atomic (tmp dir + rename) and optionally asynchronous (a snapshot
is device_get'd on the step path, the file I/O happens on a worker thread),
keeping the training loop's exposed cost to the host copy only.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Params = Any


def _flatten(tree: Params) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _treedef_of(tree: Params):
    return jax.tree_util.tree_structure(tree)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------
    def save(self, step: int, state: Dict[str, Params],
             metadata: Optional[dict] = None) -> None:
        """state: dict of named pytrees (e.g. {'params':…, 'opt':…})."""
        snap = {name: _flatten(tree) for name, tree in state.items()}
        meta = {
            "step": int(step),
            "names": {n: sorted(v.keys()) for n, v in snap.items()},
            "metadata": metadata or {},
        }
        self.wait()
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, snap, meta), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, snap, meta)

    def _write(self, step: int, snap, meta) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for name, leaves in snap.items():
            sub = os.path.join(tmp, name)
            os.makedirs(sub)
            for key, arr in leaves.items():
                fn = key.replace("/", "__") + ".npy"
                np.save(os.path.join(sub, fn), arr)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    # -- read ----------------------------------------------------------
    def list_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template: Dict[str, Params], step: Optional[int] = None,
                sharding: Optional[Dict[str, Params]] = None
                ) -> Tuple[int, Dict[str, Params]]:
        """Restore into the *structure* of ``template`` (values replaced).

        ``sharding``: optional dict of sharding pytrees — leaves are
        device_put with the target sharding, which is how a checkpoint
        written on one mesh restores onto another (elastic restart).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        base = os.path.join(self.dir, f"step_{step:08d}")
        out: Dict[str, Params] = {}
        for name, tree in template.items():
            paths = jax.tree_util.tree_flatten_with_path(tree)[0]
            treedef = jax.tree_util.tree_structure(tree)
            shard_leaves = (
                jax.tree.leaves(sharding[name]) if sharding and name in sharding
                else [None] * len(paths)
            )
            leaves = []
            for (path, leaf), shd in zip(paths, shard_leaves):
                key = "/".join(
                    str(getattr(k, "key", getattr(k, "idx", k))) for k in path
                )
                arr = np.load(os.path.join(base, name, key.replace("/", "__") + ".npy"))
                val = jax.device_put(arr, shd) if shd is not None else jax.numpy.asarray(arr)
                leaves.append(val.astype(leaf.dtype) if hasattr(leaf, "dtype") else val)
            out[name] = jax.tree_util.tree_unflatten(treedef, leaves)
        return step, out
