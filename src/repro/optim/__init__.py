from repro.optim.optimizers import (
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    ef_compress,
    ef_init,
    get_optimizer,
    warmup_cosine,
)
