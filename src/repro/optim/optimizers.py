"""Optimizers: AdamW and Adafactor (pure pytree functions, no deps).

Adafactor (factored second moment, no first moment) is the default for the
235B/314B MoE configs: optimizer state shrinks from 2 full copies (Adam m+v)
to ~row+col vectors per matrix, which is what lets those models fit v5e HBM
at 256 chips (verified by dry-run memory_analysis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any
State = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], State]
    update: Callable[[Params, State, Params, jax.Array], Tuple[Params, State]]
    name: str = "opt"


def warmup_cosine(step: jax.Array, *, peak: float, warmup: int, total: int,
                  floor: float = 0.1) -> jax.Array:
    """Linear warmup -> cosine decay to floor·peak."""
    s = step.astype(jnp.float32)
    warm = peak * s / jnp.maximum(warmup, 1)
    frac = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(s < warmup, warm, cos)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.01, clip_norm: float = 1.0) -> Optimizer:
    def init(params: Params) -> State:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        grads = clip_by_global_norm(grads, clip_norm)
        t = state["step"] + 1
        tf = t.astype(jnp.float32)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        def upd(p, m_, v_):
            mh = m_ / (1 - b1**tf)
            vh = v_ / (1 - b2**tf)
            step_ = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": t}

    return Optimizer(init=init, update=update, name="adamw")


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), momentum-free, factored v for ndim >= 2
# ---------------------------------------------------------------------------

def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0, min_dim_factor: int = 2) -> Optimizer:
    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] >= min_dim_factor and shape[-2] >= min_dim_factor

    def init(params: Params) -> State:
        def per_leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),          # row
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # col
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"v": jax.tree.map(per_leaf, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["step"] + 1
        beta2 = 1.0 - (t.astype(jnp.float32) + 1.0) ** -0.8

        def per_leaf(g, p, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(g.shape):
                vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
                denom = (
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)[..., None]
                )
                upd = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                upd = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + eps)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            newp = p.astype(jnp.float32) - lr * (upd + weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), new_s

        flat_g, tree = jax.tree.flatten(grads)
        flat_p = jax.tree.leaves(params)
        flat_s = tree.flatten_up_to(state["v"])
        outs = [per_leaf(g, p, s) for g, p, s in zip(flat_g, flat_p, flat_s)]
        new_params = tree.unflatten([o[0] for o in outs])
        new_v = tree.unflatten([o[1] for o in outs])
        return new_params, {"v": new_v, "step": t}

    return Optimizer(init=init, update=update, name="adafactor")


def get_optimizer(name: str) -> Optimizer:
    if name == "adamw":
        return adamw()
    if name == "adafactor":
        return adafactor()
    raise ValueError(f"unknown optimizer {name}")


def clip_by_global_norm(grads: Params, max_norm: float) -> Params:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)


# ---------------------------------------------------------------------------
# Error-feedback gradient compression (pod-axis all-reduce payload reduction)
# ---------------------------------------------------------------------------

def ef_compress(grads: Params, residual: Params, dtype=jnp.bfloat16
                ) -> Tuple[Params, Params]:
    """Compress grads to ``dtype`` with error feedback.

    Returns (compressed grads — what crosses the slow pod/DCN link — and the
    new residual). The residual re-enters next step, so quantization error is
    not lost, only delayed (EF-SGD; convergence-preserving)."""
    def per_leaf(g, r):
        full = g.astype(jnp.float32) + r
        comp = full.astype(dtype)
        return comp, full - comp.astype(jnp.float32)
    flat = jax.tree.map(per_leaf, grads, residual)
    comp = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return comp, res


def ef_init(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
