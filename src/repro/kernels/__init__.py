from repro.kernels import ops, ref
from repro.kernels.mscm_kernel import (
    group_blocks_by_chunk,
    mscm_fused,
    mscm_grouped,
    mscm_pregather,
)

__all__ = [
    "ops",
    "ref",
    "mscm_fused",
    "mscm_pregather",
    "mscm_grouped",
    "group_blocks_by_chunk",
]
