from repro.kernels import ops, ref
from repro.kernels.mscm_kernel import (
    group_blocks_by_chunk,
    mscm_fused,
    mscm_grouped,
    mscm_pregather,
)
from repro.kernels.ops import (
    group_blocks_device,
    grouped_tile_bound,
    mscm_grouped_level,
    mscm_pallas,
    mscm_pallas_grouped,
)

__all__ = [
    "ops",
    "ref",
    "mscm_fused",
    "mscm_pregather",
    "mscm_grouped",
    "mscm_grouped_level",
    "mscm_pallas",
    "mscm_pallas_grouped",
    "group_blocks_by_chunk",
    "group_blocks_device",
    "grouped_tile_bound",
]
