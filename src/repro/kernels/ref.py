"""Pure-jnp / numpy oracles for the MSCM kernels.

``mscm_ref`` is the dense-algebra ground truth: reconstruct W from the chunk
tiles, evaluate the full product X·W, and read out the masked blocks. Every
MSCM variant (JAX and Pallas) must match it.

``block_ref_marching`` is a numpy marching-pointer implementation of the
paper's Algorithm 2 (the one iterator with no TPU analogue) — kept as an
independent scalar oracle for property tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def mscm_ref(
    x_dense: jax.Array,   # f32 [n, d+1] (dense queries incl. sentinel slot)
    rows: jax.Array,      # int32 [C, R] sentinel-padded
    vals: jax.Array,      # f32 [C, R, B]
    block_q: jax.Array,   # int32 [A]
    block_c: jax.Array,   # int32 [A]
) -> jax.Array:
    """Dense oracle: A[a] = (x[block_q[a]] · W)[block_c[a]·B : +B]."""
    c, r, b = vals.shape
    d_plus = x_dense.shape[1]
    # Scatter chunk tiles into the dense [d+1, C*B] weight matrix. Sentinel
    # rows (== d) land in the zero slot of x_dense, contributing nothing.
    w = jnp.zeros((d_plus, c * b), dtype=vals.dtype)
    col_ids = (jnp.arange(c)[:, None, None] * b + jnp.arange(b)[None, None, :])
    col_ids = jnp.broadcast_to(col_ids, (c, r, b))
    row_ids = jnp.broadcast_to(rows[:, :, None], (c, r, b))
    w = w.at[row_ids.reshape(-1), col_ids.reshape(-1)].add(vals.reshape(-1))
    w = w.at[d_plus - 1, :].set(0.0)  # sentinel row carries no weight
    full = x_dense @ w                                        # [n, C*B]
    cols = block_c[:, None] * b + jnp.arange(b)[None, :]      # [A, B]
    return full[block_q[:, None], cols]


def block_ref_marching(
    x_idx: np.ndarray,     # int32 [nnz_x] sorted query support
    x_val: np.ndarray,     # f32 [nnz_x]
    chunk_rows: np.ndarray,  # int32 [R] sentinel-padded, sorted
    chunk_vals: np.ndarray,  # f32 [R, B]
    d: int,
) -> np.ndarray:
    """Paper Algorithm 2 with the marching-pointer iterator (numpy scalar)."""
    b = chunk_vals.shape[1]
    z = np.zeros(b, dtype=np.float64)
    ix, ik = 0, 0
    nx, nk = len(x_idx), len(chunk_rows)
    while ix < nx and ik < nk:
        jx, jk = int(x_idx[ix]), int(chunk_rows[ik])
        if jx >= d or jk >= d:
            break
        if jx == jk:
            z += float(x_val[ix]) * chunk_vals[ik].astype(np.float64)
            ix += 1
            ik += 1
        elif jx < jk:
            ix += 1
        else:
            ik += 1
    return z.astype(np.float32)
